//! Bench: the L1/L3 hot path — forward/inverse 3D wavelet transform per
//! block and per batch, native vs PJRT engine (when artifacts exist).
//! This is the §Perf tracking bench for the transform kernel.
use cubismz::pipeline::{NativeEngine, WaveletEngine};
use cubismz::runtime::{default_artifacts_dir, PjrtEngine};
use cubismz::util::bench::bench_budget;
use cubismz::util::prng::Pcg32;
use cubismz::wavelet::{max_levels, WaveletKind};

fn main() {
    let bs = 32usize;
    let vol = bs * bs * bs;
    let batch = 64usize;
    let mut rng = Pcg32::new(1);
    let mut data = vec![0f32; batch * vol];
    rng.fill_f32(&mut data, -10.0, 10.0);
    let bytes = batch * vol * 4;
    println!("bench wavelet_hot: {batch} blocks of {bs}^3 ({} MB)", bytes / 1_000_000);

    for kind in WaveletKind::ALL {
        let mut buf = data.clone();
        let s = bench_budget(&format!("native/fwd/{}", kind.name()), 1.5, 200, || {
            NativeEngine.forward_batch(kind, &mut buf, bs, max_levels(bs));
        });
        s.report_mbps(bytes);
        let s = bench_budget(&format!("native/inv/{}", kind.name()), 1.5, 200, || {
            NativeEngine.inverse_batch(kind, &mut buf, bs, max_levels(bs));
        });
        s.report_mbps(bytes);
    }

    match PjrtEngine::new(default_artifacts_dir()) {
        Ok(engine) => {
            for kind in [WaveletKind::Avg3] {
                let mut buf = data.clone();
                let s = bench_budget(&format!("pjrt/fwd/{}", kind.name()), 3.0, 50, || {
                    engine.forward_batch(kind, &mut buf, bs, max_levels(bs));
                });
                s.report_mbps(bytes);
            }
        }
        Err(e) => println!("pjrt bench skipped: {e}"),
    }
}
