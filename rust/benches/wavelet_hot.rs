//! Bench: the L1/L3 hot path — forward/inverse 3D wavelet transform per
//! block and per batch, scalar vs SIMD dispatch (and vs the PJRT engine
//! when artifacts exist). This is the §Perf tracking bench for the
//! transform kernel; it emits `BENCH_wavelet.json` with a
//! scalar-vs-simd section per kernel and asserts the vectorized y/z
//! passes actually pay for themselves on hosts with vector units.
//!
//! `WAVELET_HOT_FAST=1` shrinks the batch and budgets for CI.
use cubismz::pipeline::{NativeEngine, WaveletEngine};
use cubismz::runtime::{default_artifacts_dir, PjrtEngine};
use cubismz::simd::{self, SimdLevel};
use cubismz::util::bench::{bench_budget, write_json, Json};
use cubismz::util::prng::Pcg32;
use cubismz::wavelet::{max_levels, WaveletKind};

fn main() {
    let fast = std::env::var("WAVELET_HOT_FAST").is_ok();
    let bs = 32usize;
    let vol = bs * bs * bs;
    let batch = if fast { 24usize } else { 64 };
    let (budget, max_samples) = if fast { (0.35, 40) } else { (1.2, 200) };
    let mut rng = Pcg32::new(1);
    let mut data = vec![0f32; batch * vol];
    rng.fill_f32(&mut data, -10.0, 10.0);
    let bytes = batch * vol * 4;
    let detected = simd::detect();
    println!(
        "bench wavelet_hot: {batch} blocks of {bs}^3 ({} MB), simd {}",
        bytes / 1_000_000,
        detected.name()
    );

    let mut rows = Vec::new();
    let (mut scalar_total, mut simd_total) = (0.0f64, 0.0f64);
    for kind in WaveletKind::ALL {
        // dispatch must never change the transform output: run forward
        // under both levels on identical inputs and compare bits
        let mut a = data.clone();
        let mut b = data.clone();
        let prev = simd::override_level(SimdLevel::Scalar);
        NativeEngine.forward_batch(kind, &mut a, bs, max_levels(bs));
        simd::override_level(detected);
        NativeEngine.forward_batch(kind, &mut b, bs, max_levels(bs));
        simd::override_level(prev);
        assert!(
            a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{}: scalar and {} forward transforms differ",
            kind.name(),
            detected.name()
        );

        for fwd in [true, false] {
            let dir = if fwd { "fwd" } else { "inv" };
            let mut buf = data.clone();
            let mut run = |lvl: SimdLevel, label: &str| {
                let prev = simd::override_level(lvl);
                let s = bench_budget(
                    &format!("{label}/{dir}/{}", kind.name()),
                    budget,
                    max_samples,
                    || {
                        if fwd {
                            NativeEngine.forward_batch(kind, &mut buf, bs, max_levels(bs));
                        } else {
                            NativeEngine.inverse_batch(kind, &mut buf, bs, max_levels(bs));
                        }
                    },
                );
                simd::override_level(prev);
                s.report_mbps(bytes);
                s
            };
            let sc = run(SimdLevel::Scalar, "scalar");
            let sv = run(detected, "simd");
            scalar_total += sc.min;
            simd_total += sv.min;
            rows.push(Json::Obj(vec![
                ("name".into(), Json::Str(format!("{dir}/{}", kind.name()))),
                ("scalar_mbps".into(), Json::Num(bytes as f64 / 1e6 / sc.min)),
                ("simd_mbps".into(), Json::Num(bytes as f64 / 1e6 / sv.min)),
                ("speedup".into(), Json::Num(sc.min / sv.min)),
            ]));
        }
    }
    let total_speedup = scalar_total / simd_total;
    println!(
        "total fwd+inv speedup ({} vs scalar, min-time): {total_speedup:.2}x",
        detected.name()
    );
    if detected != SimdLevel::Scalar {
        assert!(
            total_speedup >= 1.5,
            "SIMD transform must beat scalar by >= 1.5x on a {} host: {total_speedup:.2}x",
            detected.name()
        );
    }

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("wavelet".into())),
        ("simd".into(), Json::Str(detected.name().into())),
        ("bs".into(), Json::Int(bs as i64)),
        ("batch".into(), Json::Int(batch as i64)),
        ("rows".into(), Json::Arr(rows)),
        ("total_speedup".into(), Json::Num(total_speedup)),
    ]);
    write_json("BENCH_wavelet.json", &doc).expect("write BENCH_wavelet.json");
    println!("wrote BENCH_wavelet.json");

    match PjrtEngine::new(default_artifacts_dir()) {
        Ok(engine) => {
            for kind in [WaveletKind::Avg3] {
                let mut buf = data.clone();
                let s = bench_budget(&format!("pjrt/fwd/{}", kind.name()), 3.0, 50, || {
                    engine.forward_batch(kind, &mut buf, bs, max_levels(bs));
                });
                s.report_mbps(bytes);
            }
        }
        Err(e) => println!("pjrt bench skipped: {e}"),
    }
}
