//! Bench: multi-stream Engine throughput — N concurrent submissions vs
//! the same N submitted sequentially, on one multi-generation pool.
//!
//! The tentpole claim this guards: concurrent tenants sharing one
//! session must beat taking turns, because idle workers steal across the
//! live submissions (each stream's barrier tails and serial sections —
//! field stats, header assembly, output concat — overlap a sibling's
//! parallel phase instead of idling the pool). Streams are sized to
//! leave a scheduling tail (spans slightly outnumber workers), the shape
//! where a lone submission scales worst.
//!
//! Hard asserts:
//! * every concurrently compressed stream is byte-identical to a lone
//!   submission of the same field (and every concurrent decode
//!   bit-identical to the serial decoder) — scheduling never leaks into
//!   any tenant's bytes;
//! * on hosts with >= 8 hardware threads, aggregate throughput of 4
//!   concurrent submissions is >= 1.3x the sequential baseline for both
//!   compression and decompression.
//!
//! Emits `BENCH_concurrency.json`. `ENGINE_CONCURRENCY_FAST=1` shrinks
//! fields and budgets for CI; `ENGINE_CONCURRENCY_N` overrides the field
//! side.
use cubismz::core::Field3;
use cubismz::pipeline::{decompress_field, CompressParams, Engine, NativeEngine, PipelineConfig};
use cubismz::util::bench::{bench_budget, write_json, Json};
use cubismz::util::prng::Pcg32;

/// Concurrent tenants (the issue's "several simultaneous streams").
const STREAMS: usize = 4;
/// Pool size the 1.3x target is specified at.
const THREADS: usize = 8;

fn main() {
    let fast = std::env::var("ENGINE_CONCURRENCY_FAST").is_ok();
    let n: usize = std::env::var("ENGINE_CONCURRENCY_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 64 } else { 96 });
    let bs = if fast { 16 } else { 32 };
    assert!(n % bs == 0, "field side must be divisible by the block size {bs}");
    let (budget, samples) = if fast { (0.8, 4) } else { (3.0, 10) };
    let hw = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let raw_bytes = n * n * n * 4 * STREAMS;
    println!(
        "bench engine_concurrency: {STREAMS} x {n}^3 streams ({} MB raw), pool {THREADS}, \
         {hw} hardware threads",
        raw_bytes / 1_000_000
    );

    // spans slightly outnumber the pool so a lone submission has a
    // scheduling tail — the regime concurrency exists to fill
    let nblocks = (n / bs).pow(3);
    let block_raw = bs * bs * bs * 4 + 4;
    let span_blocks = (nblocks / (THREADS + 2)).max(1);
    let chunk_bytes = span_blocks * block_raw;
    let mut cfg = PipelineConfig::paper_default(1e-3);
    cfg.bs = bs;
    let params = CompressParams::from_config(&cfg);
    let engine = Engine::builder().threads(THREADS).chunk_bytes(chunk_bytes).build();
    let engine = &engine;

    let fields: Vec<Field3> = (0..STREAMS as u64)
        .map(|i| {
            let mut rng = Pcg32::new(4000 + i);
            Field3::from_vec(n, n, n, cubismz::util::prop::gen_smooth_field(&mut rng, n))
        })
        .collect();

    // lone-submission references: the bytes every mode must reproduce
    let references: Vec<Vec<u8>> = fields
        .iter()
        .map(|f| engine.compress_vec(f, "q", &params).0)
        .collect();
    let nchunks = {
        let (file, _) = cubismz::pipeline::CzbFile::parse_header(&references[0]).unwrap();
        file.chunks.len()
    };
    println!("  {nchunks} chunks per stream (chunk_bytes {chunk_bytes})");

    // --- compression: sequential baseline vs concurrent submissions ---
    let seq_c = bench_budget("compress/4 sequential", budget, samples, || {
        for f in &fields {
            std::hint::black_box(engine.compress_vec(f, "q", &params));
        }
    });
    seq_c.report_mbps(raw_bytes);
    let conc_c = bench_budget("compress/4 concurrent", budget, samples, || {
        std::thread::scope(|s| {
            for f in &fields {
                s.spawn(move || std::hint::black_box(engine.compress_vec(f, "q", &params)));
            }
        })
    });
    conc_c.report_mbps(raw_bytes);

    // per-stream byte identity under full concurrency
    let outputs: Vec<Vec<u8>> = std::thread::scope(|s| {
        let handles: Vec<_> = fields
            .iter()
            .map(|f| s.spawn(move || engine.compress_vec(f, "q", &params).0))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (k, (got, expect)) in outputs.iter().zip(&references).enumerate() {
        assert_eq!(got, expect, "concurrent stream {k} drifted from its lone submission");
    }

    // --- decompression: sequential baseline vs concurrent submissions ---
    let seq_d = bench_budget("decompress/4 sequential", budget, samples, || {
        for bytes in &references {
            std::hint::black_box(engine.decompress_bytes(bytes).unwrap());
        }
    });
    seq_d.report_mbps(raw_bytes);
    let conc_d = bench_budget("decompress/4 concurrent", budget, samples, || {
        std::thread::scope(|s| {
            for bytes in &references {
                s.spawn(move || std::hint::black_box(engine.decompress_bytes(bytes).unwrap()));
            }
        })
    });
    conc_d.report_mbps(raw_bytes);

    // per-stream bit identity under full concurrency, vs the serial decoder
    let decoded: Vec<Field3> = std::thread::scope(|s| {
        let handles: Vec<_> = references
            .iter()
            .map(|bytes| s.spawn(move || engine.decompress_bytes(bytes).unwrap().0))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (k, (got, bytes)) in decoded.iter().zip(&references).enumerate() {
        let (serial, _) = decompress_field(bytes, &NativeEngine).unwrap();
        assert!(
            got.data.iter().zip(&serial.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "concurrent decode of stream {k} drifted from the serial decoder"
        );
    }

    let sp_c = seq_c.mean / conc_c.mean;
    let sp_d = seq_d.mean / conc_d.mean;
    println!("  compress:   {sp_c:.2}x aggregate vs sequential (target >= 1.3x at 8 threads)");
    println!("  decompress: {sp_d:.2}x aggregate vs sequential (target >= 1.3x at 8 threads)");
    if hw >= 8 {
        assert!(
            sp_c >= 1.3,
            "concurrent compression must beat sequential submissions: {sp_c:.2}x"
        );
        assert!(
            sp_d >= 1.3,
            "concurrent decompression must beat sequential submissions: {sp_d:.2}x"
        );
    } else {
        println!("  (only {hw} hardware threads — 1.3x target not enforced on this host)");
    }

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("engine_concurrency".into())),
        ("field".into(), Json::Str(format!("smooth/{n}^3 x{STREAMS}"))),
        ("raw_bytes".into(), Json::Int(raw_bytes as i64)),
        ("hw_threads".into(), Json::Int(hw as i64)),
        ("pool_threads".into(), Json::Int(THREADS as i64)),
        ("streams".into(), Json::Int(STREAMS as i64)),
        ("chunks_per_stream".into(), Json::Int(nchunks as i64)),
        (
            "rows".into(),
            Json::Arr(vec![
                Json::Obj(vec![
                    ("name".into(), Json::Str("compress_sequential".into())),
                    ("mbps".into(), Json::Num(raw_bytes as f64 / 1e6 / seq_c.mean)),
                ]),
                Json::Obj(vec![
                    ("name".into(), Json::Str("compress_concurrent".into())),
                    ("mbps".into(), Json::Num(raw_bytes as f64 / 1e6 / conc_c.mean)),
                    ("speedup_vs_sequential".into(), Json::Num(sp_c)),
                ]),
                Json::Obj(vec![
                    ("name".into(), Json::Str("decompress_sequential".into())),
                    ("mbps".into(), Json::Num(raw_bytes as f64 / 1e6 / seq_d.mean)),
                ]),
                Json::Obj(vec![
                    ("name".into(), Json::Str("decompress_concurrent".into())),
                    ("mbps".into(), Json::Num(raw_bytes as f64 / 1e6 / conc_d.mean)),
                    ("speedup_vs_sequential".into(), Json::Num(sp_d)),
                ]),
            ]),
        ),
    ]);
    write_json("BENCH_concurrency.json", &doc).expect("write BENCH_concurrency.json");
    println!("wrote BENCH_concurrency.json");
}
