//! Bench: Table 3 — compression/decompression speed (MB/s) and CR for the
//! main schemes at matched PSNR (criterion is unavailable offline; uses
//! the in-tree harness `cubismz::util::bench`).
use cubismz::codec::Codec;
use cubismz::pipeline::{
    compress_field, decompress_field, CoeffCodec, NativeEngine, PipelineConfig, ShuffleMode,
    Stage1,
};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::util::bench::bench_budget;
use cubismz::wavelet::WaveletKind;

fn main() {
    let n = 96;
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    let bytes = f.nbytes();
    println!("bench speed_table3: p at 10k, {n}^3 ({} MB)", bytes / 1_000_000);
    let rows: Vec<(&str, PipelineConfig)> = vec![
        ("w3ai+shuf+zlib", PipelineConfig::paper_default(1e-3)),
        ("w3ai+shuf+zstd", {
            let mut c = PipelineConfig::paper_default(1e-3);
            c.stage2 = Codec::Zstd;
            c
        }),
        ("w3ai+shuf+lz4", {
            let mut c = PipelineConfig::paper_default(1e-3);
            c.stage2 = Codec::Lz4;
            c
        }),
        ("zfp", PipelineConfig::new(32, Stage1::Zfp { tol_rel: 8e-4 }, Codec::None)),
        ("sz", PipelineConfig::new(32, Stage1::Sz { eb_rel: 8e-4 }, Codec::None)),
        ("fpzip20", PipelineConfig::new(32, Stage1::Fpzip { prec: 20 }, Codec::None)),
        (
            "w4+shuf+zlib",
            PipelineConfig::new(
                32,
                Stage1::Wavelet {
                    kind: WaveletKind::Interp4,
                    eps_rel: 1e-3,
                    zbits: 0,
                    coeff: CoeffCodec::None,
                },
                Codec::ZlibDef,
            )
            .with_shuffle(ShuffleMode::Byte4),
        ),
    ];
    for (label, cfg) in rows {
        let s = bench_budget(&format!("compress/{label}"), 2.0, 20, || {
            compress_field(&f, "p", &cfg, &NativeEngine)
        });
        s.report_mbps(bytes);
        let (stream, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        let s = bench_budget(&format!("decompress/{label}"), 2.0, 20, || {
            decompress_field(&stream, &NativeEngine).unwrap()
        });
        s.report_mbps(bytes);
        println!("{:40} CR {:.2}", format!("  ({label})"), st.ratio());
    }
}
