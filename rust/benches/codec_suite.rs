//! Bench: the from-scratch lossless codecs vs the real zlib/zstd
//! reference baselines on stage-1-like payloads (shuffled wavelet
//! coefficient streams). §Perf tracking for czlib.
#[cfg(reference_codecs)]
use cubismz::codec::reference;
use cubismz::codec::{shuffle, Codec};
use cubismz::util::bench::bench_budget;
use cubismz::util::prng::Pcg32;

fn raw_payload() -> Vec<u8> {
    // realistic stage-1 output: drifting small floats
    let mut rng = Pcg32::new(0xBE7C4);
    let mut data = Vec::new();
    let mut v = 0.0f32;
    for _ in 0..1_500_000 {
        v += rng.next_f32() * 0.01 - 0.005;
        data.extend_from_slice(&v.to_le_bytes());
    }
    data
}

fn main() {
    let raw = raw_payload();
    let data = shuffle::byte_shuffle(&raw, 4);
    let bytes = data.len();
    println!("bench codec_suite: {} MB shuffled coefficient payload", bytes / 1_000_000);
    for codec in [Codec::Lz4, Codec::Zstd, Codec::ZlibDef, Codec::ZlibBest, Codec::Lzma] {
        let s = bench_budget(&format!("compress/{}", codec.name()), 2.0, 50, || {
            codec.compress_vec(&data)
        });
        s.report_mbps(bytes);
        let comp = codec.compress_vec(&data);
        let s = bench_budget(&format!("decompress/{}", codec.name()), 1.5, 100, || {
            codec.decompress_vec(&comp).unwrap()
        });
        s.report_mbps(bytes);
        println!(
            "{:40} CR {:.2}",
            format!("  ({})", codec.name()),
            bytes as f64 / comp.len() as f64
        );
    }
    // shuffle preconditioners: ShuffleMode::Bit4 (bit planes) vs Byte4 on
    // the same coefficient stream — CR is the decision metric, the
    // kernels' own cost is reported alongside
    println!("shuffle preconditioner comparison (same raw payload):");
    let s = bench_budget("shuffle/byte4", 1.0, 50, || shuffle::byte_shuffle(&raw, 4));
    s.report_mbps(raw.len());
    let s = bench_budget("shuffle/bit4", 1.0, 10, || shuffle::bit_shuffle(&raw, 4));
    s.report_mbps(raw.len());
    let bit = shuffle::bit_shuffle(&raw, 4);
    for codec in [Codec::Lz4, Codec::ZlibDef] {
        let c_none = codec.compress_vec(&raw).len();
        let c_byte = codec.compress_vec(&data).len();
        let c_bit = codec.compress_vec(&bit).len();
        println!(
            "  {:10} CR none {:.2} | byte4 {:.2} | bit4 {:.2}",
            codec.name(),
            raw.len() as f64 / c_none as f64,
            raw.len() as f64 / c_byte as f64,
            raw.len() as f64 / c_bit as f64,
        );
    }

    // reference baselines (need the flate2/zstd crates: --cfg reference_codecs)
    #[cfg(reference_codecs)]
    {
        let s =
            bench_budget("compress/real-zlib-6", 2.0, 50, || reference::zlib_compress(&data, 6));
        s.report_mbps(bytes);
        let comp = reference::zlib_compress(&data, 6);
        println!("{:40} CR {:.2}", "  (real-zlib-6)", bytes as f64 / comp.len() as f64);
        let s =
            bench_budget("compress/real-zstd-3", 2.0, 50, || reference::zstd_compress(&data, 3));
        s.report_mbps(bytes);
        let comp = reference::zstd_compress(&data, 3);
        println!("{:40} CR {:.2}", "  (real-zstd-3)", bytes as f64 / comp.len() as f64);
    }
    #[cfg(not(reference_codecs))]
    println!("reference baselines skipped (build with --cfg reference_codecs)");
}
