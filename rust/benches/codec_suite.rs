//! Bench: the from-scratch lossless codecs vs the real zlib/zstd
//! reference baselines on stage-1-like payloads (shuffled wavelet
//! coefficient streams). §Perf tracking for the stage-2 layer.
//!
//! Iterates the [`cubismz::codec::stage2`] registry (no hard-coded codec
//! list: a newly registered codec shows up here automatically) and emits
//! `BENCH_stage2.json` — per-codec compress/decompress MB/s + CR, the
//! shuffle preconditioner comparison, and the framed single-chunk
//! decompression thread-scaling rows (zlib-best path) that
//! `scripts/bench_trend.py` diffs across CI runs.
//!
//! Also sweeps every error-bound contract × honoring stage-1 codec pair
//! on a smooth probe field and emits `BENCH_quality.json` — achieved
//! PSNR and CR per (bound, codec) row — so CI trends quality alongside
//! throughput.
//!
//! `CODEC_SUITE_FAST=1` shrinks the payload and budgets for CI smoke use.
#[cfg(reference_codecs)]
use cubismz::codec::reference;
use cubismz::codec::{shuffle, stage2, Codec};
use cubismz::core::Field3;
use cubismz::pipeline::{
    compress_field, decompress_field_mt, stage1, Bound, NativeEngine, PipelineConfig, Stage1,
};
use cubismz::util::bench::{bench_budget, write_json, Json};
use cubismz::util::prng::Pcg32;

fn fast_mode() -> bool {
    std::env::var("CODEC_SUITE_FAST").is_ok()
}

fn raw_payload(n_floats: usize) -> Vec<u8> {
    // realistic stage-1 output: drifting small floats
    let mut rng = Pcg32::new(0xBE7C4);
    let mut data = Vec::new();
    let mut v = 0.0f32;
    for _ in 0..n_floats {
        v += rng.next_f32() * 0.01 - 0.005;
        data.extend_from_slice(&v.to_le_bytes());
    }
    data
}

fn main() {
    let fast = fast_mode();
    let (n_floats, budget, max_samples) =
        if fast { (400_000, 0.4, 10) } else { (1_500_000, 2.0, 50) };
    let raw = raw_payload(n_floats);
    let data = shuffle::byte_shuffle(&raw, 4);
    let bytes = data.len();
    println!(
        "bench codec_suite: {} MB shuffled coefficient payload ({} registered codecs{})",
        bytes / 1_000_000,
        stage2::REGISTRY.len(),
        if fast { ", fast mode" } else { "" }
    );
    let mut codec_rows = Vec::new();
    for codec in stage2::REGISTRY {
        if codec.id() == 0 {
            continue; // direct copy: throughput is memcpy, CR is 1
        }
        let s = bench_budget(&format!("compress/{}", codec.name()), budget, max_samples, || {
            let mut out = Vec::new();
            codec.compress_into(&data, &mut out);
            out
        });
        s.report_mbps(bytes);
        let mut comp = Vec::new();
        codec.compress_into(&data, &mut comp);
        let sd = bench_budget(
            &format!("decompress/{}", codec.name()),
            budget * 0.75,
            max_samples * 2,
            || {
                let mut out = Vec::new();
                codec.decompress_into(&comp, data.len(), &mut out).unwrap();
                out
            },
        );
        sd.report_mbps(bytes);
        let cr = bytes as f64 / comp.len() as f64;
        println!("{:40} CR {:.2}", format!("  ({})", codec.name()), cr);
        codec_rows.push(Json::Obj(vec![
            ("name".into(), Json::Str(codec.name().into())),
            ("effort".into(), Json::Str(format!("{:?}", codec.effort()))),
            ("compress_mbps".into(), Json::Num(s.mbps(bytes))),
            ("decompress_mbps".into(), Json::Num(sd.mbps(bytes))),
            ("ratio".into(), Json::Num(cr)),
        ]));
    }

    // shuffle preconditioners: ShuffleMode::Bit4 (bit planes) vs Byte4 on
    // the same coefficient stream — CR is the decision metric, the
    // kernels' own cost is reported alongside (the bit kernel is the
    // word-parallel 8x8 transpose)
    println!("shuffle preconditioner comparison (same raw payload):");
    let s = bench_budget("shuffle/byte4", budget * 0.5, 50, || shuffle::byte_shuffle(&raw, 4));
    s.report_mbps(raw.len());
    let byte4_mbps = s.mbps(raw.len());
    let s = bench_budget("shuffle/bit4", budget * 0.5, 50, || shuffle::bit_shuffle(&raw, 4));
    s.report_mbps(raw.len());
    let bit4_mbps = s.mbps(raw.len());
    let bit = shuffle::bit_shuffle(&raw, 4);
    let mut shuffle_rows = vec![Json::Obj(vec![
        ("name".into(), Json::Str("kernels".into())),
        ("byte4_mbps".into(), Json::Num(byte4_mbps)),
        ("bit4_mbps".into(), Json::Num(bit4_mbps)),
    ])];
    // the same kernels with dispatch pinned to scalar — the trend diff
    // tracks both rows, and the outputs must be identical either way
    {
        let prev = cubismz::simd::override_level(cubismz::simd::SimdLevel::Scalar);
        let s = bench_budget("shuffle/byte4-scalar", budget * 0.5, 50, || {
            shuffle::byte_shuffle(&raw, 4)
        });
        s.report_mbps(raw.len());
        let byte4_sc = s.mbps(raw.len());
        let s = bench_budget("shuffle/bit4-scalar", budget * 0.5, 50, || {
            shuffle::bit_shuffle(&raw, 4)
        });
        s.report_mbps(raw.len());
        let bit4_sc = s.mbps(raw.len());
        assert_eq!(shuffle::byte_shuffle(&raw, 4), data, "scalar shuffle must match dispatched");
        cubismz::simd::override_level(prev);
        shuffle_rows.push(Json::Obj(vec![
            ("name".into(), Json::Str("kernels-scalar".into())),
            ("byte4_mbps".into(), Json::Num(byte4_sc)),
            ("bit4_mbps".into(), Json::Num(bit4_sc)),
        ]));
    }
    for codec in [Codec::Lz4, Codec::ZlibDef] {
        let c_none = codec.compress_vec(&raw).len();
        let c_byte = codec.compress_vec(&data).len();
        let c_bit = codec.compress_vec(&bit).len();
        println!(
            "  {:10} CR none {:.2} | byte4 {:.2} | bit4 {:.2}",
            codec.name(),
            raw.len() as f64 / c_none as f64,
            raw.len() as f64 / c_byte as f64,
            raw.len() as f64 / c_bit as f64,
        );
        shuffle_rows.push(Json::Obj(vec![
            ("name".into(), Json::Str(codec.name().into())),
            ("cr_none".into(), Json::Num(raw.len() as f64 / c_none as f64)),
            ("cr_byte4".into(), Json::Num(raw.len() as f64 / c_byte as f64)),
            ("cr_bit4".into(), Json::Num(raw.len() as f64 / c_bit as f64)),
        ]));
    }

    // framed intra-chunk parallelism: a single-chunk zlib-best archive
    // must decompress faster with more threads (the frames fan out)
    let n = if fast { 64 } else { 128 };
    let mut rng = Pcg32::new(77);
    let f = Field3::from_vec(n, n, n, cubismz::util::prop::gen_smooth_field(&mut rng, n));
    let mut cfg = PipelineConfig::paper_default(1e-4);
    cfg.stage2 = Codec::ZlibBest;
    cfg.chunk_bytes = 1 << 30; // one chunk
    cfg.frame_bytes = 64 << 10; // many frames inside it
    cfg.nthreads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let (stream, st) = compress_field(&f, "p", &cfg, &NativeEngine);
    assert_eq!(st.nchunks, 1, "scaling bench needs a single-chunk archive");
    println!(
        "single-chunk stage-2 scaling: {n}^3 field, zlib-best, {} compressed bytes",
        stream.len()
    );
    let mut scaling_rows = Vec::new();
    let mut d1 = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let sd = bench_budget(&format!("single-chunk decompress/t={threads}"), budget, 20, || {
            decompress_field_mt(&stream, &NativeEngine, threads).unwrap()
        });
        sd.report_mbps(f.nbytes());
        if threads == 1 {
            d1 = sd.mean;
        }
        println!("  t={threads}: {:.2}x vs 1 thread", d1 / sd.mean);
        scaling_rows.push(Json::Obj(vec![
            ("threads".into(), Json::Int(threads as i64)),
            ("decompress_mbps".into(), Json::Num(sd.mbps(f.nbytes()))),
            ("speedup".into(), Json::Num(d1 / sd.mean)),
        ]));
    }

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("stage2".into())),
        ("payload_bytes".into(), Json::Int(bytes as i64)),
        ("codecs".into(), Json::Arr(codec_rows)),
        ("shuffle".into(), Json::Arr(shuffle_rows)),
        ("single_chunk_scaling".into(), Json::Arr(scaling_rows)),
    ]);
    write_json("BENCH_stage2.json", &doc).expect("write BENCH_stage2.json");
    println!("wrote BENCH_stage2.json");

    // error-bound contract sweep: every (bound, honoring stage-1 codec)
    // pair on the same smooth probe field. The row metrics are achieved
    // quality (PSNR, max relative error) and CR — the quality
    // counterpart of the throughput table above.
    let qn = if fast { 32usize } else { 64 };
    let mut rng = Pcg32::new(0xB0DD);
    let qf = Field3::from_vec(qn, qn, qn, cubismz::util::prop::gen_smooth_field(&mut rng, qn));
    let bounds =
        [Bound::Rel(1e-2), Bound::Rel(1e-3), Bound::Abs(1e-3), Bound::Psnr(60.0), Bound::Lossless];
    println!("error-bound quality sweep: {qn}^3 probe field");
    let mut quality_rows = Vec::new();
    for bound in &bounds {
        for codec in stage1::REGISTRY {
            if !codec.honors(bound.kind()) {
                continue;
            }
            // knob placeholders; apply_bound resolves them per field
            let template = match codec.id() {
                0 => Stage1::Copy,
                2 => Stage1::Zfp { tol_rel: 0.0 },
                3 => Stage1::Sz { eb_rel: 0.0 },
                4 => Stage1::Fpzip { prec: 32 },
                _ => continue, // wavelet honors nothing; unknown future ids
            };
            let mut cfg = PipelineConfig::paper_default(0.0);
            cfg.stage1 = template;
            cfg.bound = *bound;
            let (stream, st) = compress_field(&qf, "p", &cfg, &NativeEngine);
            let q = st.quality;
            println!(
                "  {:20} {:>7}: CR {:.2}  psnr {:.1} dB  max-rel {:.3e}",
                bound.describe(),
                codec.name(),
                q.ratio,
                q.psnr_db,
                q.max_rel_err
            );
            quality_rows.push(Json::Obj(vec![
                ("bound".into(), Json::Str(bound.describe())),
                ("codec".into(), Json::Str(codec.name().into())),
                ("cr".into(), Json::Num(q.ratio)),
                // exact roundtrips fold to +inf; cap so the JSON value
                // stays a number the trend diff can score
                ("psnr_db".into(), Json::Num(q.psnr_db.min(300.0))),
                ("max_rel_err".into(), Json::Num(q.max_rel_err)),
                ("max_abs_err".into(), Json::Num(q.max_abs_err)),
                ("compressed_bytes".into(), Json::Int(stream.len() as i64)),
            ]));
        }
    }
    let qdoc = Json::Obj(vec![
        ("bench".into(), Json::Str("quality".into())),
        ("field".into(), Json::Str(format!("smooth-{qn}^3"))),
        ("rows".into(), Json::Arr(quality_rows)),
    ]);
    write_json("BENCH_quality.json", &qdoc).expect("write BENCH_quality.json");
    println!("wrote BENCH_quality.json");

    // reference baselines (need the flate2/zstd crates: --cfg reference_codecs)
    #[cfg(reference_codecs)]
    {
        let s =
            bench_budget("compress/real-zlib-6", 2.0, 50, || reference::zlib_compress(&data, 6));
        s.report_mbps(bytes);
        let comp = reference::zlib_compress(&data, 6);
        println!("{:40} CR {:.2}", "  (real-zlib-6)", bytes as f64 / comp.len() as f64);
        let s =
            bench_budget("compress/real-zstd-3", 2.0, 50, || reference::zstd_compress(&data, 3));
        s.report_mbps(bytes);
        let comp = reference::zstd_compress(&data, 3);
        println!("{:40} CR {:.2}", "  (real-zstd-3)", bytes as f64 / comp.len() as f64);
    }
    #[cfg(not(reference_codecs))]
    println!("reference baselines skipped (build with --cfg reference_codecs)");
}
