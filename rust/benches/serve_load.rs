//! Bench: `czb serve` under concurrent client load — request latency
//! quantiles and aggregate throughput through the TCP front-end.
//!
//! Spins up a real loopback server (one shared engine), then drives it
//! with several client connections issuing a compress → decompress →
//! verify cycle over mixed field sizes. Every response is checked
//! bit-identical against a locally compressed reference, so the bench
//! doubles as a sustained-load correctness test.
//!
//! Emits `BENCH_serve.json` with lower-is-better `p50_ms`/`p99_ms` rows
//! per operation plus aggregate `mbps` (raw field bytes moved through
//! the compress path per wall second). `SERVE_LOAD_FAST=1` shrinks the
//! run for CI.
use std::sync::Arc;
use std::time::{Duration, Instant};

use cubismz::core::Field3;
use cubismz::pipeline::{CompressParams, Engine, PipelineConfig, ShuffleMode};
use cubismz::service::{Client, ServeConfig, Server};
use cubismz::util::bench::{write_json, Json};

/// Concurrent client connections.
const CLIENTS: usize = 4;
const EPS: f32 = 1e-3;
const BS: u32 = 16;

/// Latency samples for one operation, in seconds.
#[derive(Default)]
struct Samples(Vec<f64>);

impl Samples {
    fn quantile_ms(&mut self, q: f64) -> f64 {
        assert!(!self.0.is_empty(), "no samples recorded");
        self.0.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((self.0.len() as f64 - 1.0) * q).round() as usize;
        self.0[idx] * 1e3
    }

    fn mean_ms(&self) -> f64 {
        self.0.iter().sum::<f64>() / self.0.len() as f64 * 1e3
    }
}

/// What one client thread brings home.
#[derive(Default)]
struct ClientRun {
    compress: Samples,
    decompress: Samples,
    verify: Samples,
    raw_bytes: u64,
    requests: u64,
}

fn smooth_field(seed: usize, n: usize) -> Field3 {
    let data = (0..n * n * n)
        .map(|i| (((i * 31 + seed * 127) % 509) as f32 * 0.061).sin() * 0.8)
        .collect();
    Field3::from_vec(n, n, n, data)
}

fn main() {
    let fast = std::env::var("SERVE_LOAD_FAST").is_ok();
    let budget = if fast { Duration::from_millis(800) } else { Duration::from_secs(6) };
    let sizes = if fast { [16usize, 32] } else { [32usize, 48] };
    let cfg = ServeConfig {
        // admission sized well above the client count: this bench
        // measures service latency, not backpressure
        admit_normal: CLIENTS * 4,
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind loopback server");
    let addr = server.local_addr().unwrap();
    let handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run().expect("accept loop"));
    println!(
        "bench serve_load: {CLIENTS} clients x {:?} fields, {:.1}s budget, server on {addr}",
        sizes,
        budget.as_secs_f64()
    );

    // local references: the bytes the server must reproduce per
    // (client, size) pair — also what `verify` walks
    let local = Engine::builder().build();
    let params = {
        let mut p = CompressParams::from_config(&PipelineConfig::paper_default(EPS));
        p.bs = BS as usize;
        p.shuffle = ShuffleMode::Byte4;
        p
    };
    let fields: Vec<Vec<Field3>> = (0..CLIENTS)
        .map(|c| sizes.iter().map(|&n| smooth_field(c, n)).collect())
        .collect();
    let references: Vec<Vec<Vec<u8>>> = fields
        .iter()
        .map(|fs| fs.iter().map(|f| local.compress_vec(f, "q", &params).0).collect())
        .collect();
    let fields = Arc::new(fields);
    let references = Arc::new(references);

    let t0 = Instant::now();
    let runs: Vec<ClientRun> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let fields = Arc::clone(&fields);
                let references = Arc::clone(&references);
                s.spawn(move || {
                    let mut run = ClientRun::default();
                    let mut client = Client::connect(addr)
                        .expect("connect")
                        .tenant(&format!("bench-{c}"));
                    let mut i = 0usize;
                    while t0.elapsed() < budget {
                        let field = &fields[c][i % fields[c].len()];
                        let reference = &references[c][i % fields[c].len()];
                        let raw = (field.data.len() * 4) as u64;

                        let q0 = Instant::now();
                        let czb = client
                            .compress("q", field, BS, EPS, ShuffleMode::Byte4)
                            .expect("transport")
                            .expect("compress refused");
                        run.compress.0.push(q0.elapsed().as_secs_f64());
                        assert_eq!(&czb, reference, "client {c}: stream drifted under load");

                        let q0 = Instant::now();
                        let (_, back) = client
                            .decompress(&czb)
                            .expect("transport")
                            .expect("decompress refused");
                        run.decompress.0.push(q0.elapsed().as_secs_f64());
                        assert_eq!(
                            back.data.len(),
                            field.data.len(),
                            "client {c}: decode shape drifted"
                        );

                        let q0 = Instant::now();
                        let summary = client
                            .verify(&czb)
                            .expect("transport")
                            .expect("verify refused");
                        run.verify.0.push(q0.elapsed().as_secs_f64());
                        assert!(summary.clean, "client {c}: stream failed remote verify");

                        run.raw_bytes += raw;
                        run.requests += 3;
                        i += 1;
                    }
                    run
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();
    handle.shutdown();
    server_thread.join().unwrap();

    let mut compress = Samples::default();
    let mut decompress = Samples::default();
    let mut verify = Samples::default();
    let mut raw_bytes = 0u64;
    let mut requests = 0u64;
    for mut r in runs {
        compress.0.append(&mut r.compress.0);
        decompress.0.append(&mut r.decompress.0);
        verify.0.append(&mut r.verify.0);
        raw_bytes += r.raw_bytes;
        requests += r.requests;
    }
    // raw field bytes make the round trip twice (up on compress, down
    // on decompress) — rate the compress direction only
    let mbps = raw_bytes as f64 / 1e6 / elapsed;
    let rps = requests as f64 / elapsed;

    let mut rows = Vec::new();
    for (name, s) in [
        ("compress", &mut compress),
        ("decompress", &mut decompress),
        ("verify", &mut verify),
    ] {
        let (p50, p99, mean) = (s.quantile_ms(0.5), s.quantile_ms(0.99), s.mean_ms());
        println!(
            "  {name:<10} {:>6} reqs  p50 {p50:.3} ms  p99 {p99:.3} ms  mean {mean:.3} ms",
            s.0.len()
        );
        rows.push(Json::Obj(vec![
            ("name".into(), Json::Str(name.into())),
            ("requests".into(), Json::Int(s.0.len() as i64)),
            ("p50_ms".into(), Json::Num(p50)),
            ("p99_ms".into(), Json::Num(p99)),
            ("mean_ms".into(), Json::Num(mean)),
        ]));
    }
    println!("  aggregate: {mbps:.1} MB/s raw through compress, {rps:.0} req/s over {CLIENTS} clients");

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("serve_load".into())),
        ("clients".into(), Json::Int(CLIENTS as i64)),
        ("sizes".into(), Json::Arr(sizes.iter().map(|&n| Json::Int(n as i64)).collect())),
        ("elapsed_secs".into(), Json::Num(elapsed)),
        ("requests".into(), Json::Int(requests as i64)),
        ("mbps".into(), Json::Num(mbps)),
        ("requests_per_sec".into(), Json::Num(rps)),
        ("rows".into(), Json::Arr(rows)),
    ]);
    write_json("BENCH_serve.json", &doc).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
