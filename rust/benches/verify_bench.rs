//! Bench: the price of integrity. Measures `verify_stream` (checksum
//! walk, no inflate) throughput, full v4 decode throughput, and — by
//! rebuilding the identical payload stream as a checksum-less v3 file —
//! the decode-time overhead the per-chunk CRC32C verification adds.
//! The acceptance bar is < 3% overhead. Emits `BENCH_verify.json` for
//! `scripts/bench_trend.py`; `VERIFY_BENCH_FAST=1` shrinks the field
//! and budgets for CI.
use cubismz::pipeline::{
    compress_field, decompress_field_mt, verify_stream, CzbFile, NativeEngine, PipelineConfig,
};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::util::bench::{bench_budget, write_json, Json};

/// Rebuild a v4 stream as a byte-equivalent v3 stream: same chunk
/// payloads, no CRC column, no header digest. The only differences a
/// decoder sees are the missing checksum verification and a slightly
/// smaller header — the cleanest possible A/B for checksum cost.
fn as_v3(stream: &[u8]) -> Vec<u8> {
    let (file, hsize) = CzbFile::parse_header(stream).expect("bench stream parses");
    let delta = (file.chunks.len() * 4 + 4) as u64;
    let mut v3 = file.clone();
    v3.version = 3;
    v3.chunk_crcs.clear();
    for c in &mut v3.chunks {
        c.offset -= delta;
    }
    let mut out = Vec::with_capacity(stream.len() - delta as usize);
    v3.write_header(&mut out);
    assert_eq!(out.len() as u64, hsize as u64 - delta);
    out.extend_from_slice(&stream[hsize..]);
    out
}

fn main() {
    let fast = std::env::var("VERIFY_BENCH_FAST").is_ok();
    let n = if fast { 64 } else { 96 };
    let budget = if fast { 0.6 } else { 2.0 };
    let nthreads = std::env::var("VERIFY_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1usize);
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    let bytes = f.nbytes();
    println!(
        "bench verify: p at 10k, {n}^3 ({} MB), {nthreads} thread(s){}",
        bytes / 1_000_000,
        if fast { ", fast mode" } else { "" }
    );
    let eps_list: &[f32] = if fast { &[1e-3] } else { &[1e-2, 1e-3, 1e-4] };
    let mut rows = Vec::new();
    for &eps in eps_list {
        let cfg = PipelineConfig::paper_default(eps).with_threads(nthreads);
        let (stream, _) = compress_field(&f, "p", &cfg, &NativeEngine);
        let v3_stream = as_v3(&stream);
        // same payload bytes decode to the same field through both
        // headers — the A/B is honest or the bench is meaningless
        let (a, _) = decompress_field_mt(&stream, &NativeEngine, nthreads).unwrap();
        let (b, _) = decompress_field_mt(&v3_stream, &NativeEngine, nthreads).unwrap();
        assert!(
            a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "v3 rebuild decodes differently"
        );

        let sv = bench_budget(&format!("verify/eps={eps:.0e}"), budget * 0.5, 200, || {
            verify_stream(&stream).unwrap()
        });
        sv.report_mbps(bytes);
        let s4 = bench_budget(&format!("decode_v4/eps={eps:.0e}"), budget, 50, || {
            decompress_field_mt(&stream, &NativeEngine, nthreads).unwrap()
        });
        s4.report_mbps(bytes);
        let s3 = bench_budget(&format!("decode_v3/eps={eps:.0e}"), budget, 50, || {
            decompress_field_mt(&v3_stream, &NativeEngine, nthreads).unwrap()
        });
        s3.report_mbps(bytes);
        let overhead_pct = (s4.mean / s3.mean - 1.0) * 100.0;
        println!("  checksum overhead: {overhead_pct:+.2}% of decode time");
        rows.push(Json::Obj(vec![
            ("eps".into(), Json::Num(eps as f64)),
            ("verify_mbps".into(), Json::Num(bytes as f64 / 1e6 / sv.mean)),
            ("decode_mbps".into(), Json::Num(bytes as f64 / 1e6 / s4.mean)),
            ("decode_v3_mbps".into(), Json::Num(bytes as f64 / 1e6 / s3.mean)),
            ("checksum_overhead_pct".into(), Json::Num(overhead_pct)),
        ]));
    }
    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("verify".into())),
        ("field".into(), Json::Str(format!("p@10k/{n}^3"))),
        ("raw_bytes".into(), Json::Int(bytes as i64)),
        ("nthreads".into(), Json::Int(nthreads as i64)),
        ("rows".into(), Json::Arr(rows)),
    ]);
    let out = "BENCH_verify.json";
    write_json(out, &doc).expect("write BENCH_verify.json");
    println!("wrote {out}");
}
