//! Bench: compress and whole-field decompress thread scaling on the
//! paper-default config (W³ai + shuffle + ZLIB, bs=32) over a 256³ smooth
//! field — the acceptance gauge for the dynamic span-queue scheduler.
//!
//! Asserts the scheduler's hard invariants at every thread count (the
//! `.czb` stream is byte-identical; parallel decode matches serial
//! bit-for-bit) and reports speedups vs 1 thread; the ≥3x-at-8-threads
//! throughput target is checked when the host actually has ≥8 hardware
//! threads. Emits `BENCH_thread_scaling.json`.
//!
//! Also measures *single-chunk* stage-2 decompression (zlib-best path):
//! a one-chunk archive decodes through the framed intra-chunk wide path,
//! so its sub-frames fan out across threads — the speedup rows land in
//! this bench's JSON and, via `codec_suite`, in `BENCH_stage2.json`.
//!
//! Field side can be overridden with `THREAD_SCALING_N` (divisible by 32).
use cubismz::codec::Codec;
use cubismz::core::Field3;
use cubismz::pipeline::{compress_field, decompress_field_mt, NativeEngine, PipelineConfig};
use cubismz::util::bench::{bench_budget, write_json, Json};
use cubismz::util::prng::Pcg32;

fn main() {
    let n: usize = std::env::var("THREAD_SCALING_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    assert!(n % 32 == 0, "THREAD_SCALING_N must be divisible by 32");
    let mut rng = Pcg32::new(42);
    let f = Field3::from_vec(n, n, n, cubismz::util::prop::gen_smooth_field(&mut rng, n));
    let bytes = f.nbytes();
    let hw = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    println!(
        "bench thread_scaling: {n}^3 smooth field ({} MB), {hw} hardware threads",
        bytes / 1_000_000
    );

    // paper-default 4 MiB chunks at 256^3 and above; shrunk for smoke sizes
    // so the scheduler still has ~16 spans to hand out (otherwise small
    // fields collapse to one chunk and the parallel asserts are vacuous)
    let block_raw = 32 * 32 * 32 * 4 + 4;
    let chunk_bytes = (bytes / 16).clamp(block_raw, 4 << 20);
    println!("  chunk_bytes = {chunk_bytes}");

    let mut rows = Vec::new();
    let mut reference_stream: Option<Vec<u8>> = None;
    let mut reference_field: Option<Vec<f32>> = None;
    let (mut c1, mut d1) = (0.0f64, 0.0f64); // 1-thread means
    let (mut c8, mut d8) = (0.0f64, 0.0f64);
    for threads in [1usize, 2, 4, 8] {
        let mut cfg = PipelineConfig::paper_default(1e-3).with_threads(threads);
        cfg.chunk_bytes = chunk_bytes;
        let s = bench_budget(&format!("compress/t={threads}"), 3.0, 12, || {
            compress_field(&f, "p", &cfg, &NativeEngine)
        });
        s.report_mbps(bytes);
        let (stream, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        match &reference_stream {
            None => reference_stream = Some(stream.clone()),
            Some(r) => assert_eq!(
                &stream, r,
                "compressed stream must be byte-identical across thread counts"
            ),
        }
        let sd = bench_budget(&format!("decompress/t={threads}"), 3.0, 12, || {
            decompress_field_mt(&stream, &NativeEngine, threads).unwrap()
        });
        sd.report_mbps(bytes);
        let (back, _) = decompress_field_mt(&stream, &NativeEngine, threads).unwrap();
        let bits: Vec<f32> = back.data;
        match &reference_field {
            None => reference_field = Some(bits),
            Some(r) => assert!(
                r.iter().zip(&bits).all(|(a, b)| a.to_bits() == b.to_bits()),
                "parallel decode must match serial bit-for-bit (t={threads})"
            ),
        }
        if threads == 1 {
            c1 = s.mean;
            d1 = sd.mean;
        }
        if threads == 8 {
            c8 = s.mean;
            d8 = sd.mean;
        }
        println!(
            "  t={threads}: compress {:.2}x decompress {:.2}x (ratio {:.2}, {} chunks)",
            c1 / s.mean,
            d1 / sd.mean,
            st.ratio(),
            st.nchunks
        );
        rows.push(Json::Obj(vec![
            ("threads".into(), Json::Int(threads as i64)),
            ("compress_mbps".into(), Json::Num(bytes as f64 / 1e6 / s.mean)),
            ("decompress_mbps".into(), Json::Num(bytes as f64 / 1e6 / sd.mean)),
            ("compress_speedup".into(), Json::Num(c1 / s.mean)),
            ("decompress_speedup".into(), Json::Num(d1 / sd.mean)),
        ]));
    }
    let (cs, ds) = (c1 / c8, d1 / d8);
    println!("scaling-check (8t vs 1t, target >= 3x): compress {cs:.2}x, decompress {ds:.2}x");
    if hw >= 8 {
        assert!(
            cs >= 3.0 && ds >= 3.0,
            "thread scaling below target on {hw}-thread host: compress {cs:.2}x, decompress {ds:.2}x"
        );
    } else {
        println!("  (only {hw} hardware threads — target not enforced on this host)");
    }
    // single-chunk stage-2 decompression (zlib-best): the framed wide
    // path must scale a one-chunk archive across threads, bit-exactly
    let sc_n = n.min(128);
    let mut rng = Pcg32::new(77);
    let sf = Field3::from_vec(sc_n, sc_n, sc_n, cubismz::util::prop::gen_smooth_field(&mut rng, sc_n));
    let mut scfg = PipelineConfig::paper_default(1e-4).with_threads(hw);
    scfg.stage2 = Codec::ZlibBest;
    scfg.chunk_bytes = 1 << 30; // a single chunk
    scfg.frame_bytes = 64 << 10; // many sub-frames inside it
    let (sc_stream, sc_st) = compress_field(&sf, "p", &scfg, &NativeEngine);
    assert_eq!(sc_st.nchunks, 1, "single-chunk section needs one chunk");
    println!(
        "single-chunk stage-2 decompress ({sc_n}^3, zlib-best, {} compressed bytes, {}-byte frames):",
        sc_stream.len(),
        scfg.frame_bytes
    );
    let mut sc_rows = Vec::new();
    let mut sc_d1 = 0.0f64;
    let mut sc_d8 = 0.0f64;
    let mut sc_reference: Option<Vec<f32>> = None;
    for threads in [1usize, 2, 4, 8] {
        let sd = bench_budget(&format!("single-chunk decompress/t={threads}"), 2.0, 12, || {
            decompress_field_mt(&sc_stream, &NativeEngine, threads).unwrap()
        });
        sd.report_mbps(sf.nbytes());
        let (back, _) = decompress_field_mt(&sc_stream, &NativeEngine, threads).unwrap();
        match &sc_reference {
            None => sc_reference = Some(back.data),
            Some(r) => assert!(
                r.iter().zip(&back.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "single-chunk wide decode must stay bit-exact (t={threads})"
            ),
        }
        if threads == 1 {
            sc_d1 = sd.mean;
        }
        if threads == 8 {
            sc_d8 = sd.mean;
        }
        println!("  t={threads}: {:.2}x vs 1 thread", sc_d1 / sd.mean);
        sc_rows.push(Json::Obj(vec![
            ("threads".into(), Json::Int(threads as i64)),
            ("decompress_mbps".into(), Json::Num(sd.mbps(sf.nbytes()))),
            ("speedup".into(), Json::Num(sc_d1 / sd.mean)),
        ]));
    }
    if hw >= 8 {
        let sp = sc_d1 / sc_d8;
        println!("single-chunk scaling-check (8t vs 1t, target >= 1.5x): {sp:.2}x");
        assert!(
            sp >= 1.5,
            "framed single-chunk decompression must speed up with threads: {sp:.2}x"
        );
    }

    // scalar-vs-simd pipeline comparison: same field, same config, both
    // dispatch modes — and the streams must stay byte-identical to the
    // default-dispatch reference above (whole-archive bit-exactness)
    let detected = cubismz::simd::detect();
    let mut modes = vec![cubismz::simd::SimdLevel::Scalar];
    if detected != cubismz::simd::SimdLevel::Scalar {
        modes.push(detected);
    }
    let cmp_threads = [1usize, hw.clamp(2, 8)];
    println!("simd comparison ({} vs scalar):", detected.name());
    let mut simd_rows = Vec::new();
    for &mode in &modes {
        for &threads in &cmp_threads {
            let mut cfg = PipelineConfig::paper_default(1e-3).with_threads(threads);
            cfg.chunk_bytes = chunk_bytes;
            let prev = cubismz::simd::override_level(mode);
            let s = bench_budget(&format!("compress/{}/t={threads}", mode.name()), 2.0, 8, || {
                compress_field(&f, "p", &cfg, &NativeEngine)
            });
            s.report_mbps(bytes);
            let (stream, _) = compress_field(&f, "p", &cfg, &NativeEngine);
            assert_eq!(
                Some(&stream),
                reference_stream.as_ref(),
                "{} stream must match the default-dispatch reference",
                mode.name()
            );
            let sd = bench_budget(&format!("decompress/{}/t={threads}", mode.name()), 2.0, 8, || {
                decompress_field_mt(&stream, &NativeEngine, threads).unwrap()
            });
            sd.report_mbps(bytes);
            cubismz::simd::override_level(prev);
            simd_rows.push(Json::Obj(vec![
                ("name".into(), Json::Str(format!("{}/t{threads}", mode.name()))),
                ("simd".into(), Json::Str(mode.name().into())),
                ("threads".into(), Json::Int(threads as i64)),
                ("compress_mbps".into(), Json::Num(bytes as f64 / 1e6 / s.mean)),
                ("decompress_mbps".into(), Json::Num(bytes as f64 / 1e6 / sd.mean)),
            ]));
        }
    }

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("thread_scaling".into())),
        ("field".into(), Json::Str(format!("smooth/{n}^3"))),
        ("raw_bytes".into(), Json::Int(bytes as i64)),
        ("hw_threads".into(), Json::Int(hw as i64)),
        ("rows".into(), Json::Arr(rows)),
        ("single_chunk_stage2".into(), Json::Arr(sc_rows)),
        ("simd_compare".into(), Json::Arr(simd_rows)),
    ]);
    write_json("BENCH_thread_scaling.json", &doc).expect("write BENCH_thread_scaling.json");
    println!("wrote BENCH_thread_scaling.json");
}
