//! Bench: compress and whole-field decompress thread scaling on the
//! paper-default config (W³ai + shuffle + ZLIB, bs=32) over a 256³ smooth
//! field — the acceptance gauge for the dynamic span-queue scheduler.
//!
//! Asserts the scheduler's hard invariants at every thread count (the
//! `.czb` stream is byte-identical; parallel decode matches serial
//! bit-for-bit) and reports speedups vs 1 thread; the ≥3x-at-8-threads
//! throughput target is checked when the host actually has ≥8 hardware
//! threads. Emits `BENCH_thread_scaling.json`.
//!
//! Field side can be overridden with `THREAD_SCALING_N` (divisible by 32).
use cubismz::core::Field3;
use cubismz::pipeline::{compress_field, decompress_field_mt, NativeEngine, PipelineConfig};
use cubismz::util::bench::{bench_budget, write_json, Json};
use cubismz::util::prng::Pcg32;

fn main() {
    let n: usize = std::env::var("THREAD_SCALING_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    assert!(n % 32 == 0, "THREAD_SCALING_N must be divisible by 32");
    let mut rng = Pcg32::new(42);
    let f = Field3::from_vec(n, n, n, cubismz::util::prop::gen_smooth_field(&mut rng, n));
    let bytes = f.nbytes();
    let hw = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    println!(
        "bench thread_scaling: {n}^3 smooth field ({} MB), {hw} hardware threads",
        bytes / 1_000_000
    );

    // paper-default 4 MiB chunks at 256^3 and above; shrunk for smoke sizes
    // so the scheduler still has ~16 spans to hand out (otherwise small
    // fields collapse to one chunk and the parallel asserts are vacuous)
    let block_raw = 32 * 32 * 32 * 4 + 4;
    let chunk_bytes = (bytes / 16).clamp(block_raw, 4 << 20);
    println!("  chunk_bytes = {chunk_bytes}");

    let mut rows = Vec::new();
    let mut reference_stream: Option<Vec<u8>> = None;
    let mut reference_field: Option<Vec<f32>> = None;
    let (mut c1, mut d1) = (0.0f64, 0.0f64); // 1-thread means
    let (mut c8, mut d8) = (0.0f64, 0.0f64);
    for threads in [1usize, 2, 4, 8] {
        let mut cfg = PipelineConfig::paper_default(1e-3).with_threads(threads);
        cfg.chunk_bytes = chunk_bytes;
        let s = bench_budget(&format!("compress/t={threads}"), 3.0, 12, || {
            compress_field(&f, "p", &cfg, &NativeEngine)
        });
        s.report_mbps(bytes);
        let (stream, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        match &reference_stream {
            None => reference_stream = Some(stream.clone()),
            Some(r) => assert_eq!(
                &stream, r,
                "compressed stream must be byte-identical across thread counts"
            ),
        }
        let sd = bench_budget(&format!("decompress/t={threads}"), 3.0, 12, || {
            decompress_field_mt(&stream, &NativeEngine, threads).unwrap()
        });
        sd.report_mbps(bytes);
        let (back, _) = decompress_field_mt(&stream, &NativeEngine, threads).unwrap();
        let bits: Vec<f32> = back.data;
        match &reference_field {
            None => reference_field = Some(bits),
            Some(r) => assert!(
                r.iter().zip(&bits).all(|(a, b)| a.to_bits() == b.to_bits()),
                "parallel decode must match serial bit-for-bit (t={threads})"
            ),
        }
        if threads == 1 {
            c1 = s.mean;
            d1 = sd.mean;
        }
        if threads == 8 {
            c8 = s.mean;
            d8 = sd.mean;
        }
        println!(
            "  t={threads}: compress {:.2}x decompress {:.2}x (ratio {:.2}, {} chunks)",
            c1 / s.mean,
            d1 / sd.mean,
            st.ratio(),
            st.nchunks
        );
        rows.push(Json::Obj(vec![
            ("threads".into(), Json::Int(threads as i64)),
            ("compress_mbps".into(), Json::Num(bytes as f64 / 1e6 / s.mean)),
            ("decompress_mbps".into(), Json::Num(bytes as f64 / 1e6 / sd.mean)),
            ("compress_speedup".into(), Json::Num(c1 / s.mean)),
            ("decompress_speedup".into(), Json::Num(d1 / sd.mean)),
        ]));
    }
    let (cs, ds) = (c1 / c8, d1 / d8);
    println!("scaling-check (8t vs 1t, target >= 3x): compress {cs:.2}x, decompress {ds:.2}x");
    if hw >= 8 {
        assert!(
            cs >= 3.0 && ds >= 3.0,
            "thread scaling below target on {hw}-thread host: compress {cs:.2}x, decompress {ds:.2}x"
        );
    } else {
        println!("  (only {hw} hardware threads — target not enforced on this host)");
    }
    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("thread_scaling".into())),
        ("field".into(), Json::Str(format!("smooth/{n}^3"))),
        ("raw_bytes".into(), Json::Int(bytes as i64)),
        ("hw_threads".into(), Json::Int(hw as i64)),
        ("rows".into(), Json::Arr(rows)),
    ]);
    write_json("BENCH_thread_scaling.json", &doc).expect("write BENCH_thread_scaling.json");
    println!("wrote BENCH_thread_scaling.json");
}
