//! Bench: cross-quantity `.czs` decode scaling — the multi-QoI ex-situ
//! read path. Builds a 7-quantity archive on disk, decodes it with
//! `Engine::decompress_dataset` at 1/2/4/8 threads (lazy file-backed
//! open each sample) and reports the speedup over the serial
//! per-quantity baseline (one thread, one quantity after another).
//!
//! Asserts the streaming invariants along the way: a lazy open keeps
//! untouched sections off the heap, and every thread count decodes
//! bit-identically to the eager in-memory path. The ≥1.5x-at-8-threads
//! fan-out target is enforced on hosts with ≥8 hardware threads. Also
//! sweeps the `DatasetOptions::cache_chunks` knob over a random
//! block-access workload. Emits `BENCH_dataset.json`.
//!
//! `DATASET_SCALING_FAST=1` shrinks fields and budgets for CI;
//! `DATASET_SCALING_N` overrides the field side (divisible by 32).
use cubismz::core::Field3;
use cubismz::pipeline::{CompressParams, Dataset, DatasetOptions, Engine, NativeEngine};
use cubismz::util::bench::{bench_budget, write_json, Json};
use cubismz::util::prng::Pcg32;

/// Quantities per step (the paper's CFD workflow dumps ~7).
const NQ: usize = 7;

fn main() {
    let fast = std::env::var("DATASET_SCALING_FAST").is_ok();
    let n: usize = std::env::var("DATASET_SCALING_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 64 } else { 128 });
    assert!(n % 32 == 0, "DATASET_SCALING_N must be divisible by 32");
    let (budget, samples) = if fast { (1.0, 5) } else { (3.0, 12) };
    let hw = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let raw_bytes = n * n * n * 4 * NQ;
    println!(
        "bench dataset_scaling: {NQ} x {n}^3 quantities ({} MB raw), {hw} hardware threads",
        raw_bytes / 1_000_000
    );

    let dir = std::env::temp_dir().join("cubismz_dataset_scaling");
    std::fs::create_dir_all(&dir).expect("create bench dir");
    let path = dir.join("step.czs");

    // several chunks per quantity so intra-quantity decode can spread
    // too, but few enough that cross-quantity fan-out is what matters
    let chunk_bytes = (n * n * n * 4 / 8).max(32 * 32 * 32 * 4 + 4);
    let writer_engine = Engine::builder().threads(hw).chunk_bytes(chunk_bytes).build();
    let params = CompressParams::paper_default(1e-3);
    let mut w = Dataset::create(&path).expect("create archive");
    for i in 0..NQ as u64 {
        let mut rng = Pcg32::new(1000 + i);
        let f = Field3::from_vec(n, n, n, cubismz::util::prop::gen_smooth_field(&mut rng, n));
        w.write_quantity(&writer_engine, &f, &format!("q{i}"), &params).expect("write quantity");
    }
    w.finish().expect("finish archive");
    let archive_bytes = std::fs::metadata(&path).expect("stat archive").len();
    println!("  archive: {archive_bytes} bytes, chunk_bytes {chunk_bytes}");

    // streaming open: decoding one quantity must leave the rest on disk
    let serial = Engine::builder().threads(1).build();
    let lazy = Dataset::open(&path).expect("open archive");
    assert_eq!(lazy.resident_bytes(), 0, "nothing resident before first touch");
    let (q0, _) = lazy.read_quantity("q0", &serial).expect("decode q0");
    let resident_one = lazy.resident_bytes();
    assert!(
        (resident_one as u64) < archive_bytes,
        "lazy open must not pull the whole archive for one quantity"
    );
    println!("  lazy open: {resident_one} of {archive_bytes} bytes resident after one quantity");

    // eager per-quantity reference bits for the identity checks
    let eager = Dataset::from_bytes(std::fs::read(&path).expect("read archive")).expect("parse");
    let reference: Vec<Vec<f32>> = eager
        .entries()
        .iter()
        .map(|e| {
            serial.decompress_bytes(eager.section(&e.name).expect("section")).expect("decode").0.data
        })
        .collect();
    assert!(
        q0.data.iter().zip(&reference[0]).all(|(a, b)| a.to_bits() == b.to_bits()),
        "lazy single-quantity decode must match the eager path"
    );

    // serial per-quantity baseline: one thread, one quantity after
    // another — the pre-fan-out decompress_dataset_file shape. Re-opens
    // per sample so no decoded-chunk cache warms across samples.
    let sb = bench_budget("serial per-quantity baseline", budget, samples, || {
        let ds = Dataset::open(&path).unwrap();
        for e in ds.entries() {
            serial.decompress_bytes(ds.section(&e.name).unwrap()).unwrap();
        }
    });
    sb.report_mbps(raw_bytes);

    let mut rows = Vec::new();
    let mut t8 = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let engine = Engine::builder().threads(threads).build();
        let s = bench_budget(&format!("decompress_dataset/t={threads}"), budget, samples, || {
            let ds = Dataset::open(&path).unwrap();
            engine.decompress_dataset(&ds, None).unwrap()
        });
        s.report_mbps(raw_bytes);
        // bit identity vs the eager per-quantity reference
        let ds = Dataset::open(&path).unwrap();
        let decoded = engine.decompress_dataset(&ds, None).unwrap();
        for ((name, field, _), expect) in decoded.iter().zip(&reference) {
            assert!(
                field.data.iter().zip(expect).all(|(a, b)| a.to_bits() == b.to_bits()),
                "lazy fan-out decode of {name} must match the eager path (t={threads})"
            );
        }
        if threads == 8 {
            t8 = s.mean;
        }
        println!("  t={threads}: {:.2}x vs serial baseline", sb.mean / s.mean);
        rows.push(Json::Obj(vec![
            ("threads".into(), Json::Int(threads as i64)),
            ("decode_mbps".into(), Json::Num(raw_bytes as f64 / 1e6 / s.mean)),
            ("speedup_vs_serial".into(), Json::Num(sb.mean / s.mean)),
        ]));
    }
    if hw >= 8 {
        let sp = sb.mean / t8;
        println!("fan-out scaling check (8t vs serial baseline, target >= 1.5x): {sp:.2}x");
        assert!(
            sp >= 1.5,
            "cross-quantity decode must beat the serial per-quantity baseline: {sp:.2}x"
        );
    } else {
        println!("  (only {hw} hardware threads — 1.5x target not enforced on this host)");
    }

    // cache-size sweep: random block access through the shared cache —
    // the DatasetOptions::cache_chunks knob this bench exists to size
    let wav = NativeEngine;
    let reads = if fast { 300 } else { 3000 };
    let mut sweep = Vec::new();
    for cache_chunks in [4usize, 32, 128] {
        let ds = DatasetOptions::new().cache_chunks(cache_chunks).open(&path).unwrap();
        let mut reader = ds.block_reader("q0", &wav).unwrap();
        let bs = reader.file.bs as usize;
        let nblocks = reader.file.nblocks;
        let mut blk = vec![0f32; bs * bs * bs];
        let mut rng = Pcg32::new(7);
        let t = std::time::Instant::now();
        for _ in 0..reads {
            let id = rng.below(nblocks);
            reader.read_block(id, &mut blk).unwrap();
        }
        let secs = t.elapsed().as_secs_f64();
        println!(
            "  cache_chunks={cache_chunks}: {reads} random block reads in {:.1} ms ({} hits / {} misses)",
            secs * 1e3,
            reader.cache_hits,
            reader.cache_misses
        );
        sweep.push(Json::Obj(vec![
            ("cache_chunks".into(), Json::Int(cache_chunks as i64)),
            ("reads".into(), Json::Int(reads as i64)),
            ("secs".into(), Json::Num(secs)),
            ("hits".into(), Json::Int(reader.cache_hits as i64)),
            ("misses".into(), Json::Int(reader.cache_misses as i64)),
        ]));
    }

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("dataset_scaling".into())),
        ("field".into(), Json::Str(format!("smooth/{n}^3 x{NQ}"))),
        ("raw_bytes".into(), Json::Int(raw_bytes as i64)),
        ("archive_bytes".into(), Json::Int(archive_bytes as i64)),
        ("hw_threads".into(), Json::Int(hw as i64)),
        ("resident_after_one_quantity".into(), Json::Int(resident_one as i64)),
        ("serial_baseline_mbps".into(), Json::Num(raw_bytes as f64 / 1e6 / sb.mean)),
        ("rows".into(), Json::Arr(rows)),
        ("cache_sweep".into(), Json::Arr(sweep)),
    ]);
    write_json("BENCH_dataset.json", &doc).expect("write BENCH_dataset.json");
    println!("wrote BENCH_dataset.json");
}
