//! Bench: end-to-end pipeline throughput (the paper's §4.1 scenario) —
//! full field in, .czb stream out — across tolerance levels, plus
//! whole-field decompression and the random-access path with the chunk
//! cache. Emits `BENCH_pipeline.json` (MB/s per stage, ratio, nthreads)
//! so the perf trajectory is machine-trackable across PRs.
use cubismz::core::block::Block;
use cubismz::pipeline::{
    compress_field, decompress_field_mt, BlockReader, NativeEngine, PipelineConfig,
};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::util::bench::{bench_budget, write_json, Json};
use cubismz::util::prng::Pcg32;

fn main() {
    let n = 96;
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    let bytes = f.nbytes();
    let nthreads = std::env::var("PIPELINE_E2E_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1usize);
    println!("bench pipeline_e2e: p at 10k, {n}^3 ({} MB), {nthreads} thread(s)", bytes / 1_000_000);
    let mut rows = Vec::new();
    for eps in [1e-2f32, 1e-3, 1e-4] {
        let cfg = PipelineConfig::paper_default(eps).with_threads(nthreads);
        let s = bench_budget(&format!("compress/eps={eps:.0e}"), 2.5, 20, || {
            compress_field(&f, "p", &cfg, &NativeEngine)
        });
        s.report_mbps(bytes);
        let (stream, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        let sd = bench_budget(&format!("decompress/eps={eps:.0e}"), 2.0, 20, || {
            decompress_field_mt(&stream, &NativeEngine, nthreads).unwrap()
        });
        sd.report_mbps(bytes);
        // per-stage throughput from the pipeline's own timers (seconds are
        // summed over threads, so this is per-core throughput)
        let mbps = |secs: f64| {
            if secs > 0.0 {
                bytes as f64 / 1e6 / secs
            } else {
                0.0
            }
        };
        rows.push(Json::Obj(vec![
            ("eps".into(), Json::Num(eps as f64)),
            ("ratio".into(), Json::Num(st.ratio())),
            ("nchunks".into(), Json::Int(st.nchunks as i64)),
            ("compress_mbps".into(), Json::Num(bytes as f64 / 1e6 / s.mean)),
            ("decompress_mbps".into(), Json::Num(bytes as f64 / 1e6 / sd.mean)),
            ("stage1_mbps_per_core".into(), Json::Num(mbps(st.t_stage1))),
            ("stage2_mbps_per_core".into(), Json::Num(mbps(st.t_stage2))),
        ]));
    }
    // random block access through the LRU chunk cache
    let cfg = {
        let mut c = PipelineConfig::paper_default(1e-3);
        c.chunk_bytes = 64 << 10;
        c
    };
    let (stream, st) = compress_field(&f, "p", &cfg, &NativeEngine);
    println!("  ({} chunks over {} blocks)", st.nchunks, st.nblocks);
    let engine = NativeEngine;
    let mut reader = BlockReader::new(&stream, &engine).unwrap().with_cache_capacity(8);
    let mut blk = Block::zeros(32);
    let mut rng = Pcg32::new(2);
    let nblocks = st.nblocks as u32;
    let s = bench_budget("random_block_read(cached)", 1.5, 2000, || {
        let id = rng.below(nblocks);
        reader.read_block(id, &mut blk.data).unwrap();
    });
    s.report();
    println!("  cache: {} hits / {} misses", reader.cache_hits, reader.cache_misses);

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("pipeline_e2e".into())),
        ("field".into(), Json::Str(format!("p@10k/{n}^3"))),
        ("raw_bytes".into(), Json::Int(bytes as i64)),
        ("nthreads".into(), Json::Int(nthreads as i64)),
        ("rows".into(), Json::Arr(rows)),
        (
            "random_block_read_ms".into(),
            Json::Num(s.mean * 1e3),
        ),
    ]);
    let out = "BENCH_pipeline.json";
    write_json(out, &doc).expect("write BENCH_pipeline.json");
    println!("wrote {out}");
}
