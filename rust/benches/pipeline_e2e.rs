//! Bench: end-to-end pipeline throughput (the paper's §4.1 scenario) —
//! full field in, .czb stream out — across tolerance levels, plus the
//! random-access decompression path with the chunk cache.
use cubismz::core::block::Block;
use cubismz::pipeline::{compress_field, BlockReader, NativeEngine, PipelineConfig};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::util::bench::bench_budget;
use cubismz::util::prng::Pcg32;

fn main() {
    let n = 96;
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    let bytes = f.nbytes();
    println!("bench pipeline_e2e: p at 10k, {n}^3 ({} MB)", bytes / 1_000_000);
    for eps in [1e-2f32, 1e-3, 1e-4] {
        let cfg = PipelineConfig::paper_default(eps);
        let s = bench_budget(&format!("compress/eps={eps:.0e}"), 2.5, 20, || {
            compress_field(&f, "p", &cfg, &NativeEngine)
        });
        s.report_mbps(bytes);
    }
    // random block access through the LRU chunk cache
    let cfg = {
        let mut c = PipelineConfig::paper_default(1e-3);
        c.chunk_bytes = 64 << 10;
        c
    };
    let (stream, st) = compress_field(&f, "p", &cfg, &NativeEngine);
    println!("  ({} chunks over {} blocks)", st.nchunks, st.nblocks);
    let engine = NativeEngine;
    let mut reader = BlockReader::new(&stream, &engine).unwrap().with_cache_capacity(8);
    let mut blk = Block::zeros(32);
    let mut rng = Pcg32::new(2);
    let nblocks = st.nblocks as u32;
    let s = bench_budget("random_block_read(cached)", 1.5, 2000, || {
        let id = rng.below(nblocks);
        reader.read_block(id, &mut blk.data).unwrap();
    });
    s.report();
    println!("  cache: {} hits / {} misses", reader.cache_hits, reader.cache_misses);
}
