//! Compile-only stand-in for the external `xla` crate (xla-rs).
//!
//! Mirrors exactly the API surface `runtime/pjrt_xla.rs` calls so the
//! `--cfg pjrt_runtime` codepath can be type-checked on the offline
//! image (no registry, no XLA binaries). Every constructor fails with an
//! explanatory error, so code that probes availability — which is all of
//! the callers — falls back to the native engine at runtime exactly like
//! the default-build stub engine does. Swap the path dependency in
//! `rust/Cargo.toml` for the real crate to execute PJRT for real.
use std::fmt;

/// Stub error: every operation reports the runtime as unavailable.
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} is compile-only — link the real xla crate to run the PJRT engine"
    ))
}

/// Stand-in for `xla::PjRtClient`. `cpu()` always fails, which is the
/// single probe point every caller goes through.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Stand-in for `xla::PjRtBuffer` (what `execute` yields).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("compile-only"), "{e}");
    }
}
