//! End-to-end integration over the whole stack minus PJRT: simulator ->
//! grid -> pipeline -> parallel shared-file I/O -> decompress -> metrics,
//! including the multi-rank in-process cluster path.
use cubismz::cluster::{partition, Comm, InProcComm};
use cubismz::codec::Codec;
use cubismz::core::block::{Block, BlockGrid};
use cubismz::core::{Field3, FieldStats};
use cubismz::io::parallel::shared_write;
use cubismz::metrics::{compression_ratio, psnr};
use cubismz::pipeline::{
    compress_field, decompress_field, decompress_field_mt, CoeffCodec, CompressParams, Engine,
    NativeEngine, PipelineConfig, ShuffleMode, Stage1,
};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::wavelet::WaveletKind;

fn tmpdir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join("cubismz_integration");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn simulator_to_file_to_field_all_qois() {
    let sim = CloudSim::new(CloudConfig::paper(64));
    let cfg = PipelineConfig::paper_default(1e-3);
    for qoi in Qoi::ALL {
        let f = sim.field(qoi, step_to_time(5000));
        let (bytes, st) = compress_field(&f, qoi.name(), &cfg, &NativeEngine);
        assert!(st.ratio() > 2.0, "{qoi:?} ratio {}", st.ratio());
        let path = tmpdir().join(format!("{}.czb", qoi.name()));
        std::fs::write(&path, &bytes).unwrap();
        let read_back = std::fs::read(&path).unwrap();
        let (g, file) = decompress_field(&read_back, &NativeEngine).unwrap();
        assert_eq!(file.name, qoi.name());
        let p = psnr(&f.data, &g.data).unwrap();
        assert!(p > 45.0, "{qoi:?} psnr {p}");
    }
}

#[test]
fn multi_rank_compress_and_shared_write_roundtrips() {
    // 4 ranks, each compressing its own block partition, exscan offsets,
    // single shared file (the paper's in-situ I/O path)
    let sim = CloudSim::new(CloudConfig::paper(64));
    let f = sim.field(Qoi::Pressure, step_to_time(5000));
    let bs = 32usize;
    let grid = BlockGrid::new(&f, bs);
    let nblocks = grid.nblocks();
    let size = 4;
    let comms = InProcComm::group(size);
    let path = tmpdir().join("shared_p.bin");

    // per-rank payload: length-prefixed compressed sub-streams
    let payloads: Vec<Vec<u8>> = std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = &f;
                let grid = &grid;
                let path = path.clone();
                s.spawn(move || {
                    let (lo, hi) = partition(nblocks, c.rank(), c.size());
                    let mut blk = Block::zeros(bs);
                    let mut raw = Vec::new();
                    for id in lo..hi {
                        grid.extract(f, id, &mut blk);
                        for v in &blk.data {
                            raw.extend_from_slice(&v.to_le_bytes());
                        }
                    }
                    let comp = Codec::ZlibDef.compress_vec(&raw);
                    let header = [0x42u8; 8];
                    let rep = shared_write(
                        &path,
                        &c,
                        if c.rank() == 0 { Some(&header[..]) } else { None },
                        8,
                        &comp,
                    )
                    .unwrap();
                    assert_eq!(rep.bytes as usize, comp.len());
                    (c.rank(), rep.offset, comp)
                })
            })
            .collect();
        let mut out: Vec<(usize, u64, Vec<u8>)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        out.sort_by_key(|(r, ..)| *r);
        // verify each rank's bytes landed at its offset
        let file = std::fs::read(&path).unwrap();
        for (_, off, comp) in &out {
            assert_eq!(&file[*off as usize..*off as usize + comp.len()], &comp[..]);
        }
        out.into_iter().map(|(_, _, c)| c).collect()
    });

    // decompress all rank payloads and reassemble the field
    let mut out_field = Field3::zeros(f.nx, f.ny, f.nz);
    let mut blk = Block::zeros(bs);
    for (rank, comp) in payloads.iter().enumerate() {
        let raw = Codec::ZlibDef.decompress_vec(comp).unwrap();
        let (lo, hi) = partition(nblocks, rank, size);
        assert_eq!(raw.len(), (hi - lo) * bs * bs * bs * 4);
        for (j, id) in (lo..hi).enumerate() {
            let start = j * bs * bs * bs * 4;
            for (k, c) in raw[start..start + bs * bs * bs * 4].chunks_exact(4).enumerate() {
                blk.data[k] = f32::from_le_bytes(c.try_into().unwrap());
            }
            grid.insert(&mut out_field, id, &blk);
        }
    }
    assert_eq!(out_field.data, f.data);
}

#[test]
fn table1_style_stats_are_stable() {
    let sim = CloudSim::new(CloudConfig::paper(64));
    for step in [5000, 10000] {
        let a2 = sim.field(Qoi::Alpha2, step_to_time(step));
        let st = FieldStats::compute(&a2.data);
        assert!(st.min >= 0.0 && st.max <= 1.0);
        assert!(st.mean > 0.0 && st.mean < 0.2, "a2 mean {}", st.mean);
    }
}

#[test]
fn restart_snapshot_fpzip_lossless_ratio_in_paper_band() {
    // paper §4.4: lossless FPZIP restart files compress 2.62x..4.25x
    let sim = CloudSim::new(CloudConfig::paper(64));
    let cfg = PipelineConfig::new(32, Stage1::Fpzip { prec: 32 }, Codec::None);
    let mut total_raw = 0usize;
    let mut total_comp = 0usize;
    for qoi in Qoi::ALL {
        let f = sim.field(qoi, step_to_time(5000));
        let (bytes, st) = compress_field(&f, qoi.name(), &cfg, &NativeEngine);
        // bit-exact restart requirement
        let (back, _) = decompress_field(&bytes, &NativeEngine).unwrap();
        for (a, b) in f.data.iter().zip(&back.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "{qoi:?} restart must be lossless");
        }
        total_raw += st.raw_bytes;
        total_comp += st.compressed_bytes;
    }
    let cr = compression_ratio(total_raw, total_comp).unwrap();
    assert!(cr > 1.5 && cr < 20.0, "restart CR {cr}");
}

#[test]
fn zbits_and_shuffle_improve_ratio_without_breaking_bounds() {
    // Exp 2 (Fig 5): shuffle raises CR at identical PSNR; Z4 raises CR
    // with bounded PSNR cost
    let sim = CloudSim::new(CloudConfig::paper(64));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    let mk = |zbits, shuffle| {
        let stage1 = Stage1::Wavelet {
            kind: WaveletKind::Avg3,
            eps_rel: 1e-3,
            zbits,
            coeff: CoeffCodec::None,
        };
        let cfg = PipelineConfig::new(32, stage1, Codec::ZlibDef).with_shuffle(shuffle);
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        let (back, _) = decompress_field(&bytes, &NativeEngine).unwrap();
        (st.ratio(), psnr(&f.data, &back.data).unwrap())
    };
    let (cr_plain, ps_plain) = mk(0, ShuffleMode::None);
    let (cr_shuf, ps_shuf) = mk(0, ShuffleMode::Byte4);
    let (cr_z4, ps_z4) = mk(4, ShuffleMode::Byte4);
    assert!(cr_shuf > cr_plain, "shuffle: {cr_shuf} vs {cr_plain}");
    assert!((ps_shuf - ps_plain).abs() < 1e-9, "shuffle must not change PSNR");
    assert!(cr_z4 >= cr_shuf, "z4: {cr_z4} vs {cr_shuf}");
    assert!(ps_z4 <= ps_shuf + 0.01 && ps_z4 > ps_shuf - 12.0, "z4 psnr {ps_z4} vs {ps_shuf}");
}

#[test]
fn simd_dispatch_never_changes_the_stream() {
    // the fuzzed per-kernel equivalence tests live next to each kernel;
    // this is the whole-archive claim: compressing under forced-scalar
    // and under the host's best vector level, at several thread counts,
    // must produce byte-identical .czb streams, and either mode must
    // decode the other's stream bit-for-bit
    let detected = cubismz::simd::detect();
    let sim = CloudSim::new(CloudConfig::paper(64));
    let f = sim.field(Qoi::Pressure, step_to_time(5000));
    let mut cfg = PipelineConfig::paper_default(1e-3);
    cfg.chunk_bytes = 256 << 10; // multiple chunks even at 64^3
    let mut reference: Option<Vec<u8>> = None;
    for lvl in [cubismz::simd::SimdLevel::Scalar, detected] {
        for threads in [1usize, 2, 4, 8] {
            let cfgn = cfg.with_threads(threads);
            let prev = cubismz::simd::override_level(lvl);
            let (bytes, _) = compress_field(&f, "p", &cfgn, &NativeEngine);
            cubismz::simd::override_level(prev);
            match &reference {
                None => reference = Some(bytes),
                Some(r) => assert_eq!(
                    r, &bytes,
                    "stream differs under {} dispatch at {threads} threads",
                    lvl.name()
                ),
            }
        }
    }
    let stream = reference.unwrap();
    let mut decoded: Option<Vec<f32>> = None;
    for lvl in [cubismz::simd::SimdLevel::Scalar, detected] {
        let prev = cubismz::simd::override_level(lvl);
        let (back, _) = decompress_field_mt(&stream, &NativeEngine, 4).unwrap();
        cubismz::simd::override_level(prev);
        match &decoded {
            None => decoded = Some(back.data),
            Some(r) => assert!(
                r.iter().zip(&back.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "decode differs under {} dispatch",
                lvl.name()
            ),
        }
    }
}

#[test]
fn thread_count_never_changes_the_stream() {
    // the dynamic span-queue schedule fixes chunk boundaries by block-id
    // arithmetic: compressing with any thread count — through the legacy
    // free function OR a persistent Engine session — must produce the
    // exact same bytes, and chunk-parallel decode must reproduce the
    // serial field bit-for-bit
    let sim = CloudSim::new(CloudConfig::paper(64));
    let f = sim.field(Qoi::Density, step_to_time(5000));
    let mut cfg = PipelineConfig::paper_default(1e-3);
    cfg.chunk_bytes = 256 << 10; // multiple chunks even at 64^3
    let (bytes1, st) = compress_field(&f, "rho", &cfg, &NativeEngine);
    assert!(st.nchunks > 1, "need multiple chunks, got {}", st.nchunks);
    let params = CompressParams::from_config(&cfg);
    for nthreads in [1usize, 2, 4, 7] {
        let cfgn = cfg.with_threads(nthreads);
        let (bytesn, _) = compress_field(&f, "rho", &cfgn, &NativeEngine);
        assert_eq!(bytes1, bytesn, "legacy nthreads {nthreads}");
        // session API cross-check: same stream from the worker pool
        let engine = Engine::builder().threads(nthreads).chunk_bytes(cfg.chunk_bytes).build();
        let (bytes_e, _) = engine.compress_vec(&f, "rho", &params);
        assert_eq!(bytes1, bytes_e, "engine nthreads {nthreads}");
        // and the session decodes to the serial field bit-for-bit
        let (eng_field, _) = engine.decompress_bytes(&bytes_e).unwrap();
        let (serial, _) = decompress_field(&bytes1, &NativeEngine).unwrap();
        assert!(
            serial.data.iter().zip(&eng_field.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "engine decode must match serial (nthreads {nthreads})"
        );
    }
    let (serial, _) = decompress_field(&bytes1, &NativeEngine).unwrap();
    let (parallel, _) = decompress_field_mt(&bytes1, &NativeEngine, 4).unwrap();
    assert!(
        serial.data.iter().zip(&parallel.data).all(|(a, b)| a.to_bits() == b.to_bits()),
        "parallel whole-field decode must match serial"
    );
}
