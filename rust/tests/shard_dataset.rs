//! Integration: sharded datasets (`.czm` + per-shard `.czs`) — cross-
//! shard access bit-identical to the unsharded archive at several
//! thread counts, missing-shard salvage isolation, and shard-verify
//! outcomes. Shards are built directly (no service sockets); the
//! spawned-worker path is covered in tests/cli_integration.rs.
use cubismz::core::block::{Block, BlockGrid};
use cubismz::distrib::{shard_verify, Manifest, ManifestQuantity, ShardEntry, ShardedDataset};
use cubismz::pipeline::{CompressParams, Dataset, Engine, NativeEngine};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::util::crc32c::crc32c;
use std::path::PathBuf;

const N: usize = 32;

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("cubismz_shard_tests");
    std::fs::create_dir_all(&d).unwrap();
    d.join(name)
}

/// Build a 2-shard dataset (shard 0: p,E — shard 1: rho,a2) plus the
/// equivalent unsharded archive from the same fields and params.
/// Returns (manifest path, unsharded archive path).
fn build(tag: &str) -> (PathBuf, PathBuf) {
    let sim = CloudSim::new(CloudConfig::paper(N));
    let t = step_to_time(5000);
    let engine = Engine::builder().threads(4).build();
    let params = CompressParams::paper_default(1e-3);

    let plain = tmp(&format!("{tag}.czs"));
    let mut w = Dataset::create(&plain).unwrap();
    for qoi in Qoi::ALL {
        w.write_quantity(&engine, &sim.field(qoi, t), qoi.name(), &params).unwrap();
    }
    w.finish().unwrap();

    // interleaved ownership (qi % 2) so logical order differs from
    // shard-file order — the reassembly must follow the manifest
    let mut shards = Vec::new();
    for i in 0..2usize {
        let path = tmp(&format!("{tag}.shard{i}.czs"));
        let mut w = Dataset::create(&path).unwrap();
        for (qi, qoi) in Qoi::ALL.iter().enumerate() {
            if qi % 2 == i {
                w.write_quantity(&engine, &sim.field(*qoi, t), qoi.name(), &params).unwrap();
            }
        }
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        shards.push(ShardEntry {
            path: path.file_name().unwrap().to_string_lossy().into_owned(),
            file_len: bytes.len() as u64,
            file_crc: crc32c(&bytes),
        });
    }
    let quantities = Qoi::ALL
        .iter()
        .enumerate()
        .map(|(qi, q)| ManifestQuantity {
            name: q.name().to_string(),
            shard: qi % 2,
            nx: N as u32,
            ny: N as u32,
            nz: N as u32,
        })
        .collect();
    let mpath = tmp(&format!("{tag}.czm"));
    Manifest { shards, quantities }.write(&mpath).unwrap();
    (mpath, plain)
}

#[test]
fn cross_shard_access_is_bit_identical_to_unsharded() {
    let (mpath, plain) = build("identity");
    for threads in [1usize, 2, 4, 8] {
        let engine = Engine::builder().threads(threads).build();
        let plain_ds = Dataset::open(&plain).unwrap();
        let sharded = ShardedDataset::open(&mpath).unwrap();
        assert_eq!(sharded.names(), plain_ds.names(), "logical order follows the manifest");

        // whole-dataset decode, quantity by quantity bit-identical
        let decoded = sharded.decompress(&engine).unwrap();
        assert_eq!(decoded.len(), Qoi::ALL.len());
        for ((name, field, file), want) in decoded.iter().zip(Qoi::ALL) {
            assert_eq!(name, want.name());
            let (reference, rfile) = plain_ds.read_quantity(name, &engine).unwrap();
            assert_eq!(file.name, rfile.name);
            assert_eq!(field.data.len(), reference.data.len());
            assert!(
                field.data.iter().zip(&reference.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{name} differs at {threads} threads"
            );
        }

        // random block access routes through the owning shard's cache
        // and agrees with the whole-field decode bit-for-bit
        let (full, file) = sharded.read_quantity("rho", &engine).unwrap();
        let bs = file.bs as usize;
        let grid = BlockGrid::new(&full, bs);
        let weng = NativeEngine;
        let mut reader = sharded.block_reader("rho", &weng).unwrap();
        let mut blk = vec![0f32; bs * bs * bs];
        let mut expected = Block::zeros(bs);
        for id in [0u32, file.nblocks / 2, file.nblocks - 1] {
            reader.read_block(id, &mut blk).unwrap();
            grid.extract(&full, id as usize, &mut expected);
            assert!(
                blk.iter().zip(&expected.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "block {id} differs at {threads} threads"
            );
        }
    }
}

#[test]
fn missing_shard_salvages_with_siblings_intact() {
    let (mpath, plain) = build("salvage");
    let engine = Engine::builder().threads(2).build();
    std::fs::remove_file(ShardedDataset::open(&mpath).unwrap().shard_path(1)).unwrap();

    // strict decode refuses a lost shard outright
    let sharded = ShardedDataset::open(&mpath).unwrap();
    assert!(sharded.decompress(&engine).is_err());

    // salvage isolates the loss: shard 1's quantities come back zeroed
    // at the manifest dims, shard 0's stay bit-identical
    let decodes = sharded.decompress_salvage(&engine).unwrap();
    assert_eq!(decodes.len(), Qoi::ALL.len());
    let plain_ds = Dataset::open(&plain).unwrap();
    for d in &decodes {
        if d.shard == 1 {
            assert!(d.report.is_err(), "{} should be reported lost", d.name);
            assert_eq!((d.field.nx, d.field.ny, d.field.nz), (N, N, N));
            assert!(d.field.data.iter().all(|v| v.to_bits() == 0), "{} not zeroed", d.name);
        } else {
            assert!(d.is_clean(), "{} should decode clean", d.name);
            let (reference, _) = plain_ds.read_quantity(&d.name, &engine).unwrap();
            assert!(
                d.field.data.iter().zip(&reference.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{} differs from unsharded decode",
                d.name
            );
        }
    }

    // single-quantity access: lost shard errors, sibling still serves
    assert!(sharded.read_quantity("rho", &engine).is_err());
    assert!(sharded.read_quantity("p", &engine).is_ok());
}

#[test]
fn shard_verify_reports_clean_then_corrupt() {
    let (mpath, _plain) = build("verify");
    let engine = Engine::builder().threads(2).build();
    let report = shard_verify(&mpath, false, &engine).unwrap();
    assert!(report.is_clean());
    assert_eq!(report.entries.len(), 2);

    // flip one payload byte in shard 0: the manifest's whole-file CRC
    // must flag it while the sibling shard stays clean
    let spath = ShardedDataset::open(&mpath).unwrap().shard_path(0);
    let mut bytes = std::fs::read(&spath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&spath, &bytes).unwrap();
    let report = shard_verify(&mpath, false, &engine).unwrap();
    assert!(!report.is_clean());
    assert!(report.entries[0].file.is_err(), "file CRC must catch the flip");
    assert!(report.entries[1].is_clean(), "sibling shard must stay clean");

    // a wholly missing shard is also a file-level failure, not a panic
    std::fs::remove_file(&spath).unwrap();
    let report = shard_verify(&mpath, false, &engine).unwrap();
    assert!(report.entries[0].file.is_err());
    assert!(report.entries[1].is_clean());
}
