//! End-to-end fault-injection harness: scripted I/O faults
//! ([`cubismz::io::fault::FaultPlan`]) armed on the real `.czs` read
//! path, proving the integrity stack's contract — every fault is either
//! retried transparently, detected by a checksum, or salvaged around;
//! never a panic, a hang, or a silently wrong answer.
//!
//! The fault script is deterministic. `CZB_FAULT_SEED` (env) varies the
//! synthetic fields and the randomized fault placements so CI can sweep
//! seeds; any failure replays exactly by pinning the seed it printed.
use cubismz::core::Field3;
use cubismz::io::fault::FaultPlan;
use cubismz::pipeline::{
    verify_stream, CompressParams, CzbFile, Dataset, DatasetOptions, Engine,
};
use cubismz::util::prng::Pcg32;
use cubismz::util::prop::gen_smooth_field;
use std::io::ErrorKind;
use std::path::PathBuf;

/// The harness seed: `CZB_FAULT_SEED` when set (CI sweeps it), a fixed
/// default otherwise. Printed so a failing run is replayable.
fn seed() -> u64 {
    let s = std::env::var("CZB_FAULT_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE);
    eprintln!("fault harness: CZB_FAULT_SEED={s}");
    s
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("cubismz_fault_tests");
    std::fs::create_dir_all(&d).unwrap();
    d.join(name)
}

/// Write a two-quantity `.czs` archive (multiple chunks per section)
/// and return its path plus the clean per-quantity decodes that every
/// faulted run is compared against bit-for-bit.
fn build_archive(name: &str, seed: u64) -> (PathBuf, Vec<(String, Vec<f32>)>) {
    let path = tmp(name);
    let engine = Engine::builder().threads(2).chunk_bytes(32 << 10).build();
    let params = CompressParams::paper_default(1e-3);
    let n = 48;
    let mut writer = Dataset::create(&path).unwrap();
    for (i, q) in ["q0", "q1"].iter().enumerate() {
        let mut rng = Pcg32::new(seed ^ (i as u64 + 1));
        let f = Field3::from_vec(n, n, n, gen_smooth_field(&mut rng, n));
        writer.write_quantity(&engine, &f, q, &params).unwrap();
    }
    writer.finish().unwrap();
    let ds = Dataset::open(&path).unwrap();
    let baseline = engine
        .decompress_dataset(&ds, None)
        .unwrap()
        .into_iter()
        .map(|(name, field, file)| {
            assert!(file.chunks.len() > 2, "need multiple chunks, got {}", file.chunks.len());
            (name, field.data)
        })
        .collect();
    (path, baseline)
}

fn assert_bit_identical(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    assert!(
        a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
        "{what}: decode not bit-identical"
    );
}

#[test]
fn transient_errors_are_retried_transparently() {
    let seed = seed();
    let (path, baseline) = build_archive("transients.czs", seed);
    // a transient on the very first read (the trailer load) plus a few
    // more scattered over the early ops — each retry consumes its own
    // op index, so spacing by 2 never exceeds the per-read budget
    let mut rng = Pcg32::new(seed);
    let mut plan = FaultPlan::new().fail_op(0, ErrorKind::Interrupted);
    for i in 1..6 {
        let kind = if rng.next_u32() % 2 == 0 {
            ErrorKind::Interrupted
        } else {
            ErrorKind::WouldBlock
        };
        plan = plan.fail_op(i * 2 + (rng.next_u32() % 2) as usize, kind);
    }
    let ds = DatasetOptions::new().open_with_faults(&path, plan).unwrap();
    let engine = Engine::builder().threads(2).build();
    for (name, clean) in &baseline {
        let (field, _) = ds.read_quantity(name, &engine).unwrap();
        assert_bit_identical(clean, &field.data, name);
    }
    assert!(ds.faults_injected().unwrap() > 0, "the script never fired");
}

#[test]
fn short_reads_are_completed_by_the_retry_loop() {
    let seed = seed();
    let (path, baseline) = build_archive("short_reads.czs", seed);
    // ops 0 and 1 are the trailer loads, later ops land on header
    // prefixes and section reads; every short read must be continued
    // where it left off, whichever read it hits
    let mut rng = Pcg32::new(seed ^ 0x5);
    let mut plan = FaultPlan::new().short_read(0, 1).short_read(1, 2);
    for i in 2..8 {
        plan = plan.short_read(i, 1 + (rng.next_u32() % 7) as usize);
    }
    let ds = DatasetOptions::new().open_with_faults(&path, plan).unwrap();
    let engine = Engine::builder().threads(2).build();
    for (name, clean) in &baseline {
        let (field, _) = ds.read_quantity(name, &engine).unwrap();
        assert_bit_identical(clean, &field.data, name);
    }
    assert!(ds.faults_injected().unwrap() > 0, "the script never fired");
}

#[test]
fn persistent_transients_give_up_with_an_error_not_a_hang() {
    let seed = seed();
    let (path, _) = build_archive("persistent.czs", seed);
    // every one of the first 40 ops fails: the retry budget (8) must
    // run out and surface an error — Interrupted retries carry no
    // backoff sleep, so this is also fast
    let mut plan = FaultPlan::new();
    for op in 0..40 {
        plan = plan.fail_op(op, ErrorKind::Interrupted);
    }
    let err = DatasetOptions::new().open_with_faults(&path, plan).unwrap_err();
    assert!(err.contains("still failing"), "want retry-exhaustion error, got: {err}");
}

#[test]
fn truncation_surfaces_as_a_clean_error() {
    let seed = seed();
    let (path, _) = build_archive("truncated.czs", seed);
    let len = std::fs::metadata(&path).unwrap().len();
    // the trailer lives at the end of the archive, so any mid-file
    // truncation must fail the open — cleanly, naming the cause
    for cut in [0, 4, len / 2, len - 1] {
        let plan = FaultPlan::new().truncate_at(cut);
        let err = DatasetOptions::new().open_with_faults(&path, plan).unwrap_err();
        assert!(
            err.contains("truncated") || err.contains("not a .czs") || err.contains("czs"),
            "cut at {cut}: unhelpful error: {err}"
        );
    }
}

#[test]
fn bit_flips_are_detected_then_salvaged_at_every_thread_count() {
    let seed = seed();
    let (path, baseline) = build_archive("flips.czs", seed);
    let clean = Dataset::open(&path).unwrap();
    let entries: Vec<_> = clean.entries().to_vec();
    // flip one payload bit near the end of q0's section (clear of the
    // header-prefix reads `quantity_header` does)
    let q0 = &entries[0];
    let flip_at = q0.offset + q0.len - 5;
    let mut reference_corrupt: Option<Vec<usize>> = None;
    for threads in [1usize, 2, 4, 8] {
        let plan = FaultPlan::new().flip_bit(flip_at, 0x10);
        let ds = DatasetOptions::new().open_with_faults(&path, plan).unwrap();
        let engine = Engine::builder().threads(threads).build();
        // strict decode refuses the quantity: the section digest sees
        // the flip on first touch
        let err = ds.read_quantity("q0", &engine).unwrap_err();
        assert!(err.contains("digest mismatch"), "threads {threads}: {err}");
        // the sibling is untouched
        let (q1, _) = ds.read_quantity("q1", &engine).unwrap();
        assert_bit_identical(&baseline[1].1, &q1.data, "q1");
        // salvage decodes around the one corrupt chunk
        let salvaged = engine.decompress_dataset_salvage(&ds, None).unwrap();
        let (_, r0) = &salvaged[0];
        let (field0, _, rep0) = r0.as_ref().unwrap();
        assert!(!rep0.is_clean(), "threads {threads}: flip went undetected");
        assert_eq!(rep0.corrupt_chunks.len(), 1, "threads {threads}: {:?}", rep0.corrupt_chunks);
        assert!(rep0.corrupt_chunks[0].1.contains("checksum mismatch"));
        assert_eq!(field0.data.len(), baseline[0].1.len());
        // the corrupt chunk set is identical at every thread count
        let ids: Vec<usize> = rep0.corrupt_chunks.iter().map(|(i, _)| *i).collect();
        match &reference_corrupt {
            None => reference_corrupt = Some(ids),
            Some(want) => assert_eq!(&ids, want, "threads {threads}"),
        }
        let (_, r1) = &salvaged[1];
        let (field1, _, rep1) = r1.as_ref().unwrap();
        assert!(rep1.is_clean());
        assert_bit_identical(&baseline[1].1, &field1.data, "q1 salvage");
        assert!(ds.faults_injected().unwrap() > 0);
    }
}

#[test]
fn single_bit_flips_are_classified_by_region() {
    let seed = seed();
    // in-memory .czb: flips in each structural region must be
    // classified by the right checksum layer, at 1 and at 8 threads
    let n = 48;
    let mut rng = Pcg32::new(seed ^ 0x9E37);
    let f = Field3::from_vec(n, n, n, gen_smooth_field(&mut rng, n));
    let session = Engine::builder().threads(2).chunk_bytes(32 << 10).build();
    let (bytes, _) = session.compress_vec(&f, "p", &CompressParams::paper_default(1e-3));
    let (file, hsize) = CzbFile::parse_header(&bytes).unwrap();
    assert!(file.chunks.len() > 2);
    let regions = [
        ("fixed header", 7usize, "digest mismatch"),
        // a chunk-table entry (offset/len/rawsize of chunk 1)
        ("chunk table", hsize - file.chunks.len() * 4 - 4 - 12, "digest mismatch"),
        // the stored CRC column itself
        ("crc column", hsize - 4 - 2, "digest mismatch"),
        // last chunk's payload
        ("payload", bytes.len() - 3, "checksum mismatch"),
    ];
    for threads in [1usize, 8] {
        let engine = Engine::builder().threads(threads).build();
        for (region, at, want) in &regions {
            let mut bad = bytes.clone();
            bad[*at] ^= 0x04;
            let err = engine.decompress_bytes(&bad).unwrap_err();
            assert!(
                err.contains(want),
                "{region} flip at {at}, {threads} threads: want '{want}', got: {err}"
            );
            // verify agrees with decode on the classification: header
            // damage is unwalkable, payload damage is localized
            match verify_stream(&bad) {
                Ok(rep) => {
                    assert_eq!(*want, "checksum mismatch", "{region}: verify walked header damage");
                    assert_eq!(rep.corrupt_chunks.len(), 1, "{region}");
                }
                Err(e) => {
                    assert_eq!(*want, "digest mismatch", "{region}: verify refused payload damage");
                    assert!(e.contains(want), "{region}: {e}");
                }
            }
        }
    }
    // the czs trailer region: flipping the last stored section digest
    // byte parses fine but fails that section's first touch
    let path = tmp("trailer_flip.czs");
    {
        let mut w = Dataset::create(&path).unwrap();
        w.write_quantity(&session, &f, "p", &CompressParams::paper_default(1e-3)).unwrap();
        w.finish().unwrap();
    }
    let len = std::fs::metadata(&path).unwrap().len();
    // trailer tail is 12 bytes; the byte before it is the last byte of
    // the last entry's stored CRC32C
    for threads in [1usize, 8] {
        let plan = FaultPlan::new().flip_bit(len - 13, 0x80);
        let ds = DatasetOptions::new().open_with_faults(&path, plan).unwrap();
        let engine = Engine::builder().threads(threads).build();
        let err = ds.read_quantity("p", &engine).unwrap_err();
        assert!(err.contains("digest mismatch"), "trailer flip, {threads} threads: {err}");
    }
}

#[test]
fn seeded_transient_storm_never_corrupts_a_decode() {
    let seed = seed();
    let (path, baseline) = build_archive("storm.czs", seed);
    // a mixed storm: transients and short reads interleaved over the
    // early ops, placement drawn from the seed. Decodes must stay
    // bit-identical — a wrong answer here is the harness's red alarm.
    let mut rng = Pcg32::new(seed ^ 0xDEAD);
    let mut plan = FaultPlan::new().fail_op(0, ErrorKind::Interrupted);
    for i in 1..10 {
        let op = i * 2 + (rng.next_u32() % 2) as usize;
        plan = if rng.next_u32() % 2 == 0 {
            plan.fail_op(op, ErrorKind::Interrupted)
        } else {
            plan.short_read(op, 1 + (rng.next_u32() % 5) as usize)
        };
    }
    let ds = DatasetOptions::new().open_with_faults(&path, plan).unwrap();
    for threads in [1usize, 2, 4, 8] {
        let engine = Engine::builder().threads(threads).build();
        let decoded = engine.decompress_dataset(&ds, None).unwrap();
        for ((name, clean), (dname, field, _)) in baseline.iter().zip(&decoded) {
            assert_eq!(name, dname);
            assert_bit_identical(clean, &field.data, name);
        }
    }
    assert!(ds.faults_injected().unwrap() > 0, "the storm never fired");
}
