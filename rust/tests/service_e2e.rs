//! End-to-end service tests: real TCP sockets against `czb serve`'s
//! server type — concurrent clients sharing one engine, admission
//! backpressure, tenant quotas, priority lanes, corrupt-frame
//! isolation, and graceful drain.
use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use cubismz::core::Field3;
use cubismz::pipeline::{Bound, CompressParams, CzbFile, Engine, PipelineConfig, ShuffleMode};
use cubismz::service::metrics_export::sample;
use cubismz::service::proto::{Priority, Status};
use cubismz::service::{Client, Refusal, ServeConfig, Server, ServerHandle};

/// Start a server on an ephemeral loopback port; returns its address,
/// handle, and the thread running the accept loop.
fn start(cfg: ServeConfig) -> (SocketAddr, ServerHandle, std::thread::JoinHandle<()>) {
    let server = Server::bind(&cfg).expect("bind loopback");
    let addr = server.local_addr().unwrap();
    let handle = server.handle();
    let t = std::thread::spawn(move || server.run().expect("accept loop"));
    (addr, handle, t)
}

fn small_cfg() -> ServeConfig {
    ServeConfig { threads: 2, ..ServeConfig::default() }
}

fn field_for(seed: usize, n: usize) -> Field3 {
    let data = (0..n * n * n)
        .map(|i| (((i * 37 + seed * 101) % 251) as f32 * 0.13).sin())
        .collect();
    Field3::from_vec(n, n, n, data)
}

/// The params the server derives from a request: paper defaults with
/// the request's bs/eps/shuffle — what a local compress must use for
/// byte-identity.
fn server_params(bs: u32, eps: f32, shuffle: ShuffleMode) -> CompressParams {
    let mut p = CompressParams::from_config(&PipelineConfig::paper_default(eps));
    p.bs = bs as usize;
    p.shuffle = shuffle;
    p
}

fn unwrap_reply<T>(r: Result<Result<T, Refusal>, String>) -> T {
    r.expect("transport").expect("server refused")
}

/// A raw connection that has sent a request header declaring `body_len`
/// bytes but no body yet — it holds an admission permit open until the
/// body is sent (or the socket drops).
fn stall_permit(addr: SocketAddr, body_len: u64) -> TcpStream {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut hdr = [0u8; 16];
    hdr[..4].copy_from_slice(b"CZRQ");
    hdr[4] = 1; // version
    hdr[5] = 3; // verify
    hdr[6] = 0; // normal priority
    hdr[7] = 0; // anonymous tenant
    hdr[8..16].copy_from_slice(&body_len.to_le_bytes());
    s.write_all(&hdr).unwrap();
    s.flush().unwrap();
    // give the acceptor + handler time to park on the body read
    std::thread::sleep(Duration::from_millis(150));
    s
}

#[test]
fn four_concurrent_clients_get_bit_identical_roundtrips() {
    let (addr, handle, t) = start(small_cfg());
    let local = Arc::new(Engine::builder().threads(2).build());
    let workers: Vec<_> = (0..4)
        .map(|i| {
            let local = Arc::clone(&local);
            std::thread::spawn(move || {
                let field = field_for(i, 24);
                let shuffle = if i % 2 == 0 { ShuffleMode::Byte4 } else { ShuffleMode::None };
                let name = format!("q{i}");
                let mut c = Client::connect(addr).unwrap().tenant(&format!("tenant-{i}"));
                let czb = unwrap_reply(c.compress(&name, &field, 8, 1e-4, shuffle));
                // byte-identical to a local compress with the same params
                let (local_czb, _) =
                    local.compress_vec(&field, &name, &server_params(8, 1e-4, shuffle));
                assert_eq!(czb, local_czb, "client {i}: server stream differs from local");
                // remote decode matches local decode bit-for-bit
                let (rname, back) = unwrap_reply(c.decompress(&czb));
                assert_eq!(rname, name);
                let (lfield, _) = local.decompress_bytes(&czb).unwrap();
                assert_eq!(back.data, lfield.data, "client {i}: decode differs");
                // and the stream verifies clean remotely
                let summary = unwrap_reply(c.verify(&czb));
                assert!(summary.clean);
                assert_eq!(summary.corrupt_chunks, 0);
            })
        })
        .collect();
    for w in workers {
        w.join().expect("client thread");
    }
    // one shared engine served everything: counters prove it
    let mut c = Client::connect(addr).unwrap();
    let stat = unwrap_reply(c.stat());
    assert_eq!(sample(&stat, "czb_requests_total{op=\"compress\"}"), Some(4.0), "{stat}");
    assert_eq!(sample(&stat, "czb_requests_total{op=\"decompress\"}"), Some(4.0));
    assert_eq!(sample(&stat, "czb_requests_total{op=\"verify\"}"), Some(4.0));
    // the stat's own ok response is counted after the text is rendered
    assert_eq!(sample(&stat, "czb_responses_total{status=\"ok\"}"), Some(12.0));
    assert!(sample(&stat, "czb_engine_raw_bytes_total").unwrap() >= (4 * 24 * 24 * 24 * 4) as f64);
    assert!(
        sample(&stat, "czb_request_latency_seconds_count{op=\"compress\"}").unwrap() >= 4.0
    );
    assert_eq!(sample(&stat, "czb_queue_depth"), Some(0.0), "all permits returned");
    assert_eq!(sample(&stat, "czb_tenant_requests_total{tenant=\"tenant-0\"}"), Some(3.0), "{stat}");
    handle.shutdown();
    t.join().unwrap();
}

#[test]
fn bounded_compress_over_tcp_records_contract_and_psnr() {
    let (addr, handle, t) = start(small_cfg());
    let field = field_for(11, 24);
    let bound = Bound::Rel(1e-3);
    let mut c = Client::connect(addr).unwrap().tenant("sim-q");
    let czb = unwrap_reply(c.compress_bounded("p", &field, 8, 1e-4, ShuffleMode::Byte4, bound));
    // the returned stream carries the contract and the measured quality
    let (file, _) = CzbFile::parse_header(&czb).unwrap();
    assert_eq!(file.bound, bound);
    let q = file.achieved_quality().expect("v5 stream records quality");
    assert!(bound.check(&q).is_ok(), "{:?}", bound.check(&q));
    // and it still verifies clean remotely
    let summary = unwrap_reply(c.verify(&czb));
    assert!(summary.clean);
    // the tenant's achieved PSNR shows up in the live metrics
    let stat = unwrap_reply(c.stat());
    assert_eq!(
        sample(&stat, "czb_tenant_achieved_psnr_db_count{tenant=\"sim-q\"}"),
        Some(1.0),
        "{stat}"
    );
    assert!(
        sample(&stat, "czb_tenant_achieved_psnr_db_sum{tenant=\"sim-q\"}").unwrap() > 0.0
    );
    handle.shutdown();
    t.join().unwrap();
}

#[test]
fn saturated_admission_yields_busy_not_a_hang() {
    let cfg = ServeConfig {
        threads: 1,
        admit_normal: 1,
        admit_high_extra: 1,
        retry_after_ms: 77,
        ..ServeConfig::default()
    };
    let (addr, handle, t) = start(cfg);
    // park a request on the only normal slot
    let mut parked = stall_permit(addr, 64);
    // a normal-lane request is refused immediately with the retry hint
    let mut c = Client::connect(addr).unwrap();
    let refusal = c.verify(b"whatever").expect("transport").expect_err("must be refused");
    assert_eq!(refusal.status, Status::Busy);
    assert_eq!(refusal.retry_after_ms, 77);
    // the reserved lane still admits a high-priority request
    let mut hi = Client::connect(addr).unwrap().priority(Priority::High);
    let r = hi.verify(b"also not a czb").expect("transport");
    let refusal = r.expect_err("a garbage body is an error, not a refusal... ");
    assert_eq!(refusal.status, Status::Error, "high lane must have served the request");
    // release the parked permit: send the declared body, read the reply
    parked.write_all(&[0u8; 64]).unwrap();
    parked.flush().unwrap();
    // the slot frees up and normal requests serve again
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        match c.verify(b"still not a czb").expect("transport") {
            Err(r) if r.status == Status::Busy => {
                assert!(std::time::Instant::now() < deadline, "slot never freed");
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(r) => {
                assert_eq!(r.status, Status::Error);
                break;
            }
            Ok(_) => panic!("garbage cannot verify clean"),
        }
    }
    handle.shutdown();
    t.join().unwrap();
}

#[test]
fn tenant_quotas_throttle_then_refill() {
    let cfg = ServeConfig {
        threads: 1,
        quota_capacity: 4096,
        // slow enough that scheduler jitter between requests cannot
        // accidentally refill the 2048 bytes the follow-up needs
        // (~16 bytes/ms: a 2048-byte refill takes ~125ms)
        quota_rate: 16_384,
        ..ServeConfig::default()
    };
    let (addr, handle, t) = start(cfg);
    let mut a = Client::connect(addr).unwrap().tenant("sim-a");
    // drain the bucket with one full-capacity request (garbage body:
    // the quota charges on admission, not on decode success)
    let r = a.verify(&vec![1u8; 4096]).expect("transport");
    assert_eq!(r.expect_err("garbage").status, Status::Error);
    // an immediate follow-up is throttled with a retry hint
    let refusal = a.verify(&vec![1u8; 2048]).expect("transport").expect_err("throttled");
    assert_eq!(refusal.status, Status::Quota);
    assert!(refusal.retry_after_ms >= 1);
    // a different tenant is unaffected
    let mut b = Client::connect(addr).unwrap().tenant("sim-b");
    let r = b.verify(&vec![1u8; 2048]).expect("transport");
    assert_eq!(r.expect_err("garbage").status, Status::Error, "tenant b must be admitted");
    // after the hinted wait the bucket covers the request again
    std::thread::sleep(Duration::from_millis(refusal.retry_after_ms as u64 + 20));
    let r = a.verify(&vec![1u8; 2048]).expect("transport");
    assert_eq!(r.expect_err("garbage").status, Status::Error, "bucket must have refilled");
    // throttling is metered per tenant
    let stat = unwrap_reply(a.stat());
    assert_eq!(sample(&stat, "czb_tenant_throttled_total{tenant=\"sim-a\"}"), Some(1.0));
    assert_eq!(sample(&stat, "czb_tenant_throttled_total{tenant=\"sim-b\"}"), Some(0.0));
    assert_eq!(sample(&stat, "czb_responses_total{status=\"quota\"}"), Some(1.0));
    handle.shutdown();
    t.join().unwrap();
}

#[test]
fn corrupt_frame_on_one_connection_never_disturbs_siblings() {
    let (addr, handle, t) = start(small_cfg());
    let field = field_for(7, 24);
    let mut good = Client::connect(addr).unwrap();
    let czb = unwrap_reply(good.compress("q", &field, 8, 1e-4, ShuffleMode::Byte4));
    // sibling 1: pure garbage at the frame layer
    {
        let mut evil = TcpStream::connect(addr).unwrap();
        evil.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        evil.flush().unwrap();
    } // dropped: server answers bad_request and closes
      // sibling 2: valid magic, hostile declared length
    {
        let mut evil = TcpStream::connect(addr).unwrap();
        let mut hdr = [0u8; 16];
        hdr[..4].copy_from_slice(b"CZRQ");
        hdr[4] = 1;
        hdr[5] = 1;
        hdr[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        evil.write_all(&hdr).unwrap();
        evil.flush().unwrap();
    }
    // the good connection keeps serving across the sibling failures
    let (_, back) = unwrap_reply(good.decompress(&czb));
    assert_eq!(back.data.len(), field.data.len());
    let summary = unwrap_reply(good.verify(&czb));
    assert!(summary.clean);
    // both evil frames are rejected (their handlers run async — poll)
    let mut fresh = Client::connect(addr).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let stat = loop {
        let stat = unwrap_reply(fresh.stat());
        if sample(&stat, "czb_responses_total{status=\"bad_request\"}") >= Some(2.0) {
            break stat;
        }
        assert!(std::time::Instant::now() < deadline, "bad frames never rejected: {stat}");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(sample(&stat, "czb_queue_depth"), Some(0.0), "no permit leaked");
    handle.shutdown();
    t.join().unwrap();
}

#[test]
fn graceful_shutdown_drains_and_stops_accepting() {
    let (addr, handle, t) = start(small_cfg());
    let field = field_for(3, 16);
    let mut c = Client::connect(addr).unwrap();
    let czb = unwrap_reply(c.compress("q", &field, 8, 1e-3, ShuffleMode::None));
    assert!(!czb.is_empty());
    // a client-initiated shutdown acks, then refuses new work
    unwrap_reply(c.shutdown());
    assert!(handle.is_shutting_down());
    let refusal = c.compress("q", &field, 8, 1e-3, ShuffleMode::None);
    match refusal {
        Ok(Err(r)) => assert_eq!(r.status, Status::ShuttingDown),
        // the drain may already have closed the connection under us —
        // that is also a clean refusal, not a hang
        Err(_) => {}
        Ok(Ok(_)) => panic!("work admitted during drain"),
    }
    // the accept loop exits and the port closes
    t.join().unwrap();
    std::thread::sleep(Duration::from_millis(50));
    assert!(
        TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_err(),
        "listener must be closed after drain"
    );
}
