//! End-to-end CLI tests: drive the `czb` binary exactly as a user would
//! (gen -> compress -> info -> psnr -> decompress -> recompress).
use std::path::PathBuf;
use std::process::Command;

fn czb() -> Command {
    Command::new(env!("CARGO_BIN_EXE_czb"))
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("cubismz_cli_tests");
    std::fs::create_dir_all(&d).unwrap();
    d.join(name)
}

fn run_ok(cmd: &mut Command) -> String {
    let out = cmd.output().expect("spawn czb");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(
        out.status.success(),
        "command failed: {:?}\nstdout: {stdout}\nstderr: {}",
        cmd,
        String::from_utf8_lossy(&out.stderr)
    );
    stdout
}

#[test]
fn full_cli_flow() {
    let h5 = tmp("cli.h5l");
    let out = run_ok(czb().args([
        "gen", "--size", "64", "--step", "10000", "--out",
        h5.to_str().unwrap(),
    ]));
    assert!(out.contains("wrote"));
    assert!(h5.exists());

    let czb_file = tmp("cli_p.czb");
    let out = run_ok(czb().args([
        "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
        czb_file.to_str().unwrap(), "--eps", "1e-3", "--shuffle",
    ]));
    assert!(out.contains("CR"), "{out}");

    let out = run_ok(czb().args(["info", "--in", czb_file.to_str().unwrap()]));
    assert!(out.contains("dataset     : p"), "{out}");
    assert!(out.contains("64x64x64"), "{out}");

    let out = run_ok(czb().args([
        "psnr", "--ref", h5.to_str().unwrap(), "--dataset", "p", "--in",
        czb_file.to_str().unwrap(),
    ]));
    let db: f64 = out
        .trim()
        .strip_prefix("PSNR ")
        .and_then(|s| s.strip_suffix(" dB"))
        .unwrap()
        .parse()
        .unwrap();
    assert!(db > 50.0, "psnr {db}");

    let h5_out = tmp("cli_p_out.h5l");
    run_ok(czb().args([
        "decompress", "--in", czb_file.to_str().unwrap(), "--out",
        h5_out.to_str().unwrap(),
    ]));
    assert!(h5_out.exists());

    let re = tmp("cli_p_zfp.czb");
    let out = run_ok(czb().args([
        "recompress", "--in", czb_file.to_str().unwrap(), "--out", re.to_str().unwrap(),
        "--scheme", "zfp", "--eps", "1e-3", "--stage2", "none",
    ]));
    assert!(out.contains("CR"), "{out}");
    let out = run_ok(czb().args(["info", "--in", re.to_str().unwrap()]));
    assert!(out.contains("Zfp"), "{out}");
}

#[test]
fn cli_simd_env_never_changes_output_or_faults() {
    // every CZB_SIMD value — including levels this host may not have and
    // outright garbage — must run fine (unavailable levels clamp to
    // scalar, never fault) and produce byte-identical archives
    let h5 = tmp("cli_simd.h5l");
    run_ok(czb().args([
        "gen", "--size", "32", "--step", "5000", "--out", h5.to_str().unwrap(),
    ]));
    let mut reference: Option<Vec<u8>> = None;
    for mode in ["auto", "scalar", "avx2", "neon", "bogus"] {
        let out_file = tmp(&format!("cli_simd_{mode}.czb"));
        run_ok(czb().env("CZB_SIMD", mode).args([
            "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
            out_file.to_str().unwrap(), "--eps", "1e-3", "--threads", "4",
        ]));
        let bytes = std::fs::read(&out_file).unwrap();
        match &reference {
            None => reference = Some(bytes),
            Some(r) => assert_eq!(r, &bytes, "CZB_SIMD={mode} changed the archive"),
        }
        let info = run_ok(czb().env("CZB_SIMD", mode).args([
            "info", "--in", out_file.to_str().unwrap(),
        ]));
        assert!(info.contains("host simd"), "{info}");
    }
}

#[test]
fn cli_dataset_flow() {
    let h5 = tmp("cli_ds.h5l");
    run_ok(czb().args([
        "gen", "--size", "64", "--step", "5000", "--out", h5.to_str().unwrap(),
    ]));

    // all quantities through one Engine session into one archive
    let czs = tmp("cli_ds.czs");
    let out = run_ok(czb().args([
        "compress-dataset", "--in", h5.to_str().unwrap(), "--out", czs.to_str().unwrap(),
        "--eps", "1e-3", "--shuffle", "--threads", "2",
    ]));
    assert!(out.contains("4 quantities"), "{out}");
    assert!(czs.exists());

    let out = run_ok(czb().args(["info", "--in", czs.to_str().unwrap()]));
    assert!(out.contains("czs dataset archive"), "{out}");
    assert!(out.contains("quantities  : 4"), "{out}");
    assert!(out.contains("total CR"), "{out}");

    let h5_back = tmp("cli_ds_out.h5l");
    let out = run_ok(czb().args([
        "decompress-dataset", "--in", czs.to_str().unwrap(), "--out",
        h5_back.to_str().unwrap(), "--threads", "2",
    ]));
    assert!(out.contains("4 quantities"), "{out}");
    assert!(h5_back.exists());

    // subset selection
    let czs_sub = tmp("cli_ds_sub.czs");
    let out = run_ok(czb().args([
        "compress-dataset", "--in", h5.to_str().unwrap(), "--out", czs_sub.to_str().unwrap(),
        "--qoi", "p,rho",
    ]));
    assert!(out.contains("2 quantities"), "{out}");
}

#[test]
fn cli_shuffle_modes() {
    let h5 = tmp("cli_shuf.h5l");
    run_ok(czb().args([
        "gen", "--size", "32", "--step", "5000", "--out", h5.to_str().unwrap(), "--qoi", "p",
    ]));
    for mode in ["byte4", "bit4", "none"] {
        let f = tmp(&format!("cli_shuf_{mode}.czb"));
        run_ok(czb().args([
            "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
            f.to_str().unwrap(), "--shuffle", mode,
        ]));
        let info = run_ok(czb().args(["info", "--in", f.to_str().unwrap()]));
        let expect = match mode {
            "byte4" => "Byte4",
            "bit4" => "Bit4",
            _ => "None",
        };
        assert!(info.contains(expect), "mode {mode}: {info}");
        let back = tmp(&format!("cli_shuf_{mode}.h5l"));
        run_ok(czb().args([
            "decompress", "--in", f.to_str().unwrap(), "--out", back.to_str().unwrap(),
        ]));
    }
    // unknown mode is rejected
    let st = czb()
        .args([
            "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
            tmp("x.czb").to_str().unwrap(), "--shuffle", "bitplane",
        ])
        .output()
        .unwrap();
    assert!(!st.status.success());
}

#[test]
fn cli_rejects_bad_input() {
    let st = czb().args(["compress", "--in", "/nonexistent.h5l"]).output().unwrap();
    assert!(!st.status.success());
    let st = czb().args(["bogus-command"]).output().unwrap();
    assert!(!st.status.success());
    let st = czb().output().unwrap();
    assert!(!st.status.success());
}

#[test]
fn cli_all_schemes_produce_valid_files() {
    let h5 = tmp("cli_schemes.h5l");
    run_ok(czb().args([
        "gen", "--size", "32", "--step", "5000", "--out", h5.to_str().unwrap(), "--qoi", "rho",
    ]));
    for (i, (scheme, extra)) in [
        ("wavelet", vec!["--wavelet", "w4"]),
        ("wavelet", vec!["--wavelet", "w4l", "--zbits", "4"]),
        ("zfp", vec![]),
        ("sz", vec![]),
        ("fpzip", vec!["--prec", "20"]),
        ("fpzip-lossless", vec![]),
        ("copy", vec!["--stage2", "lzma"]),
    ]
    .into_iter()
    .enumerate()
    {
        let out_file = tmp(&format!("cli_{scheme}_{i}.czb"));
        let mut cmd = czb();
        cmd.args([
            "compress", "--in", h5.to_str().unwrap(), "--dataset", "rho", "--out",
            out_file.to_str().unwrap(), "--scheme", scheme,
        ]);
        for e in &extra {
            cmd.arg(e);
        }
        run_ok(&mut cmd);
        run_ok(czb().args(["info", "--in", out_file.to_str().unwrap()]));
        // every scheme must round-trip through decompress
        let back = tmp(&format!("cli_{scheme}_{i}.h5l"));
        run_ok(czb().args([
            "decompress", "--in", out_file.to_str().unwrap(), "--out", back.to_str().unwrap(),
        ]));
    }
}

#[test]
fn cli_bound_contract_flow() {
    // compress under a relative error-bound contract: the scheme is
    // auto-picked, the contract + achieved quality land in the stream,
    // and verify --bounds signs off on it
    let h5 = tmp("cli_bound.h5l");
    run_ok(czb().args([
        "gen", "--size", "32", "--step", "5000", "--out", h5.to_str().unwrap(), "--qoi", "p",
    ]));
    let f = tmp("cli_bound.czb");
    let out = run_ok(czb().args([
        "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
        f.to_str().unwrap(), "--rel-err", "1e-3",
    ]));
    assert!(out.contains("CR"), "{out}");

    let info = run_ok(czb().args(["info", "--in", f.to_str().unwrap()]));
    assert!(info.contains("bound       : rel-err <= 1e-3"), "{info}");
    assert!(info.contains("within contract"), "{info}");

    let st = czb().args(["verify", "--in", f.to_str().unwrap(), "--bounds"]).output().unwrap();
    assert_eq!(st.status.code(), Some(0), "{}", String::from_utf8_lossy(&st.stdout));
    let vout = String::from_utf8_lossy(&st.stdout);
    assert!(vout.contains("contract rel-err <= 1e-3"), "{vout}");

    // the decoded field must actually honor the bound end to end
    let back = tmp("cli_bound.h5l.out");
    run_ok(czb().args([
        "decompress", "--in", f.to_str().unwrap(), "--out", back.to_str().unwrap(),
    ]));

    // an explicit scheme that cannot honor the bound is a hard error
    let st = czb()
        .args([
            "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
            tmp("cli_bound_bad.czb").to_str().unwrap(), "--rel-err", "1e-3",
            "--scheme", "wavelet",
        ])
        .output()
        .unwrap();
    assert!(!st.status.success());
    let err = String::from_utf8_lossy(&st.stderr);
    assert!(err.contains("cannot honor"), "{err}");

    // a lossless contract round-trips bit-exactly through fpzip
    let fl = tmp("cli_bound_lossless.czb");
    run_ok(czb().args([
        "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
        fl.to_str().unwrap(), "--lossless",
    ]));
    let info = run_ok(czb().args(["info", "--in", fl.to_str().unwrap()]));
    assert!(info.contains("bound       : lossless"), "{info}");
    let st = czb().args(["verify", "--in", fl.to_str().unwrap(), "--bounds"]).output().unwrap();
    assert_eq!(st.status.code(), Some(0));
}

#[test]
fn cli_rejects_bad_tolerances() {
    let h5 = tmp("cli_badtol.h5l");
    run_ok(czb().args([
        "gen", "--size", "32", "--step", "5000", "--out", h5.to_str().unwrap(), "--qoi", "p",
    ]));
    let out_file = tmp("cli_badtol.czb");
    let _ = std::fs::remove_file(&out_file); // stale runs must not fake a pass
    // negative, NaN and non-numeric tolerances must all be rejected up
    // front — for the legacy knob and for every contract flag
    for bad in [
        vec!["--eps", "-1"],
        vec!["--eps", "NaN"],
        vec!["--abs-err", "-1e-3"],
        vec!["--rel-err", "0"],
        vec!["--rel-err", "inf"],
        vec!["--psnr", "-40"],
        vec!["--psnr", "nan"],
        // a contract and the raw knob together are ambiguous
        vec!["--eps", "1e-3", "--rel-err", "1e-3"],
        // contracts are mutually exclusive
        vec!["--abs-err", "1e-3", "--rel-err", "1e-3"],
    ] {
        let mut cmd = czb();
        cmd.args([
            "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
            out_file.to_str().unwrap(),
        ]);
        for b in &bad {
            cmd.arg(b);
        }
        let st = cmd.output().unwrap();
        assert!(!st.status.success(), "{bad:?} must be rejected");
        assert!(!out_file.exists(), "{bad:?} must not write output");
    }
}

#[test]
fn cli_verify_bounds_exit_codes() {
    // the three verify --bounds outcomes: 0 = contract met, 3 = contract
    // violated (integrity still intact), 1 = unreadable input
    let h5 = tmp("cli_vb.h5l");
    run_ok(czb().args([
        "gen", "--size", "32", "--step", "5000", "--out", h5.to_str().unwrap(), "--qoi", "p",
    ]));
    let f = tmp("cli_vb.czb");
    run_ok(czb().args([
        "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
        f.to_str().unwrap(), "--rel-err", "1e-2",
    ]));
    let st = czb().args(["verify", "--in", f.to_str().unwrap(), "--bounds"]).output().unwrap();
    assert_eq!(st.status.code(), Some(0));

    // forge a violation: tighten the recorded contract far below what the
    // stream achieved, then re-seal the header digest so integrity checks
    // still pass — only the bound check can catch it
    let mut bytes = std::fs::read(&f).unwrap();
    let (file, hsize) = cubismz::pipeline::CzbFile::parse_header(&bytes).unwrap();
    assert_eq!(file.bound, cubismz::pipeline::Bound::Rel(1e-2));
    let bound_off = hsize - 4 - file.chunks.len() * 12 - 9;
    bytes[bound_off + 1..bound_off + 9].copy_from_slice(&1e-12f64.to_le_bytes());
    let digest = cubismz::util::crc32c::crc32c(&bytes[..hsize - 4]);
    bytes[hsize - 4..hsize].copy_from_slice(&digest.to_le_bytes());
    let forged = tmp("cli_vb_violated.czb");
    std::fs::write(&forged, &bytes).unwrap();

    // plain verify: integrity is fine, exit 0
    let st = czb().args(["verify", "--in", forged.to_str().unwrap()]).output().unwrap();
    assert_eq!(st.status.code(), Some(0), "{}", String::from_utf8_lossy(&st.stdout));
    // --bounds: the achieved quality exceeds the (forged) contract, exit 3
    let st =
        czb().args(["verify", "--in", forged.to_str().unwrap(), "--bounds"]).output().unwrap();
    assert_eq!(st.status.code(), Some(3), "{}", String::from_utf8_lossy(&st.stdout));
    let out = String::from_utf8_lossy(&st.stdout);
    assert!(out.contains("BOUND VIOLATED"), "{out}");

    // unreadable input is exit 1, same as plain verify
    let garbage = tmp("cli_vb_garbage.czb");
    std::fs::write(&garbage, b"not a czb stream at all").unwrap();
    let st =
        czb().args(["verify", "--in", garbage.to_str().unwrap(), "--bounds"]).output().unwrap();
    assert_eq!(st.status.code(), Some(1));
}

#[test]
fn cli_tune_beats_or_matches_the_default_mapping() {
    // tune must report a configuration per quantity, and its pick can
    // never compress worse than the untuned default mapping (the ladder
    // always includes factor 1.0 = the plain conservative mapping)
    let st = czb()
        .args(["tune", "--rel-err", "1e-3", "--size", "32", "--qoi", "p", "--threads", "2"])
        .output()
        .unwrap();
    let out = String::from_utf8_lossy(&st.stdout).into_owned();
    assert!(st.status.success(), "{out}\n{}", String::from_utf8_lossy(&st.stderr));
    assert!(out.contains("--scheme"), "{out}");
    let tuned_cr: f64 = out
        .lines()
        .find(|l| l.contains("--scheme"))
        .and_then(|l| l.split("CR ").nth(1))
        .and_then(|s| s.split_whitespace().next())
        .unwrap()
        .parse()
        .unwrap();

    // untuned default for the same contract on the same probe field
    let h5 = tmp("cli_tune.h5l");
    run_ok(czb().args([
        "gen", "--size", "32", "--step", "5000", "--out", h5.to_str().unwrap(), "--qoi", "p",
    ]));
    let f = tmp("cli_tune.czb");
    let out = run_ok(czb().args([
        "compress", "--in", h5.to_str().unwrap(), "--dataset", "p", "--out",
        f.to_str().unwrap(), "--rel-err", "1e-3", "--threads", "2",
    ]));
    let default_cr: f64 = out
        .lines()
        .find(|l| l.contains("CR"))
        .and_then(|l| l.split("CR ").nth(1))
        .and_then(|s| s.split_whitespace().next())
        .unwrap()
        .parse()
        .unwrap();
    assert!(
        tuned_cr >= default_cr * 0.999,
        "tuned CR {tuned_cr} worse than default {default_cr}"
    );

    // a tune without a contract is an error
    let st = czb().args(["tune", "--size", "32"]).output().unwrap();
    assert!(!st.status.success());

    // codecs lists the stage-1 registry with honored bound kinds
    let out = run_ok(czb().args(["codecs"]));
    assert!(out.contains("stage-1"), "{out}");
    assert!(out.contains("honors"), "{out}");
}

#[test]
fn cli_unknown_flags_are_usage_errors() {
    // a typo'd flag must exit 2 with a usage message, not run silently
    for argv in [
        vec!["compress", "--in", "x.h5l", "--dataset", "p", "--out", "x.czb", "--treads", "8"],
        vec!["gen", "--size", "32", "--out", "x.h5l", "--paper"],
        vec!["verify", "--in", "x.czb", "--deeply"],
        vec!["info", "--in", "x.czb", "--cache", "4"],
        vec!["serve", "--port", "9321"],
        vec!["client", "--op", "stat", "--address", "127.0.0.1:1"],
        vec!["codecs", "--verbose"],
    ] {
        let out = czb().args(&argv).output().unwrap();
        assert_eq!(out.status.code(), Some(2), "{argv:?} must exit 2 (usage)");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("unknown flag"), "{argv:?}: {err}");
        assert!(err.contains("USAGE"), "{argv:?} must print usage");
    }
    // known flags still pass flag validation (codecs takes none at all)
    let out = czb().args(["codecs"]).output().unwrap();
    assert!(out.status.success());
    // usage documents the service front-end
    let out = czb().output().unwrap();
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("serve"), "usage must document serve: {err}");
    assert!(err.contains("shutdown frame drains"), "{err}");
}

#[test]
fn cli_help_enumerates_every_subcommand() {
    // `czb help` exits 0 and prints the usage on stdout, ending in a
    // machine-checkable `commands:` line. Every command on that line
    // must be documented in the usage body AND be a real registered
    // command — probed by sending it a bogus flag, which a registered
    // command rejects as a *flag* error (exit 2, "unknown flag"), never
    // as an unknown command. This pins usage text to the dispatch table
    // so a new subcommand can't ship undocumented.
    let out = czb().args(["help"]).output().unwrap();
    assert_eq!(out.status.code(), Some(0), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    let line = text
        .lines()
        .find(|l| l.starts_with("commands: "))
        .expect("usage must carry a commands: line");
    let commands: Vec<&str> =
        line.trim_start_matches("commands: ").split_whitespace().collect();
    // the full surface, not a subset: all the flows plus the shard ops
    for must in
        ["compress", "decompress", "verify", "tune", "serve", "client", "shard-compress",
         "shard-decompress", "shard-verify", "help", "info", "codecs"]
    {
        assert!(commands.contains(&must), "commands line is missing {must}: {line}");
    }
    let body = text.split("commands: ").next().unwrap();
    for cmd in &commands {
        assert!(body.contains(*cmd), "usage body does not document {cmd}");
        let probe = czb().args([*cmd, "--bogus-flag-zz"]).output().unwrap();
        assert_eq!(probe.status.code(), Some(2), "{cmd} flag probe");
        let err = String::from_utf8_lossy(&probe.stderr);
        assert!(err.contains("unknown flag"), "{cmd}: {err}");
        assert!(!err.contains("unknown command"), "{cmd} is listed but not registered: {err}");
    }
}

#[test]
fn cli_shard_roundtrip_over_spawned_workers() {
    let h5 = tmp("cli_shard.h5l");
    run_ok(czb().args([
        "gen", "--size", "32", "--step", "5000", "--out", h5.to_str().unwrap(),
    ]));

    // reference: offline single-archive flow with the server's pipeline
    // (workers compress with stage2 zlib-def — proven equivalent by the
    // service protocol contract)
    let czs = tmp("cli_shard_ref.czs");
    run_ok(czb().args([
        "compress-dataset", "--in", h5.to_str().unwrap(), "--out", czs.to_str().unwrap(),
        "--stage2", "zlib-def", "--threads", "2",
    ]));
    let ref_h5 = tmp("cli_shard_ref.h5l");
    run_ok(czb().args([
        "decompress-dataset", "--in", czs.to_str().unwrap(), "--out",
        ref_h5.to_str().unwrap(), "--threads", "2",
    ]));
    let reference = std::fs::read(&ref_h5).unwrap();

    // scatter across 2 spawned czb-serve workers
    let czm = tmp("cli_shard.czm");
    let out = run_ok(czb().args([
        "shard-compress", "--in", h5.to_str().unwrap(), "--out", czm.to_str().unwrap(),
        "--shards", "2", "--worker-threads", "2",
    ]));
    assert!(out.contains("2 shards"), "{out}");
    assert!(czm.exists());

    // manifest-aware info: shards listed and present
    let info = run_ok(czb().args(["info", "--in", czm.to_str().unwrap()]));
    assert!(info.contains("czm shard manifest"), "{info}");
    assert!(info.contains("quantities  : 4"), "{info}");
    assert!(info.contains("present"), "{info}");
    assert!(!info.contains("MISSING"), "{info}");

    // shard-verify signs off
    let st = czb().args(["shard-verify", "--in", czm.to_str().unwrap()]).output().unwrap();
    assert_eq!(
        st.status.code(),
        Some(0),
        "{}{}",
        String::from_utf8_lossy(&st.stdout),
        String::from_utf8_lossy(&st.stderr)
    );

    // gather at every tested thread count: the .h5l coming back must be
    // byte-identical to the unsharded reference flow
    for threads in ["1", "2", "4", "8"] {
        let back = tmp(&format!("cli_shard_back_{threads}.h5l"));
        run_ok(czb().args([
            "shard-decompress", "--in", czm.to_str().unwrap(), "--out",
            back.to_str().unwrap(), "--threads", threads,
        ]));
        assert_eq!(
            std::fs::read(&back).unwrap(),
            reference,
            "sharded gather differs from the unsharded flow at {threads} threads"
        );
    }

    // kill one shard: the gather degrades to salvage (exit 3) with the
    // other shard's quantities still bit-identical and the lost ones
    // zero-filled — never a hard failure
    let shard1 = tmp("cli_shard.shard1.czs");
    assert!(shard1.exists(), "expected shard file next to the manifest");
    std::fs::remove_file(&shard1).unwrap();
    let damaged = tmp("cli_shard_damaged.h5l");
    let st = czb()
        .args([
            "shard-decompress", "--in", czm.to_str().unwrap(), "--out",
            damaged.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert_eq!(st.status.code(), Some(3), "{}", String::from_utf8_lossy(&st.stdout));
    let stdout = String::from_utf8_lossy(&st.stdout);
    assert!(stdout.contains("LOST"), "{stdout}");
    let all = cubismz::io::h5lite::read_all(&damaged).unwrap();
    let refall = cubismz::io::h5lite::read_all(&ref_h5).unwrap();
    assert_eq!(all.len(), refall.len());
    let (mut intact, mut zeroed) = (0usize, 0usize);
    for (d, r) in all.iter().zip(&refall) {
        assert_eq!(d.name, r.name, "quantity order must follow the manifest");
        if d.data.iter().all(|v| v.to_bits() == 0) {
            zeroed += 1;
        } else {
            assert!(
                d.data.iter().zip(&r.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{} neither intact nor zero-filled",
                d.name
            );
            intact += 1;
        }
    }
    assert!(intact > 0, "surviving shard's quantities must decode intact");
    assert!(zeroed > 0, "lost shard's quantities must zero-fill");

    // and the verifier now flags the dataset
    let st = czb().args(["shard-verify", "--in", czm.to_str().unwrap()]).output().unwrap();
    assert_eq!(st.status.code(), Some(3), "{}", String::from_utf8_lossy(&st.stdout));

    // info survives the missing shard and says so
    let info = run_ok(czb().args(["info", "--in", czm.to_str().unwrap()]));
    assert!(info.contains("MISSING"), "{info}");
}
