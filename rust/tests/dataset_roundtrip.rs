//! Integration: a whole simulation step — every QoI — through one
//! Engine session into a `.czs` archive on disk, then back: whole-
//! quantity decode, PSNR fidelity, and random access to a single
//! quantity/block without touching the rest of the archive.
use cubismz::core::block::{Block, BlockGrid};
use cubismz::metrics::psnr;
use cubismz::pipeline::{CompressParams, Dataset, Engine, NativeEngine, ShuffleMode};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("cubismz_dataset_tests");
    std::fs::create_dir_all(&d).unwrap();
    d.join(name)
}

#[test]
fn multi_quantity_archive_roundtrips_with_random_access() {
    let n = 64;
    let sim = CloudSim::new(CloudConfig::paper(n));
    let t = step_to_time(5000);
    let engine = Engine::builder().threads(4).chunk_bytes(64 << 10).build();
    let params = CompressParams::paper_default(1e-3);

    // one session, one archive, all four QoIs
    let path = tmp("step5000.czs");
    let mut writer = Dataset::create(&path).unwrap();
    for qoi in Qoi::ALL {
        let f = sim.field(qoi, t);
        let st = writer.write_quantity(&engine, &f, qoi.name(), &params).unwrap();
        assert!(st.ratio() > 2.0, "{qoi:?} ratio {}", st.ratio());
    }
    writer.finish().unwrap();

    let ds = Dataset::open(&path).unwrap();
    let names: Vec<&str> = ds.names();
    assert_eq!(names, Qoi::ALL.map(|q| q.name()).to_vec());

    // whole-quantity decode matches the original within the eps bound
    for qoi in Qoi::ALL {
        let f = sim.field(qoi, t);
        let (back, file) = ds.read_quantity(qoi.name(), &engine).unwrap();
        assert_eq!(file.name, qoi.name());
        assert_eq!((back.nx, back.ny, back.nz), (n, n, n));
        let p = psnr(&f.data, &back.data).unwrap();
        assert!(p > 45.0, "{qoi:?} psnr {p}");
    }

    // random access to a single quantity/block: a BlockReader over the
    // pressure section decodes exactly the blocks we ask for and agrees
    // with the whole-field decode bit-for-bit
    let (full, file) = ds.read_quantity("p", &engine).unwrap();
    let bs = file.bs as usize;
    let grid = BlockGrid::new(&full, bs);
    let weng = NativeEngine;
    let mut reader = ds.block_reader("p", &weng).unwrap();
    let mut blk = vec![0f32; bs * bs * bs];
    let mut expected = Block::zeros(bs);
    for id in [0u32, 1, file.nblocks / 2, file.nblocks - 1] {
        reader.read_block(id, &mut blk).unwrap();
        grid.extract(&full, id as usize, &mut expected);
        assert!(
            blk.iter().zip(&expected.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "block {id}"
        );
    }
    assert!(reader.read_block(file.nblocks, &mut blk).is_err());

    // quantity headers are independent .czb headers
    let q = ds.quantity_header("rho").unwrap();
    assert_eq!(q.name, "rho");
    assert_eq!(q.bs as usize, 32);
}

#[test]
fn archive_sections_are_byte_identical_to_single_quantity_streams() {
    // repackaging guarantee: the .czs container adds framing around
    // byte-identical .czb sections, for every shuffle mode
    let sim = CloudSim::new(CloudConfig::paper(32));
    let f = sim.field(Qoi::Pressure, step_to_time(5000));
    for shuffle in [ShuffleMode::None, ShuffleMode::Byte4, ShuffleMode::Bit4] {
        let engine = Engine::builder().threads(2).build();
        let params = CompressParams::paper_default(1e-3).with_shuffle(shuffle);
        let (direct, _) = engine.compress_vec(&f, "p", &params);
        let mut writer = cubismz::pipeline::DatasetWriter::new(Vec::<u8>::new()).unwrap();
        writer.write_quantity(&engine, &f, "p", &params).unwrap();
        let ds = Dataset::from_bytes(writer.finish().unwrap()).unwrap();
        assert_eq!(ds.section("p").unwrap(), &direct[..], "{shuffle:?}");
    }
}
