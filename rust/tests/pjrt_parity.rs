//! Cross-layer integration: the PJRT-executed Pallas kernel (L1, AOT via
//! L2) must match the native Rust engine (L3) on the same blocks, and both
//! must match the python-exported test vectors. Requires `make artifacts`.
use cubismz::pipeline::{NativeEngine, WaveletEngine};
use cubismz::runtime::{default_artifacts_dir, PjrtEngine, ARTIFACT_BS};
use cubismz::util::prng::Pcg32;
use cubismz::wavelet::{max_levels, WaveletKind};

/// The PJRT engine, when both the artifacts exist and the build carries
/// the real runtime (default builds ship a stub whose constructor fails —
/// skip, don't panic).
fn pjrt_engine() -> Option<PjrtEngine> {
    if !default_artifacts_dir().join("wavelet_fwd_w3a_b32_n1.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    match PjrtEngine::new(default_artifacts_dir()) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping: pjrt engine unavailable: {e}");
            None
        }
    }
}

fn rel_close(a: &[f32], b: &[f32], scale: f32, tol: f32) -> Result<(), String> {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * scale {
            return Err(format!("idx {i}: {x} vs {y} (scale {scale})"));
        }
    }
    Ok(())
}

#[test]
fn pjrt_matches_native_forward_and_inverse() {
    let engine = match pjrt_engine() {
        Some(e) => e,
        None => return,
    };
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
    let vol = ARTIFACT_BS * ARTIFACT_BS * ARTIFACT_BS;
    let mut rng = Pcg32::new(0xABCD);
    // n = 19 exercises both the 16-wide chunk and the single-block path
    let n = 19;
    let mut data = vec![0f32; n * vol];
    rng.fill_f32(&mut data, -80.0, 80.0);
    for kind in WaveletKind::ALL {
        let mut pjrt = data.clone();
        let mut native = data.clone();
        engine.forward_batch(kind, &mut pjrt, ARTIFACT_BS, max_levels(ARTIFACT_BS));
        NativeEngine.forward_batch(kind, &mut native, ARTIFACT_BS, max_levels(ARTIFACT_BS));
        rel_close(&pjrt, &native, 80.0, 2e-5)
            .unwrap_or_else(|e| panic!("{kind:?} forward: {e}"));
        engine.inverse_batch(kind, &mut pjrt, ARTIFACT_BS, max_levels(ARTIFACT_BS));
        rel_close(&pjrt, &data, 80.0, 5e-5)
            .unwrap_or_else(|e| panic!("{kind:?} roundtrip: {e}"));
    }
}

#[test]
fn native_matches_python_test_vectors() {
    let tv_dir = default_artifacts_dir().join("testvectors");
    if !tv_dir.is_dir() {
        eprintln!("skipping: test vectors not built");
        return;
    }
    for kind in WaveletKind::ALL {
        let path = tv_dir.join(format!("wavelet_{}_b32.bin", kind.artifact_tag()));
        let bytes = std::fs::read(&path).expect("test vector file");
        let bs = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        assert_eq!(bs, ARTIFACT_BS);
        let vol = bs * bs * bs;
        let floats: Vec<f32> = bytes[8..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(floats.len(), 2 * n * vol, "vector payload size");
        let (input, expected) = floats.split_at(n * vol);
        let mut got = input.to_vec();
        NativeEngine.forward_batch(kind, &mut got, bs, max_levels(bs));
        rel_close(&got, expected, 50.0, 2e-5)
            .unwrap_or_else(|e| panic!("{kind:?} vs python vectors: {e}"));
    }
}

#[test]
fn pipeline_with_pjrt_engine_end_to_end() {
    let engine = match pjrt_engine() {
        Some(e) => e,
        None => return,
    };
    use cubismz::core::Field3;
    use cubismz::metrics::psnr;
    use cubismz::pipeline::{compress_field, decompress_field, PipelineConfig};
    let mut rng = Pcg32::new(7);
    let n = 64;
    let f = Field3::from_vec(n, n, n, cubismz::util::prop::gen_smooth_field(&mut rng, n));
    let cfg = PipelineConfig::paper_default(1e-3);
    let (bytes_pjrt, st_pjrt) = compress_field(&f, "p", &cfg, &engine);
    let (bytes_native, st_native) = compress_field(&f, "p", &cfg, &NativeEngine);
    // engines agree on compressibility (identical spec; tiny fp skew can
    // move a coefficient across the threshold, so sizes are near-equal,
    // not byte-identical)
    let ratio = bytes_pjrt.len() as f64 / bytes_native.len() as f64;
    assert!((0.98..1.02).contains(&ratio), "size skew {ratio}");
    assert_eq!(st_pjrt.nblocks, st_native.nblocks);
    // decompress the pjrt-compressed stream with the native engine
    let (back, _) = decompress_field(&bytes_pjrt, &NativeEngine).unwrap();
    let p = psnr(&f.data, &back.data).unwrap();
    assert!(p > 40.0, "psnr {p}");
}
