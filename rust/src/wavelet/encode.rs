//! Substage-1 encoder for wavelet coefficients: ε-decimation of detail
//! coefficients, significance bit-mask + packed f32 stream (paper §2.3),
//! optional bit-zeroing of least-significant mantissa bits (Z4/Z8).
//!
//! Block wire format (little endian):
//! `[u32 nsig][bs³/8 bytes mask][nsig × f32 coefficients]`
//! The coarse (bs>>levels)³ cube is always kept so the reconstruction
//! baseline survives arbitrary thresholds.

/// Encoder statistics for one block.
#[derive(Clone, Copy, Debug, Default)]
pub struct EncodedStats {
    pub nsig: usize,
    pub total: usize,
}

/// Size in bytes of an encoded block with `nsig` significant coefficients.
pub fn encoded_size(bs: usize, nsig: usize) -> usize {
    4 + (bs * bs * bs) / 8 + 4 * nsig
}

#[inline]
fn is_coarse(i: usize, bs: usize, coarse: usize) -> bool {
    let x = i % bs;
    let y = (i / bs) % bs;
    let z = i / (bs * bs);
    x < coarse && y < coarse && z < coarse
}

/// Zero the `zbits` least significant bits of an f32 (paper's Z4/Z8).
#[inline]
pub fn zero_low_bits(v: f32, zbits: u32) -> f32 {
    if zbits == 0 {
        return v;
    }
    f32::from_bits(v.to_bits() & (u32::MAX << zbits))
}

/// Encode transformed coefficients of a bs³ block into `out` (appended).
/// `threshold` is absolute; `levels` identifies the always-kept coarse cube;
/// `zbits` zeroes low mantissa bits of kept detail coefficients.
pub fn encode_block(
    coeffs: &[f32],
    bs: usize,
    levels: usize,
    threshold: f32,
    zbits: u32,
    out: &mut Vec<u8>,
) -> EncodedStats {
    let vol = bs * bs * bs;
    debug_assert_eq!(coeffs.len(), vol);
    debug_assert_eq!(vol % 8, 0);
    let coarse = bs >> levels;
    let mask_len = vol / 8;
    let start = out.len();
    out.extend_from_slice(&[0u8; 4]); // nsig placeholder
    out.resize(start + 4 + mask_len, 0);
    let mut nsig = 0u32;
    // first pass: build mask
    for (i, &c) in coeffs.iter().enumerate() {
        let keep = c.abs() >= threshold || is_coarse(i, bs, coarse);
        if keep {
            out[start + 4 + i / 8] |= 1 << (i % 8);
            nsig += 1;
        }
    }
    // second pass: append kept coefficients
    out.reserve(nsig as usize * 4);
    for (i, &c) in coeffs.iter().enumerate() {
        if out[start + 4 + i / 8] & (1 << (i % 8)) != 0 {
            let v = if is_coarse(i, bs, coarse) { c } else { zero_low_bits(c, zbits) };
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out[start..start + 4].copy_from_slice(&nsig.to_le_bytes());
    EncodedStats { nsig: nsig as usize, total: vol }
}

/// Decode one block from `buf`, writing bs³ coefficients into `coeffs`.
/// Returns the number of bytes consumed.
pub fn decode_block(buf: &[u8], bs: usize, coeffs: &mut [f32]) -> Result<usize, String> {
    let vol = bs * bs * bs;
    debug_assert_eq!(coeffs.len(), vol);
    let mask_len = vol / 8;
    if buf.len() < 4 + mask_len {
        return Err(format!("encoded block truncated: {} < {}", buf.len(), 4 + mask_len));
    }
    let nsig = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    let need = 4 + mask_len + 4 * nsig;
    if buf.len() < need {
        return Err(format!("encoded block truncated: {} < {need}", buf.len()));
    }
    let mask = &buf[4..4 + mask_len];
    let mut off = 4 + mask_len;
    let mut seen = 0usize;
    for i in 0..vol {
        if mask[i / 8] & (1 << (i % 8)) != 0 {
            coeffs[i] = f32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
            off += 4;
            seen += 1;
        } else {
            coeffs[i] = 0.0;
        }
    }
    if seen != nsig {
        return Err(format!("mask population {seen} != header nsig {nsig}"));
    }
    Ok(off)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;
    use crate::wavelet::transform3d::{forward_3d, inverse_3d, max_levels, Scratch};
    use crate::wavelet::WaveletKind;

    #[test]
    fn roundtrip_zero_threshold_is_exact() {
        prop_cases(0xE0C0DE, 10, |rng, _| {
            let bs = 8;
            let mut c = vec![0.0f32; bs * bs * bs];
            rng.fill_f32(&mut c, -10.0, 10.0);
            let mut out = Vec::new();
            let st = encode_block(&c, bs, 1, 0.0, 0, &mut out);
            assert_eq!(st.nsig, c.len());
            let mut back = vec![0.0f32; c.len()];
            let consumed = decode_block(&out, bs, &mut back).unwrap();
            assert_eq!(consumed, out.len());
            assert_eq!(c, back);
        });
    }

    #[test]
    fn threshold_drops_small_details() {
        let bs = 8;
        let mut c = vec![1e-6f32; bs * bs * bs];
        c[500] = 5.0; // one large detail (outside the coarse cube)
        let mut out = Vec::new();
        let st = encode_block(&c, bs, 1, 1e-3, 0, &mut out);
        // kept: the coarse 4^3 cube + the one large detail
        assert_eq!(st.nsig, 4 * 4 * 4 + 1);
        let mut back = vec![0.0f32; c.len()];
        decode_block(&out, bs, &mut back).unwrap();
        assert_eq!(back[500], 5.0);
        assert_eq!(back[400], 0.0);
    }

    #[test]
    fn coarse_cube_survives_any_threshold() {
        let bs = 16;
        let levels = 2;
        let c = vec![1e-9f32; bs * bs * bs];
        let mut out = Vec::new();
        let st = encode_block(&c, bs, levels, 1e3, 0, &mut out);
        assert_eq!(st.nsig, 4 * 4 * 4);
    }

    #[test]
    fn bit_zeroing_reduces_precision_not_sign() {
        let v = 3.141592653f32;
        let z8 = zero_low_bits(v, 8);
        assert!((v - z8).abs() < 1e-4);
        assert!(z8 != v);
        assert_eq!(zero_low_bits(-v, 8), -zero_low_bits(v, 8).abs() * 1.0);
        assert_eq!(zero_low_bits(v, 0), v);
    }

    #[test]
    fn end_to_end_error_bounded() {
        // transform -> threshold -> decode -> inverse stays within a small
        // multiple of epsilon (superposition over levels)
        prop_cases(0xF00D, 6, |rng, _| {
            let bs = 16;
            let levels = max_levels(bs);
            let mut x = crate::util::prop::gen_smooth_field(rng, bs);
            let range = {
                let (lo, hi) = x
                    .iter()
                    .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
                (hi - lo).max(1e-30)
            };
            let orig = x.clone();
            let mut s = Scratch::new(bs);
            forward_3d(WaveletKind::Avg3, &mut x, bs, levels, &mut s);
            let eps = 1e-3f32 * range;
            let mut out = Vec::new();
            encode_block(&x, bs, levels, eps, 0, &mut out);
            let mut back = vec![0.0f32; x.len()];
            decode_block(&out, bs, &mut back).unwrap();
            inverse_3d(WaveletKind::Avg3, &mut back, bs, levels, &mut s);
            let maxerr = orig
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            // superposition bound: L levels x 3 axes x predictor gain
            assert!(
                maxerr <= 40.0 * eps,
                "maxerr {maxerr} vs eps {eps} (x{})",
                maxerr / eps
            );
        });
    }

    #[test]
    fn truncated_input_is_an_error() {
        let bs = 8;
        let c = vec![1.0f32; bs * bs * bs];
        let mut out = Vec::new();
        encode_block(&c, bs, 1, 0.0, 0, &mut out);
        let mut back = vec![0.0f32; c.len()];
        assert!(decode_block(&out[..10], bs, &mut back).is_err());
        assert!(decode_block(&out[..out.len() - 1], bs, &mut back).is_err());
    }
}
