//! Wavelets "on the interval" (paper §2.3): three lifting schemes —
//! fourth-order interpolating (W⁴), fourth-order lifted interpolating
//! (W⁴li) and third-order average-interpolating (W³ai) — plus the
//! separable multi-level 3D transform and the ε-threshold encoder.
//!
//! The 1D lifting spec here is the single source of truth shared with the
//! Pallas kernel (`python/compile/kernels/wavelet3d.py`); both sides must
//! implement it identically (see DESIGN.md §6).
pub mod encode;
pub mod lift1d;
pub mod transform3d;

pub use encode::{decode_block, encode_block, EncodedStats};
pub use lift1d::{forward_1d, inverse_1d};
pub use transform3d::{forward_3d, inverse_3d, max_levels};

/// The three wavelet families evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WaveletKind {
    /// W⁴: fourth-order interpolating wavelets (Donoho), predict-only.
    Interp4,
    /// W⁴li: fourth-order lifted interpolating wavelets (adds an update
    /// step preserving the running average).
    Lift4,
    /// W³ai: third-order average-interpolating wavelets.
    Avg3,
}

impl WaveletKind {
    pub const ALL: [WaveletKind; 3] = [WaveletKind::Interp4, WaveletKind::Lift4, WaveletKind::Avg3];

    pub fn name(&self) -> &'static str {
        match self {
            WaveletKind::Interp4 => "W4",
            WaveletKind::Lift4 => "W4li",
            WaveletKind::Avg3 => "W3ai",
        }
    }

    /// Stable id used in file headers and artifact names.
    pub fn id(&self) -> u8 {
        match self {
            WaveletKind::Interp4 => 0,
            WaveletKind::Lift4 => 1,
            WaveletKind::Avg3 => 2,
        }
    }

    pub fn from_id(id: u8) -> Option<Self> {
        match id {
            0 => Some(WaveletKind::Interp4),
            1 => Some(WaveletKind::Lift4),
            2 => Some(WaveletKind::Avg3),
            _ => None,
        }
    }

    /// Artifact name fragment (matches python/compile/aot.py).
    pub fn artifact_tag(&self) -> &'static str {
        match self {
            WaveletKind::Interp4 => "w4",
            WaveletKind::Lift4 => "w4l",
            WaveletKind::Avg3 => "w3a",
        }
    }
}
