//! 1D lifting steps on the interval (clamped boundary stencils).
//!
//! Forward packs the result as `[s_0..s_{h-1} | d_0..d_{h-1}]` (h = m/2)
//! into the input slice; inverse restores the interleaved samples.
//! All arithmetic is plain f32 (no FMA) so the Pallas kernel, which lowers
//! to elementwise HLO under interpret=True, produces matching results.
//!
//! The kernels are written once over [`F32Lanes`] and instantiated at
//! `f32` (the public scalar entry points — and the equivalence oracle
//! for the vector path) and at the arch vector types, where each lane
//! carries one *independent* line (`wavelet::transform3d` tiles the
//! strided y/z passes that way). Because the trait exposes only plain
//! `+`/`-`/`*`, the no-FMA/fixed-order contract above holds for every
//! instantiation: per element, the vector path executes the exact
//! scalar op tree and is bit-identical to it.
use super::WaveletKind;
use crate::simd::lanes::F32Lanes;

#[inline(always)]
fn clamp(i: isize, h: usize) -> usize {
    i.clamp(0, h as isize - 1) as usize
}

/// W⁴ predict: cubic interpolation of odd sample `2k+1` from even
/// neighbors. Interior stencil (-1/16, 9/16, 9/16, -1/16); at the interval
/// boundaries one-sided cubic Lagrange stencils keep full order ("wavelets
/// on the interval", Cohen–Daubechies–Vial-style boundary adaptation).
#[inline(always)]
fn pred4<V: F32Lanes>(e: &[V], k: usize, h: usize) -> V {
    if h == 2 {
        // only two evens: linear predict / extrapolate
        return if k == 0 {
            V::splat(0.5) * (e[0] + e[1])
        } else {
            V::splat(1.5) * e[1] - V::splat(0.5) * e[0]
        };
    }
    if k == 0 {
        // cubic through e[0..4] evaluated at sample position 1
        V::splat(0.3125) * e[0] + V::splat(0.9375) * e[1] - V::splat(0.3125) * e[2]
            + V::splat(0.0625) * e[3]
    } else if k + 2 == h {
        // cubic through e[h-4..h] evaluated at position 5
        V::splat(0.0625) * e[h - 4] - V::splat(0.3125) * e[h - 3] + V::splat(0.9375) * e[h - 2]
            + V::splat(0.3125) * e[h - 1]
    } else if k + 1 == h {
        // linear extrapolation beyond the last even sample: higher-order
        // one-sided stencils here have |w|-sum ~6 and amplify fp noise
        // multiplicatively across passes/levels (numerically unstable)
        V::splat(1.5) * e[h - 1] - V::splat(0.5) * e[h - 2]
    } else {
        V::splat(-0.0625) * e[k - 1] + V::splat(0.5625) * e[k] + V::splat(0.5625) * e[k + 1]
            - V::splat(0.0625) * e[k + 2]
    }
}

/// W³ai predict of the pairwise difference `o[k]-e[k]` from the averages.
/// Interior: (s[k+1]-s[k-1])/4 (annihilates quadratics); boundaries use
/// one-sided quadratic stencils of the same order.
#[inline(always)]
fn pred_avg3<V: F32Lanes>(s: &[V], k: usize, h: usize) -> V {
    if h == 2 {
        return V::splat(0.5) * (s[1] - s[0]);
    }
    if k == 0 {
        V::splat(-0.75) * s[0] + V::splat(1.0) * s[1] - V::splat(0.25) * s[2]
    } else if k + 1 == h {
        V::splat(0.75) * s[h - 1] - V::splat(1.0) * s[h - 2] + V::splat(0.25) * s[h - 3]
    } else {
        V::splat(0.25) * (s[k + 1] - s[k - 1])
    }
}

/// Forward 1D lifting step over `V::LANES` independent lines.
/// `line.len()` = m (even, >= 4); `tmp` >= m.
#[inline(always)]
pub(crate) fn forward_1d_v<V: F32Lanes>(kind: WaveletKind, line: &mut [V], tmp: &mut [V]) {
    let m = line.len();
    debug_assert!(m >= 4 && m % 2 == 0);
    let h = m / 2;
    let (s, d) = tmp[..m].split_at_mut(h);
    match kind {
        WaveletKind::Interp4 => {
            for k in 0..h {
                s[k] = line[2 * k];
            }
            for k in 0..h {
                d[k] = line[2 * k + 1] - pred4(s, k, h);
            }
        }
        WaveletKind::Lift4 => {
            // predict with raw evens, then update the scaling coefficients
            for k in 0..h {
                s[k] = line[2 * k];
            }
            for k in 0..h {
                d[k] = line[2 * k + 1] - pred4(s, k, h);
            }
            for k in 0..h {
                let dm = d[clamp(k as isize - 1, h)];
                s[k] = s[k] + V::splat(0.25) * (dm + d[k]);
            }
        }
        WaveletKind::Avg3 => {
            for k in 0..h {
                s[k] = V::splat(0.5) * (line[2 * k] + line[2 * k + 1]);
            }
            for k in 0..h {
                d[k] = (line[2 * k + 1] - line[2 * k]) - pred_avg3(s, k, h);
            }
        }
    }
    line[..m].copy_from_slice(&tmp[..m]);
}

/// Inverse 1D lifting step over `V::LANES` independent lines: `line`
/// holds `[s | d]`, restores samples.
#[inline(always)]
pub(crate) fn inverse_1d_v<V: F32Lanes>(kind: WaveletKind, line: &mut [V], tmp: &mut [V]) {
    let m = line.len();
    debug_assert!(m >= 4 && m % 2 == 0);
    let h = m / 2;
    match kind {
        WaveletKind::Interp4 => {
            let (s, d) = line[..m].split_at(h);
            for k in 0..h {
                tmp[2 * k] = s[k];
                tmp[2 * k + 1] = d[k] + pred4(s, k, h);
            }
        }
        WaveletKind::Lift4 => {
            // undo update into tmp[..h] (raw evens), then undo predict,
            // interleaving directly into `line`. Ascending k is safe: the
            // write frontier 2k+1 never passes an unread d[j] (j >= k).
            {
                let (s, d) = line[..m].split_at(h);
                for k in 0..h {
                    let dm = d[clamp(k as isize - 1, h)];
                    tmp[k] = s[k] - V::splat(0.25) * (dm + d[k]);
                }
            }
            for k in 0..h {
                let o = line[h + k] + pred4(&tmp[..h], k, h);
                line[2 * k] = tmp[k];
                line[2 * k + 1] = o;
            }
            return;
        }
        WaveletKind::Avg3 => {
            let (s, d) = line[..m].split_at(h);
            for k in 0..h {
                let diff = d[k] + pred_avg3(s, k, h);
                tmp[2 * k] = s[k] - V::splat(0.5) * diff;
                tmp[2 * k + 1] = s[k] + V::splat(0.5) * diff;
            }
        }
    }
    line[..m].copy_from_slice(&tmp[..m]);
}

/// Forward 1D lifting step. `line.len()` = m (even, >= 4); `tmp` >= m.
pub fn forward_1d(kind: WaveletKind, line: &mut [f32], tmp: &mut [f32]) {
    forward_1d_v::<f32>(kind, line, tmp);
}

/// Inverse 1D lifting step: `line` holds `[s | d]`, restores samples.
pub fn inverse_1d(kind: WaveletKind, line: &mut [f32], tmp: &mut [f32]) {
    inverse_1d_v::<f32>(kind, line, tmp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;

    fn roundtrip_err(kind: WaveletKind, x: &[f32]) -> f32 {
        let mut line = x.to_vec();
        let mut tmp = vec![0.0; x.len()];
        forward_1d(kind, &mut line, &mut tmp);
        inverse_1d(kind, &mut line, &mut tmp);
        x.iter()
            .zip(&line)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    #[test]
    fn perfect_reconstruction_all_kinds() {
        prop_cases(0xA11CE, 50, |rng, _| {
            let m = [4usize, 8, 16, 32, 64][rng.below(5) as usize];
            let mut x = vec![0.0f32; m];
            rng.fill_f32(&mut x, -100.0, 100.0);
            for kind in WaveletKind::ALL {
                let err = roundtrip_err(kind, &x);
                assert!(err <= 2e-4, "{kind:?} m={m} err={err}");
            }
        });
    }

    #[test]
    fn interp4_annihilates_cubics() {
        // cubic polynomial sampled away from the boundary -> interior
        // detail coefficients must vanish (order-4 predictor)
        let m = 32;
        let x: Vec<f32> = (0..m)
            .map(|i| {
                let t = i as f32 / m as f32;
                0.3 + t + 2.0 * t * t - 1.5 * t * t * t
            })
            .collect();
        let mut line = x.clone();
        let mut tmp = vec![0.0; m];
        forward_1d(WaveletKind::Interp4, &mut line, &mut tmp);
        let h = m / 2;
        for k in 2..h - 2 {
            assert!(
                line[h + k].abs() < 1e-5,
                "interior detail d[{k}]={} should vanish for cubic",
                line[h + k]
            );
        }
    }

    #[test]
    fn avg3_annihilates_quadratics() {
        let m = 32;
        let x: Vec<f32> = (0..m)
            .map(|i| {
                let t = i as f32;
                1.0 + 0.5 * t + 0.25 * t * t
            })
            .collect();
        let mut line = x.clone();
        let mut tmp = vec![0.0; m];
        forward_1d(WaveletKind::Avg3, &mut line, &mut tmp);
        let h = m / 2;
        for k in 1..h - 1 {
            let rel = line[h + k].abs() / x[2 * k].abs().max(1.0);
            assert!(rel < 1e-5, "interior detail d[{k}]={} for quadratic", line[h + k]);
        }
    }

    #[test]
    fn lift4_preserves_mean_better_than_interp4() {
        // the update step makes scaling coeffs track local averages:
        // for an oscillating signal, the s-band mean of W4li stays closer
        // to the signal mean than plain subsampling (W4)
        let mut rng = Pcg32::new(77);
        let m = 64;
        let mut x = vec![0.0f32; m];
        rng.fill_f32(&mut x, 0.0, 1.0);
        let mean_x: f32 = x.iter().sum::<f32>() / m as f32;
        let mut tmp = vec![0.0; m];
        let mut a = x.clone();
        forward_1d(WaveletKind::Interp4, &mut a, &mut tmp);
        let mut b = x.clone();
        forward_1d(WaveletKind::Lift4, &mut b, &mut tmp);
        let h = m / 2;
        let mean_a: f32 = a[..h].iter().sum::<f32>() / h as f32;
        let mean_b: f32 = b[..h].iter().sum::<f32>() / h as f32;
        assert!(
            (mean_b - mean_x).abs() <= (mean_a - mean_x).abs() + 1e-3,
            "lift4 mean drift {} vs interp4 {}",
            (mean_b - mean_x).abs(),
            (mean_a - mean_x).abs()
        );
    }

    #[test]
    fn smooth_signal_details_are_small() {
        let m = 64;
        let x: Vec<f32> = (0..m).map(|i| (i as f32 * 0.1).sin() * 10.0).collect();
        let mut tmp = vec![0.0; m];
        for kind in WaveletKind::ALL {
            let mut line = x.clone();
            forward_1d(kind, &mut line, &mut tmp);
            let h = m / 2;
            let dmax = line[h..].iter().map(|v| v.abs()).fold(0.0, f32::max);
            let smax = line[..h].iter().map(|v| v.abs()).fold(0.0, f32::max);
            assert!(dmax < 0.05 * smax, "{kind:?}: details {dmax} vs scale {smax}");
        }
    }

    #[test]
    fn constant_signal_zero_details_exact() {
        let m = 16;
        let x = vec![3.75f32; m];
        let mut tmp = vec![0.0; m];
        for kind in WaveletKind::ALL {
            let mut line = x.clone();
            forward_1d(kind, &mut line, &mut tmp);
            for k in m / 2..m {
                assert_eq!(line[k], 0.0, "{kind:?}");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn vector_lift_is_bit_identical_to_scalar_per_lane() {
        // direct kernel-level oracle check: 8 lanes of random bit
        // patterns (NaN/subnormals included) through the generic kernel
        // must equal 8 scalar runs, bit for bit
        use crate::simd::lanes::F32x8;
        if crate::simd::detect() != crate::simd::SimdLevel::Avx2 {
            return;
        }
        prop_cases(0x1f32, 30, |rng, _| {
            let m = [4usize, 8, 16, 32][rng.below(4) as usize];
            let mut lanes = vec![[0f32; 8]; m];
            for row in lanes.iter_mut() {
                for v in row.iter_mut() {
                    *v = if rng.below(6) == 0 {
                        f32::from_bits(rng.next_u32())
                    } else {
                        rng.next_f32() * 200.0 - 100.0
                    };
                }
            }
            for kind in WaveletKind::ALL {
                for fwd in [true, false] {
                    // SAFETY: detect() confirmed AVX2 above
                    let mut vline: Vec<F32x8> =
                        lanes.iter().map(|r| unsafe { F32x8::load(r.as_ptr()) }).collect();
                    let mut vtmp = vec![F32x8::splat(0.0); m];
                    if fwd {
                        forward_1d_v(kind, &mut vline, &mut vtmp);
                    } else {
                        inverse_1d_v(kind, &mut vline, &mut vtmp);
                    }
                    for lane in 0..8 {
                        let mut sline: Vec<f32> = lanes.iter().map(|r| r[lane]).collect();
                        let mut stmp = vec![0f32; m];
                        if fwd {
                            forward_1d(kind, &mut sline, &mut stmp);
                        } else {
                            inverse_1d(kind, &mut sline, &mut stmp);
                        }
                        for k in 0..m {
                            let mut out = [0f32; 8];
                            // SAFETY: out is 8 f32s
                            unsafe { vline[k].store(out.as_mut_ptr()) };
                            assert_eq!(
                                out[lane].to_bits(),
                                sline[k].to_bits(),
                                "{kind:?} fwd={fwd} m={m} k={k} lane={lane}"
                            );
                        }
                    }
                }
            }
        });
    }
}
