//! Separable multi-level 3D wavelet transform over a cubic block.
//!
//! Per level ℓ (cube side m = bs >> ℓ, down to 4): transform along x, then
//! y, then z on the leading m³ subcube; each 1D step packs scaling
//! coefficients into the first m/2 entries and details into the last m/2.
//! The Pallas kernel implements the identical schedule.
use super::lift1d::{forward_1d, inverse_1d};
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use super::lift1d::{forward_1d_v, inverse_1d_v};
use super::WaveletKind;
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use crate::simd::lanes::F32Lanes;
use crate::simd::{self, SimdLevel};

/// Number of levels taken by default: halve until the coarse cube is 4³.
pub fn max_levels(bs: usize) -> usize {
    debug_assert!(bs.is_power_of_two() && bs >= 4);
    (bs.trailing_zeros() as usize).saturating_sub(2)
}

/// Scratch space reused across blocks (no allocation in the hot loop).
pub struct Scratch {
    line: Vec<f32>,
    tmp: Vec<f32>,
}

impl Scratch {
    pub fn new(bs: usize) -> Self {
        Self { line: vec![0.0; bs], tmp: vec![0.0; bs] }
    }

    /// Grow to serve blocks of side `bs`. Oversized buffers are fine:
    /// every line operation slices to the live length.
    fn ensure(&mut self, bs: usize) {
        if self.line.len() < bs {
            self.line.resize(bs, 0.0);
            self.tmp.resize(bs, 0.0);
        }
    }
}

thread_local! {
    /// Per-thread scratch shared by every batch transform on this thread:
    /// pipeline workers call [`forward_batch`]/[`inverse_batch`] once per
    /// block batch, and allocating two line buffers per call used to be
    /// the last allocation in the stage-1 hot loop.
    static TLS_SCRATCH: std::cell::RefCell<Scratch> =
        std::cell::RefCell::new(Scratch { line: Vec::new(), tmp: Vec::new() });
}

#[inline(always)]
fn gather_line(data: &[f32], base: usize, stride: usize, m: usize, line: &mut [f32]) {
    if stride == 1 {
        line[..m].copy_from_slice(&data[base..base + m]);
    } else {
        for i in 0..m {
            line[i] = data[base + i * stride];
        }
    }
}

#[inline(always)]
fn scatter_line(data: &mut [f32], base: usize, stride: usize, m: usize, line: &[f32]) {
    if stride == 1 {
        data[base..base + m].copy_from_slice(&line[..m]);
    } else {
        for i in 0..m {
            data[base + i * stride] = line[i];
        }
    }
}

/// Apply `f` to every axis line of the leading m³ subcube of a bs³ block.
fn for_each_line(
    data: &mut [f32],
    bs: usize,
    m: usize,
    axis: usize,
    scratch: &mut Scratch,
    mut f: impl FnMut(&mut [f32], &mut [f32]),
) {
    let (stride, s1, s2) = match axis {
        0 => (1, bs, bs * bs),          // x lines indexed by (y, z)
        1 => (bs, 1, bs * bs),          // y lines indexed by (x, z)
        _ => (bs * bs, 1, bs),          // z lines indexed by (x, y)
    };
    if stride == 1 {
        // x lines are contiguous: transform in place, no gather/scatter
        // (perf pass: saves two copies of every line per level)
        for j in 0..m {
            for i in 0..m {
                let base = i * s1 + j * s2;
                f(&mut data[base..base + m], &mut scratch.tmp);
            }
        }
        return;
    }
    for j in 0..m {
        for i in 0..m {
            let base = i * s1 + j * s2;
            gather_line(data, base, stride, m, &mut scratch.line);
            f(&mut scratch.line[..m], &mut scratch.tmp);
            scatter_line(data, base, stride, m, &scratch.line[..m]);
        }
    }
}

/// Largest cube side the stack tiles in [`tiled_axis_pass`] serve.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
const MAX_TILE_SIDE: usize = 128;

/// Strided y/z lifting pass vectorized across `V::LANES` adjacent-x
/// lines: lane `l` of tile element `e` is `data[base + l + e*stride]`,
/// so each lane carries one independent line and the per-element op
/// sequence is exactly the scalar `forward_1d`/`inverse_1d` — output
/// is bit-identical to the scalar gather/scatter walk (no FMA, no
/// reassociation; see `crate::simd`). Replaces m one-float strided
/// gathers per line with m/LANES vector tiles per LANES lines.
///
/// # Safety
/// Caller guarantees the arch feature behind `V` is available on this
/// host, `data` is a full bs³ block, `axis` is 1 or 2, and
/// `V::LANES <= m <= MAX_TILE_SIDE` with m a power of two (so
/// `V::LANES` divides m). Bounds: the largest index touched is
/// `(m-1)*(1 + stride + s2) <= bs³ - 1`.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn tiled_axis_pass<V: F32Lanes>(
    kind: WaveletKind,
    fwd: bool,
    data: &mut [f32],
    bs: usize,
    m: usize,
    axis: usize,
) {
    debug_assert!(axis == 1 || axis == 2);
    debug_assert!(m >= V::LANES && m % V::LANES == 0 && m <= MAX_TILE_SIDE);
    debug_assert_eq!(data.len(), bs * bs * bs);
    let (stride, s2) = if axis == 1 { (bs, bs * bs) } else { (bs * bs, bs) };
    let mut line = [V::splat(0.0); MAX_TILE_SIDE];
    let mut tmp = [V::splat(0.0); MAX_TILE_SIDE];
    for j in 0..m {
        let mut x = 0;
        while x < m {
            let base = x + j * s2;
            for (e, v) in line[..m].iter_mut().enumerate() {
                *v = V::load(data.as_ptr().add(base + e * stride));
            }
            if fwd {
                forward_1d_v(kind, &mut line[..m], &mut tmp[..m]);
            } else {
                inverse_1d_v(kind, &mut line[..m], &mut tmp[..m]);
            }
            for (e, v) in line[..m].iter().enumerate() {
                v.store(data.as_mut_ptr().add(base + e * stride));
            }
            x += V::LANES;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tiled_axis_pass_avx2(
    kind: WaveletKind,
    fwd: bool,
    data: &mut [f32],
    bs: usize,
    m: usize,
    axis: usize,
) {
    tiled_axis_pass::<crate::simd::lanes::F32x8>(kind, fwd, data, bs, m, axis);
}

#[cfg(target_arch = "aarch64")]
unsafe fn tiled_axis_pass_neon(
    kind: WaveletKind,
    fwd: bool,
    data: &mut [f32],
    bs: usize,
    m: usize,
    axis: usize,
) {
    tiled_axis_pass::<crate::simd::lanes::F32x4>(kind, fwd, data, bs, m, axis);
}

/// One lifting pass along `axis` at cube side `m`: tiled vector path
/// for the strided y/z axes when dispatched, scalar line walk
/// otherwise (x lines are contiguous and transform in place already —
/// vectorizing them needs an 8x8 in-register transpose, a tracked
/// follow-up). `m < LANES` levels (the coarse 4³ tail) stay scalar.
fn axis_pass(
    kind: WaveletKind,
    fwd: bool,
    data: &mut [f32],
    bs: usize,
    m: usize,
    axis: usize,
    scratch: &mut Scratch,
    lvl: SimdLevel,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if lvl == SimdLevel::Avx2 && axis != 0 && (8..=MAX_TILE_SIDE).contains(&m) {
            // SAFETY: Avx2 is only dispatched on hosts where
            // simd::detect() saw the feature; bounds per tiled_axis_pass
            unsafe { tiled_axis_pass_avx2(kind, fwd, data, bs, m, axis) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if lvl == SimdLevel::Neon && axis != 0 && (4..=MAX_TILE_SIDE).contains(&m) {
            // SAFETY: NEON is baseline on aarch64; bounds per tiled_axis_pass
            unsafe { tiled_axis_pass_neon(kind, fwd, data, bs, m, axis) };
            return;
        }
    }
    let _ = lvl;
    if fwd {
        for_each_line(data, bs, m, axis, scratch, |line, tmp| forward_1d(kind, line, tmp));
    } else {
        for_each_line(data, bs, m, axis, scratch, |line, tmp| inverse_1d(kind, line, tmp));
    }
}

/// In-place forward 3D transform of a bs³ block with `levels` levels.
pub fn forward_3d(kind: WaveletKind, data: &mut [f32], bs: usize, levels: usize, scratch: &mut Scratch) {
    forward_3d_with(kind, data, bs, levels, scratch, simd::level());
}

/// In-place inverse 3D transform (reverse level and axis order).
pub fn inverse_3d(kind: WaveletKind, data: &mut [f32], bs: usize, levels: usize, scratch: &mut Scratch) {
    inverse_3d_with(kind, data, bs, levels, scratch, simd::level());
}

/// [`forward_3d`] at an explicit dispatch level (equivalence tests
/// force both paths without touching the process-wide state).
fn forward_3d_with(
    kind: WaveletKind,
    data: &mut [f32],
    bs: usize,
    levels: usize,
    scratch: &mut Scratch,
    lvl: SimdLevel,
) {
    debug_assert_eq!(data.len(), bs * bs * bs);
    debug_assert!(levels <= max_levels(bs));
    let mut m = bs;
    for _ in 0..levels {
        for axis in 0..3 {
            axis_pass(kind, true, data, bs, m, axis, scratch, lvl);
        }
        m /= 2;
    }
}

/// [`inverse_3d`] at an explicit dispatch level.
fn inverse_3d_with(
    kind: WaveletKind,
    data: &mut [f32],
    bs: usize,
    levels: usize,
    scratch: &mut Scratch,
    lvl: SimdLevel,
) {
    debug_assert_eq!(data.len(), bs * bs * bs);
    let mut m = bs >> levels;
    for _ in 0..levels {
        m *= 2;
        for axis in (0..3).rev() {
            axis_pass(kind, false, data, bs, m, axis, scratch, lvl);
        }
    }
}

/// Forward-transform a batch of contiguous bs³ blocks (the shape the PJRT
/// executable consumes: f32[n, bs, bs, bs]). Uses the thread-local scratch
/// pool — no allocation once a thread's buffers are warm.
pub fn forward_batch(kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
    let vol = bs * bs * bs;
    debug_assert_eq!(blocks.len() % vol, 0);
    TLS_SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        scratch.ensure(bs);
        for blk in blocks.chunks_exact_mut(vol) {
            forward_3d(kind, blk, bs, levels, &mut scratch);
        }
    });
}

/// Inverse-transform a batch of contiguous bs³ blocks (thread-local
/// scratch, like [`forward_batch`]).
pub fn inverse_batch(kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
    let vol = bs * bs * bs;
    debug_assert_eq!(blocks.len() % vol, 0);
    TLS_SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        scratch.ensure(bs);
        for blk in blocks.chunks_exact_mut(vol) {
            inverse_3d(kind, blk, bs, levels, &mut scratch);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::{gen_smooth_field, prop_cases};

    #[test]
    fn levels_for_block_sizes() {
        assert_eq!(max_levels(4), 0);
        assert_eq!(max_levels(8), 1);
        assert_eq!(max_levels(16), 2);
        assert_eq!(max_levels(32), 3);
        assert_eq!(max_levels(64), 4);
    }

    #[test]
    fn reconstruction_all_kinds_all_sizes() {
        prop_cases(0xBEEF, 12, |rng, _| {
            let bs = [8usize, 16, 32][rng.below(3) as usize];
            let mut x = vec![0.0f32; bs * bs * bs];
            rng.fill_f32(&mut x, -50.0, 50.0);
            for kind in WaveletKind::ALL {
                let mut y = x.clone();
                let levels = max_levels(bs);
                let mut s = Scratch::new(bs);
                forward_3d(kind, &mut y, bs, levels, &mut s);
                inverse_3d(kind, &mut y, bs, levels, &mut s);
                let err = x
                    .iter()
                    .zip(&y)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f32::max);
                // boundary extrapolation stencils amplify f32 rounding a
                // little; 2e-3 on a ±50 range is ~2e-5 relative
                assert!(err < 2e-3, "{kind:?} bs={bs} err={err}");
            }
        });
    }

    #[test]
    fn partial_levels_roundtrip() {
        let mut rng = Pcg32::new(5);
        let bs = 16;
        let mut x = vec![0.0f32; bs * bs * bs];
        rng.fill_f32(&mut x, 0.0, 1.0);
        for levels in 0..=max_levels(bs) {
            let mut y = x.clone();
            let mut s = Scratch::new(bs);
            forward_3d(WaveletKind::Avg3, &mut y, bs, levels, &mut s);
            if levels > 0 {
                assert_ne!(x, y);
            } else {
                assert_eq!(x, y);
            }
            inverse_3d(WaveletKind::Avg3, &mut y, bs, levels, &mut s);
            let err = x.iter().zip(&y).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
            assert!(err < 1e-4, "levels={levels} err={err}");
        }
    }

    #[test]
    fn smooth_field_thresholds_to_sparse() {
        // the property the whole scheme relies on: for a smooth field,
        // thresholding at 1e-3 * range keeps only a small fraction of
        // coefficients (this is what produces CR >> 1 in the paper)
        let mut rng = Pcg32::new(21);
        let bs = 32;
        let mut x = gen_smooth_field(&mut rng, bs);
        let (lo, hi) = x
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
        let eps = 1e-3 * (hi - lo);
        // W4 (order 4) compacts smooth fields harder than W3ai (order 3)
        for (kind, bound) in [
            (WaveletKind::Interp4, 0.10),
            (WaveletKind::Lift4, 0.10),
            (WaveletKind::Avg3, 0.30),
        ] {
            let mut c = x.clone();
            let mut s = Scratch::new(bs);
            forward_3d(kind, &mut c, bs, max_levels(bs), &mut s);
            let nsig = c.iter().filter(|c| c.abs() >= eps).count();
            let frac = nsig as f64 / c.len() as f64;
            assert!(frac < bound, "{kind:?}: significant fraction {frac:.3} > {bound}");
        }
    }

    #[test]
    fn avg3_higher_fidelity_at_equal_threshold_on_cavitation_data() {
        // the W3ai advantage the paper reports (Fig 3/4) on the fields
        // that are hard to compress: at the same threshold the averaging
        // basis loses less signal per dropped coefficient than W4
        use crate::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
        let sim = CloudSim::new(CloudConfig::paper(96));
        let f = sim.field(Qoi::Pressure, step_to_time(10000));
        let (lo, hi) = f.range();
        let eps = 1e-3 * (hi - lo);
        let bs = 32;
        let grid = crate::core::block::BlockGrid::new(&f, bs);
        let fidelity = |kind| {
            let mut out = crate::core::Field3::zeros(f.nx, f.ny, f.nz);
            let mut blk = crate::core::block::Block::zeros(bs);
            let mut s = Scratch::new(bs);
            for id in 0..grid.nblocks() {
                grid.extract(&f, id, &mut blk);
                forward_3d(kind, &mut blk.data, bs, max_levels(bs), &mut s);
                for v in blk.data.iter_mut() {
                    if v.abs() < eps {
                        *v = 0.0;
                    }
                }
                inverse_3d(kind, &mut blk.data, bs, max_levels(bs), &mut s);
                grid.insert(&mut out, id, &blk);
            }
            crate::metrics::psnr(&f.data, &out.data).unwrap()
        };
        let p4 = fidelity(WaveletKind::Interp4);
        let p3 = fidelity(WaveletKind::Avg3);
        assert!(p3 > p4, "avg3 psnr {p3} should beat interp4 {p4} at equal eps");
    }

    #[test]
    fn oversized_scratch_is_equivalent() {
        // the thread-local pool keeps the largest buffers seen; smaller
        // blocks transformed afterwards must be unaffected
        let mut rng = Pcg32::new(77);
        let bs = 8;
        let mut x = vec![0.0f32; bs * bs * bs];
        rng.fill_f32(&mut x, -3.0, 3.0);
        let mut with_big = x.clone();
        let mut exact = x.clone();
        let mut big = Scratch::new(64);
        let mut fit = Scratch::new(bs);
        forward_3d(WaveletKind::Lift4, &mut with_big, bs, max_levels(bs), &mut big);
        forward_3d(WaveletKind::Lift4, &mut exact, bs, max_levels(bs), &mut fit);
        assert_eq!(with_big, exact);
        // batch entrypoints go through the pool: warm it with bs=32 first
        let mut warm = vec![0.0f32; 32 * 32 * 32];
        forward_batch(WaveletKind::Avg3, &mut warm, 32, max_levels(32));
        let mut via_batch = x.clone();
        forward_batch(WaveletKind::Lift4, &mut via_batch, bs, max_levels(bs));
        assert_eq!(via_batch, exact);
    }

    #[test]
    fn tiled_simd_passes_are_bit_identical_to_scalar() {
        // fuzzed oracle check at the 3D level: full multi-level
        // transforms (covering the m=4 scalar tail and every axis)
        // under the vector dispatch must equal the scalar walk bit for
        // bit, including NaN/inf/subnormal input patterns
        let lvl = crate::simd::detect();
        if lvl == SimdLevel::Scalar {
            return; // no vector path to compare on this host
        }
        prop_cases(0x51D0, 10, |rng, _| {
            let bs = [8usize, 16, 32, 64][rng.below(4) as usize];
            let mut x = vec![0.0f32; bs * bs * bs];
            rng.fill_f32(&mut x, -100.0, 100.0);
            for v in x.iter_mut() {
                if rng.below(8) == 0 {
                    *v = f32::from_bits(rng.next_u32());
                }
            }
            for kind in WaveletKind::ALL {
                let levels = max_levels(bs);
                let mut a = x.clone();
                let mut b = x.clone();
                let mut s = Scratch::new(bs);
                forward_3d_with(kind, &mut a, bs, levels, &mut s, SimdLevel::Scalar);
                forward_3d_with(kind, &mut b, bs, levels, &mut s, lvl);
                let same = a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits());
                assert!(same, "{kind:?} bs={bs}: forward diverged from scalar oracle");
                inverse_3d_with(kind, &mut a, bs, levels, &mut s, lvl);
                inverse_3d_with(kind, &mut b, bs, levels, &mut s, SimdLevel::Scalar);
                let same = a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits());
                assert!(same, "{kind:?} bs={bs}: inverse diverged from scalar oracle");
            }
        });
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Pcg32::new(33);
        let bs = 8;
        let vol = bs * bs * bs;
        let mut batch = vec![0.0f32; 3 * vol];
        rng.fill_f32(&mut batch, -1.0, 1.0);
        let singles: Vec<Vec<f32>> = batch.chunks_exact(vol).map(|c| c.to_vec()).collect();
        forward_batch(WaveletKind::Interp4, &mut batch, bs, max_levels(bs));
        let mut s = Scratch::new(bs);
        for (i, mut single) in singles.into_iter().enumerate() {
            forward_3d(WaveletKind::Interp4, &mut single, bs, max_levels(bs), &mut s);
            assert_eq!(&batch[i * vol..(i + 1) * vol], &single[..]);
        }
    }
}
