//! Parallel shared-file output (paper §2.2): "An exclusive prefix sum scan
//! is performed for the determination of the file offset ... Each rank
//! acquires a destination offset and, starting from that offset, writes
//! its compressed buffer in the file using non-collective blocking I/O."
//! One file per quantity; rank 0 additionally owns the header region.
use crate::cluster::Comm;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::Path;

/// Outcome of a collective shared-file write on one rank.
#[derive(Clone, Copy, Debug)]
pub struct WriteReport {
    /// This rank's destination offset in the shared file.
    pub offset: u64,
    /// Bytes written by this rank (payload only).
    pub bytes: u64,
    /// Total bytes in the file across ranks (incl. header).
    pub total_bytes: u64,
    /// Seconds spent in this rank's write call.
    pub write_secs: f64,
}

/// Collectively write `header` (rank 0 only) + per-rank `payload` into a
/// single shared file. `header_len` must be identical on all ranks.
pub fn shared_write(
    path: &Path,
    comm: &dyn Comm,
    header: Option<&[u8]>,
    header_len: u64,
    payload: &[u8],
) -> std::io::Result<WriteReport> {
    if comm.rank() == 0 {
        // rank 0 creates/truncates before anyone writes
        let f = File::create(path)?;
        drop(f);
    }
    comm.barrier();
    let my = payload.len() as u64;
    let before = comm.exscan_u64(my);
    let offset = header_len + before;
    let totals = comm.allgather_u64(my);
    let total_bytes = header_len + totals.iter().sum::<u64>();
    let t = std::time::Instant::now();
    let f = OpenOptions::new().write(true).open(path)?;
    if comm.rank() == 0 {
        let h = header.expect("rank 0 must supply the header");
        assert_eq!(h.len() as u64, header_len, "header length mismatch");
        f.write_all_at(h, 0)?;
    }
    f.write_all_at(payload, offset)?;
    f.sync_data()?;
    let write_secs = t.elapsed().as_secs_f64();
    comm.barrier();
    Ok(WriteReport { offset, bytes: my, total_bytes, write_secs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{InProcComm, SelfComm};

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("cubismz_pario_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn single_rank_write() {
        let p = tmp("single.bin");
        let rep = shared_write(&p, &SelfComm, Some(b"HDR!"), 4, b"payload").unwrap();
        assert_eq!(rep.offset, 4);
        assert_eq!(rep.total_bytes, 11);
        assert_eq!(std::fs::read(&p).unwrap(), b"HDR!payload");
    }

    #[test]
    fn multi_rank_offsets_are_exscan_ordered() {
        let p = tmp("multi.bin");
        let comms = InProcComm::group(4);
        let reports: Vec<WriteReport> = std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| {
                    let p = p.clone();
                    s.spawn(move || {
                        let rank = c.rank();
                        let payload = vec![b'a' + rank as u8; (rank + 1) * 3];
                        let header = if rank == 0 { Some(&b"HH"[..]) } else { None };
                        shared_write(&p, &c, header, 2, &payload).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // offsets: 2, 5, 11, 20; total = 2 + 3+6+9+12 = 32
        let mut offs: Vec<u64> = reports.iter().map(|r| r.offset).collect();
        offs.sort();
        assert_eq!(offs, vec![2, 5, 11, 20]);
        assert!(reports.iter().all(|r| r.total_bytes == 32));
        let data = std::fs::read(&p).unwrap();
        assert_eq!(&data[..2], b"HH");
        assert_eq!(&data[2..5], b"aaa");
        assert_eq!(&data[5..11], b"bbbbbb");
        assert_eq!(&data[11..20], b"ccccccccc");
        assert_eq!(&data[20..32], b"dddddddddddd");
    }
}
