//! Filesystem throughput measurement: the HACC-IO-style baseline the
//! paper overlays in Fig. 11 (uncompressed shared-file writes), plus a
//! helper to measure effective write bandwidth for the scaling model.
use std::io::Write;
use std::path::Path;

/// Measured write bandwidth for one payload size.
#[derive(Clone, Copy, Debug)]
pub struct BandwidthSample {
    pub bytes: usize,
    pub secs: f64,
}

impl BandwidthSample {
    pub fn mbps(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.secs
    }

    pub fn gbps(&self) -> f64 {
        self.bytes as f64 / 1e9 / self.secs
    }
}

/// Write `bytes` of synthetic data to `path` (create+write+sync), return
/// the timing — the HACC-IO pattern of one contiguous stream per rank.
pub fn measure_write(path: &Path, bytes: usize) -> std::io::Result<BandwidthSample> {
    let payload = vec![0x5Au8; bytes.min(8 << 20)];
    let t = std::time::Instant::now();
    let mut f = std::fs::File::create(path)?;
    let mut left = bytes;
    while left > 0 {
        let n = left.min(payload.len());
        f.write_all(&payload[..n])?;
        left -= n;
    }
    f.sync_all()?;
    let secs = t.elapsed().as_secs_f64().max(1e-9);
    let _ = std::fs::remove_file(path);
    Ok(BandwidthSample { bytes, secs })
}

/// Measure read bandwidth of an existing file.
pub fn measure_read(path: &Path) -> std::io::Result<BandwidthSample> {
    let t = std::time::Instant::now();
    let data = std::fs::read(path)?;
    let secs = t.elapsed().as_secs_f64().max(1e-9);
    Ok(BandwidthSample { bytes: data.len(), secs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_bandwidth_positive() {
        let d = std::env::temp_dir().join("cubismz_tp_tests");
        std::fs::create_dir_all(&d).unwrap();
        let s = measure_write(&d.join("tp.bin"), 4 << 20).unwrap();
        assert!(s.mbps() > 1.0, "suspiciously slow: {} MB/s", s.mbps());
        assert_eq!(s.bytes, 4 << 20);
    }

    #[test]
    fn read_bandwidth_positive() {
        let d = std::env::temp_dir().join("cubismz_tp_tests");
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("tpr.bin");
        std::fs::write(&p, vec![1u8; 1 << 20]).unwrap();
        let s = measure_read(&p).unwrap();
        assert!(s.mbps() > 1.0);
    }
}
