//! Deterministic fault injection for the end-to-end integrity harness.
//!
//! A [`FaultPlan`] is a *script*: which I/O operations fail transiently,
//! which reads come back short, which bytes flip in flight, where the
//! file appears to end. Built once (consuming builder), then armed on a
//! real I/O path — the `.czs` [`crate::pipeline::dataset::FileSource`]
//! via `DatasetOptions::open_with_faults`, or any `Read`/`Write` via
//! the [`FaultReader`] / [`FaultWriter`] adapters, or a positioned-read
//! file via [`FaultFile`]. Everything a plan does is a pure function of
//! its script and the monotonic operation counter, so a failing run
//! replays exactly (`CZB_FAULT_SEED` pins the script the test harness
//! generates).
//!
//! The plan is immutable after build; the only mutable state is two
//! atomic counters (operations seen, faults fired), which makes one
//! plan safely shareable across the concurrent readers a `.czs` decode
//! fans out — each scripted fault fires on exactly one operation index,
//! whichever thread draws it.
//!
//! Fault classes and what the stack above must do with them:
//!
//! * **Transient errors** (`ErrorKind::Interrupted` / `WouldBlock`) —
//!   retried in place by `FileSource::read_exact_at`'s bounded
//!   retry-with-backoff; the caller never sees them unless they
//!   persist past the budget.
//! * **Short reads** — the retry loop continues where the read left
//!   off; no layer may assume one call fills its buffer.
//! * **Bit flips** — survive the read path untouched by design; the
//!   CRC32C layers (czb chunk/header digests, czs section digests)
//!   must detect them, and salvage decode must contain them.
//! * **Truncation** — the file appears to end at byte N; reads past it
//!   return EOF, which must surface as a clean error, never a hang or
//!   panic.
use std::io::{Read, Write};
use std::sync::atomic::{AtomicUsize, Ordering};

/// A scripted set of I/O faults. See the module docs for the classes.
/// `Default`/[`FaultPlan::new`] is the empty plan (no faults), so a
/// faulted code path with an empty plan behaves identically to the
/// unfaulted one — the property the harness's control runs pin.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// `(operation index, kind)`: that numbered read/write attempt
    /// fails with a transient error of this kind instead of running.
    transient: Vec<(usize, std::io::ErrorKind)>,
    /// `(operation index, max bytes)`: that attempt is capped to a
    /// short (but nonzero) length.
    short_reads: Vec<(usize, usize)>,
    /// `(absolute byte offset, bit mask)`: data read over this offset
    /// comes back with these bits flipped.
    flips: Vec<(u64, u8)>,
    /// The file pretends to end at this byte.
    truncate_at: Option<u64>,
    /// Monotonic count of read/write attempts routed through the plan.
    ops: AtomicUsize,
    /// Faults actually fired (a test's proof that its script ran).
    injected: AtomicUsize,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Script attempt number `op` (0-based, counted across the whole
    /// plan) to fail with a transient error of `kind`.
    pub fn fail_op(mut self, op: usize, kind: std::io::ErrorKind) -> Self {
        self.transient.push((op, kind));
        self
    }

    /// Script attempt number `op` to read at most `max` bytes
    /// (clamped to at least 1 — a zero-length "short read" would be
    /// indistinguishable from EOF).
    pub fn short_read(mut self, op: usize, max: usize) -> Self {
        self.short_reads.push((op, max.max(1)));
        self
    }

    /// Flip `mask`'s bits in any data read over absolute offset
    /// `offset`.
    pub fn flip_bit(mut self, offset: u64, mask: u8) -> Self {
        self.flips.push((offset, mask));
        self
    }

    /// Make the file appear to end at byte `n`.
    pub fn truncate_at(mut self, n: u64) -> Self {
        self.truncate_at = Some(n);
        self
    }

    /// Faults fired so far.
    pub fn injected(&self) -> usize {
        self.injected.load(Ordering::Relaxed)
    }

    /// Read/write attempts routed through the plan so far.
    pub fn ops(&self) -> usize {
        self.ops.load(Ordering::Relaxed)
    }

    /// The file length the plan lets callers see.
    pub fn visible_len(&self, real: u64) -> u64 {
        match self.truncate_at {
            Some(n) => n.min(real),
            None => real,
        }
    }

    /// Gate one read/write attempt at `offset` asking for `want`
    /// bytes: returns the (possibly shortened) length to actually
    /// request, or the scripted transient error. Each call consumes
    /// one operation index.
    pub fn before_read(&self, _offset: u64, want: usize) -> std::io::Result<usize> {
        let op = self.ops.fetch_add(1, Ordering::Relaxed);
        if let Some(&(_, kind)) = self.transient.iter().find(|&&(o, _)| o == op) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return Err(std::io::Error::new(kind, format!("injected transient fault at op {op}")));
        }
        if let Some(&(_, max)) = self.short_reads.iter().find(|&&(o, _)| o == op) {
            if want > max {
                self.injected.fetch_add(1, Ordering::Relaxed);
                return Ok(max);
            }
        }
        Ok(want)
    }

    /// Apply scripted bit flips to data just read from `offset`.
    pub fn after_read(&self, offset: u64, buf: &mut [u8]) {
        for &(at, mask) in &self.flips {
            if at >= offset && at < offset + buf.len() as u64 {
                buf[(at - offset) as usize] ^= mask;
                self.injected.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// A `Read` adapter driving a [`FaultPlan`] over any inner reader
/// (tracks its own stream position for the flip offsets).
pub struct FaultReader<R: Read> {
    inner: R,
    plan: FaultPlan,
    pos: u64,
}

impl<R: Read> FaultReader<R> {
    pub fn new(inner: R, plan: FaultPlan) -> Self {
        Self { inner, plan, pos: 0 }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

impl<R: Read> Read for FaultReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let mut want = buf.len();
        let visible = self.plan.visible_len(u64::MAX);
        if self.pos >= visible {
            return Ok(0);
        }
        want = want.min((visible - self.pos) as usize);
        want = self.plan.before_read(self.pos, want)?;
        let n = self.inner.read(&mut buf[..want])?;
        self.plan.after_read(self.pos, &mut buf[..n]);
        self.pos += n as u64;
        Ok(n)
    }
}

/// A `Write` adapter driving a [`FaultPlan`] over any inner writer:
/// transient errors and short writes come from the same script
/// machinery as reads; `truncate_at` becomes "disk full at byte N"
/// (a hard `WriteZero` error, since a writer cannot salvage past a
/// full disk).
pub struct FaultWriter<W: Write> {
    inner: W,
    plan: FaultPlan,
    pos: u64,
}

impl<W: Write> FaultWriter<W> {
    pub fn new(inner: W, plan: FaultPlan) -> Self {
        Self { inner, plan, pos: 0 }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for FaultWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if let Some(full_at) = self.plan.truncate_at {
            if self.pos >= full_at {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "injected: disk full",
                ));
            }
        }
        let want = self.plan.before_read(self.pos, buf.len())?;
        let n = self.inner.write(&buf[..want])?;
        self.pos += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// A positioned-read file with a [`FaultPlan`] armed — the same shape
/// [`crate::pipeline::dataset::FileSource`] exposes, for harness code
/// that wants faulted `pread`-style access without a `.czs` archive.
pub struct FaultFile {
    file: std::fs::File,
    len: u64,
    plan: FaultPlan,
}

impl FaultFile {
    pub fn open(path: &std::path::Path, plan: FaultPlan) -> std::io::Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        Ok(Self { file, len, plan })
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// One positioned read attempt through the plan (0 = EOF). May
    /// return fewer bytes than asked, exactly like `pread(2)`.
    pub fn read_at(&self, buf: &mut [u8], offset: u64) -> std::io::Result<usize> {
        let visible = self.plan.visible_len(self.len);
        if offset >= visible {
            return Ok(0);
        }
        let mut want = buf.len().min((visible - offset) as usize);
        want = self.plan.before_read(offset, want)?;
        let n = {
            #[cfg(unix)]
            {
                use std::os::unix::fs::FileExt;
                self.file.read_at(&mut buf[..want], offset)?
            }
            #[cfg(not(unix))]
            {
                use std::io::{Read, Seek, SeekFrom};
                let mut f = &self.file;
                f.seek(SeekFrom::Start(offset))?;
                f.read(&mut buf[..want])?
            }
        };
        self.plan.after_read(offset, &mut buf[..n]);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_transparent() {
        let data = b"0123456789abcdef".to_vec();
        let mut r = FaultReader::new(data.as_slice(), FaultPlan::new());
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
        assert_eq!(r.plan().injected(), 0);
    }

    #[test]
    fn scripted_faults_fire_once_at_their_op() {
        let data = vec![0u8; 64];
        let plan = FaultPlan::new()
            .fail_op(0, std::io::ErrorKind::Interrupted)
            .short_read(1, 3)
            .flip_bit(10, 0x01);
        let mut r = FaultReader::new(data.as_slice(), plan);
        let mut buf = [0u8; 64];
        // op 0: transient
        let e = r.read(&mut buf).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::Interrupted);
        // op 1: short read of at most 3 bytes
        let n = r.read(&mut buf).unwrap();
        assert_eq!(n, 3);
        // draining picks up the flipped bit at absolute offset 10
        let mut rest = Vec::new();
        r.read_to_end(&mut rest).unwrap();
        let mut whole = buf[..n].to_vec();
        whole.extend_from_slice(&rest);
        assert_eq!(whole.len(), 64);
        assert_eq!(whole[10], 0x01);
        assert!(whole.iter().enumerate().all(|(i, &b)| i == 10 || b == 0));
        assert_eq!(r.plan().injected(), 3);
    }

    #[test]
    fn truncation_reads_eof_and_writes_disk_full() {
        let data = vec![7u8; 32];
        let mut r = FaultReader::new(data.as_slice(), FaultPlan::new().truncate_at(20));
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, vec![7u8; 20]);

        let mut w = FaultWriter::new(Vec::new(), FaultPlan::new().truncate_at(5));
        w.write_all(&[1, 2, 3]).unwrap();
        w.write_all(&[4, 5]).unwrap();
        let err = w.write_all(&[6]).unwrap_err();
        assert!(err.to_string().contains("disk full"), "{err}");
        assert_eq!(w.into_inner(), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn writer_transients_and_short_writes_fire_too() {
        let plan = FaultPlan::new()
            .fail_op(1, std::io::ErrorKind::Interrupted)
            .short_read(2, 2);
        let mut w = FaultWriter::new(Vec::new(), plan);
        assert_eq!(w.write(b"ab").unwrap(), 2); // op 0: clean
        let e = w.write(b"cd").unwrap_err(); // op 1: transient
        assert_eq!(e.kind(), std::io::ErrorKind::Interrupted);
        assert_eq!(w.write(b"cdef").unwrap(), 2); // op 2: short
        assert_eq!(w.plan().injected(), 2);
        assert_eq!(w.into_inner(), b"abcd".to_vec());
    }
}
