//! `h5lite`: a minimal single-file container for named 3D f32 datasets —
//! the HDF5 stand-in (the real parallel HDF5 library is not available in
//! this environment; the paper uses HDF5 only as the input/visualization
//! container, not as the compression path under test).
//!
//! Layout: `"H5L1" | u32 ndatasets | table | payloads`, table entry =
//! `u8 name_len | name | u32 nx ny nz | u64 offset`.
use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;

/// One named 3D dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub nx: u32,
    pub ny: u32,
    pub nz: u32,
    pub data: Vec<f32>,
}

impl Dataset {
    pub fn new(name: &str, nx: usize, ny: usize, nz: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), nx * ny * nz);
        Self { name: name.into(), nx: nx as u32, ny: ny as u32, nz: nz as u32, data }
    }

    pub fn from_field(name: &str, f: &crate::core::Field3) -> Self {
        Self::new(name, f.nx, f.ny, f.nz, f.data.clone())
    }

    pub fn to_field(&self) -> crate::core::Field3 {
        crate::core::Field3::from_vec(
            self.nx as usize,
            self.ny as usize,
            self.nz as usize,
            self.data.clone(),
        )
    }
}

const MAGIC: &[u8; 4] = b"H5L1";

/// Write datasets to `path`.
pub fn write(path: &Path, datasets: &[Dataset]) -> std::io::Result<()> {
    let mut table = Vec::new();
    let mut header_len = 4 + 4;
    for d in datasets {
        header_len += 1 + d.name.len() + 12 + 8;
    }
    let mut offset = header_len as u64;
    for d in datasets {
        let name = d.name.as_bytes();
        assert!(name.len() <= 255);
        table.push(name.len() as u8);
        table.extend_from_slice(name);
        for v in [d.nx, d.ny, d.nz] {
            table.extend_from_slice(&v.to_le_bytes());
        }
        table.extend_from_slice(&offset.to_le_bytes());
        offset += (d.data.len() * 4) as u64;
    }
    let mut f = std::io::BufWriter::new(File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(datasets.len() as u32).to_le_bytes())?;
    f.write_all(&table)?;
    for d in datasets {
        // SAFETY-free path: serialize via chunks (f32 -> LE bytes)
        let mut buf = Vec::with_capacity(d.data.len() * 4);
        for v in &d.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&buf)?;
    }
    f.flush()
}

/// List dataset names and dims without loading payloads.
pub fn list(path: &Path) -> Result<Vec<(String, u32, u32, u32)>, String> {
    let bytes = std::fs::read(path).map_err(|e| e.to_string())?;
    let (table, _) = parse_table(&bytes)?;
    Ok(table.into_iter().map(|(n, nx, ny, nz, _)| (n, nx, ny, nz)).collect())
}

type TableEntry = (String, u32, u32, u32, u64);

fn parse_table(bytes: &[u8]) -> Result<(Vec<TableEntry>, usize), String> {
    if bytes.len() < 8 || &bytes[0..4] != MAGIC {
        return Err("not an h5lite file".into());
    }
    let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let mut pos = 8;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if bytes.len() < pos + 1 {
            return Err("truncated table".into());
        }
        let nl = bytes[pos] as usize;
        pos += 1;
        if bytes.len() < pos + nl + 20 {
            return Err("truncated table entry".into());
        }
        let name = String::from_utf8_lossy(&bytes[pos..pos + nl]).into_owned();
        pos += nl;
        let rd = |p: usize| u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap());
        let (nx, ny, nz) = (rd(pos), rd(pos + 4), rd(pos + 8));
        pos += 12;
        let offset = u64::from_le_bytes(bytes[pos..pos + 8].try_into().unwrap());
        pos += 8;
        out.push((name, nx, ny, nz, offset));
    }
    Ok((out, pos))
}

/// Decode one table entry's payload out of the full file buffer. All
/// size arithmetic is checked: a corrupt table with oversized dims must
/// error here, not wrap past the truncation check (or panic later when
/// the dims disagree with the decoded length).
fn decode_entry(bytes: &[u8], entry: TableEntry) -> Result<Dataset, String> {
    let (name, nx, ny, nz, offset) = entry;
    let len = (nx as usize)
        .checked_mul(ny as usize)
        .and_then(|v| v.checked_mul(nz as usize))
        .ok_or_else(|| format!("dataset {name}: dims {nx}x{ny}x{nz} overflow"))?;
    let lo = offset as usize;
    let hi = len
        .checked_mul(4)
        .and_then(|b| lo.checked_add(b))
        .ok_or_else(|| "payload offset overflow".to_string())?;
    if bytes.len() < hi {
        return Err("payload truncated".into());
    }
    let data: Vec<f32> = bytes[lo..hi]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Dataset { name, nx, ny, nz, data })
}

/// Read one dataset by name.
pub fn read(path: &Path, name: &str) -> Result<Dataset, String> {
    let mut bytes = Vec::new();
    File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .map_err(|e| e.to_string())?;
    let (table, _) = parse_table(&bytes)?;
    let entry = table
        .into_iter()
        .find(|(n, ..)| n == name)
        .ok_or_else(|| format!("dataset {name} not found"))?;
    decode_entry(&bytes, entry)
}

/// Read all datasets from ONE file read + table parse, shared by every
/// entry — what the multi-stream compress flow fans out over (per-entry
/// `read` calls would re-load the whole container once per dataset).
pub fn read_all(path: &Path) -> Result<Vec<Dataset>, String> {
    let mut bytes = Vec::new();
    File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .map_err(|e| e.to_string())?;
    let (table, _) = parse_table(&bytes)?;
    table.into_iter().map(|entry| decode_entry(&bytes, entry)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("cubismz_h5lite_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn roundtrip_multiple_datasets() {
        let mut rng = Pcg32::new(5);
        let mut d1 = vec![0f32; 4 * 6 * 8];
        rng.fill_f32(&mut d1, -1.0, 1.0);
        let mut d2 = vec![0f32; 16];
        rng.fill_f32(&mut d2, 0.0, 9.0);
        let p = tmp("rt.h5l");
        write(
            &p,
            &[Dataset::new("pressure", 4, 6, 8, d1.clone()), Dataset::new("rho", 4, 2, 2, d2.clone())],
        )
        .unwrap();
        let names = list(&p).unwrap();
        assert_eq!(names.len(), 2);
        assert_eq!(names[0].0, "pressure");
        let back = read(&p, "pressure").unwrap();
        assert_eq!(back.data, d1);
        assert_eq!((back.nx, back.ny, back.nz), (4, 6, 8));
        let back2 = read(&p, "rho").unwrap();
        assert_eq!(back2.data, d2);
        assert!(read(&p, "nope").is_err());
    }

    #[test]
    fn read_all_order_preserved() {
        let p = tmp("all.h5l");
        write(
            &p,
            &[
                Dataset::new("a", 2, 2, 2, vec![1.0; 8]),
                Dataset::new("b", 2, 2, 2, vec![2.0; 8]),
            ],
        )
        .unwrap();
        let all = read_all(&p).unwrap();
        assert_eq!(all[0].name, "a");
        assert_eq!(all[1].name, "b");
        assert_eq!(all[1].data, vec![2.0; 8]);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad.h5l");
        std::fs::write(&p, b"not a container").unwrap();
        assert!(read(&p, "x").is_err());
        assert!(list(&p).is_err());
    }
}
