//! I/O layer: the `h5lite` container (HDF5 substitute — see DESIGN.md §4),
//! raw binary readers, the exscan-offset shared-file parallel writer,
//! filesystem throughput measurement (HACC-IO-style baseline), and a
//! deterministic fault-injection harness ([`fault`]) for proving the
//! integrity layers end to end.
pub mod fault;
pub mod h5lite;
pub mod parallel;
pub mod raw;
pub mod throughput;
