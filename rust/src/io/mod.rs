//! I/O layer: the `h5lite` container (HDF5 substitute — see DESIGN.md §4),
//! raw binary readers, the exscan-offset shared-file parallel writer, and
//! filesystem throughput measurement (HACC-IO-style baseline).
pub mod h5lite;
pub mod parallel;
pub mod raw;
pub mod throughput;
