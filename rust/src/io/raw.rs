//! Raw binary field readers/writers (the paper supports plain binary
//! dumps next to HDF5, e.g. NEK5000/NGA exports).
use crate::core::Field3;
use std::io::{Read, Write};
use std::path::Path;

/// Write a bare little-endian f32 dump (no header; dims are external).
pub fn write_f32(path: &Path, data: &[f32]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    f.flush()
}

/// Read a bare f32 dump as a field of the given dims.
pub fn read_f32(path: &Path, nx: usize, ny: usize, nz: usize) -> Result<Field3, String> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .map_err(|e| e.to_string())?;
    if bytes.len() != nx * ny * nz * 4 {
        return Err(format!(
            "size mismatch: file {} bytes, dims want {}",
            bytes.len(),
            nx * ny * nz * 4
        ));
    }
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Field3::from_vec(nx, ny, nz, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn roundtrip() {
        let d = std::env::temp_dir().join("cubismz_raw_tests");
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("f.bin");
        let mut rng = Pcg32::new(3);
        let mut data = vec![0f32; 4 * 4 * 4];
        rng.fill_f32(&mut data, -2.0, 2.0);
        write_f32(&p, &data).unwrap();
        let f = read_f32(&p, 4, 4, 4).unwrap();
        assert_eq!(f.data, data);
        assert!(read_f32(&p, 8, 4, 4).is_err());
    }
}
