//! Quality metrics: compression ratio and PSNR (paper §3, eq. 1).

/// Mean squared error between two equally sized datasets.
pub fn mse(r: &[f32], d: &[f32]) -> f64 {
    assert_eq!(r.len(), d.len());
    assert!(!r.is_empty());
    let mut acc = 0.0f64;
    for (a, b) in r.iter().zip(d) {
        let e = (*a as f64) - (*b as f64);
        acc += e * e;
    }
    acc / r.len() as f64
}

/// Peak signal-to-noise ratio per paper eq. (1):
/// `PSNR = 20 log10( (max_R - min_R) / (2 sqrt(MSE)) )` in dB.
/// Identical datasets give +inf.
pub fn psnr(reference: &[f32], decoded: &[f32]) -> f64 {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in reference {
        lo = lo.min(v as f64);
        hi = hi.max(v as f64);
    }
    let m = mse(reference, decoded);
    if m == 0.0 {
        return f64::INFINITY;
    }
    20.0 * ((hi - lo) / (2.0 * m.sqrt())).log10()
}

/// Compression ratio: raw bytes / compressed bytes (incl. metadata).
pub fn compression_ratio(raw_bytes: usize, compressed_bytes: usize) -> f64 {
    assert!(compressed_bytes > 0);
    raw_bytes as f64 / compressed_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_infinite() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert!(psnr(&a, &a).is_infinite());
    }

    #[test]
    fn known_psnr_value() {
        // range 1, uniform error 0.5 -> mse 0.25 -> 20 log10(1/(2*0.5)) = 0 dB
        let r = vec![0.0f32, 1.0];
        let d = vec![0.5f32, 0.5];
        assert!((psnr(&r, &d) - 0.0).abs() < 1e-9);
    }

    #[test]
    fn smaller_error_higher_psnr() {
        let r: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let d1: Vec<f32> = r.iter().map(|v| v + 0.1).collect();
        let d2: Vec<f32> = r.iter().map(|v| v + 0.01).collect();
        assert!(psnr(&r, &d2) > psnr(&r, &d1) + 19.0);
    }

    #[test]
    fn cr_basic() {
        assert_eq!(compression_ratio(100, 10), 10.0);
    }

    #[test]
    #[should_panic]
    fn mse_len_mismatch_panics() {
        mse(&[1.0], &[1.0, 2.0]);
    }
}
