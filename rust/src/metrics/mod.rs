//! Quality metrics: compression ratio and PSNR (paper §3, eq. 1) —
//! plus the live operational metric registry ([`registry`]) the
//! service front-end exports.
//!
//! Every metric returns `Option` rather than asserting: these run over
//! *decoded* data, which after a salvage decode may be empty,
//! length-mismatched or hole-ridden — a verification report must say
//! "undefined" for such inputs, not bring the tool down mid-report.
pub mod registry;

/// Mean squared error between two equally sized datasets. `None` when
/// the inputs are empty or differ in length (the metric is undefined,
/// not zero).
pub fn mse(r: &[f32], d: &[f32]) -> Option<f64> {
    if r.is_empty() || r.len() != d.len() {
        return None;
    }
    let mut acc = 0.0f64;
    for (a, b) in r.iter().zip(d) {
        let e = (*a as f64) - (*b as f64);
        acc += e * e;
    }
    Some(acc / r.len() as f64)
}

/// Peak signal-to-noise ratio per paper eq. (1):
/// `PSNR = 20 log10( (max_R - min_R) / (2 sqrt(MSE)) )` in dB.
/// Identical datasets give `Some(+inf)`. The reference range scans
/// finite values only (a stray NaN in a salvaged field must not poison
/// the whole figure); `None` when the reference has no finite values,
/// the inputs are empty/mismatched, or the error itself is non-finite.
pub fn psnr(reference: &[f32], decoded: &[f32]) -> Option<f64> {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in reference {
        if v.is_finite() {
            lo = lo.min(v as f64);
            hi = hi.max(v as f64);
        }
    }
    if lo > hi {
        return None; // no finite reference values
    }
    let m = mse(reference, decoded)?;
    if m == 0.0 {
        return Some(f64::INFINITY);
    }
    if !m.is_finite() {
        return None;
    }
    Some(20.0 * ((hi - lo) / (2.0 * m.sqrt())).log10())
}

/// Compression ratio: raw bytes / compressed bytes (incl. metadata).
/// `None` for zero compressed bytes (nothing was produced — a failed
/// or skipped quantity, not an infinitely good one).
pub fn compression_ratio(raw_bytes: usize, compressed_bytes: usize) -> Option<f64> {
    if compressed_bytes == 0 {
        return None;
    }
    Some(raw_bytes as f64 / compressed_bytes as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_infinite() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert!(psnr(&a, &a).unwrap().is_infinite());
    }

    #[test]
    fn known_psnr_value() {
        // range 1, uniform error 0.5 -> mse 0.25 -> 20 log10(1/(2*0.5)) = 0 dB
        let r = vec![0.0f32, 1.0];
        let d = vec![0.5f32, 0.5];
        assert!((psnr(&r, &d).unwrap() - 0.0).abs() < 1e-9);
    }

    #[test]
    fn smaller_error_higher_psnr() {
        let r: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let d1: Vec<f32> = r.iter().map(|v| v + 0.1).collect();
        let d2: Vec<f32> = r.iter().map(|v| v + 0.01).collect();
        assert!(psnr(&r, &d2).unwrap() > psnr(&r, &d1).unwrap() + 19.0);
    }

    #[test]
    fn cr_basic() {
        assert_eq!(compression_ratio(100, 10), Some(10.0));
        assert_eq!(compression_ratio(100, 0), None);
    }

    #[test]
    fn undefined_inputs_are_none_not_panics() {
        assert_eq!(mse(&[1.0], &[1.0, 2.0]), None);
        assert_eq!(mse(&[], &[]), None);
        assert_eq!(psnr(&[], &[]), None);
        assert_eq!(psnr(&[1.0], &[1.0, 2.0]), None);
    }

    #[test]
    fn nan_reference_does_not_poison_the_range() {
        // a salvaged hole (or upstream NaN) in the reference: the range
        // comes from the finite values, the MSE still counts every pair
        let r = vec![0.0f32, f32::NAN, 1.0];
        let d = vec![0.5f32, f32::NAN, 0.5];
        // NaN - NaN = NaN -> mse non-finite -> undefined, but no panic
        assert_eq!(psnr(&r, &d), None);
        // all-NaN reference has no range at all
        assert_eq!(psnr(&[f32::NAN; 4], &[0.0; 4]), None);
        // finite pairs with a NaN-free error stay defined
        let r = vec![0.0f32, 1.0, f32::INFINITY];
        let d = vec![0.5f32, 0.5, f32::INFINITY];
        // inf - inf = NaN -> undefined; drop the pair and it's 0 dB
        assert_eq!(psnr(&r, &d), None);
        assert!((psnr(&r[..2], &d[..2]).unwrap() - 0.0).abs() < 1e-9);
    }
}
