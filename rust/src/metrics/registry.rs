//! Live operational metrics: lock-free counters, gauges and log-scale
//! histograms, aggregated in a [`Registry`] the long-running service
//! front-end (`czb serve`) exports as a plaintext `stat` response.
//!
//! The per-run numbers in `BENCH_*.json` answer "how fast is this
//! build"; this registry answers "what is this *process* doing right
//! now" — requests and responses by type, bytes in/out, engine stage
//! timings, queue depth, per-tenant usage. Everything on the hot path
//! is a relaxed atomic add (no locks, no allocation); only the
//! per-tenant map takes a short mutex, once per request, keyed by the
//! tenant id in the request header.
//!
//! Histograms are fixed log₂ buckets over microseconds (bucket *i*
//! covers `[2^i, 2^{i+1})` µs, 32 buckets ≈ up to 71 minutes), so a
//! quantile read costs one pass over 32 counters and never allocates.
//! Quantiles are upper-bound estimates — each sample reports the top of
//! its bucket — which is the right bias for latency SLOs (never
//! under-report).
use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonically increasing event count.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (queue depth, open connections): goes up and
/// down, may be read mid-flight.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Self {
        Self(AtomicI64::new(0))
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn sub(&self, v: i64) {
        self.0.fetch_sub(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log₂-bucketed latency histogram over microseconds. See the module
/// docs for the bucket layout and quantile bias.
pub struct Histogram {
    buckets: [AtomicU64; Histogram::NBUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    const NBUCKETS: usize = 32;

    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }

    fn bucket_of(micros: u64) -> usize {
        // floor(log2(max(v,1))), clamped into the table
        (63 - micros.max(1).leading_zeros() as usize).min(Self::NBUCKETS - 1)
    }

    /// Upper bound of bucket `i` in seconds (what quantiles report).
    fn bucket_upper_secs(i: usize) -> f64 {
        (1u64 << (i + 1).min(63)) as f64 * 1e-6
    }

    pub fn record_secs(&self, secs: f64) {
        let micros = (secs.max(0.0) * 1e6) as u64;
        self.buckets[Self::bucket_of(micros)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total recorded time in seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_micros.load(Ordering::Relaxed) as f64 * 1e-6
    }

    /// Approximate quantile `q` in `[0, 1]`, in seconds: the upper bound
    /// of the bucket containing the `ceil(q·count)`-th sample. `None`
    /// when nothing was recorded. Reads are racy against concurrent
    /// records by design — a monitoring read never blocks the hot path.
    pub fn quantile_secs(&self, q: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Some(Self::bucket_upper_secs(i));
            }
        }
        Some(Self::bucket_upper_secs(Self::NBUCKETS - 1))
    }
}

/// What one tenant (request-header id) has consumed so far.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantUsage {
    pub requests: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Requests refused by that tenant's token bucket.
    pub throttled: u64,
}

/// Number of finite buckets in a [`PsnrHist`].
pub const PSNR_BUCKETS: usize = 16;
/// Width of each finite [`PsnrHist`] bucket in dB.
pub const PSNR_BUCKET_DB: f64 = 10.0;
/// Infinite PSNR (bit-exact compression) contributes this capped value
/// to [`PsnrHist::sum_db`] so the mean stays finite.
pub const PSNR_CAP_DB: f64 = 300.0;

/// Distribution of the quality one tenant's compress requests actually
/// achieved, in PSNR dB. Fixed 10 dB buckets: bucket *i* counts samples
/// in `[10·i, 10·(i+1))`; `overflow` catches ≥ 160 dB and the infinite
/// PSNR of a bit-exact stream. Plain (non-atomic) fields — mutated
/// under the registry's tenant mutex, once per compress request.
#[derive(Clone, Copy, Debug, Default)]
pub struct PsnrHist {
    pub buckets: [u64; PSNR_BUCKETS],
    pub overflow: u64,
    pub count: u64,
    /// Sum of recorded dB (infinities capped at [`PSNR_CAP_DB`]).
    pub sum_db: f64,
}

impl PsnrHist {
    pub fn record(&mut self, db: f64) {
        // NaN cannot happen on the measurement path; clamp defensively
        // so a rogue value can never poison the whole histogram
        let v = if db.is_nan() { 0.0 } else { db.max(0.0) };
        let bucket = (v / PSNR_BUCKET_DB) as usize;
        if bucket < PSNR_BUCKETS {
            self.buckets[bucket] += 1;
        } else {
            self.overflow += 1;
        }
        self.count += 1;
        self.sum_db += v.min(PSNR_CAP_DB);
    }

    /// Mean achieved PSNR in dB (0 when nothing was recorded).
    pub fn mean_db(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_db / self.count as f64
        }
    }
}

/// Request operations the service meters, in wire order.
pub const OPS: [&str; 5] = ["compress", "decompress", "verify", "stat", "shutdown"];
/// Response statuses the service meters, in wire order.
pub const STATUSES: [&str; 6] = ["ok", "error", "busy", "quota", "shutting_down", "bad_request"];

/// The process-wide metric set. One instance is shared (`Arc`) between
/// the service front-end, the [`crate::pipeline::Engine`] it drives
/// (via `EngineBuilder::metrics`) and the exporter
/// ([`crate::service::metrics_export`]).
#[derive(Default)]
pub struct Registry {
    /// Requests received, by operation (indexed like [`OPS`]).
    pub requests: [Counter; OPS.len()],
    /// Responses sent, by status (indexed like [`STATUSES`]).
    pub responses: [Counter; STATUSES.len()],
    /// Request/response body bytes moved over the wire.
    pub bytes_in: Counter,
    pub bytes_out: Counter,
    /// Admitted submissions currently in flight (admission queue depth).
    pub queue_depth: Gauge,
    /// Open client connections.
    pub connections: Gauge,
    /// End-to-end request latency by operation (compress, decompress,
    /// verify — stat/shutdown are too cheap to matter).
    pub latency_compress: Histogram,
    pub latency_decompress: Histogram,
    pub latency_verify: Histogram,
    /// Engine-side totals, recorded by `Engine::compress`/`decompress*`
    /// whatever the caller (service, CLI batch, tests).
    pub engine_compress_calls: Counter,
    pub engine_decompress_calls: Counter,
    pub engine_raw_bytes: Counter,
    pub engine_compressed_bytes: Counter,
    pub engine_decoded_bytes: Counter,
    /// Stage wall-time totals in microseconds (summed over threads).
    pub stage1_micros: Counter,
    pub stage2_micros: Counter,
    tenants: Mutex<HashMap<String, TenantUsage>>,
    /// Achieved-PSNR distribution per tenant, fed by successful
    /// compress requests.
    tenant_psnr: Mutex<HashMap<String, PsnrHist>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one request for `tenant` ("" meters as the anonymous
    /// tenant), with the body bytes it brought and took away.
    pub fn record_tenant(&self, tenant: &str, bytes_in: u64, bytes_out: u64, throttled: bool) {
        let mut g = self.tenants.lock().unwrap();
        let u = g.entry(tenant.to_string()).or_default();
        u.requests += 1;
        u.bytes_in += bytes_in;
        u.bytes_out += bytes_out;
        if throttled {
            u.throttled += 1;
        }
    }

    /// Record the PSNR one successful compress achieved for `tenant`
    /// ("" meters as the anonymous tenant).
    pub fn record_tenant_psnr(&self, tenant: &str, psnr_db: f64) {
        let mut g = self.tenant_psnr.lock().unwrap();
        g.entry(tenant.to_string()).or_default().record(psnr_db);
    }

    /// Per-tenant achieved-PSNR histograms, sorted by tenant id for a
    /// stable export order.
    pub fn tenant_psnr_snapshot(&self) -> Vec<(String, PsnrHist)> {
        let g = self.tenant_psnr.lock().unwrap();
        let mut v: Vec<(String, PsnrHist)> = g.iter().map(|(k, u)| (k.clone(), *u)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Per-tenant usage, sorted by tenant id for a stable export order.
    pub fn tenants_snapshot(&self) -> Vec<(String, TenantUsage)> {
        let g = self.tenants.lock().unwrap();
        let mut v: Vec<(String, TenantUsage)> =
            g.iter().map(|(k, u)| (k.clone(), *u)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Latency histogram for a request op, when that op is metered.
    pub fn latency_of(&self, op_index: usize) -> Option<&Histogram> {
        match OPS.get(op_index).copied() {
            Some("compress") => Some(&self.latency_compress),
            Some("decompress") => Some(&self.latency_decompress),
            Some("verify") => Some(&self.latency_verify),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.add(3);
        g.sub(1);
        assert_eq!(g.get(), 2);
        g.set(-7);
        assert_eq!(g.get(), -7);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = Histogram::new();
        assert_eq!(h.quantile_secs(0.5), None);
        // 90 fast samples at ~100µs, 10 slow at ~50ms
        for _ in 0..90 {
            h.record_secs(100e-6);
        }
        for _ in 0..10 {
            h.record_secs(50e-3);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_secs(0.5).unwrap();
        let p99 = h.quantile_secs(0.99).unwrap();
        // p50 lands in the fast bucket (upper bound <= 256µs), p99 in the
        // slow one (upper bound >= 50ms); quantiles never under-report
        assert!(p50 >= 100e-6 && p50 <= 512e-6, "p50 {p50}");
        assert!(p99 >= 50e-3, "p99 {p99}");
        assert!(p99 <= 0.2, "p99 {p99}");
        assert!((h.sum_secs() - (90.0 * 100e-6 + 10.0 * 50e-3)).abs() < 1e-3);
        // monotone in q
        assert!(h.quantile_secs(1.0).unwrap() >= p99);
        assert!(h.quantile_secs(0.0).unwrap() <= p50);
    }

    #[test]
    fn histogram_handles_extremes() {
        let h = Histogram::new();
        h.record_secs(0.0); // clamps to the first bucket
        h.record_secs(1e9); // clamps to the last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile_secs(1.0).unwrap() > 0.0);
    }

    #[test]
    fn tenant_usage_accumulates_per_id() {
        let r = Registry::new();
        r.record_tenant("a", 100, 50, false);
        r.record_tenant("b", 10, 0, true);
        r.record_tenant("a", 1, 2, false);
        let snap = r.tenants_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[0].1.requests, 2);
        assert_eq!(snap[0].1.bytes_in, 101);
        assert_eq!(snap[0].1.bytes_out, 52);
        assert_eq!(snap[0].1.throttled, 0);
        assert_eq!(snap[1].1.throttled, 1);
    }

    #[test]
    fn tenant_psnr_histogram_buckets_and_caps() {
        let r = Registry::new();
        r.record_tenant_psnr("a", 57.3); // bucket [50, 60)
        r.record_tenant_psnr("a", 57.9);
        r.record_tenant_psnr("a", f64::INFINITY); // lossless -> overflow, capped sum
        r.record_tenant_psnr("a", -3.0); // clamps into bucket 0
        r.record_tenant_psnr("b", 200.0); // beyond the finite range
        let snap = r.tenant_psnr_snapshot();
        assert_eq!(snap.len(), 2);
        let (ref name, h) = snap[0];
        assert_eq!(name, "a");
        assert_eq!(h.count, 4);
        assert_eq!(h.buckets[5], 2);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.overflow, 1);
        let expect = 57.3 + 57.9 + PSNR_CAP_DB + 0.0;
        assert!((h.sum_db - expect).abs() < 1e-9, "{}", h.sum_db);
        assert!((h.mean_db() - expect / 4.0).abs() < 1e-9);
        assert_eq!(snap[1].1.overflow, 1);
        // NaN is clamped, never poisons the sum
        r.record_tenant_psnr("a", f64::NAN);
        let snap = r.tenant_psnr_snapshot();
        assert!(snap[0].1.sum_db.is_finite());
        assert_eq!(snap[0].1.buckets[0], 2);
    }

    #[test]
    fn latency_of_maps_metered_ops() {
        let r = Registry::new();
        assert!(r.latency_of(0).is_some());
        assert!(r.latency_of(1).is_some());
        assert!(r.latency_of(2).is_some());
        assert!(r.latency_of(3).is_none(), "stat is not metered");
        assert!(r.latency_of(99).is_none());
    }

    #[test]
    fn registry_is_send_sync() {
        const fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Registry>();
    }
}
