//! One client connection: the per-frame request loop between a
//! `Read`/`Write` pair and the shared engine.
//!
//! The handler is generic over the transport so tests can drive it with
//! in-memory buffers and the fault-injection adapters from
//! [`crate::io::fault`] — the TCP server wraps a `TcpStream` in
//! [`IdleAwareReader`] and hands it here.
//!
//! # Close policy
//!
//! The frame layer tells three situations apart and each has exactly
//! one outcome — never a panic, a hang, or a submission left behind:
//!
//! * clean EOF between frames → the client is done, close quietly;
//! * malformed frame (bad magic/version/op, oversized declaration,
//!   mid-header truncation) → one `bad_request` response, then close:
//!   the stream position can no longer be trusted;
//! * I/O error → close; the peer is gone.
//!
//! Within a well-formed frame, a *semantic* failure (corrupt `.czb`
//! body, undecodable field) earns an `error` response and the
//! connection stays open — except a compress body that fails mid-parse,
//! which also desyncs the stream and closes after responding.
//!
//! Refused requests (admission `busy`, quota, draining) have their
//! declared body drained so the next frame still parses.
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::verify_czb_bytes;
use crate::metrics::registry::Registry;
use crate::pipeline::stage1::default_scheme_for;
use crate::pipeline::{Bound, CompressParams, Engine, PipelineConfig};

use super::admission::Admission;
use super::metrics_export;
use super::proto::{self, FrameError, Op, RequestHeader, Status, VerifySummary};
use super::quota::Quota;

/// Everything a connection handler shares with its siblings.
#[derive(Clone)]
pub struct ConnCtx {
    pub engine: Arc<Engine>,
    pub metrics: Arc<Registry>,
    pub admission: Admission,
    pub quota: Arc<Quota>,
    /// Drain flag: set by a `shutdown` request or SIGTERM. Work ops are
    /// refused with `shutting_down`; `stat` and `shutdown` still serve.
    pub stop: Arc<AtomicBool>,
    /// Largest request body this server will accept.
    pub max_body: u64,
}

impl ConnCtx {
    pub fn new(
        engine: Arc<Engine>,
        metrics: Arc<Registry>,
        admission: Admission,
        quota: Arc<Quota>,
    ) -> Self {
        Self {
            engine,
            metrics,
            admission,
            quota,
            stop: Arc::new(AtomicBool::new(false)),
            max_body: proto::DEFAULT_MAX_BODY,
        }
    }

    pub fn with_max_body(mut self, n: u64) -> Self {
        self.max_body = n;
        self
    }
}

/// How a connection ended (for tests and server logs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnOutcome {
    /// EOF at a frame boundary — the client hung up normally.
    CleanClose,
    /// A malformed or desynced frame: the peer got one diagnostic
    /// response (when the pipe still worked), then we closed.
    ProtocolError,
    /// The transport failed mid-frame.
    IoError,
}

/// Serve frames until the connection ends. See the module docs for the
/// close policy.
pub fn serve_connection<R: Read, W: Write>(r: &mut R, w: &mut W, ctx: &ConnCtx) -> ConnOutcome {
    loop {
        let hdr = match proto::read_request_header(r, ctx.max_body) {
            Ok(h) => h,
            Err(FrameError::Eof) => return ConnOutcome::CleanClose,
            Err(FrameError::Malformed(m)) => {
                ctx.metrics.responses[Status::BadRequest.index()].inc();
                let _ = proto::write_response(w, Status::BadRequest, 0, m.as_bytes());
                return ConnOutcome::ProtocolError;
            }
            Err(FrameError::Io(_)) => return ConnOutcome::IoError,
        };
        ctx.metrics.requests[hdr.op.index()].inc();
        ctx.metrics.bytes_in.add(hdr.body_len);
        match handle_request(r, w, ctx, &hdr) {
            Ok(true) => {}
            Ok(false) => return ConnOutcome::ProtocolError,
            Err(_) => return ConnOutcome::IoError,
        }
    }
}

/// Handle one request whose header has been read. `Ok(true)` keeps the
/// connection open, `Ok(false)` closes it after a diagnostic response,
/// `Err` is a transport failure.
fn handle_request<R: Read, W: Write>(
    r: &mut R,
    w: &mut W,
    ctx: &ConnCtx,
    hdr: &RequestHeader,
) -> std::io::Result<bool> {
    match hdr.op {
        // stat and shutdown serve even while draining — an operator
        // watching a drain needs both.
        Op::Stat => {
            proto::drain_body(r, hdr.body_len)?;
            ctx.metrics.queue_depth.set(ctx.admission.in_flight() as i64);
            let text = metrics_export::render(&ctx.metrics);
            respond(w, ctx, hdr, Status::Ok, 0, text.as_bytes(), false)?;
            Ok(true)
        }
        Op::Shutdown => {
            proto::drain_body(r, hdr.body_len)?;
            ctx.stop.store(true, Ordering::SeqCst);
            respond(w, ctx, hdr, Status::Ok, 0, b"draining", false)?;
            Ok(true)
        }
        Op::Compress | Op::Decompress | Op::Verify => handle_work(r, w, ctx, hdr),
    }
}

fn handle_work<R: Read, W: Write>(
    r: &mut R,
    w: &mut W,
    ctx: &ConnCtx,
    hdr: &RequestHeader,
) -> std::io::Result<bool> {
    if ctx.stop.load(Ordering::SeqCst) {
        proto::drain_body(r, hdr.body_len)?;
        respond(w, ctx, hdr, Status::ShuttingDown, 0, b"server is draining", false)?;
        return Ok(true);
    }
    // Admission first, then quota: the permit is taken *before* the
    // body is read, so a saturated server refuses deterministically
    // even while clients are still streaming bodies — and a quota
    // refusal must not burn a slot it won't use.
    let permit = match ctx.admission.try_acquire(hdr.priority) {
        Ok(p) => p,
        Err(busy) => {
            proto::drain_body(r, hdr.body_len)?;
            respond(
                w,
                ctx,
                hdr,
                Status::Busy,
                busy.retry_after_ms,
                b"admission control: all slots busy",
                false,
            )?;
            return Ok(true);
        }
    };
    ctx.metrics.queue_depth.set(ctx.admission.in_flight() as i64);
    if let Err(t) = ctx.quota.try_consume(&hdr.tenant, hdr.body_len) {
        drop(permit);
        ctx.metrics.queue_depth.set(ctx.admission.in_flight() as i64);
        proto::drain_body(r, hdr.body_len)?;
        respond(
            w,
            ctx,
            hdr,
            Status::Quota,
            t.retry_after_ms,
            b"tenant byte quota exhausted",
            true,
        )?;
        return Ok(true);
    }
    let t0 = Instant::now();
    let keep_open = match hdr.op {
        Op::Compress => {
            match proto::decode_compress_body(r, hdr.body_len) {
                Err(e) => {
                    // a half-parsed compress body desyncs the stream:
                    // respond, then close
                    respond(w, ctx, hdr, Status::Error, 0, e.as_bytes(), false)?;
                    false
                }
                Ok(req) => {
                    let mut params =
                        CompressParams::from_config(&PipelineConfig::paper_default(req.eps));
                    params.bs = req.bs as usize;
                    params.shuffle = req.shuffle;
                    if req.bound != Bound::None {
                        // a request-side contract overrides the default
                        // scheme with the codec that can honor it; the
                        // knob is derived from the bound per field
                        params.stage1 = default_scheme_for(&req.bound)
                            .expect("every non-None bound kind has a default scheme");
                        params.bound = req.bound;
                    }
                    let mut out = Vec::new();
                    match ctx.engine.compress(&req.field, &req.name, &params, &mut out) {
                        Ok(st) => {
                            ctx.metrics.record_tenant_psnr(&hdr.tenant, st.quality.psnr_db);
                            respond_timed(w, ctx, hdr, t0, &out)?
                        }
                        Err(e) => {
                            respond(w, ctx, hdr, Status::Error, 0, e.to_string().as_bytes(), false)?
                        }
                    }
                    true
                }
            }
        }
        Op::Decompress => {
            let body = read_body(r, hdr.body_len)?;
            match ctx.engine.decompress_bytes(&body) {
                Ok((field, file)) => {
                    let out = proto::encode_field_body(&file.name, &field);
                    respond_timed(w, ctx, hdr, t0, &out)?;
                }
                Err(e) => respond(w, ctx, hdr, Status::Error, 0, e.as_bytes(), false)?,
            }
            true
        }
        Op::Verify => {
            let body = read_body(r, hdr.body_len)?;
            let entry = verify_czb_bytes(&body, false, &ctx.engine);
            match &entry.outcome {
                Ok(report) => {
                    let s = VerifySummary {
                        clean: report.is_clean(),
                        total_chunks: report.total_chunks as u32,
                        corrupt_chunks: report.corrupt_chunks.len() as u32,
                        lost_blocks: report.lost_blocks as u64,
                    };
                    respond_timed(w, ctx, hdr, t0, &proto::encode_verify_body(&s))?;
                }
                Err(e) => respond(w, ctx, hdr, Status::Error, 0, e.as_bytes(), false)?,
            }
            true
        }
        _ => unreachable!("handle_work only sees work ops"),
    };
    drop(permit);
    ctx.metrics.queue_depth.set(ctx.admission.in_flight() as i64);
    Ok(keep_open)
}

/// Read a whole declared body into memory (decompress/verify inputs —
/// the decode paths need random access to the stream).
fn read_body<R: Read>(r: &mut R, n: u64) -> std::io::Result<Vec<u8>> {
    let mut body = Vec::with_capacity(n.min(64 << 20) as usize);
    let copied = std::io::copy(&mut r.take(n), &mut body)?;
    if copied != n {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("stream ended {copied} bytes into a {n}-byte body"),
        ));
    }
    Ok(body)
}

/// Send a response and do the per-request accounting (response
/// counter, bytes out, per-tenant usage).
fn respond<W: Write>(
    w: &mut W,
    ctx: &ConnCtx,
    hdr: &RequestHeader,
    status: Status,
    retry_after_ms: u32,
    body: &[u8],
    throttled: bool,
) -> std::io::Result<()> {
    ctx.metrics.responses[status.index()].inc();
    ctx.metrics.bytes_out.add(body.len() as u64);
    ctx.metrics.record_tenant(&hdr.tenant, hdr.body_len, body.len() as u64, throttled);
    proto::write_response(w, status, retry_after_ms, body)
}

/// `respond` for a successful work op: also records end-to-end latency.
fn respond_timed<W: Write>(
    w: &mut W,
    ctx: &ConnCtx,
    hdr: &RequestHeader,
    t0: Instant,
    body: &[u8],
) -> std::io::Result<()> {
    if let Some(h) = ctx.metrics.latency_of(hdr.op.index()) {
        h.record_secs(t0.elapsed().as_secs_f64());
    }
    respond(w, ctx, hdr, Status::Ok, 0, body, false)
}

/// A `Read` adapter for socket transports with a read timeout: retries
/// `WouldBlock`/`TimedOut` so the blocking frame reader above can wait
/// indefinitely for the next frame, *unless* the drain flag is set —
/// then the wait reports EOF and an idle connection closes cleanly
/// instead of pinning the drain forever.
pub struct IdleAwareReader<R> {
    inner: R,
    stop: Arc<AtomicBool>,
}

impl<R: Read> IdleAwareReader<R> {
    pub fn new(inner: R, stop: Arc<AtomicBool>) -> Self {
        Self { inner, stop }
    }
}

impl<R: Read> Read for IdleAwareReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match self.inner.read(buf) {
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if self.stop.load(Ordering::SeqCst) {
                        return Ok(0);
                    }
                }
                other => return other,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Field3;
    use crate::io::fault::{FaultPlan, FaultReader};
    use crate::pipeline::ShuffleMode;
    use crate::service::proto::{
        decode_field_body, read_response_header, write_request, Priority, DEFAULT_MAX_BODY,
    };

    fn test_ctx() -> ConnCtx {
        let metrics = Arc::new(Registry::new());
        let engine = Arc::new(
            Engine::builder().threads(2).metrics(Arc::clone(&metrics)).build(),
        );
        ConnCtx::new(engine, metrics, Admission::new(4, 1, 25), Arc::new(Quota::unlimited()))
    }

    fn test_field() -> Field3 {
        let (nx, ny, nz) = (16, 16, 16);
        let data = (0..nx * ny * nz)
            .map(|i| ((i % 97) as f32 * 0.21).sin())
            .collect();
        Field3::from_vec(nx, ny, nz, data)
    }

    fn read_response(r: &mut dyn Read) -> (Status, u32, Vec<u8>) {
        let h = read_response_header(r, DEFAULT_MAX_BODY).unwrap();
        let mut body = vec![0u8; h.body_len as usize];
        r.read_exact(&mut body).unwrap();
        (h.status, h.retry_after_ms, body)
    }

    #[test]
    fn compress_decompress_verify_roundtrip_one_connection() {
        let ctx = test_ctx();
        let field = test_field();
        // frame 1: compress
        let mut wire = Vec::new();
        let body = proto::encode_compress_body("rho", &field, 8, 1e-4, ShuffleMode::Byte4);
        write_request(&mut wire, Op::Compress, Priority::Normal, "t1", &body).unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::CleanClose
        );
        let mut resp = out.as_slice();
        let (st, _, czb) = read_response(&mut resp);
        assert_eq!(st, Status::Ok);
        // frames 2+3 on one connection: decompress then verify the czb
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Decompress, Priority::Normal, "t1", &czb).unwrap();
        write_request(&mut wire, Op::Verify, Priority::High, "t1", &czb).unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::CleanClose
        );
        let mut resp = out.as_slice();
        let (st, _, fb) = read_response(&mut resp);
        assert_eq!(st, Status::Ok);
        let (name, back) = decode_field_body(&fb).unwrap();
        assert_eq!(name, "rho");
        assert_eq!(back.nx, field.nx);
        // lossy wavelet path: decoded data matches a local decode
        // bit-for-bit (done against the server's own czb)
        let (local, _) = ctx.engine.decompress_bytes(&czb).unwrap();
        assert_eq!(back.data, local.data, "server decode must be bit-identical to local");
        let (st, _, vb) = read_response(&mut resp);
        assert_eq!(st, Status::Ok);
        let summary = proto::decode_verify_body(&vb).unwrap();
        assert!(summary.clean);
        assert!(summary.total_chunks >= 1);
        // accounting moved
        assert_eq!(ctx.metrics.requests[Op::Compress.index()].get(), 1);
        assert_eq!(ctx.metrics.requests[Op::Decompress.index()].get(), 1);
        assert_eq!(ctx.metrics.responses[Status::Ok.index()].get(), 3);
        assert_eq!(ctx.admission.in_flight(), 0);
        assert_eq!(ctx.metrics.queue_depth.get(), 0);
        let tenants = ctx.metrics.tenants_snapshot();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].1.requests, 3);
    }

    #[test]
    fn bounded_compress_honors_the_contract_and_meters_psnr() {
        let ctx = test_ctx();
        let field = test_field();
        let bound = Bound::Rel(1e-3);
        let mut wire = Vec::new();
        let body = proto::encode_compress_body_bound(
            "rho",
            &field,
            8,
            1e-4,
            ShuffleMode::Byte4,
            bound,
        );
        write_request(&mut wire, Op::Compress, Priority::Normal, "t-psnr", &body).unwrap();
        write_request(&mut wire, Op::Stat, Priority::Normal, "t-psnr", b"").unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::CleanClose
        );
        let mut resp = out.as_slice();
        let (st, _, czb) = read_response(&mut resp);
        assert_eq!(st, Status::Ok, "{}", String::from_utf8_lossy(&czb));
        // the returned stream records the contract and met it
        let (file, _) = crate::pipeline::CzbFile::parse_header(&czb).unwrap();
        assert_eq!(file.bound, bound);
        let q = file.achieved_quality().expect("v5 stream records quality");
        assert!(bound.check(&q).is_ok(), "{:?}", bound.check(&q));
        // the tenant's achieved PSNR landed in the histogram export
        let (st, _, stat_body) = read_response(&mut resp);
        assert_eq!(st, Status::Ok);
        let text = String::from_utf8(stat_body).unwrap();
        assert!(
            text.contains("czb_tenant_achieved_psnr_db_count{tenant=\"t-psnr\"} 1"),
            "{text}"
        );
        let snap = ctx.metrics.tenant_psnr_snapshot();
        assert_eq!(snap.len(), 1);
        assert!((snap[0].1.mean_db() - q.psnr_db).abs() < 1e-9);
        // a malformed trailing bound is a compress-body parse error:
        // error response, then the connection closes (stream desynced)
        let mut bad = proto::encode_compress_body_bound(
            "rho",
            &field,
            8,
            1e-4,
            ShuffleMode::None,
            Bound::Abs(1e-3),
        );
        let at = bad.len() - 9;
        bad[at] = 77;
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Compress, Priority::Normal, "t-psnr", &bad).unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::ProtocolError
        );
        let (st, _, _) = read_response(&mut out.as_slice());
        assert_eq!(st, Status::Error);
        assert_eq!(ctx.admission.in_flight(), 0);
    }

    #[test]
    fn stat_and_shutdown_frames() {
        let ctx = test_ctx();
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
        write_request(&mut wire, Op::Shutdown, Priority::Normal, "", b"").unwrap();
        // after shutdown, a work op is refused with shutting_down
        let body = proto::encode_compress_body("x", &test_field(), 8, 1e-4, ShuffleMode::None);
        write_request(&mut wire, Op::Compress, Priority::Normal, "", &body).unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::CleanClose
        );
        let mut resp = out.as_slice();
        let (st, _, stat_body) = read_response(&mut resp);
        assert_eq!(st, Status::Ok);
        let text = String::from_utf8(stat_body).unwrap();
        assert!(text.contains("czb_requests_total{op=\"stat\"} 1"), "{text}");
        let (st, _, _) = read_response(&mut resp);
        assert_eq!(st, Status::Ok, "shutdown acks");
        assert!(ctx.stop.load(Ordering::SeqCst));
        let (st, _, _) = read_response(&mut resp);
        assert_eq!(st, Status::ShuttingDown, "work after shutdown is refused");
    }

    #[test]
    fn corrupt_decompress_body_keeps_the_connection_open() {
        let ctx = test_ctx();
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Decompress, Priority::Normal, "", b"not a czb").unwrap();
        write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::CleanClose
        );
        let mut resp = out.as_slice();
        let (st, _, msg) = read_response(&mut resp);
        assert_eq!(st, Status::Error);
        assert!(!msg.is_empty());
        let (st, _, _) = read_response(&mut resp);
        assert_eq!(st, Status::Ok, "the stat frame after the bad body still serves");
        assert_eq!(ctx.admission.in_flight(), 0, "no permit leaked");
    }

    #[test]
    fn malformed_magic_gets_bad_request_then_close() {
        let ctx = test_ctx();
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
        wire[0] = b'X';
        // a second, well-formed frame after the garbage must NOT be served
        write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::ProtocolError
        );
        let mut resp = out.as_slice();
        let (st, _, msg) = read_response(&mut resp);
        assert_eq!(st, Status::BadRequest);
        assert!(String::from_utf8_lossy(&msg).contains("magic"));
        assert!(resp.is_empty(), "nothing served after a desynced frame");
        assert_eq!(ctx.metrics.responses[Status::BadRequest.index()].get(), 1);
    }

    #[test]
    fn oversized_declared_body_is_refused_without_reading_it() {
        let ctx = test_ctx().with_max_body(1024);
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Decompress, Priority::Normal, "", &[0u8; 4096]).unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::ProtocolError
        );
        let (st, _, msg) = read_response(&mut out.as_slice());
        assert_eq!(st, Status::BadRequest);
        assert!(String::from_utf8_lossy(&msg).contains("exceeds"));
    }

    #[test]
    fn busy_and_quota_refusals_keep_framing() {
        let metrics = Arc::new(Registry::new());
        let engine = Arc::new(Engine::builder().threads(1).build());
        // zero-slot normal lane (clamped to 1) occupied by a held permit
        let admission = Admission::new(1, 0, 42);
        let _held = admission.try_acquire(Priority::Normal).unwrap();
        let ctx = ConnCtx::new(engine, metrics, admission, Arc::new(Quota::new(10, 1)));
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Verify, Priority::Normal, "t", b"0123456789").unwrap();
        write_request(&mut wire, Op::Stat, Priority::Normal, "t", b"").unwrap();
        let mut out = Vec::new();
        assert_eq!(
            serve_connection(&mut wire.as_slice(), &mut out, &ctx),
            ConnOutcome::CleanClose
        );
        let mut resp = out.as_slice();
        let (st, retry, _) = read_response(&mut resp);
        assert_eq!(st, Status::Busy);
        assert_eq!(retry, 42);
        let (st, _, _) = read_response(&mut resp);
        assert_eq!(st, Status::Ok, "framing intact after the refusal");
        // now free the slot: the next refusal comes from the quota
        drop(_held);
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Verify, Priority::Normal, "t", &[0u8; 10]).unwrap();
        write_request(&mut wire, Op::Verify, Priority::Normal, "t", &[0u8; 10]).unwrap();
        let mut out = Vec::new();
        serve_connection(&mut wire.as_slice(), &mut out, &ctx);
        let mut resp = out.as_slice();
        let (st, _, _) = read_response(&mut resp); // drains the full bucket (error: not a czb)
        assert_eq!(st, Status::Error);
        let (st, retry, _) = read_response(&mut resp);
        assert_eq!(st, Status::Quota);
        assert!(retry > 0, "quota refusal must carry a retry hint");
        let throttled = ctx.metrics.tenants_snapshot();
        assert_eq!(throttled[0].1.throttled, 1);
        assert_eq!(ctx.admission.in_flight(), 0);
    }

    // ---- fault-injected transports (satellite: protocol robustness) ----

    #[test]
    fn interrupted_and_short_reads_still_serve() {
        let ctx = test_ctx();
        let field = test_field();
        let body = proto::encode_compress_body("q", &field, 8, 1e-4, ShuffleMode::Byte4);
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Compress, Priority::Normal, "t", &body).unwrap();
        let plan = FaultPlan::new()
            .fail_op(0, std::io::ErrorKind::Interrupted)
            .short_read(1, 5)
            .fail_op(3, std::io::ErrorKind::Interrupted)
            .short_read(4, 7)
            .fail_op(7, std::io::ErrorKind::Interrupted);
        let mut r = FaultReader::new(wire.as_slice(), plan);
        let mut out = Vec::new();
        assert_eq!(serve_connection(&mut r, &mut out, &ctx), ConnOutcome::CleanClose);
        assert!(r.plan().injected() >= 3, "the fault script must have fired");
        let (st, _, czb) = read_response(&mut out.as_slice());
        assert_eq!(st, Status::Ok);
        let (back, _) = ctx.engine.decompress_bytes(&czb).unwrap();
        assert_eq!(back.nx, field.nx);
    }

    #[test]
    fn header_bit_flip_is_a_clean_protocol_error() {
        let ctx = test_ctx();
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
        // flip a bit in the magic as it crosses the wire
        let plan = FaultPlan::new().flip_bit(1, 0x40);
        let mut r = FaultReader::new(wire.as_slice(), plan);
        let mut out = Vec::new();
        assert_eq!(serve_connection(&mut r, &mut out, &ctx), ConnOutcome::ProtocolError);
        assert_eq!(r.plan().injected(), 1);
        let (st, _, _) = read_response(&mut out.as_slice());
        assert_eq!(st, Status::BadRequest);
        assert_eq!(ctx.admission.in_flight(), 0);
    }

    #[test]
    fn body_bit_flip_surfaces_as_a_czb_integrity_error() {
        let ctx = test_ctx();
        let field = test_field();
        let (czb, _) = ctx.engine.compress_vec(
            &field,
            "q",
            &CompressParams::from_config(&PipelineConfig::paper_default(1e-4)),
        );
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Decompress, Priority::Normal, "", &czb).unwrap();
        // flip one payload bit mid-body (past header+tenant, inside czb data)
        let at = (proto::REQ_HEADER_LEN + czb.len() / 2) as u64;
        let mut r = FaultReader::new(wire.as_slice(), FaultPlan::new().flip_bit(at, 0x10));
        let mut out = Vec::new();
        // the frame is intact, the body is corrupt: error response, open conn
        assert_eq!(serve_connection(&mut r, &mut out, &ctx), ConnOutcome::CleanClose);
        assert_eq!(r.plan().injected(), 1);
        let (st, _, msg) = read_response(&mut out.as_slice());
        assert_eq!(st, Status::Error, "{}", String::from_utf8_lossy(&msg));
        assert_eq!(ctx.admission.in_flight(), 0, "failed request returned its permit");
    }

    #[test]
    fn truncated_frames_never_hang_or_leak_permits() {
        let ctx = test_ctx();
        let field = test_field();
        let body = proto::encode_compress_body("q", &field, 8, 1e-4, ShuffleMode::None);
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Compress, Priority::Normal, "tenant", &body).unwrap();
        // cut the stream at a few hostile offsets: mid-header, mid-tenant,
        // mid-prefix, mid-samples
        for cut in [3u64, 9, 17, 30, 64, (wire.len() - 5) as u64] {
            let plan = FaultPlan::new().truncate_at(cut);
            let mut r = FaultReader::new(wire.as_slice(), plan);
            let mut out = Vec::new();
            let outcome = serve_connection(&mut r, &mut out, &ctx);
            assert_ne!(outcome, ConnOutcome::CleanClose, "cut at {cut} must be an error");
            assert_eq!(ctx.admission.in_flight(), 0, "cut at {cut} leaked a permit");
        }
        assert_eq!(ctx.metrics.queue_depth.get(), 0);
    }

    #[test]
    fn idle_aware_reader_unblocks_on_stop() {
        struct AlwaysBlocked;
        impl Read for AlwaysBlocked {
            fn read(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "poll"))
            }
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mut r = IdleAwareReader::new(AlwaysBlocked, Arc::clone(&stop));
        let flag = Arc::clone(&stop);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            flag.store(true, Ordering::SeqCst);
        });
        let mut buf = [0u8; 8];
        // blocks until the stop flag flips, then reports EOF
        assert_eq!(r.read(&mut buf).unwrap(), 0);
        t.join().unwrap();
    }
}
