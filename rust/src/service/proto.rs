//! The `czb serve` wire protocol: length-prefixed binary frames over a
//! byte stream (TCP in production; any `Read`/`Write` in tests).
//!
//! # Frame layout (protocol version 1, all integers little-endian)
//!
//! Request frame — 16-byte fixed header, then tenant id, then body:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "CZRQ"
//! 4       1     version (must be 1)
//! 5       1     op: 1 compress, 2 decompress, 3 verify, 4 stat, 5 shutdown
//! 6       1     priority: 0 normal, 1 high
//! 7       1     tenant_len (0..=255)
//! 8       8     body_len (u64)
//! 16      t     tenant id (UTF-8, tenant_len bytes; "" = anonymous)
//! 16+t    b     body (body_len bytes)
//! ```
//!
//! Response frame — 20-byte fixed header, then body:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "CZRS"
//! 4       1     version (1)
//! 5       1     status: 0 ok, 1 error, 2 busy, 3 quota, 4 shutting_down,
//!               5 bad_request
//! 6       2     reserved (0)
//! 8       4     retry_after_ms (busy/quota backpressure hint, else 0)
//! 12      8     body_len (u64)
//! 20      b     body
//! ```
//!
//! # Bodies
//!
//! * `compress` request: [`FieldRequest`] encoding — `name_len:u16`,
//!   name, `nx,ny,nz,bs:u32`, `eps:f32`, `shuffle:u8` (ShuffleMode id),
//!   3 reserved bytes, then `nx·ny·nz` raw `f32` samples, then an
//!   *optional* appended error-bound contract: `kind:u8` + `value:f64`
//!   (the 9-byte [`Bound`] wire encoding). A body without the trailing 9
//!   bytes means no contract — exactly what pre-bound clients send, per
//!   the append-only versioning rule below. Response body is the
//!   finished `.czb` stream (v5: contract + achieved quality recorded).
//! * `decompress` request: a whole `.czb` stream. Response body is the
//!   field encoding — `name_len:u16`, name, `nx,ny,nz:u32`, samples.
//! * `verify` request: a whole `.czb` stream. Response body is 17
//!   bytes: `clean:u8`, `total_chunks:u32`, `corrupt_chunks:u32`,
//!   `lost_blocks:u64`.
//! * `stat` request: empty body. Response body: plaintext metrics in
//!   Prometheus exposition style (see [`super::metrics_export`]).
//! * `shutdown` request: empty body. Response `ok`, after which the
//!   server drains: in-flight requests finish, new ones get
//!   `shutting_down`.
//! * Any `error`/`busy`/`quota`/`shutting_down`/`bad_request` response:
//!   body is a UTF-8 message.
//!
//! # Error and backpressure semantics
//!
//! `busy` (admission control full) and `quota` (tenant token bucket
//! empty) carry `retry_after_ms` > 0: the request was *not* processed
//! and the client should retry after the hint. The connection stays
//! open — the server drains the refused request's body to keep frame
//! framing intact. `bad_request` (bad magic/version/lengths) means the
//! stream can no longer be trusted: the server responds once and closes
//! the connection. `error` (e.g. a corrupt `.czb` in a decompress body)
//! keeps the connection open — the frame itself was well-formed.
//!
//! # Versioning rule
//!
//! The version byte gates both header layouts; a server refuses any
//! other version with `bad_request` naming the version it speaks.
//! Within version 1, bodies may only grow by appending fields — a
//! parser must ignore trailing bytes it does not know. Incompatible
//! layout changes bump the version byte.
use crate::core::Field3;
use crate::pipeline::{Bound, ShuffleMode, BOUND_WIRE_LEN};
use std::io::{Read, Write};

pub const REQ_MAGIC: &[u8; 4] = b"CZRQ";
pub const RESP_MAGIC: &[u8; 4] = b"CZRS";
pub const PROTO_VERSION: u8 = 1;
pub const REQ_HEADER_LEN: usize = 16;
pub const RESP_HEADER_LEN: usize = 20;

/// Default cap on request/response body size (1 GiB). A declared body
/// beyond the server's cap is refused with `bad_request` before any of
/// it is read.
pub const DEFAULT_MAX_BODY: u64 = 1 << 30;

/// Request operation. Wire ids are `index + 1` into
/// [`crate::metrics::registry::OPS`] order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Compress,
    Decompress,
    Verify,
    Stat,
    Shutdown,
}

impl Op {
    pub const ALL: [Op; 5] = [Op::Compress, Op::Decompress, Op::Verify, Op::Stat, Op::Shutdown];

    pub fn id(self) -> u8 {
        self.index() as u8 + 1
    }

    /// Index into [`crate::metrics::registry::OPS`].
    pub fn index(self) -> usize {
        match self {
            Op::Compress => 0,
            Op::Decompress => 1,
            Op::Verify => 2,
            Op::Stat => 3,
            Op::Shutdown => 4,
        }
    }

    pub fn from_id(v: u8) -> Option<Self> {
        Self::ALL.into_iter().find(|o| o.id() == v)
    }
}

/// Response status. Wire ids index [`crate::metrics::registry::STATUSES`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Ok,
    /// The request was well-formed but failed (corrupt stream, bad
    /// dimensions, ...). Connection stays open.
    Error,
    /// Admission control refused the request; retry after the hint.
    Busy,
    /// The tenant's byte quota is exhausted; retry after the hint.
    Quota,
    /// The server is draining; no new work is accepted.
    ShuttingDown,
    /// The frame itself was malformed; the server closes the connection.
    BadRequest,
}

impl Status {
    pub const ALL: [Status; 6] =
        [Status::Ok, Status::Error, Status::Busy, Status::Quota, Status::ShuttingDown, Status::BadRequest];

    pub fn id(self) -> u8 {
        self.index() as u8
    }

    /// Index into [`crate::metrics::registry::STATUSES`].
    pub fn index(self) -> usize {
        match self {
            Status::Ok => 0,
            Status::Error => 1,
            Status::Busy => 2,
            Status::Quota => 3,
            Status::ShuttingDown => 4,
            Status::BadRequest => 5,
        }
    }

    pub fn from_id(v: u8) -> Option<Self> {
        Self::ALL.into_iter().find(|s| s.id() == v)
    }
}

/// Human name of a status (the registry's label for it).
pub fn status_name(s: Status) -> &'static str {
    crate::metrics::registry::STATUSES[s.index()]
}

/// Request priority lane (see [`super::admission`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    #[default]
    Normal,
    High,
}

impl Priority {
    pub fn id(self) -> u8 {
        match self {
            Priority::Normal => 0,
            Priority::High => 1,
        }
    }

    pub fn from_id(v: u8) -> Option<Self> {
        match v {
            0 => Some(Priority::Normal),
            1 => Some(Priority::High),
            _ => None,
        }
    }
}

/// A parsed request header (body not yet read — it may be streamed).
#[derive(Clone, Debug)]
pub struct RequestHeader {
    pub op: Op,
    pub priority: Priority,
    pub tenant: String,
    pub body_len: u64,
}

/// A parsed response header.
#[derive(Clone, Copy, Debug)]
pub struct ResponseHeader {
    pub status: Status,
    pub retry_after_ms: u32,
    pub body_len: u64,
}

/// Why a request frame could not be parsed. `Malformed` earns one
/// `bad_request` response before the connection closes; `Io` closes it
/// silently (the peer is gone or the stream already desynced).
#[derive(Debug)]
pub enum FrameError {
    /// Clean EOF exactly at a frame boundary: the client hung up.
    Eof,
    Malformed(String),
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Eof => write!(f, "connection closed"),
            FrameError::Malformed(m) => write!(f, "malformed frame: {m}"),
            FrameError::Io(e) => write!(f, "i/o: {e}"),
        }
    }
}

fn u32_at(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().unwrap())
}

fn u64_at(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().unwrap())
}

/// Read one request header (+ tenant id) from `r`. `max_body` bounds
/// the declared body length; an oversized frame is `Malformed` and the
/// body has NOT been consumed — the caller must close the connection.
pub fn read_request_header(r: &mut dyn Read, max_body: u64) -> Result<RequestHeader, FrameError> {
    let mut hdr = [0u8; REQ_HEADER_LEN];
    read_exact_or_eof(r, &mut hdr)?;
    if &hdr[..4] != REQ_MAGIC {
        return Err(FrameError::Malformed(format!("bad request magic {:02x?}", &hdr[..4])));
    }
    if hdr[4] != PROTO_VERSION {
        return Err(FrameError::Malformed(format!(
            "protocol version {} not supported (server speaks {PROTO_VERSION})",
            hdr[4]
        )));
    }
    let op = Op::from_id(hdr[5])
        .ok_or_else(|| FrameError::Malformed(format!("unknown op {}", hdr[5])))?;
    let priority = Priority::from_id(hdr[6])
        .ok_or_else(|| FrameError::Malformed(format!("unknown priority {}", hdr[6])))?;
    let tenant_len = hdr[7] as usize;
    let body_len = u64_at(&hdr, 8);
    if body_len > max_body {
        return Err(FrameError::Malformed(format!(
            "declared body of {body_len} bytes exceeds the {max_body}-byte limit"
        )));
    }
    let mut tenant = vec![0u8; tenant_len];
    r.read_exact(&mut tenant).map_err(FrameError::Io)?;
    let tenant = String::from_utf8(tenant)
        .map_err(|_| FrameError::Malformed("tenant id is not UTF-8".into()))?;
    Ok(RequestHeader { op, priority, tenant, body_len })
}

/// `read_exact` that reports a zero-byte start as [`FrameError::Eof`]
/// (client hung up between frames) and a mid-header EOF as `Malformed`.
fn read_exact_or_eof(r: &mut dyn Read, buf: &mut [u8]) -> Result<(), FrameError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if filled == 0 {
                    FrameError::Eof
                } else {
                    FrameError::Malformed(format!(
                        "stream ended {filled} bytes into a {}-byte header",
                        buf.len()
                    ))
                });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

/// Write one complete request frame.
pub fn write_request(
    w: &mut dyn Write,
    op: Op,
    priority: Priority,
    tenant: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let tenant = tenant.as_bytes();
    assert!(tenant.len() <= u8::MAX as usize, "tenant id longer than 255 bytes");
    let mut hdr = [0u8; REQ_HEADER_LEN];
    hdr[..4].copy_from_slice(REQ_MAGIC);
    hdr[4] = PROTO_VERSION;
    hdr[5] = op.id();
    hdr[6] = priority.id();
    hdr[7] = tenant.len() as u8;
    hdr[8..16].copy_from_slice(&(body.len() as u64).to_le_bytes());
    w.write_all(&hdr)?;
    w.write_all(tenant)?;
    w.write_all(body)?;
    w.flush()
}

/// Write one complete response frame.
pub fn write_response(
    w: &mut dyn Write,
    status: Status,
    retry_after_ms: u32,
    body: &[u8],
) -> std::io::Result<()> {
    let mut hdr = [0u8; RESP_HEADER_LEN];
    hdr[..4].copy_from_slice(RESP_MAGIC);
    hdr[4] = PROTO_VERSION;
    hdr[5] = status.id();
    hdr[8..12].copy_from_slice(&retry_after_ms.to_le_bytes());
    hdr[12..20].copy_from_slice(&(body.len() as u64).to_le_bytes());
    w.write_all(&hdr)?;
    w.write_all(body)?;
    w.flush()
}

/// Read one response header from `r`, bounding the body at `max_body`.
pub fn read_response_header(r: &mut dyn Read, max_body: u64) -> Result<ResponseHeader, FrameError> {
    let mut hdr = [0u8; RESP_HEADER_LEN];
    read_exact_or_eof(r, &mut hdr)?;
    if &hdr[..4] != RESP_MAGIC {
        return Err(FrameError::Malformed(format!("bad response magic {:02x?}", &hdr[..4])));
    }
    if hdr[4] != PROTO_VERSION {
        return Err(FrameError::Malformed(format!("unknown response version {}", hdr[4])));
    }
    let status = Status::from_id(hdr[5])
        .ok_or_else(|| FrameError::Malformed(format!("unknown status {}", hdr[5])))?;
    let retry_after_ms = u32_at(&hdr, 8);
    let body_len = u64_at(&hdr, 12);
    if body_len > max_body {
        return Err(FrameError::Malformed(format!(
            "response body of {body_len} bytes exceeds the {max_body}-byte limit"
        )));
    }
    Ok(ResponseHeader { status, retry_after_ms, body_len })
}

/// A decoded `compress` request body: which field to compress, with
/// which format-affecting parameters.
#[derive(Clone, Debug)]
pub struct FieldRequest {
    pub name: String,
    pub field: Field3,
    pub bs: u32,
    pub eps: f32,
    pub shuffle: ShuffleMode,
    /// Error-bound contract the client asked for ([`Bound::None`] when
    /// the body carried no trailing bound field).
    pub bound: Bound,
}

/// Fixed-size prefix of a compress body before the samples:
/// `name_len:u16` + `nx,ny,nz,bs:u32` + `eps:f32` + `shuffle:u8` + 3
/// reserved bytes.
const COMPRESS_PREFIX: usize = 2 + 4 * 4 + 4 + 4;

/// Encode a `compress` request body with no error-bound contract (the
/// legacy body layout pre-bound clients send).
pub fn encode_compress_body(
    name: &str,
    field: &Field3,
    bs: u32,
    eps: f32,
    shuffle: ShuffleMode,
) -> Vec<u8> {
    encode_compress_body_bound(name, field, bs, eps, shuffle, Bound::None)
}

/// Encode a `compress` request body; a non-`None` `bound` is appended
/// as the trailing 9-byte contract field.
pub fn encode_compress_body_bound(
    name: &str,
    field: &Field3,
    bs: u32,
    eps: f32,
    shuffle: ShuffleMode,
    bound: Bound,
) -> Vec<u8> {
    let name = name.as_bytes();
    assert!(name.len() <= u16::MAX as usize, "quantity name longer than 65535 bytes");
    let mut out = Vec::with_capacity(COMPRESS_PREFIX + name.len() + field.nbytes() + BOUND_WIRE_LEN);
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name);
    for d in [field.nx as u32, field.ny as u32, field.nz as u32, bs] {
        out.extend_from_slice(&d.to_le_bytes());
    }
    out.extend_from_slice(&eps.to_le_bytes());
    out.push(shuffle.id());
    out.extend_from_slice(&[0u8; 3]);
    for v in &field.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    if bound != Bound::None {
        out.extend_from_slice(&bound.encode());
    }
    out
}

/// Decode a `compress` request body by *streaming* exactly `body_len`
/// bytes out of `r` — the sample payload goes straight from the socket
/// into the field buffer, never through an intermediate copy.
pub fn decode_compress_body(r: &mut dyn Read, body_len: u64) -> Result<FieldRequest, String> {
    let mut name_len = [0u8; 2];
    r.read_exact(&mut name_len).map_err(|e| format!("reading compress body: {e}"))?;
    let name_len = u16::from_le_bytes(name_len) as usize;
    let fixed = (COMPRESS_PREFIX + name_len) as u64;
    if body_len < fixed {
        return Err(format!("compress body of {body_len} bytes is shorter than its {fixed}-byte prefix"));
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name).map_err(|e| format!("reading quantity name: {e}"))?;
    let name = String::from_utf8(name).map_err(|_| "quantity name is not UTF-8".to_string())?;
    let mut rest = [0u8; COMPRESS_PREFIX - 2];
    r.read_exact(&mut rest).map_err(|e| format!("reading compress params: {e}"))?;
    let nx = u32_at(&rest, 0) as usize;
    let ny = u32_at(&rest, 4) as usize;
    let nz = u32_at(&rest, 8) as usize;
    let bs = u32_at(&rest, 12);
    let eps = f32::from_le_bytes(rest[16..20].try_into().unwrap());
    let shuffle = ShuffleMode::from_id(rest[20])
        .ok_or_else(|| format!("unknown shuffle mode {}", rest[20]))?;
    let nsamples = nx
        .checked_mul(ny)
        .and_then(|v| v.checked_mul(nz))
        .ok_or_else(|| format!("field dimensions {nx}x{ny}x{nz} overflow"))?;
    let declared = body_len - fixed;
    let expected = nsamples as u64 * 4;
    // exactly the samples (no contract) or the samples plus the 9-byte
    // trailing bound field — anything else desyncs the stream
    let has_bound = declared == expected + BOUND_WIRE_LEN as u64;
    if declared != expected && !has_bound {
        return Err(format!(
            "field {nx}x{ny}x{nz} needs {expected} sample bytes, body declares {declared}"
        ));
    }
    if bs == 0 || !eps.is_finite() || eps <= 0.0 {
        return Err(format!("bad compress params: bs {bs}, eps {eps}"));
    }
    let mut data = vec![0f32; nsamples];
    read_f32_into(r, &mut data)?;
    let bound = if has_bound {
        let mut b = [0u8; BOUND_WIRE_LEN];
        r.read_exact(&mut b).map_err(|e| format!("reading bound field: {e}"))?;
        Bound::decode(&b)?
    } else {
        Bound::None
    };
    Ok(FieldRequest { name, field: Field3::from_vec(nx, ny, nz, data), bs, eps, shuffle, bound })
}

/// Encode a decoded field as a `decompress` response body.
pub fn encode_field_body(name: &str, field: &Field3) -> Vec<u8> {
    let name = name.as_bytes();
    let mut out = Vec::with_capacity(2 + name.len() + 12 + field.nbytes());
    out.extend_from_slice(&(name.len().min(u16::MAX as usize) as u16).to_le_bytes());
    out.extend_from_slice(&name[..name.len().min(u16::MAX as usize)]);
    for d in [field.nx as u32, field.ny as u32, field.nz as u32] {
        out.extend_from_slice(&d.to_le_bytes());
    }
    for v in &field.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a `decompress` response body.
pub fn decode_field_body(body: &[u8]) -> Result<(String, Field3), String> {
    if body.len() < 2 {
        return Err("field body shorter than its name length".into());
    }
    let name_len = u16::from_le_bytes(body[..2].try_into().unwrap()) as usize;
    let dims_at = 2 + name_len;
    if body.len() < dims_at + 12 {
        return Err("field body shorter than its dimensions".into());
    }
    let name = String::from_utf8(body[2..dims_at].to_vec())
        .map_err(|_| "field name is not UTF-8".to_string())?;
    let nx = u32_at(body, dims_at) as usize;
    let ny = u32_at(body, dims_at + 4) as usize;
    let nz = u32_at(body, dims_at + 8) as usize;
    let nsamples = nx
        .checked_mul(ny)
        .and_then(|v| v.checked_mul(nz))
        .ok_or_else(|| format!("field dimensions {nx}x{ny}x{nz} overflow"))?;
    let samples = &body[dims_at + 12..];
    if samples.len() != nsamples * 4 {
        return Err(format!(
            "field {nx}x{ny}x{nz} needs {} sample bytes, body carries {}",
            nsamples * 4,
            samples.len()
        ));
    }
    let data: Vec<f32> = samples
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((name, Field3::from_vec(nx, ny, nz, data)))
}

/// A `verify` response body: the checksum walk's summary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerifySummary {
    pub clean: bool,
    pub total_chunks: u32,
    pub corrupt_chunks: u32,
    pub lost_blocks: u64,
}

pub fn encode_verify_body(s: &VerifySummary) -> Vec<u8> {
    let mut out = Vec::with_capacity(17);
    out.push(s.clean as u8);
    out.extend_from_slice(&s.total_chunks.to_le_bytes());
    out.extend_from_slice(&s.corrupt_chunks.to_le_bytes());
    out.extend_from_slice(&s.lost_blocks.to_le_bytes());
    out
}

pub fn decode_verify_body(body: &[u8]) -> Result<VerifySummary, String> {
    if body.len() < 17 {
        return Err(format!("verify body of {} bytes is shorter than 17", body.len()));
    }
    Ok(VerifySummary {
        clean: body[0] != 0,
        total_chunks: u32_at(body, 1),
        corrupt_chunks: u32_at(body, 5),
        lost_blocks: u64_at(body, 9),
    })
}

/// Read exactly `out.len()` little-endian f32s from `r` into `out`,
/// going through a bounded stack buffer (streaming: the whole payload
/// is never held as raw bytes).
fn read_f32_into(r: &mut dyn Read, out: &mut [f32]) -> Result<(), String> {
    let mut buf = [0u8; 16 << 10];
    let mut at = 0usize;
    while at < out.len() {
        let want = ((out.len() - at) * 4).min(buf.len());
        r.read_exact(&mut buf[..want]).map_err(|e| format!("reading field samples: {e}"))?;
        for c in buf[..want].chunks_exact(4) {
            out[at] = f32::from_le_bytes(c.try_into().unwrap());
            at += 1;
        }
    }
    Ok(())
}

/// Read and discard exactly `n` body bytes (keeps frame framing intact
/// after a refused request).
pub fn drain_body(r: &mut dyn Read, n: u64) -> std::io::Result<()> {
    std::io::copy(&mut r.take(n), &mut std::io::sink()).and_then(|copied| {
        if copied == n {
            Ok(())
        } else {
            Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("stream ended {copied} bytes into a {n}-byte body"),
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_frames_roundtrip() {
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Compress, Priority::High, "tenant-a", b"hello").unwrap();
        let mut r = wire.as_slice();
        let h = read_request_header(&mut r, DEFAULT_MAX_BODY).unwrap();
        assert_eq!(h.op, Op::Compress);
        assert_eq!(h.priority, Priority::High);
        assert_eq!(h.tenant, "tenant-a");
        assert_eq!(h.body_len, 5);
        let mut body = vec![0u8; 5];
        std::io::Read::read_exact(&mut r, &mut body).unwrap();
        assert_eq!(&body, b"hello");
        assert!(r.is_empty());
    }

    #[test]
    fn response_frames_roundtrip() {
        let mut wire = Vec::new();
        write_response(&mut wire, Status::Busy, 250, b"try later").unwrap();
        let mut r = wire.as_slice();
        let h = read_response_header(&mut r, DEFAULT_MAX_BODY).unwrap();
        assert_eq!(h.status, Status::Busy);
        assert_eq!(h.retry_after_ms, 250);
        assert_eq!(h.body_len, 9);
    }

    #[test]
    fn malformed_headers_are_rejected_cleanly() {
        // wrong magic
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
        wire[0] = b'X';
        assert!(matches!(
            read_request_header(&mut wire.as_slice(), DEFAULT_MAX_BODY),
            Err(FrameError::Malformed(_))
        ));
        // wrong version
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
        wire[4] = 9;
        let e = read_request_header(&mut wire.as_slice(), DEFAULT_MAX_BODY).unwrap_err();
        assert!(e.to_string().contains("version 9"), "{e}");
        // unknown op / priority
        for (at, v) in [(5usize, 99u8), (6, 7)] {
            let mut wire = Vec::new();
            write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
            wire[at] = v;
            assert!(matches!(
                read_request_header(&mut wire.as_slice(), DEFAULT_MAX_BODY),
                Err(FrameError::Malformed(_))
            ));
        }
        // oversized declared body
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Compress, Priority::Normal, "", b"12345678").unwrap();
        let e = read_request_header(&mut wire.as_slice(), 4).unwrap_err();
        assert!(e.to_string().contains("exceeds"), "{e}");
        // clean EOF at a frame boundary vs mid-header truncation
        assert!(matches!(
            read_request_header(&mut [].as_slice(), DEFAULT_MAX_BODY),
            Err(FrameError::Eof)
        ));
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Stat, Priority::Normal, "", b"").unwrap();
        assert!(matches!(
            read_request_header(&mut wire[..7].to_vec().as_slice(), DEFAULT_MAX_BODY),
            Err(FrameError::Malformed(_))
        ));
    }

    #[test]
    fn compress_body_roundtrips_and_validates() {
        let field = Field3::from_vec(2, 3, 4, (0..24).map(|i| i as f32 * 0.5).collect());
        let body = encode_compress_body("rho", &field, 16, 1e-3, ShuffleMode::Byte4);
        let req = decode_compress_body(&mut body.as_slice(), body.len() as u64).unwrap();
        assert_eq!(req.name, "rho");
        assert_eq!(req.bs, 16);
        assert_eq!(req.shuffle, ShuffleMode::Byte4);
        assert!((req.eps - 1e-3).abs() < 1e-9);
        assert_eq!(req.field.data, field.data);
        // declared body shorter than the samples require
        let e = decode_compress_body(&mut body.as_slice(), body.len() as u64 - 4).unwrap_err();
        assert!(e.contains("sample bytes"), "{e}");
        // truncated stream under a correct declaration
        let e = decode_compress_body(&mut body[..body.len() - 4].as_ref(), body.len() as u64)
            .unwrap_err();
        assert!(e.contains("samples"), "{e}");
        // degenerate params
        let bad = encode_compress_body("x", &field, 0, 1e-3, ShuffleMode::None);
        assert!(decode_compress_body(&mut bad.as_slice(), bad.len() as u64)
            .unwrap_err()
            .contains("bs 0"));
        let bad = encode_compress_body("x", &field, 16, f32::NAN, ShuffleMode::None);
        assert!(decode_compress_body(&mut bad.as_slice(), bad.len() as u64).is_err());
    }

    #[test]
    fn compress_body_carries_an_optional_bound() {
        let field = Field3::from_vec(2, 2, 2, (0..8).map(|i| i as f32).collect());
        // legacy body: no trailing bound field -> Bound::None
        let body = encode_compress_body("p", &field, 8, 1e-3, ShuffleMode::None);
        let req = decode_compress_body(&mut body.as_slice(), body.len() as u64).unwrap();
        assert_eq!(req.bound, Bound::None);
        // bounded body: 9 extra bytes after the samples
        let body =
            encode_compress_body_bound("p", &field, 8, 1e-3, ShuffleMode::None, Bound::Rel(1e-3));
        let req = decode_compress_body(&mut body.as_slice(), body.len() as u64).unwrap();
        assert_eq!(req.bound, Bound::Rel(1e-3));
        assert_eq!(req.field.data, field.data, "samples unaffected by the trailing field");
        // a corrupt trailing bound is a parse error, not a silent None
        let mut bad = body.clone();
        let at = bad.len() - BOUND_WIRE_LEN;
        bad[at] = 99; // unknown kind id
        assert!(decode_compress_body(&mut bad.as_slice(), bad.len() as u64).is_err());
        // a non-finite bound value is rejected at the wire
        let mut bad = body;
        let at = bad.len() - 8;
        bad[at..].copy_from_slice(&f64::NAN.to_le_bytes());
        assert!(decode_compress_body(&mut bad.as_slice(), bad.len() as u64).is_err());
    }

    #[test]
    fn field_body_roundtrips() {
        let field = Field3::from_vec(3, 2, 2, (0..12).map(|i| -(i as f32)).collect());
        let body = encode_field_body("p", &field);
        let (name, back) = decode_field_body(&body).unwrap();
        assert_eq!(name, "p");
        assert_eq!(back.nx, 3);
        assert_eq!(back.data, field.data);
        assert!(decode_field_body(&body[..5]).is_err());
        assert!(decode_field_body(&body[..body.len() - 1]).is_err());
        assert!(decode_field_body(b"").is_err());
    }

    #[test]
    fn verify_body_roundtrips() {
        let s = VerifySummary { clean: false, total_chunks: 9, corrupt_chunks: 2, lost_blocks: 64 };
        assert_eq!(decode_verify_body(&encode_verify_body(&s)).unwrap(), s);
        assert!(decode_verify_body(&[1, 2, 3]).is_err());
    }

    #[test]
    fn drain_body_consumes_exactly_n() {
        let data = vec![1u8; 10];
        let mut r = data.as_slice();
        drain_body(&mut r, 7).unwrap();
        assert_eq!(r.len(), 3);
        let mut r = data.as_slice();
        assert!(drain_body(&mut r, 11).is_err());
    }

    #[test]
    fn interrupted_reads_are_retried_in_headers() {
        use crate::io::fault::{FaultPlan, FaultReader};
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Verify, Priority::Normal, "t", b"abc").unwrap();
        let plan = FaultPlan::new()
            .fail_op(0, std::io::ErrorKind::Interrupted)
            .short_read(1, 3)
            .fail_op(2, std::io::ErrorKind::Interrupted);
        let mut r = FaultReader::new(wire.as_slice(), plan);
        let h = read_request_header(&mut r, DEFAULT_MAX_BODY).unwrap();
        assert_eq!(h.op, Op::Verify);
        assert_eq!(h.tenant, "t");
        assert!(r.plan().injected() >= 2, "scripted faults must have fired");
    }
}
