//! `czb serve`: a long-running compression service over TCP.
//!
//! The paper positions the framework as a compression layer petascale
//! simulations stream data *through*, not a batch tool they shell out
//! to. This module is that front-end: one shared [`Engine`] (one
//! work-stealing pool) serving any number of client connections, each
//! speaking the length-prefixed binary protocol in [`proto`], with the
//! production controls a shared facility needs layered on top:
//!
//! * **admission control** ([`admission`]) — a bounded number of
//!   in-flight requests with a reserved high-priority lane; overflow is
//!   refused with `busy` + retry-after, never queued out of sight;
//! * **per-tenant quotas** ([`quota`]) — token-bucket byte budgets
//!   keyed by the tenant id in each request header;
//! * **graceful drain** — SIGTERM or a `shutdown` request stops
//!   accepting work, lets in-flight requests finish, then exits;
//! * **live metrics** ([`metrics_export`]) — every counter in
//!   [`crate::metrics::registry`] exported by a plaintext `stat`
//!   response.
//!
//! The per-connection frame loop lives in [`conn`]; [`Client`] is the
//! matching blocking client used by `czb client`, the e2e tests and
//! the `serve_load` bench.
pub mod admission;
pub mod conn;
pub mod metrics_export;
pub mod proto;
pub mod quota;

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::core::Field3;
use crate::metrics::registry::Registry;
use crate::pipeline::{Bound, Engine, ShuffleMode};

use admission::Admission;
use conn::{serve_connection, ConnCtx, IdleAwareReader};
use proto::{FrameError, Op, Priority, ResponseHeader, Status, VerifySummary};
use quota::Quota;

/// Tunables for one server instance. `Default` is a loopback
/// development server: ephemeral port, engine-default threads,
/// admission sized to the engine, quotas off.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:9321` (port 0 = ephemeral).
    pub addr: String,
    /// Engine worker threads (0 = engine default).
    pub threads: usize,
    /// Concurrent in-flight requests admitted on the normal lane
    /// (0 = 2x engine threads).
    pub admit_normal: usize,
    /// Extra slots only high-priority requests may take.
    pub admit_high_extra: usize,
    /// Backpressure hint on `busy` responses, in milliseconds.
    pub retry_after_ms: u32,
    /// Token-bucket capacity per tenant, in bytes.
    pub quota_capacity: u64,
    /// Bucket refill rate in bytes/second (0 disables quotas).
    pub quota_rate: u64,
    /// Largest request body accepted.
    pub max_body: u64,
    /// Socket read timeout — the poll granularity at which idle
    /// connections notice a drain.
    pub read_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            threads: 0,
            admit_normal: 0,
            admit_high_extra: 2,
            retry_after_ms: 100,
            quota_capacity: 256 << 20,
            quota_rate: 0,
            max_body: proto::DEFAULT_MAX_BODY,
            read_timeout: Duration::from_millis(100),
        }
    }
}

/// A remote control for a running [`Server`] (cheap to clone, safe to
/// hand to signal watchers and tests).
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Begin a graceful drain: stop admitting work, finish what's in
    /// flight, close idle connections, make [`Server::run`] return.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn is_shutting_down(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// The TCP front-end: owns the listener and the shared [`ConnCtx`].
pub struct Server {
    listener: TcpListener,
    ctx: ConnCtx,
    read_timeout: Duration,
    active: Arc<AtomicUsize>,
}

impl Server {
    /// Bind the listener and build the shared engine + registry. The
    /// server is not serving until [`Server::run`].
    pub fn bind(cfg: &ServeConfig) -> std::io::Result<Server> {
        let metrics = Arc::new(Registry::new());
        let mut b = Engine::builder().metrics(Arc::clone(&metrics));
        if cfg.threads > 0 {
            b = b.threads(cfg.threads);
        }
        let engine = Arc::new(b.build());
        let admit_normal = if cfg.admit_normal > 0 {
            cfg.admit_normal
        } else {
            engine.threads().max(1) * 2
        };
        let admission = Admission::new(admit_normal, cfg.admit_high_extra, cfg.retry_after_ms);
        let quota = Arc::new(Quota::new(cfg.quota_capacity, cfg.quota_rate));
        let ctx = ConnCtx::new(engine, metrics, admission, quota).with_max_body(cfg.max_body);
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        Ok(Server { listener, ctx, read_timeout: cfg.read_timeout, active: Arc::new(AtomicUsize::new(0)) })
    }

    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { stop: Arc::clone(&self.ctx.stop) }
    }

    /// The server's live metrics (shared with the engine it runs).
    pub fn metrics(&self) -> Arc<Registry> {
        Arc::clone(&self.ctx.metrics)
    }

    /// Accept connections until a drain is requested (via
    /// [`ServerHandle::shutdown`], a client `shutdown` frame, or
    /// SIGTERM when [`install_sigterm_drain`] was called), then wait
    /// for in-flight connections to finish and return.
    pub fn run(&self) -> std::io::Result<()> {
        while !self.ctx.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => self.spawn_handler(stream),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e),
            }
        }
        // drain: handlers see the stop flag via IdleAwareReader
        while self.active.load(Ordering::Acquire) > 0 {
            std::thread::sleep(Duration::from_millis(10));
        }
        Ok(())
    }

    fn spawn_handler(&self, stream: TcpStream) {
        // counted in the acceptor, not the handler thread: run()'s
        // drain must never observe a gap between accept and count
        struct Active(Arc<AtomicUsize>, Arc<Registry>);
        impl Drop for Active {
            fn drop(&mut self) {
                self.1.connections.sub(1);
                self.0.fetch_sub(1, Ordering::AcqRel);
            }
        }
        self.active.fetch_add(1, Ordering::AcqRel);
        self.ctx.metrics.connections.add(1);
        let guard = Active(Arc::clone(&self.active), Arc::clone(&self.ctx.metrics));
        let ctx = self.ctx.clone();
        let read_timeout = self.read_timeout;
        std::thread::spawn(move || {
            let _guard = guard;
            let _ = stream.set_read_timeout(Some(read_timeout));
            let _ = stream.set_nodelay(true);
            let Ok(read_half) = stream.try_clone() else { return };
            let mut reader = IdleAwareReader::new(read_half, Arc::clone(&ctx.stop));
            let mut writer = stream;
            let _ = serve_connection(&mut reader, &mut writer, &ctx);
        });
    }
}

#[cfg(unix)]
mod sigterm {
    use super::ServerHandle;
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static SEEN: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_sig: i32) {
        // async-signal-safe: one atomic store, nothing else
        SEEN.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    const SIGTERM: i32 = 15;
    const SIGINT: i32 = 2;

    pub fn install(handle: ServerHandle) {
        unsafe {
            signal(SIGTERM, on_term);
            signal(SIGINT, on_term);
        }
        std::thread::spawn(move || loop {
            if SEEN.load(Ordering::SeqCst) {
                handle.shutdown();
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        });
    }
}

/// Turn SIGTERM/SIGINT into a graceful drain of `handle`'s server
/// (std-only: a libc `signal(2)` registration on unix, a no-op
/// elsewhere). The handler only sets a flag; a watcher thread does the
/// actual shutdown call.
pub fn install_sigterm_drain(handle: ServerHandle) {
    #[cfg(unix)]
    sigterm::install(handle);
    #[cfg(not(unix))]
    let _ = handle;
}

/// A non-ok outcome the server chose to send: refusals (`busy`,
/// `quota`, `shutting_down`), semantic errors, protocol rejections.
#[derive(Clone, Debug)]
pub struct Refusal {
    pub status: Status,
    pub retry_after_ms: u32,
    pub message: String,
}

impl std::fmt::Display for Refusal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {} (retry after {} ms)",
            proto::status_name(self.status),
            self.message,
            self.retry_after_ms
        )
    }
}

/// What one request came back as: the decoded payload, or the server's
/// explicit refusal. Transport/protocol failures are the outer `Err`
/// on each [`Client`] call.
pub type Reply<T> = Result<T, Refusal>;

/// Blocking client for the serve protocol — used by `czb client`, the
/// e2e tests and the load bench.
pub struct Client {
    stream: TcpStream,
    tenant: String,
    priority: Priority,
    max_body: u64,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Self {
            stream,
            tenant: String::new(),
            priority: Priority::Normal,
            max_body: proto::DEFAULT_MAX_BODY,
        })
    }

    /// Tenant id stamped on every request ("" = anonymous).
    pub fn tenant(mut self, t: &str) -> Self {
        self.tenant = t.to_string();
        self
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// One raw request/response exchange. `Err` is transport or
    /// protocol failure; refusals come back as a normal header.
    pub fn request_raw(
        &mut self,
        op: Op,
        body: &[u8],
    ) -> Result<(ResponseHeader, Vec<u8>), String> {
        proto::write_request(&mut self.stream, op, self.priority, &self.tenant, body)
            .map_err(|e| format!("sending request: {e}"))?;
        let hdr = proto::read_response_header(&mut self.stream, self.max_body).map_err(|e| {
            match e {
                FrameError::Eof => "server closed the connection".to_string(),
                other => other.to_string(),
            }
        })?;
        let mut resp = vec![0u8; hdr.body_len as usize];
        self.stream
            .read_exact(&mut resp)
            .map_err(|e| format!("reading response body: {e}"))?;
        Ok((hdr, resp))
    }

    fn expect_ok(
        &mut self,
        op: Op,
        body: &[u8],
    ) -> Result<Reply<Vec<u8>>, String> {
        let (hdr, resp) = self.request_raw(op, body)?;
        if hdr.status == Status::Ok {
            Ok(Ok(resp))
        } else {
            Ok(Err(Refusal {
                status: hdr.status,
                retry_after_ms: hdr.retry_after_ms,
                message: String::from_utf8_lossy(&resp).into_owned(),
            }))
        }
    }

    /// Compress a field remotely; `Ok(Ok(bytes))` is a finished `.czb`
    /// stream, bit-identical to a local compress with the same params.
    pub fn compress(
        &mut self,
        name: &str,
        field: &Field3,
        bs: u32,
        eps: f32,
        shuffle: ShuffleMode,
    ) -> Result<Reply<Vec<u8>>, String> {
        self.compress_bounded(name, field, bs, eps, shuffle, Bound::None)
    }

    /// [`Client::compress`] under an error-bound contract: the server
    /// picks the stage-1 codec for the bound's kind, derives its knob,
    /// and the returned `.czb` records the contract plus the achieved
    /// quality (checkable with `czb verify --bounds`).
    pub fn compress_bounded(
        &mut self,
        name: &str,
        field: &Field3,
        bs: u32,
        eps: f32,
        shuffle: ShuffleMode,
        bound: Bound,
    ) -> Result<Reply<Vec<u8>>, String> {
        let body = proto::encode_compress_body_bound(name, field, bs, eps, shuffle, bound);
        self.expect_ok(Op::Compress, &body)
    }

    /// Decompress a `.czb` stream remotely.
    pub fn decompress(&mut self, czb: &[u8]) -> Result<Reply<(String, Field3)>, String> {
        Ok(match self.expect_ok(Op::Decompress, czb)? {
            Ok(body) => Ok(proto::decode_field_body(&body)?),
            Err(r) => Err(r),
        })
    }

    /// Checksum-walk a `.czb` stream remotely.
    pub fn verify(&mut self, czb: &[u8]) -> Result<Reply<VerifySummary>, String> {
        Ok(match self.expect_ok(Op::Verify, czb)? {
            Ok(body) => Ok(proto::decode_verify_body(&body)?),
            Err(r) => Err(r),
        })
    }

    /// Fetch the plaintext metrics export.
    pub fn stat(&mut self) -> Result<Reply<String>, String> {
        Ok(match self.expect_ok(Op::Stat, b"")? {
            Ok(body) => Ok(String::from_utf8_lossy(&body).into_owned()),
            Err(r) => Err(r),
        })
    }

    /// Ask the server to drain and exit.
    pub fn shutdown(&mut self) -> Result<Reply<()>, String> {
        Ok(match self.expect_ok(Op::Shutdown, b"")? {
            Ok(_) => Ok(()),
            Err(r) => Err(r),
        })
    }
}
