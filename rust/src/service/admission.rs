//! Admission control: a bounded count of in-flight requests with an
//! express lane, refusing (not queueing) the overflow.
//!
//! The worker pool underneath already multiplexes any number of
//! submissions fairly — what it cannot do is bound *memory*: every
//! admitted request holds its decoded field and response buffers alive
//! until it finishes. So the service admits at most `normal_limit`
//! concurrent requests on the normal lane, plus `high_extra` reserved
//! slots only high-priority requests may take. A refused request gets
//! an explicit `busy` response with a retry hint; nothing is ever
//! parked in an unbounded queue where the client can't see it.
//!
//! Slots are RAII: [`Admission::try_acquire`] hands out a [`Permit`]
//! whose `Drop` releases the slot, so a panicking handler or an early
//! `return` can never leak capacity.
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::proto::Priority;

/// Why a request was not admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Busy {
    /// Backpressure hint for the client, in milliseconds.
    pub retry_after_ms: u32,
}

struct Inner {
    in_flight: AtomicUsize,
    normal_limit: usize,
    total_limit: usize,
    retry_after_ms: u32,
}

/// Shared admission state (cheap to clone; all clones meter the same
/// slots).
#[derive(Clone)]
pub struct Admission {
    inner: Arc<Inner>,
}

/// One admitted in-flight request. Dropping it releases the slot.
pub struct Permit {
    inner: Arc<Inner>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.inner.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Admission {
    /// `normal_limit` slots for everyone, `high_extra` more that only
    /// [`Priority::High`] requests can occupy. Limits are clamped to at
    /// least one normal slot (an admission control that admits nothing
    /// is a misconfiguration, not a policy).
    pub fn new(normal_limit: usize, high_extra: usize, retry_after_ms: u32) -> Self {
        let normal_limit = normal_limit.max(1);
        Self {
            inner: Arc::new(Inner {
                in_flight: AtomicUsize::new(0),
                normal_limit,
                total_limit: normal_limit + high_extra,
                retry_after_ms,
            }),
        }
    }

    /// Try to occupy a slot for a request on `priority`'s lane.
    pub fn try_acquire(&self, priority: Priority) -> Result<Permit, Busy> {
        let limit = match priority {
            Priority::Normal => self.inner.normal_limit,
            Priority::High => self.inner.total_limit,
        };
        // CAS loop rather than fetch_add/undo: a burst of refused
        // requests must not transiently inflate the count past the
        // limit and refuse an admissible sibling.
        let mut cur = self.inner.in_flight.load(Ordering::Acquire);
        loop {
            if cur >= limit {
                return Err(Busy { retry_after_ms: self.inner.retry_after_ms });
            }
            match self.inner.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Ok(Permit { inner: Arc::clone(&self.inner) }),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Requests currently holding a permit.
    pub fn in_flight(&self) -> usize {
        self.inner.in_flight.load(Ordering::Acquire)
    }

    pub fn normal_limit(&self) -> usize {
        self.inner.normal_limit
    }

    pub fn total_limit(&self) -> usize {
        self.inner.total_limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permits_bound_the_normal_lane() {
        let a = Admission::new(2, 0, 100);
        let p1 = a.try_acquire(Priority::Normal).unwrap();
        let _p2 = a.try_acquire(Priority::Normal).unwrap();
        let busy = a.try_acquire(Priority::Normal).unwrap_err();
        assert_eq!(busy.retry_after_ms, 100);
        assert_eq!(a.in_flight(), 2);
        drop(p1);
        assert_eq!(a.in_flight(), 1);
        let _p3 = a.try_acquire(Priority::Normal).unwrap();
    }

    #[test]
    fn high_lane_has_reserved_headroom() {
        let a = Admission::new(1, 1, 50);
        let _p1 = a.try_acquire(Priority::Normal).unwrap();
        // normal lane is full, the express slot still admits high
        assert!(a.try_acquire(Priority::Normal).is_err());
        let _p2 = a.try_acquire(Priority::High).unwrap();
        // now even high is full
        assert!(a.try_acquire(Priority::High).is_err());
        assert_eq!(a.in_flight(), 2);
    }

    #[test]
    fn zero_limits_are_clamped_to_one_slot() {
        let a = Admission::new(0, 0, 10);
        let _p = a.try_acquire(Priority::Normal).unwrap();
        assert!(a.try_acquire(Priority::High).is_err());
    }

    #[test]
    fn dropped_permits_never_leak_under_contention() {
        let a = Admission::new(4, 2, 1);
        let threads: Vec<_> = (0..8)
            .map(|i| {
                let a = a.clone();
                std::thread::spawn(move || {
                    let pri = if i % 2 == 0 { Priority::Normal } else { Priority::High };
                    let mut admitted = 0u32;
                    for _ in 0..500 {
                        if let Ok(p) = a.try_acquire(pri) {
                            admitted += 1;
                            assert!(a.in_flight() <= a.total_limit());
                            drop(p);
                        }
                    }
                    admitted
                })
            })
            .collect();
        let total: u32 = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert!(total > 0);
        assert_eq!(a.in_flight(), 0, "every permit must have been returned");
    }
}
