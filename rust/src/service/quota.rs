//! Per-tenant byte quotas: one token bucket per tenant id, refilled at
//! a byte rate, consulted once per request with the declared body size.
//!
//! The admission layer bounds *how many* requests run at once; quotas
//! bound *how much data* each tenant may push through over time, so one
//! chatty simulation cannot starve its neighbours of engine bandwidth.
//!
//! The bucket uses a debt model: a request is admitted when the bucket
//! holds at least `min(request_bytes, capacity)` tokens, and the full
//! request size is then deducted — possibly driving the balance
//! negative. That way a single request larger than the whole capacity
//! is still serviceable (it just leaves the tenant in debt and
//! throttled for a while), instead of being unservable forever. A
//! refused request gets the time until the bucket covers it again as
//! its `retry_after_ms` hint.
//!
//! Time is injected (microseconds since server start) so tests are
//! deterministic; the public entry point reads a monotonic clock.
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Why a request was refused by its tenant's bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Throttled {
    /// When the bucket will cover the refused request, in milliseconds.
    pub retry_after_ms: u32,
}

struct Bucket {
    tokens: f64,
    last_micros: u64,
}

/// Token-bucket quotas keyed by tenant id. `rate_bytes_per_sec == 0`
/// disables quotas entirely (every request admitted) — the default for
/// a server run without `--quota-rate`.
pub struct Quota {
    capacity: f64,
    rate_per_micro: f64,
    enabled: bool,
    start: Instant,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl Quota {
    pub fn new(capacity_bytes: u64, rate_bytes_per_sec: u64) -> Self {
        Self {
            capacity: capacity_bytes.max(1) as f64,
            rate_per_micro: rate_bytes_per_sec as f64 * 1e-6,
            enabled: rate_bytes_per_sec > 0,
            start: Instant::now(),
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Unlimited quota (every request admitted, nothing tracked).
    pub fn unlimited() -> Self {
        Self::new(1, 0)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Charge `bytes` to `tenant`'s bucket, admitting or refusing.
    pub fn try_consume(&self, tenant: &str, bytes: u64) -> Result<(), Throttled> {
        self.try_consume_at(tenant, bytes, self.start.elapsed().as_micros() as u64)
    }

    /// Deterministic core: `now_micros` is time since server start.
    pub fn try_consume_at(
        &self,
        tenant: &str,
        bytes: u64,
        now_micros: u64,
    ) -> Result<(), Throttled> {
        if !self.enabled {
            return Ok(());
        }
        let mut g = self.buckets.lock().unwrap();
        let b = g
            .entry(tenant.to_string())
            .or_insert(Bucket { tokens: self.capacity, last_micros: now_micros });
        // refill for the time elapsed since this bucket was last touched
        let dt = now_micros.saturating_sub(b.last_micros) as f64;
        b.tokens = (b.tokens + dt * self.rate_per_micro).min(self.capacity);
        b.last_micros = now_micros;
        // debt model: a request bigger than the whole capacity only needs
        // a full bucket, then drives the balance negative
        let need = (bytes as f64).min(self.capacity);
        if b.tokens >= need {
            b.tokens -= bytes as f64;
            return Ok(());
        }
        let deficit = need - b.tokens;
        let micros = if self.rate_per_micro > 0.0 { deficit / self.rate_per_micro } else { f64::MAX };
        let ms = (micros / 1e3).ceil().clamp(1.0, u32::MAX as f64) as u32;
        Err(Throttled { retry_after_ms: ms })
    }

    /// Current token balance for a tenant (negative = in debt); `None`
    /// when the tenant has never been charged. Monitoring only.
    pub fn balance(&self, tenant: &str) -> Option<f64> {
        self.buckets.lock().unwrap().get(tenant).map(|b| b.tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_quota_admits_everything() {
        let q = Quota::unlimited();
        assert!(!q.enabled());
        for _ in 0..100 {
            q.try_consume("t", u64::MAX / 2).unwrap();
        }
        assert_eq!(q.balance("t"), None, "disabled quotas track nothing");
    }

    #[test]
    fn bucket_drains_then_refills_at_rate() {
        // 1000-byte bucket refilling 1000 B/s
        let q = Quota::new(1000, 1000);
        q.try_consume_at("t", 600, 0).unwrap();
        q.try_consume_at("t", 400, 0).unwrap();
        // empty now: a 500-byte request needs 500 tokens = 500ms
        let t = q.try_consume_at("t", 500, 0).unwrap_err();
        assert_eq!(t.retry_after_ms, 500);
        // 300ms later it still can't cover 500
        assert!(q.try_consume_at("t", 500, 300_000).is_err());
        // but it can cover 250
        q.try_consume_at("t", 250, 300_000).unwrap();
        // and after a full second idle the bucket is capped at capacity
        q.try_consume_at("t", 1000, 2_000_000).unwrap();
    }

    #[test]
    fn oversized_requests_use_the_debt_model() {
        let q = Quota::new(1000, 1000);
        // 5x the capacity: admitted on a full bucket...
        q.try_consume_at("t", 5000, 0).unwrap();
        assert_eq!(q.balance("t"), Some(-4000.0));
        // ...then the tenant is throttled while the debt pays down
        let t = q.try_consume_at("t", 10, 0).unwrap_err();
        // needs 10 - (-4000) = 4010 tokens at 1000 B/s
        assert_eq!(t.retry_after_ms, 4010);
        // 5 seconds later the bucket is full again
        q.try_consume_at("t", 1000, 5_000_000).unwrap();
    }

    #[test]
    fn tenants_have_independent_buckets() {
        let q = Quota::new(100, 100);
        q.try_consume_at("a", 100, 0).unwrap();
        assert!(q.try_consume_at("a", 1, 0).is_err());
        q.try_consume_at("b", 100, 0).unwrap();
        assert!(q.balance("a").unwrap() <= 0.0);
    }

    #[test]
    fn retry_hint_is_at_least_one_ms() {
        let q = Quota::new(1000, 1_000_000_000);
        q.try_consume_at("t", 1000, 0).unwrap();
        let t = q.try_consume_at("t", 1, 0).unwrap_err();
        assert!(t.retry_after_ms >= 1);
    }
}
