//! Render the live [`Registry`] as plaintext in Prometheus exposition
//! style — the body of a `stat` response.
//!
//! The format is line-oriented `name{label="value"} number`, with
//! `# HELP`/`# TYPE` comments, so any Prometheus-compatible scraper
//! (or a human with `nc`) can read it. No timestamp is emitted — the
//! scrape time is the sample time.
use crate::metrics::registry::{Registry, OPS, PSNR_BUCKETS, PSNR_BUCKET_DB, STATUSES};
use std::fmt::Write as _;

/// Latency quantiles the exporter reports per metered operation.
const QUANTILES: [(&str, f64); 3] = [("0.5", 0.5), ("0.99", 0.99), ("1", 1.0)];

/// Render the whole registry. Infallible: writing into a `String`
/// cannot fail, and every metric read is a relaxed atomic load.
pub fn render(r: &Registry) -> String {
    let mut out = String::with_capacity(4096);
    let _ = writeln!(out, "# HELP czb_build_info Process build/dispatch facts as labels.");
    let _ = writeln!(out, "# TYPE czb_build_info gauge");
    let _ = writeln!(out, "czb_build_info{{simd=\"{}\"}} 1", crate::simd::level().name());
    let _ = writeln!(out, "# HELP czb_requests_total Requests received, by operation.");
    let _ = writeln!(out, "# TYPE czb_requests_total counter");
    for (i, op) in OPS.iter().enumerate() {
        let _ = writeln!(out, "czb_requests_total{{op=\"{op}\"}} {}", r.requests[i].get());
    }
    let _ = writeln!(out, "# HELP czb_responses_total Responses sent, by status.");
    let _ = writeln!(out, "# TYPE czb_responses_total counter");
    for (i, st) in STATUSES.iter().enumerate() {
        let _ = writeln!(out, "czb_responses_total{{status=\"{st}\"}} {}", r.responses[i].get());
    }
    let _ = writeln!(out, "# HELP czb_bytes_in_total Request body bytes received.");
    let _ = writeln!(out, "# TYPE czb_bytes_in_total counter");
    let _ = writeln!(out, "czb_bytes_in_total {}", r.bytes_in.get());
    let _ = writeln!(out, "# HELP czb_bytes_out_total Response body bytes sent.");
    let _ = writeln!(out, "# TYPE czb_bytes_out_total counter");
    let _ = writeln!(out, "czb_bytes_out_total {}", r.bytes_out.get());
    let _ = writeln!(out, "# HELP czb_queue_depth Admitted requests currently in flight.");
    let _ = writeln!(out, "# TYPE czb_queue_depth gauge");
    let _ = writeln!(out, "czb_queue_depth {}", r.queue_depth.get());
    let _ = writeln!(out, "# HELP czb_connections Open client connections.");
    let _ = writeln!(out, "# TYPE czb_connections gauge");
    let _ = writeln!(out, "czb_connections {}", r.connections.get());

    let _ = writeln!(
        out,
        "# HELP czb_request_latency_seconds End-to-end request latency (bucket upper bounds)."
    );
    let _ = writeln!(out, "# TYPE czb_request_latency_seconds summary");
    for (i, op) in OPS.iter().enumerate() {
        let Some(h) = r.latency_of(i) else { continue };
        for (label, q) in QUANTILES {
            if let Some(v) = h.quantile_secs(q) {
                let _ = writeln!(
                    out,
                    "czb_request_latency_seconds{{op=\"{op}\",quantile=\"{label}\"}} {v:.6}"
                );
            }
        }
        let _ = writeln!(out, "czb_request_latency_seconds_count{{op=\"{op}\"}} {}", h.count());
        let _ = writeln!(
            out,
            "czb_request_latency_seconds_sum{{op=\"{op}\"}} {:.6}",
            h.sum_secs()
        );
    }

    let _ = writeln!(out, "# HELP czb_engine_calls_total Engine sessions run, by direction.");
    let _ = writeln!(out, "# TYPE czb_engine_calls_total counter");
    let _ = writeln!(
        out,
        "czb_engine_calls_total{{dir=\"compress\"}} {}",
        r.engine_compress_calls.get()
    );
    let _ = writeln!(
        out,
        "czb_engine_calls_total{{dir=\"decompress\"}} {}",
        r.engine_decompress_calls.get()
    );
    let _ = writeln!(out, "# HELP czb_engine_raw_bytes_total Raw bytes compressed.");
    let _ = writeln!(out, "# TYPE czb_engine_raw_bytes_total counter");
    let _ = writeln!(out, "czb_engine_raw_bytes_total {}", r.engine_raw_bytes.get());
    let _ = writeln!(out, "# HELP czb_engine_compressed_bytes_total Compressed bytes produced.");
    let _ = writeln!(out, "# TYPE czb_engine_compressed_bytes_total counter");
    let _ = writeln!(out, "czb_engine_compressed_bytes_total {}", r.engine_compressed_bytes.get());
    let _ = writeln!(out, "# HELP czb_engine_decoded_bytes_total Bytes decoded.");
    let _ = writeln!(out, "# TYPE czb_engine_decoded_bytes_total counter");
    let _ = writeln!(out, "czb_engine_decoded_bytes_total {}", r.engine_decoded_bytes.get());
    let _ = writeln!(
        out,
        "# HELP czb_engine_stage_seconds_total Stage wall time, summed over submissions."
    );
    let _ = writeln!(out, "# TYPE czb_engine_stage_seconds_total counter");
    let _ = writeln!(
        out,
        "czb_engine_stage_seconds_total{{stage=\"1\"}} {:.6}",
        r.stage1_micros.get() as f64 * 1e-6
    );
    let _ = writeln!(
        out,
        "czb_engine_stage_seconds_total{{stage=\"2\"}} {:.6}",
        r.stage2_micros.get() as f64 * 1e-6
    );

    let tenants = r.tenants_snapshot();
    if !tenants.is_empty() {
        let _ = writeln!(out, "# HELP czb_tenant_requests_total Requests, by tenant.");
        let _ = writeln!(out, "# TYPE czb_tenant_requests_total counter");
        for (t, u) in &tenants {
            let t = escape_label(t);
            let _ = writeln!(out, "czb_tenant_requests_total{{tenant=\"{t}\"}} {}", u.requests);
        }
        let _ = writeln!(out, "# HELP czb_tenant_bytes_total Body bytes, by tenant and direction.");
        let _ = writeln!(out, "# TYPE czb_tenant_bytes_total counter");
        for (t, u) in &tenants {
            let t = escape_label(t);
            let _ = writeln!(
                out,
                "czb_tenant_bytes_total{{tenant=\"{t}\",dir=\"in\"}} {}",
                u.bytes_in
            );
            let _ = writeln!(
                out,
                "czb_tenant_bytes_total{{tenant=\"{t}\",dir=\"out\"}} {}",
                u.bytes_out
            );
        }
        let _ = writeln!(out, "# HELP czb_tenant_throttled_total Quota refusals, by tenant.");
        let _ = writeln!(out, "# TYPE czb_tenant_throttled_total counter");
        for (t, u) in &tenants {
            let t = escape_label(t);
            let _ = writeln!(out, "czb_tenant_throttled_total{{tenant=\"{t}\"}} {}", u.throttled);
        }
    }

    let psnr = r.tenant_psnr_snapshot();
    if !psnr.is_empty() {
        let _ = writeln!(
            out,
            "# HELP czb_tenant_achieved_psnr_db Achieved compression PSNR per tenant \
             (lossless streams land in the +Inf bucket)."
        );
        let _ = writeln!(out, "# TYPE czb_tenant_achieved_psnr_db histogram");
        for (t, h) in &psnr {
            let t = escape_label(t);
            // cumulative counts, Prometheus histogram convention
            let mut cum = 0u64;
            for (i, b) in h.buckets.iter().enumerate() {
                cum += b;
                let _ = writeln!(
                    out,
                    "czb_tenant_achieved_psnr_db_bucket{{tenant=\"{t}\",le=\"{}\"}} {cum}",
                    PSNR_BUCKET_DB * (i + 1) as f64
                );
            }
            debug_assert_eq!(cum + h.overflow, h.count);
            let _ = writeln!(
                out,
                "czb_tenant_achieved_psnr_db_bucket{{tenant=\"{t}\",le=\"+Inf\"}} {}",
                h.count
            );
            let _ = writeln!(out, "czb_tenant_achieved_psnr_db_count{{tenant=\"{t}\"}} {}", h.count);
            let _ =
                writeln!(out, "czb_tenant_achieved_psnr_db_sum{{tenant=\"{t}\"}} {:.3}", h.sum_db);
        }
        const _: () = assert!(PSNR_BUCKETS == 16, "le labels track the bucket layout");
    }
    out
}

/// Prometheus label-value escaping: backslash, double-quote, newline.
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Pull one metric's value back out of a rendered export — test and
/// smoke-check helper ("did this counter move"), not a parser.
pub fn sample(text: &str, series: &str) -> Option<f64> {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find_map(|l| l.strip_prefix(series).and_then(|rest| rest.trim().parse::<f64>().ok()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_covers_every_metric_family() {
        let r = Registry::new();
        r.requests[0].add(3);
        r.responses[0].add(2);
        r.bytes_in.add(100);
        r.bytes_out.add(50);
        r.queue_depth.set(2);
        r.connections.set(4);
        r.latency_compress.record_secs(0.002);
        r.engine_compress_calls.inc();
        r.record_tenant("sim-a", 100, 50, true);
        let text = render(&r);
        assert_eq!(sample(&text, "czb_requests_total{op=\"compress\"}"), Some(3.0));
        assert_eq!(sample(&text, "czb_responses_total{status=\"ok\"}"), Some(2.0));
        assert_eq!(sample(&text, "czb_bytes_in_total"), Some(100.0));
        assert_eq!(sample(&text, "czb_queue_depth"), Some(2.0));
        assert_eq!(sample(&text, "czb_connections"), Some(4.0));
        assert_eq!(
            sample(&text, "czb_request_latency_seconds_count{op=\"compress\"}"),
            Some(1.0)
        );
        let p99 = sample(&text, "czb_request_latency_seconds{op=\"compress\",quantile=\"0.99\"}");
        assert!(p99.unwrap() >= 0.002);
        assert_eq!(sample(&text, "czb_tenant_requests_total{tenant=\"sim-a\"}"), Some(1.0));
        assert_eq!(sample(&text, "czb_tenant_throttled_total{tenant=\"sim-a\"}"), Some(1.0));
        assert_eq!(sample(&text, "czb_engine_calls_total{dir=\"compress\"}"), Some(1.0));
        let simd = format!("czb_build_info{{simd=\"{}\"}}", crate::simd::level().name());
        assert_eq!(sample(&text, &simd), Some(1.0));
    }

    #[test]
    fn empty_registry_renders_without_latency_or_tenants() {
        let text = render(&Registry::new());
        assert!(!text.contains("quantile"), "no samples -> no quantile lines");
        assert!(!text.contains("czb_tenant_"), "no tenants -> no tenant lines");
        assert_eq!(sample(&text, "czb_bytes_in_total"), Some(0.0));
    }

    #[test]
    fn tenant_psnr_histogram_renders_cumulative_buckets() {
        let r = Registry::new();
        r.record_tenant_psnr("sim-a", 57.0); // le="60"
        r.record_tenant_psnr("sim-a", 95.0); // le="100"
        r.record_tenant_psnr("sim-a", f64::INFINITY); // +Inf only
        let text = render(&r);
        assert_eq!(
            sample(&text, "czb_tenant_achieved_psnr_db_bucket{tenant=\"sim-a\",le=\"50\"}"),
            Some(0.0)
        );
        assert_eq!(
            sample(&text, "czb_tenant_achieved_psnr_db_bucket{tenant=\"sim-a\",le=\"60\"}"),
            Some(1.0)
        );
        assert_eq!(
            sample(&text, "czb_tenant_achieved_psnr_db_bucket{tenant=\"sim-a\",le=\"100\"}"),
            Some(2.0),
            "buckets must be cumulative"
        );
        assert_eq!(
            sample(&text, "czb_tenant_achieved_psnr_db_bucket{tenant=\"sim-a\",le=\"160\"}"),
            Some(2.0)
        );
        assert_eq!(
            sample(&text, "czb_tenant_achieved_psnr_db_bucket{tenant=\"sim-a\",le=\"+Inf\"}"),
            Some(3.0)
        );
        assert_eq!(
            sample(&text, "czb_tenant_achieved_psnr_db_count{tenant=\"sim-a\"}"),
            Some(3.0)
        );
        let sum =
            sample(&text, "czb_tenant_achieved_psnr_db_sum{tenant=\"sim-a\"}").unwrap();
        assert!((sum - (57.0 + 95.0 + 300.0)).abs() < 1e-6, "{sum}");
    }

    #[test]
    fn hostile_tenant_ids_are_escaped() {
        let r = Registry::new();
        r.record_tenant("a\"b\nc\\d", 1, 0, false);
        let text = render(&r);
        assert!(text.contains("tenant=\"a\\\"b\\nc\\\\d\""), "{text}");
    }
}
