//! The `.czm` shard manifest: the small, CRC32C'd index that stitches
//! per-shard `.czs` archives into one logical dataset. On-disk layout
//! and version history live in `docs/FORMATS.md` alongside `.czb` and
//! `.czs`; this module is the reference reader/writer.
//!
//! Design points, mirroring the `.czs` trailer parser
//! (`crate::pipeline::dataset`):
//!
//! * **Everything is covered by one CRC32C** over the whole manifest
//!   body, so a flipped bit anywhere — header, shard table, quantity
//!   table — fails [`Manifest::decode`] instead of mis-routing a read.
//! * **Strict parsing.** Truncation, trailing garbage, non-UTF-8 or
//!   duplicate names, out-of-range shard indices, zero dims and counts
//!   larger than the table could hold are all hard errors.
//! * **Shard paths are plain relative filenames**, resolved against the
//!   manifest's own directory: a manifest plus its shards is a
//!   relocatable directory, and a hostile manifest cannot point reads
//!   at `/etc` or climb out with `..`.
//! * **Dims are recorded per quantity** so a reader can zero-fill a
//!   quantity whose shard file is lost entirely (salvage semantics) and
//!   `czb info` can describe the dataset without opening any shard.
use crate::util::crc32c::crc32c;
use std::path::{Component, Path};

/// Magic bytes a `.czm` manifest starts with.
pub const CZM_MAGIC: &[u8; 4] = b"CZM1";
/// Magic bytes a `.czm` manifest ends with.
pub const CZM_TRAILER_MAGIC: &[u8; 4] = b"CZME";
/// Manifest version the writer emits (v1 is the first).
pub const CZM_VERSION: u8 = 1;

/// magic | version | 3 reserved | u32 nshards | u32 nquantities
const HEADER_LEN: usize = 16;
/// u32 CRC32C over everything before it | trailer magic
const TRAILER_LEN: usize = 8;
/// Smallest possible shard entry: u16 path_len, 1-byte path, u64
/// file_len, u32 file_crc.
const MIN_SHARD_ENTRY: usize = 2 + 1 + 8 + 4;
/// Smallest possible quantity entry: u8 name_len, 1-byte name, u32
/// shard, u32 nx/ny/nz.
const MIN_QUANTITY_ENTRY: usize = 1 + 1 + 4 + 12;

/// One shard file of a sharded dataset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    /// Shard filename, relative to the manifest's directory (plain
    /// relative path: no absolute paths, no `..`).
    pub path: String,
    /// Exact byte length of the shard `.czs` file.
    pub file_len: u64,
    /// CRC32C of the whole shard file.
    pub file_crc: u32,
}

/// One quantity of the logical dataset: which shard owns it and its
/// dims (kept here so a lost shard's quantities can still be described
/// and zero-filled).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestQuantity {
    pub name: String,
    /// Index into [`Manifest::shards`] of the owning shard.
    pub shard: usize,
    pub nx: u32,
    pub ny: u32,
    pub nz: u32,
}

impl ManifestQuantity {
    /// Raw field size in bytes (`nx*ny*nz` f32 samples).
    pub fn raw_bytes(&self) -> u64 {
        self.nx as u64 * self.ny as u64 * self.nz as u64 * 4
    }
}

/// A parsed (or to-be-written) `.czm` manifest. Quantity order is the
/// dataset's logical order — what an unsharded archive of the same
/// input would contain — independent of how quantities were packed
/// into shards.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub shards: Vec<ShardEntry>,
    pub quantities: Vec<ManifestQuantity>,
}

/// A shard path must be a plain relative filename (possibly in a
/// subdirectory) so manifests are relocatable and cannot escape their
/// own directory.
fn validate_shard_path(p: &str) -> Result<(), String> {
    if p.is_empty() {
        return Err("empty shard path".into());
    }
    let path = Path::new(p);
    if path.is_absolute() {
        return Err(format!("shard path {p:?} is absolute"));
    }
    for c in path.components() {
        match c {
            Component::Normal(_) => {}
            _ => return Err(format!("shard path {p:?} must be a plain relative path")),
        }
    }
    Ok(())
}

fn take<'a>(body: &'a [u8], pos: &mut usize, n: usize, what: &str) -> Result<&'a [u8], String> {
    let end = pos
        .checked_add(n)
        .filter(|&e| e <= body.len())
        .ok_or_else(|| format!("czm manifest truncated reading {what}"))?;
    let s = &body[*pos..end];
    *pos = end;
    Ok(s)
}

impl Manifest {
    /// Check the invariants [`Manifest::decode`] enforces, on the
    /// writer side: a manifest that would not read back must never be
    /// written.
    pub fn validate(&self) -> Result<(), String> {
        if self.shards.is_empty() {
            return Err("manifest has no shards".into());
        }
        if self.quantities.is_empty() {
            return Err("manifest has no quantities".into());
        }
        if self.shards.len() > u32::MAX as usize || self.quantities.len() > u32::MAX as usize {
            return Err("manifest table too large".into());
        }
        for (i, s) in self.shards.iter().enumerate() {
            validate_shard_path(&s.path).map_err(|e| format!("shard {i}: {e}"))?;
            if s.path.len() > u16::MAX as usize {
                return Err(format!("shard {i} path longer than {} bytes", u16::MAX));
            }
            if self.shards[..i].iter().any(|p| p.path == s.path) {
                return Err(format!("duplicate shard path {:?}", s.path));
            }
            if !self.quantities.iter().any(|q| q.shard == i) {
                return Err(format!("shard {i} ({:?}) carries no quantities", s.path));
            }
        }
        for (i, q) in self.quantities.iter().enumerate() {
            if q.name.is_empty() || q.name.len() > 255 {
                return Err(format!("quantity {i} name length {} not in 1..=255", q.name.len()));
            }
            if self.quantities[..i].iter().any(|p| p.name == q.name) {
                return Err(format!("duplicate quantity {:?}", q.name));
            }
            if q.shard >= self.shards.len() {
                return Err(format!(
                    "quantity {:?} names shard {} of {}",
                    q.name,
                    q.shard,
                    self.shards.len()
                ));
            }
            if q.nx == 0 || q.ny == 0 || q.nz == 0 {
                return Err(format!("quantity {:?} has zero dims", q.name));
            }
        }
        Ok(())
    }

    /// Serialize to the `.czm` v1 wire layout (see `docs/FORMATS.md`).
    /// Pure serializer — pair with [`Manifest::validate`] (the file
    /// writer does) so crafted-invalid bytes stay constructible in
    /// tests.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(CZM_MAGIC);
        out.push(CZM_VERSION);
        out.extend_from_slice(&[0u8; 3]);
        out.extend_from_slice(&(self.shards.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.quantities.len() as u32).to_le_bytes());
        for s in &self.shards {
            out.extend_from_slice(&(s.path.len() as u16).to_le_bytes());
            out.extend_from_slice(s.path.as_bytes());
            out.extend_from_slice(&s.file_len.to_le_bytes());
            out.extend_from_slice(&s.file_crc.to_le_bytes());
        }
        for q in &self.quantities {
            out.push(q.name.len() as u8);
            out.extend_from_slice(q.name.as_bytes());
            out.extend_from_slice(&(q.shard as u32).to_le_bytes());
            out.extend_from_slice(&q.nx.to_le_bytes());
            out.extend_from_slice(&q.ny.to_le_bytes());
            out.extend_from_slice(&q.nz.to_le_bytes());
        }
        let crc = crc32c(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(CZM_TRAILER_MAGIC);
        out
    }

    /// Strict parse of a `.czm` manifest. Any damage — truncation, a
    /// flipped bit anywhere (the CRC covers every preceding byte),
    /// trailing garbage, duplicate or malformed names/paths, dangling
    /// shard indices — is a hard error, never a best-effort read.
    pub fn decode(bytes: &[u8]) -> Result<Manifest, String> {
        if bytes.len() < HEADER_LEN + TRAILER_LEN {
            return Err(format!("czm manifest too short ({} bytes)", bytes.len()));
        }
        if &bytes[..4] != CZM_MAGIC {
            return Err("bad czm magic".into());
        }
        let version = bytes[4];
        if version != CZM_VERSION {
            return Err(format!(
                "unsupported czm version {version} (this reader speaks {CZM_VERSION})"
            ));
        }
        if &bytes[bytes.len() - 4..] != CZM_TRAILER_MAGIC {
            return Err("bad czm trailer magic".into());
        }
        let stored =
            u32::from_le_bytes(bytes[bytes.len() - 8..bytes.len() - 4].try_into().unwrap());
        let computed = crc32c(&bytes[..bytes.len() - 8]);
        if stored != computed {
            return Err(format!(
                "czm manifest CRC32C mismatch (stored {stored:08x}, computed {computed:08x})"
            ));
        }
        let nshards = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let nquantities = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let body = &bytes[HEADER_LEN..bytes.len() - TRAILER_LEN];
        if nshards == 0 {
            return Err("czm manifest declares no shards".into());
        }
        if nquantities == 0 {
            return Err("czm manifest declares no quantities".into());
        }
        // count sanity before any allocation sized by it
        if nshards > body.len() / MIN_SHARD_ENTRY {
            return Err(format!("czm shard count {nshards} larger than the table could hold"));
        }
        if nquantities > body.len() / MIN_QUANTITY_ENTRY {
            return Err(format!(
                "czm quantity count {nquantities} larger than the table could hold"
            ));
        }
        let mut pos = 0usize;
        let mut shards: Vec<ShardEntry> = Vec::with_capacity(nshards);
        for i in 0..nshards {
            let plen = u16::from_le_bytes(
                take(body, &mut pos, 2, "shard path length")?.try_into().unwrap(),
            ) as usize;
            let raw = take(body, &mut pos, plen, "shard path")?;
            let path = std::str::from_utf8(raw)
                .map_err(|_| format!("shard {i} path is not UTF-8"))?
                .to_string();
            validate_shard_path(&path).map_err(|e| format!("shard {i}: {e}"))?;
            if shards.iter().any(|s| s.path == path) {
                return Err(format!("duplicate shard path {path:?}"));
            }
            let file_len =
                u64::from_le_bytes(take(body, &mut pos, 8, "shard length")?.try_into().unwrap());
            let file_crc =
                u32::from_le_bytes(take(body, &mut pos, 4, "shard CRC")?.try_into().unwrap());
            shards.push(ShardEntry { path, file_len, file_crc });
        }
        let mut quantities: Vec<ManifestQuantity> = Vec::with_capacity(nquantities);
        for i in 0..nquantities {
            let nlen = take(body, &mut pos, 1, "quantity name length")?[0] as usize;
            if nlen == 0 {
                return Err(format!("quantity {i} has an empty name"));
            }
            let raw = take(body, &mut pos, nlen, "quantity name")?;
            let name = std::str::from_utf8(raw)
                .map_err(|_| format!("quantity {i} name is not UTF-8"))?
                .to_string();
            if quantities.iter().any(|q| q.name == name) {
                return Err(format!("duplicate quantity {name:?}"));
            }
            let shard =
                u32::from_le_bytes(take(body, &mut pos, 4, "quantity shard")?.try_into().unwrap())
                    as usize;
            if shard >= nshards {
                return Err(format!("quantity {name:?} names shard {shard} of {nshards}"));
            }
            let nx = u32::from_le_bytes(take(body, &mut pos, 4, "nx")?.try_into().unwrap());
            let ny = u32::from_le_bytes(take(body, &mut pos, 4, "ny")?.try_into().unwrap());
            let nz = u32::from_le_bytes(take(body, &mut pos, 4, "nz")?.try_into().unwrap());
            if nx == 0 || ny == 0 || nz == 0 {
                return Err(format!("quantity {name:?} has zero dims"));
            }
            quantities.push(ManifestQuantity { name, shard, nx, ny, nz });
        }
        if pos != body.len() {
            return Err("czm manifest has trailing garbage".into());
        }
        // a shard no quantity references is a writer bug or tampering
        for i in 0..nshards {
            if !quantities.iter().any(|q| q.shard == i) {
                return Err(format!("shard {i} ({:?}) carries no quantities", shards[i].path));
            }
        }
        Ok(Manifest { shards, quantities })
    }

    /// Read and parse a manifest file.
    pub fn open(path: &Path) -> Result<Manifest, String> {
        let bytes =
            std::fs::read(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        Manifest::decode(&bytes).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Validate and write the manifest via a unique sibling temp file +
    /// rename, like the `.czs` writer: a failure never leaves a partial
    /// manifest at `path`, and a re-run never clobbers a good one with
    /// a broken one.
    pub fn write(&self, path: &Path) -> Result<(), String> {
        static TMP_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        self.validate()?;
        let bytes = self.encode();
        let mut tmp_name = path
            .file_name()
            .map(|n| n.to_os_string())
            .unwrap_or_else(|| std::ffi::OsString::from("manifest.czm"));
        tmp_name.push(format!(
            ".{}.{}.tmp",
            std::process::id(),
            TMP_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let tmp_path = path.with_file_name(tmp_name);
        std::fs::write(&tmp_path, &bytes)
            .map_err(|e| format!("writing {}: {e}", tmp_path.display()))?;
        std::fs::rename(&tmp_path, path).map_err(|e| {
            let _ = std::fs::remove_file(&tmp_path);
            format!("moving {} into place: {e}", path.display())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            shards: vec![
                ShardEntry { path: "step.shard0.czs".into(), file_len: 123, file_crc: 0xDEAD },
                ShardEntry { path: "step.shard1.czs".into(), file_len: 456, file_crc: 0xBEEF },
            ],
            quantities: vec![
                ManifestQuantity { name: "p".into(), shard: 0, nx: 64, ny: 64, nz: 64 },
                ManifestQuantity { name: "rho".into(), shard: 1, nx: 64, ny: 64, nz: 64 },
                ManifestQuantity { name: "E".into(), shard: 0, nx: 32, ny: 16, nz: 8 },
            ],
        }
    }

    #[test]
    fn roundtrips() {
        let m = sample();
        assert_eq!(Manifest::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn every_truncation_fails() {
        let bytes = sample().encode();
        for n in 0..bytes.len() {
            assert!(Manifest::decode(&bytes[..n]).is_err(), "prefix of {n} bytes parsed");
        }
    }

    #[test]
    fn every_single_byte_flip_fails() {
        let bytes = sample().encode();
        for i in 0..bytes.len() {
            let mut b = bytes.clone();
            b[i] ^= 0x40;
            assert!(Manifest::decode(&b).is_err(), "flip at byte {i} parsed");
        }
    }

    #[test]
    fn trailing_garbage_fails() {
        // garbage between the tables and the trailer, with the CRC and
        // trailer recomputed to match — structure, not the checksum,
        // must reject it
        let mut m = sample().encode();
        m.truncate(m.len() - TRAILER_LEN);
        m.extend_from_slice(b"JUNK");
        let crc = crc32c(&m);
        m.extend_from_slice(&crc.to_le_bytes());
        m.extend_from_slice(CZM_TRAILER_MAGIC);
        let e = Manifest::decode(&m).unwrap_err();
        assert!(e.contains("trailing garbage"), "{e}");
    }

    #[test]
    fn structural_invariants_reject() {
        // duplicate shard path
        let mut m = sample();
        m.shards[1].path = m.shards[0].path.clone();
        assert!(Manifest::decode(&m.encode()).unwrap_err().contains("duplicate shard"));
        // duplicate quantity name
        let mut m = sample();
        m.quantities[1].name = "p".into();
        assert!(Manifest::decode(&m.encode()).unwrap_err().contains("duplicate quantity"));
        // dangling shard index
        let mut m = sample();
        m.quantities[2].shard = 9;
        assert!(Manifest::decode(&m.encode()).unwrap_err().contains("names shard"));
        // absolute shard path
        let mut m = sample();
        m.shards[0].path = "/etc/passwd".into();
        assert!(Manifest::decode(&m.encode()).unwrap_err().contains("absolute"));
        // path traversal
        let mut m = sample();
        m.shards[0].path = "../outside.czs".into();
        assert!(Manifest::decode(&m.encode()).unwrap_err().contains("relative"));
        // zero dims
        let mut m = sample();
        m.quantities[0].nx = 0;
        assert!(Manifest::decode(&m.encode()).unwrap_err().contains("zero dims"));
        // a shard no quantity references
        let mut m = sample();
        for q in &mut m.quantities {
            q.shard = 0;
        }
        assert!(Manifest::decode(&m.encode()).unwrap_err().contains("carries no quantities"));
        // validate() agrees with decode() on the writer side
        let mut m = sample();
        m.quantities[1].name = "p".into();
        assert!(m.validate().is_err());
        assert!(sample().validate().is_ok());
    }

    #[test]
    fn version_and_magic_gates() {
        let mut b = sample().encode();
        b[4] = 2; // future version
        // recompute the CRC so the version byte is what rejects it
        let tail = b.len() - TRAILER_LEN;
        let crc = crc32c(&b[..tail]);
        b[tail..tail + 4].copy_from_slice(&crc.to_le_bytes());
        assert!(Manifest::decode(&b).unwrap_err().contains("version"));
        let mut b = sample().encode();
        b[0] = b'X';
        assert!(Manifest::decode(&b).is_err());
    }
}
