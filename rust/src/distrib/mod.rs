//! Distribution layer (the multi-node half of the paper): shard a
//! dataset's quantities across N workers — spawned local `czb serve`
//! processes or remote service endpoints — into per-shard `.czs`
//! archives stitched by a `.czm` manifest ([`manifest`]), and read the
//! result back as one logical dataset ([`sharded`]) with cross-shard
//! random access and per-shard fault isolation.
//!
//! The paper's framework is OpenMP *and* MPI; the intra-node half
//! (work-stealing chunk parallelism inside every worker's
//! [`Engine`]) was already reproduced, and this module is the
//! inter-node half: quantities are the distribution unit (greedy LPT
//! packing by raw size, [`plan_shards`]), chunk ranges parallelize
//! *inside* each worker exactly as before, and the service wire
//! protocol (`docs/PROTOCOL.md`) is the only coupling between
//! coordinator and workers. Flows:
//!
//! * [`shard_compress`] — scatter: read quantities from an h5lite
//!   container, compress each on its shard's worker over the wire
//!   (tenant id `shard<i>`, so per-tenant server metrics attribute the
//!   work), pack the returned `.czb` streams into per-shard `.czs`
//!   files (temp + rename), then write the manifest last — a crash
//!   never leaves a manifest naming half-written shards.
//! * [`shard_decompress`] — gather: salvage-decode every shard
//!   ([`ShardedDataset::decompress_salvage`]) into one h5lite
//!   container; lost shards zero-fill at the manifest's recorded dims.
//! * [`shard_verify`] — manifest CRC, per-shard file length + CRC32C,
//!   the full `.czs` checksum walk per shard, and manifest↔shard
//!   consistency (every quantity present, dims matching).
//!
//! `czb shard-compress` / `shard-decompress` / `shard-verify` are the
//! CLI entry points; `czb info` understands `.czm` manifests.
use crate::anyhow;
use crate::io::h5lite;
use crate::pipeline::{Bound, DatasetOptions, DatasetWriter, Engine, ShuffleMode};
use crate::service::proto::Status;
use crate::service::Client;
use crate::util::crc32c::Crc32c;
use crate::util::error::{Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

pub mod manifest;
pub mod sharded;
pub mod worker;

pub use manifest::{Manifest, ManifestQuantity, ShardEntry, CZM_MAGIC, CZM_VERSION};
pub use sharded::{ShardedDataset, ShardedDecode};
pub use worker::{spawn_workers, SpawnedWorker};

/// Attempts a shard makes against a `busy` worker before giving up.
const BUSY_RETRIES: u32 = 100;

/// Client-side compression parameters carried to the workers — the
/// wire-protocol compress knobs (the server derives everything else
/// from its paper-default pipeline, stage-2 `zlib-def`).
#[derive(Clone, Copy, Debug)]
pub struct ShardOptions {
    pub bs: u32,
    pub eps: f32,
    pub shuffle: ShuffleMode,
    pub bound: Bound,
}

/// Where the shard workers come from.
pub enum WorkerSet {
    /// Spawn `count` local `czb serve` processes from the binary at
    /// `exe` (ephemeral ports, `threads` engine threads each) and drain
    /// them when the job finishes.
    Spawn { exe: PathBuf, count: usize, threads: usize },
    /// Use already-running service endpoints (`host:port`), one shard
    /// per endpoint.
    Endpoints(Vec<String>),
}

impl WorkerSet {
    fn requested(&self) -> usize {
        match self {
            WorkerSet::Spawn { count, .. } => *count,
            WorkerSet::Endpoints(e) => e.len(),
        }
    }
}

/// One shard's outcome from [`shard_compress`].
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// Shard filename (manifest-relative).
    pub path: String,
    /// Worker endpoint that compressed this shard.
    pub endpoint: String,
    /// Quantities packed into this shard, logical order.
    pub quantities: Vec<String>,
    pub raw_bytes: u64,
    pub compressed_bytes: u64,
    /// Final shard file length (what the manifest records).
    pub file_len: u64,
    /// CRC32C of the shard file (what the manifest records).
    pub file_crc: u32,
}

impl ShardStats {
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes.max(1) as f64
    }
}

/// Greedy LPT (longest-processing-time) packing of quantities into at
/// most `nshards` shards, balancing by raw byte size: quantities are
/// placed largest-first onto the least-loaded shard. Deterministic
/// (ties break by index) and never produces an empty shard — the
/// effective shard count is `min(nshards, sizes.len())`. Each returned
/// group is sorted, preserving logical order within a shard.
pub fn plan_shards(sizes: &[u64], nshards: usize) -> Vec<Vec<usize>> {
    let n = nshards.min(sizes.len()).max(1);
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by(|&a, &b| sizes[b].cmp(&sizes[a]).then(a.cmp(&b)));
    let mut load = vec![0u64; n];
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n];
    for idx in order {
        let s = (0..n).min_by_key(|&i| (load[i], i)).expect("n >= 1");
        load[s] += sizes[idx].max(1);
        groups[s].push(idx);
    }
    for g in &mut groups {
        g.sort_unstable();
    }
    groups
}

/// Counts and CRCs bytes on their way into the shard file, so the
/// manifest's whole-file digest costs no second read pass.
struct CrcWriter<W: Write> {
    inner: W,
    len: u64,
    crc: Crc32c,
}

impl<W: Write> Write for CrcWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.len += n as u64;
        self.crc.update(&buf[..n]);
        Ok(n)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// One remote compress with `busy` backoff: the worker's admission
/// refusals are retried after its own hint; any other refusal (quota,
/// draining, error) fails the shard.
fn compress_with_retry(
    client: &mut Client,
    name: &str,
    field: &crate::core::Field3,
    opts: &ShardOptions,
) -> Result<Vec<u8>> {
    for _ in 0..BUSY_RETRIES {
        let reply = client
            .compress_bounded(name, field, opts.bs, opts.eps, opts.shuffle, opts.bound)
            .map_err(|e| anyhow!("worker compress {name}: {e}"))?;
        match reply {
            Ok(czb) => return Ok(czb),
            Err(r) if r.status == Status::Busy => {
                std::thread::sleep(std::time::Duration::from_millis(
                    r.retry_after_ms.max(10) as u64
                ));
            }
            Err(r) => return Err(anyhow!("worker refused {name}: {r}")),
        }
    }
    Err(anyhow!("worker stayed busy through {BUSY_RETRIES} attempts for {name}"))
}

/// Compress one shard: connect to its worker, compress each owned
/// quantity over the wire, pack the returned `.czb` sections into a
/// `.czs` at a unique temp path, rename into place. Returns the stats
/// the manifest entry is built from.
fn compress_one_shard(
    input: &Path,
    final_path: &Path,
    shard_idx: usize,
    endpoint: &str,
    names: &[&str],
    opts: &ShardOptions,
) -> Result<ShardStats> {
    static TMP_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let mut tmp_name = final_path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("shard.czs"));
    tmp_name.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        TMP_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let tmp_path = final_path.with_file_name(tmp_name);
    let r = (|| {
        let mut client = Client::connect(endpoint)
            .with_context(|| format!("shard {shard_idx}: connecting worker {endpoint}"))?
            .tenant(&format!("shard{shard_idx}"));
        let file = std::fs::File::create(&tmp_path)
            .with_context(|| format!("creating {}", tmp_path.display()))?;
        let sink = CrcWriter { inner: std::io::BufWriter::new(file), len: 0, crc: Crc32c::new() };
        let mut writer = DatasetWriter::new(sink)
            .with_context(|| format!("starting shard {shard_idx} archive"))?;
        let mut raw = 0u64;
        let mut comp = 0u64;
        for name in names {
            let ds = h5lite::read(input, name).map_err(|e| anyhow!(e))?;
            let field = ds.to_field();
            let czb = compress_with_retry(&mut client, name, &field, opts)?;
            writer
                .write_section(name, &czb)
                .with_context(|| format!("shard {shard_idx}: packing section {name}"))?;
            raw += field.nbytes() as u64;
            comp += czb.len() as u64;
        }
        let sink = writer.finish().with_context(|| format!("finishing shard {shard_idx}"))?;
        let (file_len, file_crc) = (sink.len, sink.crc.finish());
        std::fs::rename(&tmp_path, final_path)
            .with_context(|| format!("moving {} into place", final_path.display()))?;
        Ok(ShardStats {
            path: final_path
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default(),
            endpoint: endpoint.to_string(),
            quantities: names.iter().map(|n| n.to_string()).collect(),
            raw_bytes: raw,
            compressed_bytes: comp,
            file_len,
            file_crc,
        })
    })();
    if r.is_err() {
        let _ = std::fs::remove_file(&tmp_path);
    }
    r
}

/// Scatter: shard-compress an h5lite container (optionally a
/// comma-separated `only` subset) into per-shard `.czs` files next to
/// `manifest_path` (`<stem>.shard<i>.czs`) plus the `.czm` manifest.
///
/// Quantities are packed by [`plan_shards`] and each shard's worker
/// compresses its quantities over the service protocol — the resulting
/// sections (and hence a later gather) are bit-identical to an offline
/// `czb compress-dataset --stage2 zlib-def` of the same input at any
/// thread or shard count. On any failure every written shard file is
/// removed and no manifest is written; spawned workers are always
/// drained.
pub fn shard_compress(
    input: &Path,
    only: Option<&str>,
    manifest_path: &Path,
    workers: &WorkerSet,
    opts: &ShardOptions,
) -> Result<Vec<ShardStats>> {
    let wanted: Option<Vec<&str>> =
        only.map(|s| s.split(',').map(str::trim).filter(|s| !s.is_empty()).collect());
    let listed = h5lite::list(input).map_err(|e| anyhow!(e))?;
    let quantities: Vec<(String, u32, u32, u32)> = listed
        .into_iter()
        .filter(|(name, ..)| match &wanted {
            None => true,
            Some(w) => w.contains(&name.as_str()),
        })
        .collect();
    if let Some(w) = &wanted {
        // a typo'd subset name must fail loudly, not silently shrink
        // the dataset
        let missing: Vec<&str> = w
            .iter()
            .filter(|n| !quantities.iter().any(|(name, ..)| name == *n))
            .copied()
            .collect();
        if !missing.is_empty() {
            return Err(anyhow!(
                "requested quantities not in {}: {}",
                input.display(),
                missing.join(",")
            ));
        }
    }
    if quantities.is_empty() {
        return Err(anyhow!("no datasets matched in {}", input.display()));
    }
    if workers.requested() == 0 {
        return Err(anyhow!("need at least one shard worker"));
    }
    let sizes: Vec<u64> = quantities
        .iter()
        .map(|&(_, nx, ny, nz)| nx as u64 * ny as u64 * nz as u64 * 4)
        .collect();
    let plan = plan_shards(&sizes, workers.requested());
    let n = plan.len();
    let stem = manifest_path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".to_string());

    // spawned workers drain on every exit path (stop() below + Drop)
    let mut spawned: Vec<SpawnedWorker> = Vec::new();
    let endpoints: Vec<String> = match workers {
        WorkerSet::Endpoints(e) => e.iter().take(n).cloned().collect(),
        WorkerSet::Spawn { exe, threads, .. } => {
            spawned = worker::spawn_workers(exe, n, *threads)?;
            spawned.iter().map(|w| w.addr().to_string()).collect()
        }
    };

    let slots: Vec<Mutex<Option<Result<ShardStats>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for (i, group) in plan.iter().enumerate() {
            let endpoint = endpoints[i].as_str();
            let names: Vec<&str> =
                group.iter().map(|&qi| quantities[qi].0.as_str()).collect();
            let final_path = manifest_path.with_file_name(format!("{stem}.shard{i}.czs"));
            let slots = &slots;
            s.spawn(move || {
                *slots[i].lock().unwrap() =
                    Some(compress_one_shard(input, &final_path, i, endpoint, &names, opts));
            });
        }
    });
    for w in &mut spawned {
        w.stop();
    }

    let mut stats: Vec<ShardStats> = Vec::with_capacity(n);
    let mut first_err: Option<crate::util::error::Error> = None;
    for slot in slots {
        match slot.into_inner().unwrap().expect("every shard thread reports") {
            Ok(st) => stats.push(st),
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    if let Some(e) = first_err {
        // no partial shard set: a later open must see all shards + a
        // manifest, or nothing
        for st in &stats {
            let _ = std::fs::remove_file(manifest_path.with_file_name(&st.path));
        }
        return Err(e);
    }

    // owner[qi] = shard index, for the logical-order quantity table
    let mut owner = vec![0usize; quantities.len()];
    for (sidx, group) in plan.iter().enumerate() {
        for &qi in group {
            owner[qi] = sidx;
        }
    }
    let m = Manifest {
        shards: stats
            .iter()
            .map(|st| ShardEntry {
                path: st.path.clone(),
                file_len: st.file_len,
                file_crc: st.file_crc,
            })
            .collect(),
        quantities: quantities
            .iter()
            .enumerate()
            .map(|(qi, (name, nx, ny, nz))| ManifestQuantity {
                name: name.clone(),
                shard: owner[qi],
                nx: *nx,
                ny: *ny,
                nz: *nz,
            })
            .collect(),
    };
    if let Err(e) = m.write(manifest_path) {
        for st in &stats {
            let _ = std::fs::remove_file(manifest_path.with_file_name(&st.path));
        }
        return Err(anyhow!(e));
    }
    Ok(stats)
}

/// Gather: salvage-decode a sharded dataset back into one h5lite
/// container, in the manifest's logical order. Lost shards or corrupt
/// sections come back zero-filled with the loss recorded per quantity
/// — the caller (e.g. `czb shard-decompress`) decides the exit code.
/// Errors only when the manifest is unreadable or *nothing* was
/// salvageable.
pub fn shard_decompress(
    manifest_path: &Path,
    output: &Path,
    engine: &Engine,
    opts: &DatasetOptions,
) -> Result<Vec<ShardedDecode>> {
    let ds = ShardedDataset::open_with(manifest_path, *opts).map_err(|e| anyhow!(e))?;
    let decodes = ds.decompress_salvage(engine).map_err(|e| anyhow!(e))?;
    if decodes.iter().all(|d| d.report.is_err()) {
        return Err(anyhow!("nothing salvageable in {}", manifest_path.display()));
    }
    let datasets: Vec<h5lite::Dataset> =
        decodes.iter().map(|d| h5lite::Dataset::from_field(&d.name, &d.field)).collect();
    h5lite::write(output, &datasets)?;
    Ok(decodes)
}

/// One shard's verification outcome.
pub struct ShardVerifyEntry {
    /// Shard filename (manifest-relative).
    pub path: String,
    /// Manifest-level file check: presence, exact length, whole-file
    /// CRC32C.
    pub file: std::result::Result<(), String>,
    /// The shard archive's own checksum walk (`czb verify` semantics);
    /// `None` when the file was unreadable.
    pub sections: Option<crate::coordinator::VerifyReport>,
    /// Manifest↔shard consistency failures: quantities missing from
    /// the shard or recorded with different dims.
    pub mapping: Vec<String>,
}

impl ShardVerifyEntry {
    pub fn is_clean(&self) -> bool {
        self.file.is_ok()
            && self.mapping.is_empty()
            && matches!(&self.sections, Some(r) if r.is_clean())
    }
}

/// Aggregated [`shard_verify`] outcome.
pub struct ShardVerifyReport {
    pub entries: Vec<ShardVerifyEntry>,
}

impl ShardVerifyReport {
    pub fn is_clean(&self) -> bool {
        self.entries.iter().all(|e| e.is_clean())
    }
}

/// Manifest-level file check: the shard exists, is exactly the length
/// the manifest recorded, and its whole-file CRC32C matches.
fn check_shard_file(path: &Path, entry: &ShardEntry) -> std::result::Result<(), String> {
    let meta = std::fs::metadata(path).map_err(|e| format!("missing: {e}"))?;
    if meta.len() != entry.file_len {
        return Err(format!("length {} != manifest {}", meta.len(), entry.file_len));
    }
    let mut f = std::fs::File::open(path).map_err(|e| format!("open: {e}"))?;
    let mut crc = Crc32c::new();
    let mut buf = vec![0u8; 1 << 20];
    loop {
        let n = f.read(&mut buf).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            break;
        }
        crc.update(&buf[..n]);
    }
    let got = crc.finish();
    if got != entry.file_crc {
        return Err(format!("file CRC32C {got:08x} != manifest {:08x}", entry.file_crc));
    }
    Ok(())
}

/// Verify a sharded dataset: manifest CRC (at open), per-shard file
/// length + whole-file CRC32C, the full per-section checksum walk of
/// each shard (`deep` additionally decodes, as in `czb verify --deep`),
/// and manifest↔shard quantity consistency. Shards fail independently;
/// an unreadable *manifest* is the only hard error.
pub fn shard_verify(manifest_path: &Path, deep: bool, engine: &Engine) -> Result<ShardVerifyReport> {
    let m = Manifest::open(manifest_path).map_err(|e| anyhow!(e))?;
    let dir = manifest_path.parent().map(|p| p.to_path_buf()).unwrap_or_default();
    let mut entries = Vec::with_capacity(m.shards.len());
    for (i, s) in m.shards.iter().enumerate() {
        let path = dir.join(&s.path);
        let file = check_shard_file(&path, s);
        let mut mapping: Vec<String> = Vec::new();
        let mut sections = None;
        match crate::coordinator::verify_file(&path, deep, engine) {
            Ok(r) => sections = Some(r),
            // an unreadable file is already reported by `file`; only
            // surface a verify failure the file check missed
            Err(e) if file.is_ok() => mapping.push(format!("verify: {e}")),
            Err(_) => {}
        }
        if let Ok(ds) = DatasetOptions::new().open(&path) {
            for q in m.quantities.iter().filter(|q| q.shard == i) {
                match ds.quantity_header(&q.name) {
                    Ok(h) => {
                        if (h.nx, h.ny, h.nz) != (q.nx, q.ny, q.nz) {
                            mapping.push(format!(
                                "quantity {}: shard records {}x{}x{}, manifest {}x{}x{}",
                                q.name, h.nx, h.ny, h.nz, q.nx, q.ny, q.nz
                            ));
                        }
                    }
                    Err(e) => mapping.push(format!("quantity {}: {e}", q.name)),
                }
            }
        }
        entries.push(ShardVerifyEntry { path: s.path.clone(), file, sections, mapping });
    }
    Ok(ShardVerifyReport { entries })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_deterministic_balanced_and_never_empty() {
        // more shards than quantities: effective count shrinks
        assert_eq!(plan_shards(&[100], 4), vec![vec![0]]);
        // LPT: largest first onto the least-loaded shard
        let plan = plan_shards(&[10, 80, 20, 70], 2);
        assert_eq!(plan.len(), 2);
        let load = |g: &Vec<usize>| -> u64 {
            g.iter().map(|&i| [10u64, 80, 20, 70][i]).sum()
        };
        // perfect split exists (80+10 / 70+20) and LPT finds it here
        assert_eq!(load(&plan[0]), 90);
        assert_eq!(load(&plan[1]), 90);
        // deterministic: same input, same plan
        assert_eq!(plan, plan_shards(&[10, 80, 20, 70], 2));
        // groups preserve logical (index) order
        for g in &plan {
            let mut sorted = g.clone();
            sorted.sort_unstable();
            assert_eq!(*g, sorted);
        }
        // every quantity appears exactly once
        let mut all: Vec<usize> = plan.concat();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3]);
    }
}
