//! One logical dataset over N shard `.czs` files, stitched by a `.czm`
//! manifest: random access routes each quantity to its owning shard's
//! lazy [`Dataset`] (its `SectionSource` + shared `ChunkCache`
//! machinery, untouched), and whole-dataset decode degrades per shard —
//! a lost or corrupt shard zero-fills its quantities and shows up in
//! the aggregated reports instead of failing the dataset.
use super::manifest::{Manifest, ManifestQuantity};
use crate::core::Field3;
use crate::pipeline::{
    BlockReader, CzbFile, Dataset, DatasetOptions, DecodeReport, Engine, WaveletEngine,
};
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

/// A sharded dataset handle: the parsed manifest plus one lazily opened
/// [`Dataset`] per shard. Opening the manifest touches no shard file;
/// each shard opens (trailer read only) on first access, and a shard
/// that fails to open caches its error — every quantity it owns fails
/// the same way while the other shards stay fully readable.
pub struct ShardedDataset {
    manifest: Manifest,
    dir: PathBuf,
    opts: DatasetOptions,
    shards: Vec<OnceLock<Result<Dataset, String>>>,
}

/// One quantity's outcome from [`ShardedDataset::decompress_salvage`],
/// in the manifest's logical order.
pub struct ShardedDecode {
    pub name: String,
    /// Index of the owning shard.
    pub shard: usize,
    /// The decoded field; zero-filled (at the manifest's recorded dims)
    /// when the shard was lost or the section undecodable.
    pub field: Field3,
    /// `Ok` — the section was salvage-decoded (the report lists any
    /// corrupt chunks). `Err` — the shard or section was unreadable and
    /// `field` is all zeros.
    pub report: Result<DecodeReport, String>,
}

impl ShardedDecode {
    /// Fully intact: decoded with no chunk lost.
    pub fn is_clean(&self) -> bool {
        matches!(&self.report, Ok(r) if r.is_clean())
    }
}

impl ShardedDataset {
    /// Open a manifest with default options. No shard file is touched.
    pub fn open(path: &Path) -> Result<Self, String> {
        Self::open_with(path, DatasetOptions::new())
    }

    /// Open a manifest; `opts` (e.g. the chunk-cache size) applies to
    /// every shard archive as it lazily opens.
    pub fn open_with(path: &Path, opts: DatasetOptions) -> Result<Self, String> {
        let manifest = Manifest::open(path)?;
        let dir = path.parent().map(|p| p.to_path_buf()).unwrap_or_default();
        let shards = (0..manifest.shards.len()).map(|_| OnceLock::new()).collect();
        Ok(Self { manifest, dir, opts, shards })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The logical quantity list, in dataset order.
    pub fn quantities(&self) -> &[ManifestQuantity] {
        &self.manifest.quantities
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.quantities.iter().map(|q| q.name.as_str()).collect()
    }

    /// Absolute path of shard `idx` (manifest-relative resolution).
    pub fn shard_path(&self, idx: usize) -> PathBuf {
        self.dir.join(&self.manifest.shards[idx].path)
    }

    /// The shard's archive handle, opened lazily on first touch. An
    /// open failure is cached: a missing shard fails consistently
    /// without re-stat'ing the filesystem on every access.
    pub fn shard(&self, idx: usize) -> Result<&Dataset, String> {
        self.shards[idx]
            .get_or_init(|| self.opts.open(&self.shard_path(idx)))
            .as_ref()
            .map_err(|e| format!("shard {idx} ({}): {e}", self.manifest.shards[idx].path))
    }

    fn quantity(&self, name: &str) -> Result<&ManifestQuantity, String> {
        self.manifest
            .quantities
            .iter()
            .find(|q| q.name == name)
            .ok_or_else(|| format!("no quantity {name} in manifest"))
    }

    /// Decode one quantity through its owning shard's session-pool path
    /// — other shards are not touched (or even opened).
    pub fn read_quantity(&self, name: &str, engine: &Engine) -> Result<(Field3, CzbFile), String> {
        let q = self.quantity(name)?;
        self.shard(q.shard)?.read_quantity(name, engine)
    }

    /// Random block access into one quantity via the owning shard's
    /// chunk-cached [`BlockReader`] — readers into the same shard share
    /// that shard's archive-wide cache, exactly as on an unsharded
    /// archive.
    pub fn block_reader<'a>(
        &'a self,
        name: &str,
        wavelet_engine: &'a dyn WaveletEngine,
    ) -> Result<BlockReader<'a>, String> {
        let q = self.quantity(name)?;
        self.shard(q.shard)?.block_reader(name, wavelet_engine)
    }

    /// Quantity indices grouped by owning shard, shard order.
    fn by_shard(&self) -> Vec<(usize, Vec<usize>)> {
        let mut groups: Vec<(usize, Vec<usize>)> =
            (0..self.manifest.shards.len()).map(|s| (s, Vec::new())).collect();
        for (qi, q) in self.manifest.quantities.iter().enumerate() {
            groups[q.shard].1.push(qi);
        }
        groups
    }

    /// Strict whole-dataset decode: every quantity, in logical order,
    /// each shard's group decoded concurrently on the session pool.
    /// Bit-identical to decoding the same input from an unsharded
    /// archive at every thread count; any failure anywhere is an error
    /// (use [`ShardedDataset::decompress_salvage`] to degrade instead).
    pub fn decompress(&self, engine: &Engine) -> Result<Vec<(String, Field3, CzbFile)>, String> {
        let mut out: Vec<Option<(String, Field3, CzbFile)>> =
            self.manifest.quantities.iter().map(|_| None).collect();
        for (sidx, qidxs) in self.by_shard() {
            let ds = self.shard(sidx)?;
            let names: Vec<&str> =
                qidxs.iter().map(|&qi| self.manifest.quantities[qi].name.as_str()).collect();
            let decoded = engine.decompress_dataset(ds, Some(&names))?;
            for (&qi, item) in qidxs.iter().zip(decoded) {
                out[qi] = Some(item);
            }
        }
        Ok(out.into_iter().map(|o| o.expect("every manifest quantity decoded")).collect())
    }

    /// Salvage whole-dataset decode with per-shard fault isolation:
    ///
    /// * a shard that opens cleanly salvage-decodes its quantities
    ///   (corrupt chunks zero-filled and listed in the `DecodeReport`);
    /// * a quantity whose section is unreadable — or missing from its
    ///   shard — comes back zero-filled at the manifest's recorded dims
    ///   with the error in `report`;
    /// * a wholly lost shard degrades the same way without affecting
    ///   any other shard.
    ///
    /// On clean data the decoded fields are bit-identical to
    /// [`ShardedDataset::decompress`]. The outer `Err` is manifest-level
    /// only (it currently never fires once the handle is open).
    pub fn decompress_salvage(&self, engine: &Engine) -> Result<Vec<ShardedDecode>, String> {
        let zero = |qi: usize, e: String| {
            let q = &self.manifest.quantities[qi];
            ShardedDecode {
                name: q.name.clone(),
                shard: q.shard,
                field: Field3::zeros(q.nx as usize, q.ny as usize, q.nz as usize),
                report: Err(e),
            }
        };
        let mut out: Vec<Option<ShardedDecode>> =
            self.manifest.quantities.iter().map(|_| None).collect();
        for (sidx, qidxs) in self.by_shard() {
            let ds = match self.shard(sidx) {
                Ok(ds) => ds,
                Err(e) => {
                    for &qi in &qidxs {
                        out[qi] = Some(zero(qi, e.clone()));
                    }
                    continue;
                }
            };
            // quantities the shard doesn't actually carry (tampering, a
            // stale manifest) fail individually, not the whole group
            let mut present: Vec<usize> = Vec::new();
            for &qi in &qidxs {
                let name = self.manifest.quantities[qi].name.as_str();
                if ds.entries().iter().any(|e| e.name == name) {
                    present.push(qi);
                } else {
                    out[qi] = Some(zero(qi, format!("shard {sidx} carries no section {name}")));
                }
            }
            let names: Vec<&str> =
                present.iter().map(|&qi| self.manifest.quantities[qi].name.as_str()).collect();
            match engine.decompress_dataset_salvage(ds, Some(&names)) {
                Ok(results) => {
                    for (&qi, (name, r)) in present.iter().zip(results) {
                        out[qi] = Some(match r {
                            Ok((field, _file, rep)) => ShardedDecode {
                                name,
                                shard: sidx,
                                field,
                                report: Ok(rep),
                            },
                            Err(e) => zero(qi, e),
                        });
                    }
                }
                Err(e) => {
                    for &qi in &present {
                        out[qi] = Some(zero(qi, e.clone()));
                    }
                }
            }
        }
        Ok(out.into_iter().map(|o| o.expect("every manifest quantity accounted for")).collect())
    }
}

// compile-time guarantee: sharded handles stay shareable across the
// same concurrent readers a plain Dataset supports
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<ShardedDataset>();
};
