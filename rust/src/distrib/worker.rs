//! Spawned shard workers: `czb serve` as the worker runtime. Each
//! worker is one local `czb serve` process bound to an ephemeral
//! 127.0.0.1 port — the same binary, protocol, admission control and
//! metrics as a production service endpoint (`docs/PROTOCOL.md`,
//! `docs/OPERATIONS.md`), so the spawned-local and remote-endpoint
//! paths of `czb shard-compress` exercise identical code.
use crate::anyhow;
use crate::service::Client;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader};
use std::path::Path;
use std::process::{Child, ChildStdout, Command, Stdio};

/// One spawned `czb serve` worker process. Dropping the handle kills
/// the process; [`SpawnedWorker::stop`] drains it gracefully first.
pub struct SpawnedWorker {
    child: Child,
    addr: String,
    /// Kept open for the worker's lifetime: dropping the pipe would
    /// turn the worker's own progress prints into a broken-pipe panic.
    _stdout: Option<BufReader<ChildStdout>>,
}

impl SpawnedWorker {
    /// The `host:port` the worker announced on startup.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Graceful stop: ask the worker to drain (the protocol `shutdown`
    /// frame), then reap it. Errors are ignored — a worker that already
    /// died is exactly as stopped as one that drained.
    pub fn stop(&mut self) {
        if let Ok(mut c) = Client::connect(self.addr.as_str()) {
            let _ = c.shutdown();
        }
        let _ = self.child.wait();
    }
}

impl Drop for SpawnedWorker {
    fn drop(&mut self) {
        // no-op after a graceful stop (the child is already reaped);
        // the hard kill only fires on error/panic paths
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_one(exe: &Path, threads: usize) -> Result<SpawnedWorker> {
    let mut child = Command::new(exe)
        .args(["serve", "--addr", "127.0.0.1:0", "--threads", &threads.to_string()])
        .stdout(Stdio::piped())
        .spawn()
        .with_context(|| format!("spawning {} serve", exe.display()))?;
    let stdout = child.stdout.take().expect("stdout was piped");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    // `czb serve` prints "listening on <addr>" once the ephemeral port
    // is bound; EOF before that means the worker failed to start (its
    // stderr is inherited, so the cause is already on our stderr)
    let addr = loop {
        line.clear();
        let n = reader.read_line(&mut line).context("reading worker startup output")?;
        if n == 0 {
            let _ = child.kill();
            let _ = child.wait();
            return Err(anyhow!("worker exited before announcing its listen address"));
        }
        if let Some(rest) = line.split("listening on ").nth(1) {
            if let Some(tok) = rest.split_whitespace().next() {
                break tok.to_string();
            }
        }
    };
    Ok(SpawnedWorker { child, addr, _stdout: Some(reader) })
}

/// Spawn `count` local `czb serve` workers (the `czb` binary at `exe`),
/// each on an ephemeral port with `threads` engine threads (0 = all
/// cores, the serve default). Either every worker is up and announced,
/// or all are killed and the first failure is returned.
pub fn spawn_workers(exe: &Path, count: usize, threads: usize) -> Result<Vec<SpawnedWorker>> {
    if count == 0 {
        return Err(anyhow!("need at least one worker"));
    }
    let mut workers: Vec<SpawnedWorker> = Vec::with_capacity(count);
    for i in 0..count {
        match spawn_one(exe, threads) {
            Ok(w) => workers.push(w),
            Err(e) => {
                // Drop kills the already-spawned ones
                drop(workers);
                return Err(anyhow!("spawning worker {i}: {e}"));
            }
        }
    }
    Ok(workers)
}
