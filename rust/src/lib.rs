//! CubismZ-RS: parallel two-substage compression framework for
//! block-structured 3D scientific data (reproduction of Hadjidoukas &
//! Wermelinger, "A Parallel Data Compression Framework for Large Scale 3D
//! Scientific Data", 2019). See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.
pub use pipeline::{CompressParams, Dataset, DatasetWriter, Engine, EngineBuilder};

pub mod cluster;
pub mod codec;
pub mod coordinator;
pub mod core;
pub mod distrib;
pub mod fpc;
pub mod io;
pub mod metrics;
pub mod pipeline;
pub mod runtime;
pub mod scaling;
pub mod service;
pub mod sim;
pub mod simd;
pub mod util;
pub mod wavelet;
