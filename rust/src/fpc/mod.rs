//! Lossy/lossless floating-point compressors (paper §2.3 "State-of-the-art
//! floating point compressors"), all reimplemented from scratch following
//! the published algorithms:
//!
//! * [`zfp`]   — Lindstrom 2014: 4³ cells, block-floating-point, integer
//!   decorrelating lifting transform, sequency reorder, negabinary,
//!   group-tested bit-plane coding; fixed-accuracy mode.
//! * [`sz`]    — Di & Cappello 2016 (SZ 1.4/2.0 hybrid): Lorenzo
//!   prediction + error-bounded linear quantization + Huffman, with an
//!   outlier escape.
//! * [`fpzip`] — Lindstrom & Isenburg 2006: 3D Lorenzo prediction over a
//!   monotonic int mapping of floats, residual length-class entropy
//!   coding; lossless, or lossy via precision truncation.
//! * [`spdp`]  — Burtscher & Claggett 2017 positioning: byte-stream
//!   stride-delta preconditioner + fast LZ; lossless.
pub mod fpzip;
pub mod spdp;
pub mod sz;
pub mod zfp;

/// 3D dimensions of an array handed to a float compressor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dims3 {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
}

impl Dims3 {
    pub fn cube(n: usize) -> Self {
        Self { nx: n, ny: n, nz: n }
    }
    pub fn len(&self) -> usize {
        self.nx * self.ny * self.nz
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Monotonic (total-order-preserving) mapping f32 -> u32 used by fpzip
/// and the sign-aware parts of sz. `map(a) < map(b)` iff `a < b` for all
/// finite floats including -0/+0 ordering.
#[inline]
pub fn f32_to_ordered_u32(v: f32) -> u32 {
    let b = v.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

/// Inverse of [`f32_to_ordered_u32`].
#[inline]
pub fn ordered_u32_to_f32(u: u32) -> f32 {
    let b = if u & 0x8000_0000 != 0 { u & 0x7fff_ffff } else { !u };
    f32::from_bits(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::{gen_floats, prop_cases};

    #[test]
    fn ordered_mapping_is_monotone_and_invertible() {
        prop_cases(0xFA, 10, |rng, _| {
            let mut vals = gen_floats(rng, 500);
            for &v in &vals {
                assert_eq!(ordered_u32_to_f32(f32_to_ordered_u32(v)).to_bits(), v.to_bits());
            }
            vals.sort_by(|a, b| a.total_cmp(b));
            for w in vals.windows(2) {
                assert!(f32_to_ordered_u32(w[0]) <= f32_to_ordered_u32(w[1]));
            }
        });
        let _ = Pcg32::new(0);
    }

    #[test]
    fn dims_len() {
        assert_eq!(Dims3::cube(4).len(), 64);
        assert_eq!(Dims3 { nx: 2, ny: 3, nz: 5 }.len(), 30);
    }
}
