//! SPDP-like lossless float compressor (Burtscher & Claggett 2017
//! positioning): a byte-granularity stride-delta preconditioner over the
//! raw IEEE bytes followed by a fast LZ stage. SPDP is "tailored to
//! sequences of single and double-precision floating-point data"; the
//! stride delta exposes the slowly-varying exponent/sign bytes.
//!
//! Stream: `[u8 ver][u8 stride][czlib Fast stream of the delta bytes]`

/// Compress `data` (any byte payload; `stride` 4 for f32, 8 for f64).
pub fn compress_bytes(data: &[u8], stride: u8, out: &mut Vec<u8>) {
    assert!(stride > 0);
    out.push(1u8);
    out.push(stride);
    let s = stride as usize;
    let mut delta = vec![0u8; data.len()];
    for i in 0..data.len() {
        delta[i] = if i >= s { data[i].wrapping_sub(data[i - s]) } else { data[i] };
    }
    crate::codec::czlib::compress(&delta, crate::codec::czlib::Level::Fast, out);
}

/// Compress an f32 slice.
pub fn compress(data: &[f32], out: &mut Vec<u8>) {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    compress_bytes(&bytes, 4, out);
}

/// Decompress to raw bytes.
pub fn decompress_bytes(input: &[u8]) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    decompress_bytes_into(input, &mut out)?;
    Ok(out)
}

/// Decompress to raw bytes in a caller-owned buffer (cleared); the
/// stride-delta is undone in place, so no intermediate buffer is needed.
pub fn decompress_bytes_into(input: &[u8], out: &mut Vec<u8>) -> Result<(), String> {
    if input.len() < 2 {
        return Err("spdp stream too short".into());
    }
    if input[0] != 1 {
        return Err(format!("spdp version {}", input[0]));
    }
    let s = input[1] as usize;
    if s == 0 {
        return Err("bad stride".into());
    }
    out.clear();
    crate::codec::czlib::decompress(&input[2..], out)?;
    // forward prefix reconstruction: out[i - s] is already undone when
    // out[i] is updated, so the delta buffer doubles as the output
    for i in s..out.len() {
        out[i] = out[i].wrapping_add(out[i - s]);
    }
    Ok(())
}

/// Decompress to f32s.
pub fn decompress(input: &[u8]) -> Result<Vec<f32>, String> {
    let mut bytes = Vec::new();
    let mut out = Vec::new();
    decompress_into(input, &mut bytes, &mut out)?;
    Ok(out)
}

/// Decompress to f32s in caller-owned buffers (cleared): `bytes` is the
/// raw-byte scratch, `out` receives the floats.
pub fn decompress_into(
    input: &[u8],
    bytes: &mut Vec<u8>,
    out: &mut Vec<f32>,
) -> Result<(), String> {
    decompress_bytes_into(input, bytes)?;
    if bytes.len() % 4 != 0 {
        return Err("payload not a multiple of 4".into());
    }
    out.clear();
    out.extend(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::{gen_floats, prop_cases};

    #[test]
    fn roundtrip_adversarial_floats() {
        prop_cases(0x5bdb, 10, |rng, _| {
            let n = 1 + rng.below(5000) as usize;
            let data = gen_floats(rng, n);
            let mut out = Vec::new();
            compress(&data, &mut out);
            let back = decompress(&out).unwrap();
            assert_eq!(back.len(), data.len());
            for (a, b) in data.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn beats_plain_lz_on_drifting_floats() {
        // slowly drifting values: exponent/high-mantissa bytes repeat at
        // stride 4 -> delta turns them into zero runs
        let mut rng = Pcg32::new(0xD1F7);
        let mut data = Vec::new();
        let mut v = 1000.0f32;
        for _ in 0..50_000 {
            v += rng.next_f32() - 0.5;
            data.push(v);
        }
        let mut spdp_out = Vec::new();
        compress(&data, &mut spdp_out);
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let plain = crate::codec::Codec::ZlibDef.compress_vec(&bytes);
        assert!(
            spdp_out.len() < plain.len(),
            "spdp {} vs plain zlib {}",
            spdp_out.len(),
            plain.len()
        );
    }

    #[test]
    fn empty_input() {
        let mut out = Vec::new();
        compress(&[], &mut out);
        assert_eq!(decompress(&out).unwrap(), Vec::<f32>::new());
    }

    #[test]
    fn corrupt_errors() {
        assert!(decompress(&[2, 4, 0]).is_err());
        assert!(decompress(&[1]).is_err());
    }

    #[test]
    fn decompress_into_reuses_dirty_buffers() {
        let mut rng = Pcg32::new(0x21);
        let data = gen_floats(&mut rng, 513);
        let mut comp = Vec::new();
        compress(&data, &mut comp);
        let mut bytes = vec![0xEEu8; 5]; // dirty + wrong size
        let mut out = vec![3.5f32; 9999];
        for _ in 0..3 {
            decompress_into(&comp, &mut bytes, &mut out).unwrap();
            assert_eq!(out.len(), data.len());
            for (a, b) in data.iter().zip(&out) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
