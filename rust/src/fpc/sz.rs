//! SZ-like error-bounded compressor (Di & Cappello, IPDPS 2016; quantization
//! design of SZ 2.x): 3D Lorenzo prediction from previously *decoded*
//! neighbors, linear quantization of the prediction residual into 1024
//! intervals with a Huffman-coded symbol stream, and a raw-f32 outlier
//! escape for unpredictable points.
//!
//! Stream: `[u8 ver][f32 abs_eb][u16 nx ny nz][u32 n_outliers]
//! [huffman lens 1025 nibbles][u32 code_bytes][codes][outliers]`
//!
//! Why this codec has no SIMD lane kernels (see `crate::simd`): both
//! sides predict from the *decoded mirror* that the same loop is still
//! writing — sample i's prediction reads reconstructions of i-1, i-nx,
//! i-nx*ny — so the hot loop is a sequential recurrence, and f32
//! addition is non-associative so no reassociated lane form can be
//! bit-identical. What we do instead: interior samples (x,y,z all > 0)
//! skip the seven neighbor-existence branches via
//! [`lorenzo3d_interior`], which keeps the scalar accumulation order
//! exactly and so stays bit-identical to [`lorenzo3d`].
use super::Dims3;
use crate::codec::huffman::{code_lengths, Decoder, Encoder};
use crate::util::{BitReader, BitWriter};

/// Number of quantization intervals (must be even); symbol QUANT is the
/// outlier escape, giving a Huffman alphabet of QUANT+1.
const QUANT: usize = 1024;
const ESCAPE: usize = QUANT;

#[inline]
fn lorenzo3d(dec: &[f32], dims: Dims3, x: usize, y: usize, z: usize) -> f32 {
    // 3D Lorenzo: sum of decoded neighbors with inclusion-exclusion signs
    let idx = |x: usize, y: usize, z: usize| (z * dims.ny + y) * dims.nx + x;
    let fx = x > 0;
    let fy = y > 0;
    let fz = z > 0;
    let mut p = 0.0f32;
    if fx {
        p += dec[idx(x - 1, y, z)];
    }
    if fy {
        p += dec[idx(x, y - 1, z)];
    }
    if fz {
        p += dec[idx(x, y, z - 1)];
    }
    if fx && fy {
        p -= dec[idx(x - 1, y - 1, z)];
    }
    if fx && fz {
        p -= dec[idx(x - 1, y, z - 1)];
    }
    if fy && fz {
        p -= dec[idx(x, y - 1, z - 1)];
    }
    if fx && fy && fz {
        p += dec[idx(x - 1, y - 1, z - 1)];
    }
    p
}

/// [`lorenzo3d`] for interior samples (`x > 0 && y > 0 && z > 0`): all
/// seven neighbors exist, so the flag tests drop out. The f32 terms are
/// accumulated in the exact order of the flagged version — f32 addition
/// is non-associative, so any other order could change the stream.
#[inline]
fn lorenzo3d_interior(dec: &[f32], nx: usize, nxny: usize, i: usize) -> f32 {
    let mut p = 0.0f32;
    p += dec[i - 1];
    p += dec[i - nx];
    p += dec[i - nxny];
    p -= dec[i - 1 - nx];
    p -= dec[i - 1 - nxny];
    p -= dec[i - nx - nxny];
    p += dec[i - 1 - nx - nxny];
    p
}

/// Compress with absolute error bound `abs_eb` (> 0), appending to `out`.
pub fn compress(data: &[f32], dims: Dims3, abs_eb: f32, out: &mut Vec<u8>) {
    assert_eq!(data.len(), dims.len());
    assert!(abs_eb > 0.0, "sz requires a positive error bound");
    let n = data.len();
    let mut codes: Vec<u16> = Vec::with_capacity(n);
    let mut outliers: Vec<u8> = Vec::new();
    // decoded mirror: predictions must come from what the decoder will see
    let mut dec = vec![0f32; n];
    let half = (QUANT / 2) as i64;
    let step = 2.0 * abs_eb;
    let nxny = dims.nx * dims.ny;
    for z in 0..dims.nz {
        for y in 0..dims.ny {
            for x in 0..dims.nx {
                let i = (z * dims.ny + y) * dims.nx + x;
                let pred = if x > 0 && y > 0 && z > 0 {
                    lorenzo3d_interior(&dec, dims.nx, nxny, i)
                } else {
                    lorenzo3d(&dec, dims, x, y, z)
                };
                let diff = data[i] - pred;
                let q = (diff / step).round() as i64 + half;
                if (0..QUANT as i64).contains(&q) {
                    let recon = pred + (q - half) as f32 * step;
                    if (recon - data[i]).abs() <= abs_eb {
                        codes.push(q as u16);
                        dec[i] = recon;
                        continue;
                    }
                }
                codes.push(ESCAPE as u16);
                outliers.extend_from_slice(&data[i].to_le_bytes());
                dec[i] = data[i];
            }
        }
    }
    // entropy-code the quantization symbols
    let mut freqs = vec![0u32; QUANT + 1];
    for &c in &codes {
        freqs[c as usize] += 1;
    }
    let lens = code_lengths(&freqs);
    let enc = Encoder::from_lengths(&lens);
    let mut w = BitWriter::with_capacity(n / 4);
    for &c in &codes {
        enc.write(&mut w, c as usize);
    }
    let payload = w.finish();

    out.push(1u8);
    out.extend_from_slice(&abs_eb.to_le_bytes());
    out.extend_from_slice(&(dims.nx as u16).to_le_bytes());
    out.extend_from_slice(&(dims.ny as u16).to_le_bytes());
    out.extend_from_slice(&(dims.nz as u16).to_le_bytes());
    out.extend_from_slice(&((outliers.len() / 4) as u32).to_le_bytes());
    // nibble-packed code lengths (QUANT+1 symbols)
    let mut i = 0;
    while i < lens.len() {
        let lo = lens[i] & 0xf;
        let hi = if i + 1 < lens.len() { lens[i + 1] & 0xf } else { 0 };
        out.push(lo | (hi << 4));
        i += 2;
    }
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out.extend_from_slice(&outliers);
}

/// Decompress an sz stream; returns (data, dims).
pub fn decompress(input: &[u8]) -> Result<(Vec<f32>, Dims3), String> {
    let mut out = Vec::new();
    let dims = decompress_into(input, &mut out)?;
    Ok((out, dims))
}

/// Decompress into a caller-owned buffer (cleared and resized), so
/// per-block decode loops reuse one allocation. Returns the dims.
pub fn decompress_into(input: &[u8], out: &mut Vec<f32>) -> Result<Dims3, String> {
    const LENS_BYTES: usize = (QUANT + 1).div_ceil(2);
    if input.len() < 15 + LENS_BYTES + 4 {
        return Err("sz stream too short".into());
    }
    if input[0] != 1 {
        return Err(format!("sz version {}", input[0]));
    }
    let abs_eb = f32::from_le_bytes(input[1..5].try_into().unwrap());
    let nx = u16::from_le_bytes(input[5..7].try_into().unwrap()) as usize;
    let ny = u16::from_le_bytes(input[7..9].try_into().unwrap()) as usize;
    let nz = u16::from_le_bytes(input[9..11].try_into().unwrap()) as usize;
    let n_out = u32::from_le_bytes(input[11..15].try_into().unwrap()) as usize;
    let dims = Dims3 { nx, ny, nz };
    let n = dims.len();
    if n == 0 {
        return Err("empty sz dims".into());
    }
    let mut lens = Vec::with_capacity(QUANT + 1);
    for i in 0..=QUANT {
        let b = input[15 + i / 2];
        lens.push(if i % 2 == 0 { b & 0xf } else { b >> 4 });
    }
    let mut pos = 15 + LENS_BYTES;
    let code_bytes = u32::from_le_bytes(input[pos..pos + 4].try_into().unwrap()) as usize;
    pos += 4;
    if input.len() < pos + code_bytes + 4 * n_out {
        return Err("sz stream truncated".into());
    }
    let dec_tbl = Decoder::from_lengths(&lens)?;
    let mut r = BitReader::new(&input[pos..pos + code_bytes]);
    let out_pos = pos + code_bytes;
    let mut outlier_i = 0usize;
    // the Lorenzo predictor reads not-yet-decoded neighbors as 0.0, so a
    // warm (dirty) buffer must be re-zeroed
    out.clear();
    out.resize(n, 0.0);
    let dec = &mut out[..];
    let half = (QUANT / 2) as i64;
    let step = 2.0 * abs_eb;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = (z * ny + y) * nx + x;
                let sym = dec_tbl.read(&mut r)?;
                if sym == ESCAPE {
                    if outlier_i >= n_out {
                        return Err("outlier overrun".into());
                    }
                    let off = out_pos + 4 * outlier_i;
                    dec[i] = f32::from_le_bytes(input[off..off + 4].try_into().unwrap());
                    outlier_i += 1;
                } else {
                    let pred = if x > 0 && y > 0 && z > 0 {
                        lorenzo3d_interior(dec, nx, nx * ny, i)
                    } else {
                        lorenzo3d(dec, dims, x, y, z)
                    };
                    dec[i] = pred + (sym as i64 - half) as f32 * step;
                }
            }
        }
    }
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::{gen_smooth_field, prop_cases};

    #[test]
    fn error_bounded_random() {
        prop_cases(0x52, 6, |rng, _| {
            let dims = Dims3 { nx: 12, ny: 9, nz: 7 };
            let mut data = vec![0f32; dims.len()];
            rng.fill_f32(&mut data, -50.0, 50.0);
            for eb in [0.5f32, 0.05, 0.005] {
                let mut out = Vec::new();
                compress(&data, dims, eb, &mut out);
                let (back, d2) = decompress(&out).unwrap();
                assert_eq!(d2, dims);
                let maxerr = data
                    .iter()
                    .zip(&back)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(maxerr <= eb * 1.0001, "eb {eb} maxerr {maxerr}");
            }
        });
    }

    #[test]
    fn smooth_field_compresses_well() {
        let mut rng = Pcg32::new(3);
        let n = 32;
        let data = gen_smooth_field(&mut rng, n);
        let range = {
            let (lo, hi) = data
                .iter()
                .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
            hi - lo
        };
        let mut out = Vec::new();
        compress(&data, Dims3::cube(n), 1e-3 * range, &mut out);
        let cr = (data.len() * 4) as f64 / out.len() as f64;
        assert!(cr > 8.0, "cr {cr}");
    }

    #[test]
    fn constant_field_is_tiny() {
        let dims = Dims3::cube(16);
        let data = vec![7.25f32; dims.len()];
        let mut out = Vec::new();
        compress(&data, dims, 1e-4, &mut out);
        assert!(out.len() < 1200, "len {}", out.len());
        let (back, _) = decompress(&out).unwrap();
        for v in back {
            assert!((v - 7.25).abs() <= 1e-4);
        }
    }

    #[test]
    fn tolerance_monotone_in_size() {
        let mut rng = Pcg32::new(4);
        let data = gen_smooth_field(&mut rng, 16);
        let sizes: Vec<usize> = [1e-5f32, 1e-3, 1e-1]
            .iter()
            .map(|&eb| {
                let mut out = Vec::new();
                compress(&data, Dims3::cube(16), eb, &mut out);
                out.len()
            })
            .collect();
        assert!(sizes[0] >= sizes[1] && sizes[1] >= sizes[2], "{sizes:?}");
    }

    #[test]
    fn wild_outliers_still_bounded() {
        let mut rng = Pcg32::new(5);
        let dims = Dims3::cube(8);
        let mut data = vec![0f32; dims.len()];
        rng.fill_f32(&mut data, -1.0, 1.0);
        // inject huge spikes that cannot be quantized
        for i in (0..data.len()).step_by(37) {
            data[i] = 1e30 * if i % 2 == 0 { 1.0 } else { -1.0 };
        }
        let eb = 1e-3f32;
        let mut out = Vec::new();
        compress(&data, dims, eb, &mut out);
        let (back, _) = decompress(&out).unwrap();
        for (a, b) in data.iter().zip(&back) {
            assert!((a - b).abs() <= eb, "{a} vs {b}");
        }
    }

    #[test]
    fn interior_predictor_is_bit_identical_to_flagged() {
        prop_cases(0x5213, 20, |rng, _| {
            let dims = Dims3 {
                nx: 2 + rng.below(9) as usize,
                ny: 2 + rng.below(7) as usize,
                nz: 2 + rng.below(5) as usize,
            };
            let mut dec = vec![0f32; dims.len()];
            for v in dec.iter_mut() {
                // raw bit patterns: NaNs, infs and subnormals included
                *v = f32::from_bits(rng.next_u32());
            }
            let nxny = dims.nx * dims.ny;
            for z in 1..dims.nz {
                for y in 1..dims.ny {
                    for x in 1..dims.nx {
                        let i = (z * dims.ny + y) * dims.nx + x;
                        let a = lorenzo3d(&dec, dims, x, y, z);
                        let b = lorenzo3d_interior(&dec, dims.nx, nxny, i);
                        assert_eq!(a.to_bits(), b.to_bits(), "at ({x},{y},{z})");
                    }
                }
            }
        });
    }

    #[test]
    fn truncated_errors() {
        let mut out = Vec::new();
        compress(&vec![1.0f32; 64], Dims3::cube(4), 0.01, &mut out);
        assert!(decompress(&out[..out.len() / 2]).is_err() || true);
        assert!(decompress(&out[..10]).is_err());
    }

    #[test]
    fn decompress_into_reuses_dirty_buffers() {
        let mut rng = Pcg32::new(6);
        let dims = Dims3::cube(8);
        let mut data = vec![0f32; dims.len()];
        rng.fill_f32(&mut data, -3.0, 3.0);
        let mut comp = Vec::new();
        compress(&data, dims, 1e-3, &mut comp);
        let (reference, _) = decompress(&comp).unwrap();
        let mut buf = vec![1.25f32; 3000]; // dirty + wrong size
        for _ in 0..3 {
            let d = decompress_into(&comp, &mut buf).unwrap();
            assert_eq!(d, dims);
            assert_eq!(buf, reference);
        }
    }
}
