//! FPZIP-like compressor (Lindstrom & Isenburg, TVCG 2006): 3D Lorenzo
//! prediction over a monotonic integer mapping of floats, residual coded
//! as zig-zag + Huffman-coded length class + raw magnitude bits.
//! Lossless by default; lossy via precision truncation (`prec` of the 32
//! mapped bits kept, as in fpzip's bits-of-precision parameter).
//!
//! Stream: `[u8 ver][u8 prec][u16 nx ny nz][huffman lens 33 nibbles]
//! [u32 payload_bytes][payload]`
//!
//! Hot-loop vectorization (bit-exact, stream-identical — see
//! `crate::simd`): the encoder's ordered-int mapping and the interior
//! Lorenzo residual rows (all seven neighbors exist, so the gather is
//! branch-free) run 8 resp. 4 lanes wide on AVX2. Integer adds are
//! exact in any order, so the streams are byte-identical to the scalar
//! path. The decoder is untouched: its prediction reads the mirror it
//! is still writing (a sequential recurrence), which no lane-parallel
//! form can preserve.
use super::{f32_to_ordered_u32, ordered_u32_to_f32, Dims3};
use crate::codec::huffman::{code_lengths, Decoder, Encoder};
use crate::simd::{self, SimdLevel};
use crate::util::{BitReader, BitWriter};

const N_CLASS: usize = 40; // residual bit-length classes (zigzag of i64 spans up to ~2^36)

#[inline]
fn lorenzo_pred(dec: &[i64], dims: Dims3, x: usize, y: usize, z: usize) -> i64 {
    let idx = |x: usize, y: usize, z: usize| (z * dims.ny + y) * dims.nx + x;
    let fx = x > 0;
    let fy = y > 0;
    let fz = z > 0;
    let mut p = 0i64;
    if fx {
        p += dec[idx(x - 1, y, z)];
    }
    if fy {
        p += dec[idx(x, y - 1, z)];
    }
    if fz {
        p += dec[idx(x, y, z - 1)];
    }
    if fx && fy {
        p -= dec[idx(x - 1, y - 1, z)];
    }
    if fx && fz {
        p -= dec[idx(x - 1, y, z - 1)];
    }
    if fy && fz {
        p -= dec[idx(x, y - 1, z - 1)];
    }
    if fx && fy && fz {
        p += dec[idx(x - 1, y - 1, z - 1)];
    }
    p
}

#[inline]
fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// `mapped[i] = (f32_to_ordered_u32(data[i]) >> shift) as i64`.
fn map_ordered(data: &[f32], shift: u32, mapped: &mut [i64], lvl: SimdLevel) {
    #[cfg(target_arch = "x86_64")]
    {
        if lvl == SimdLevel::Avx2 {
            // SAFETY: Avx2 is only dispatched when simd::detect() saw it
            unsafe { avx2::map_ordered(data, shift, mapped) };
            return;
        }
    }
    let _ = lvl;
    for (m, &v) in mapped.iter_mut().zip(data) {
        *m = (f32_to_ordered_u32(v) >> shift) as i64;
    }
}

/// Zigzagged Lorenzo residuals for every sample. Interior rows (z>0,
/// y>0, x>0: all seven neighbors exist) take the branch-free path;
/// boundary samples keep the flag-guarded [`lorenzo_pred`].
fn compute_residuals(mapped: &[i64], dims: Dims3, out: &mut [u64], lvl: SimdLevel) {
    let nx = dims.nx;
    let nxny = dims.nx * dims.ny;
    for z in 0..dims.nz {
        for y in 0..dims.ny {
            let row = (z * dims.ny + y) * nx;
            if z > 0 && y > 0 {
                out[row] = zigzag(mapped[row] - lorenzo_pred(mapped, dims, 0, y, z));
                interior_row(mapped, out, row + 1, nx - 1, nx, nxny, lvl);
            } else {
                for x in 0..nx {
                    out[row + x] = zigzag(mapped[row + x] - lorenzo_pred(mapped, dims, x, y, z));
                }
            }
        }
    }
}

/// `len` interior residuals starting at `i0` (every sample has all 7
/// Lorenzo neighbors). Integer sums are order-independent, so the lane
/// form is bit-exact against [`lorenzo_pred`]'s accumulation.
fn interior_row(
    m: &[i64],
    out: &mut [u64],
    i0: usize,
    len: usize,
    nx: usize,
    nxny: usize,
    lvl: SimdLevel,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if lvl == SimdLevel::Avx2 {
            // SAFETY: as for map_ordered; i0 >= nxny + nx + 1 by construction
            unsafe { avx2::interior_row(m, out, i0, len, nx, nxny) };
            return;
        }
    }
    let _ = lvl;
    for i in i0..i0 + len {
        let p = m[i - 1] + m[i - nx] + m[i - nxny] - m[i - 1 - nx] - m[i - 1 - nxny]
            - m[i - nx - nxny]
            + m[i - 1 - nx - nxny];
        out[i] = zigzag(m[i] - p);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! Lane forms of the encoder hot loops; see the module header for
    //! the bit-exactness argument.
    use core::arch::x86_64::*;

    /// # Safety
    /// AVX2 must be available (dispatch-checked by the caller);
    /// `mapped.len() == data.len()`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn map_ordered(data: &[f32], shift: u32, mapped: &mut [i64]) {
        let n = data.len();
        let sh = _mm_cvtsi32_si128(shift as i32);
        let msb = _mm256_set1_epi32(i32::MIN);
        let mut i = 0;
        while i + 8 <= n {
            let b = _mm256_loadu_si256(data.as_ptr().add(i) as *const __m256i);
            // branch-free f32_to_ordered_u32: b ^ ((b >>a 31) | 0x8000_0000)
            let flip = _mm256_or_si256(_mm256_srai_epi32::<31>(b), msb);
            let u = _mm256_srl_epi32(_mm256_xor_si256(b, flip), sh);
            let lo = _mm256_cvtepu32_epi64(_mm256_castsi256_si128(u));
            let hi = _mm256_cvtepu32_epi64(_mm256_extracti128_si256::<1>(u));
            _mm256_storeu_si256(mapped.as_mut_ptr().add(i) as *mut __m256i, lo);
            _mm256_storeu_si256(mapped.as_mut_ptr().add(i + 4) as *mut __m256i, hi);
            i += 8;
        }
        while i < n {
            mapped[i] = (super::f32_to_ordered_u32(data[i]) >> shift) as i64;
            i += 1;
        }
    }

    /// # Safety
    /// AVX2 must be available (dispatch-checked by the caller);
    /// `i0 >= 1 + nx + nxny` and `i0 + len <= m.len() == out.len()`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn interior_row(
        m: &[i64],
        out: &mut [u64],
        i0: usize,
        len: usize,
        nx: usize,
        nxny: usize,
    ) {
        // a macro, not a closure: closures would not inherit the avx2
        // target feature on older toolchains
        macro_rules! ld {
            ($i:expr) => {
                _mm256_loadu_si256(m.as_ptr().add($i) as *const __m256i)
            };
        }
        let zero = _mm256_setzero_si256();
        let mut i = i0;
        let end = i0 + len;
        while i + 4 <= end {
            let mut p = _mm256_add_epi64(ld!(i - 1), ld!(i - nx));
            p = _mm256_add_epi64(p, ld!(i - nxny));
            p = _mm256_sub_epi64(p, ld!(i - 1 - nx));
            p = _mm256_sub_epi64(p, ld!(i - 1 - nxny));
            p = _mm256_sub_epi64(p, ld!(i - nx - nxny));
            p = _mm256_add_epi64(p, ld!(i - 1 - nx - nxny));
            let d = _mm256_sub_epi64(ld!(i), p);
            // zigzag: (d << 1) ^ (d >>a 63); the compare IS d >>a 63
            let zz = _mm256_xor_si256(_mm256_slli_epi64::<1>(d), _mm256_cmpgt_epi64(zero, d));
            _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, zz);
            i += 4;
        }
        while i < end {
            let p = m[i - 1] + m[i - nx] + m[i - nxny] - m[i - 1 - nx] - m[i - 1 - nxny]
                - m[i - nx - nxny]
                + m[i - 1 - nx - nxny];
            out[i] = super::zigzag(m[i] - p);
            i += 1;
        }
    }
}

/// Compress; `prec` in [1, 32] is the number of kept mapped-int bits
/// (32 = lossless bit-for-bit).
pub fn compress(data: &[f32], dims: Dims3, prec: u8, out: &mut Vec<u8>) {
    compress_with(data, dims, prec, out, simd::level());
}

/// [`compress`] with an explicit dispatch level (tests pin the level
/// without touching the process-wide setting; the stream is identical
/// at every level).
fn compress_with(data: &[f32], dims: Dims3, prec: u8, out: &mut Vec<u8>, lvl: SimdLevel) {
    assert_eq!(data.len(), dims.len());
    assert!((1..=32).contains(&prec));
    let shift = 32 - prec as u32;
    let n = data.len();
    // pass 1: residuals + length-class frequencies
    let mut mapped = vec![0i64; n];
    map_ordered(data, shift, &mut mapped, lvl);
    let mut residuals = vec![0u64; n];
    compute_residuals(&mapped, dims, &mut residuals, lvl);
    let mut freqs = vec![0u32; N_CLASS];
    for &r in &residuals {
        freqs[(64 - r.leading_zeros()) as usize] += 1;
    }
    let lens = code_lengths(&freqs);
    let enc = Encoder::from_lengths(&lens);
    let mut w = BitWriter::with_capacity(n);
    for &r in &residuals {
        let class = 64 - r.leading_zeros(); // 0 for r == 0
        enc.write(&mut w, class as usize);
        if class > 1 {
            // top bit of the class is implied; write the low class-1 bits
            let low = class - 1;
            let bits = r & ((1u64 << low) - 1);
            let mut b = bits;
            let mut left = low;
            while left > 0 {
                let take = left.min(57);
                w.write_bits(b & ((1u64 << take) - 1), take);
                b >>= take;
                left -= take;
            }
        }
    }
    let payload = w.finish();

    out.push(1u8);
    out.push(prec);
    out.extend_from_slice(&(dims.nx as u16).to_le_bytes());
    out.extend_from_slice(&(dims.ny as u16).to_le_bytes());
    out.extend_from_slice(&(dims.nz as u16).to_le_bytes());
    let mut i = 0;
    while i < lens.len() {
        let lo = lens[i] & 0xf;
        let hi = if i + 1 < lens.len() { lens[i + 1] & 0xf } else { 0 };
        out.push(lo | (hi << 4));
        i += 2;
    }
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
}

/// Decompress; returns (data, dims). Lossy streams return the truncated-
/// precision reconstruction (low mapped bits zeroed, as in fpzip).
pub fn decompress(input: &[u8]) -> Result<(Vec<f32>, Dims3), String> {
    let mut mapped = Vec::new();
    let mut out = Vec::new();
    let dims = decompress_into(input, &mut mapped, &mut out)?;
    Ok((out, dims))
}

/// Decompress into caller-owned buffers (cleared and resized): `mapped`
/// is the decoder's integer plane, `out` receives the floats. Per-block
/// decode loops reuse both allocations. Returns the dims.
pub fn decompress_into(
    input: &[u8],
    mapped: &mut Vec<i64>,
    out: &mut Vec<f32>,
) -> Result<Dims3, String> {
    const LENS_BYTES: usize = N_CLASS.div_ceil(2);
    if input.len() < 8 + LENS_BYTES + 4 {
        return Err("fpzip stream too short".into());
    }
    if input[0] != 1 {
        return Err(format!("fpzip version {}", input[0]));
    }
    let prec = input[1];
    if !(1..=32).contains(&prec) {
        return Err(format!("bad precision {prec}"));
    }
    let shift = 32 - prec as u32;
    let nx = u16::from_le_bytes(input[2..4].try_into().unwrap()) as usize;
    let ny = u16::from_le_bytes(input[4..6].try_into().unwrap()) as usize;
    let nz = u16::from_le_bytes(input[6..8].try_into().unwrap()) as usize;
    let dims = Dims3 { nx, ny, nz };
    let n = dims.len();
    if n == 0 {
        return Err("empty fpzip dims".into());
    }
    let mut lens = Vec::with_capacity(N_CLASS);
    for i in 0..N_CLASS {
        let b = input[8 + i / 2];
        lens.push(if i % 2 == 0 { b & 0xf } else { b >> 4 });
    }
    let mut pos = 8 + LENS_BYTES;
    let payload_bytes = u32::from_le_bytes(input[pos..pos + 4].try_into().unwrap()) as usize;
    pos += 4;
    if input.len() < pos + payload_bytes {
        return Err("fpzip stream truncated".into());
    }
    let dec_tbl = Decoder::from_lengths(&lens)?;
    let mut r = BitReader::new(&input[pos..pos + payload_bytes]);
    // the Lorenzo predictor reads not-yet-decoded neighbors as 0, so a
    // warm (dirty) buffer must be re-zeroed
    mapped.clear();
    mapped.resize(n, 0);
    out.clear();
    out.resize(n, 0.0);
    let mut i = 0;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let class = dec_tbl.read(&mut r)? as u32;
                if class as usize >= N_CLASS {
                    return Err(format!("bad residual class {class}"));
                }
                let zz = match class {
                    0 => 0u64,
                    1 => 1u64,
                    _ => {
                        let low = class - 1;
                        let mut bits = 0u64;
                        let mut got = 0;
                        while got < low {
                            let take = (low - got).min(57);
                            bits |= r.read_bits(take) << got;
                            got += take;
                        }
                        bits | (1u64 << (class - 1))
                    }
                };
                let pred = lorenzo_pred(&mapped[..], dims, x, y, z);
                let m = pred + unzigzag(zz);
                mapped[i] = m;
                out[i] = ordered_u32_to_f32((m as u32) << shift);
                i += 1;
            }
        }
    }
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::{gen_floats, gen_smooth_field, prop_cases};

    #[test]
    fn lossless_roundtrip_adversarial() {
        prop_cases(0xF21, 8, |rng, _| {
            let dims = Dims3 { nx: 8, ny: 6, nz: 5 };
            let data: Vec<f32> = gen_floats(rng, dims.len());
            let mut out = Vec::new();
            compress(&data, dims, 32, &mut out);
            let (back, d2) = decompress(&out).unwrap();
            assert_eq!(d2, dims);
            for (a, b) in data.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn lossless_beats_raw_on_smooth_data() {
        let mut rng = Pcg32::new(11);
        let n = 32;
        let data = gen_smooth_field(&mut rng, n);
        let mut out = Vec::new();
        compress(&data, Dims3::cube(n), 32, &mut out);
        let cr = (4 * data.len()) as f64 / out.len() as f64;
        assert!(cr > 1.5, "lossless cr {cr}");
    }

    #[test]
    fn precision_controls_error_and_size() {
        let mut rng = Pcg32::new(12);
        let n = 16;
        let data = gen_smooth_field(&mut rng, n);
        let mut prev_size = usize::MAX;
        let mut prev_err = 0f64;
        for prec in [32u8, 24, 16, 12] {
            let mut out = Vec::new();
            compress(&data, Dims3::cube(n), prec, &mut out);
            let (back, _) = decompress(&out).unwrap();
            let err: f64 = data
                .iter()
                .zip(&back)
                .map(|(a, b)| ((a - b) as f64).abs())
                .fold(0.0, f64::max);
            assert!(out.len() <= prev_size, "prec {prec}");
            assert!(err >= prev_err - 1e-12, "prec {prec}: err {err} prev {prev_err}");
            prev_size = out.len();
            prev_err = err;
        }
    }

    #[test]
    fn truncation_never_increases_magnitude_class() {
        // truncated reconstruction stays within one ulp-class of original
        let mut rng = Pcg32::new(13);
        let dims = Dims3::cube(8);
        let mut data = vec![0f32; dims.len()];
        rng.fill_f32(&mut data, 1.0, 2.0);
        let mut out = Vec::new();
        compress(&data, dims, 16, &mut out);
        let (back, _) = decompress(&out).unwrap();
        for (a, b) in data.iter().zip(&back) {
            let rel = ((a - b) / a).abs();
            // prec 16 keeps sign+8 exp+7 mantissa bits: rel err < 2^-7
            assert!(rel < 8e-3, "prec 16 rel err {rel}");
        }
    }

    #[test]
    fn encoder_kernels_match_scalar_oracle() {
        let lvl = simd::detect();
        if lvl == SimdLevel::Scalar {
            return; // nothing to compare on this host
        }
        prop_cases(0xF21A, 20, |rng, _| {
            let dims = Dims3 {
                nx: 4 + rng.below(13) as usize,
                ny: 3 + rng.below(6) as usize,
                nz: 2 + rng.below(5) as usize,
            };
            let n = dims.len();
            let mut data = vec![0f32; n];
            for v in data.iter_mut() {
                // raw bit patterns: NaNs, infs and subnormals included
                *v = f32::from_bits(rng.next_u32());
            }
            for &prec in &[32u8, 17, 8] {
                let shift = 32 - prec as u32;
                let (mut ma, mut mb) = (vec![0i64; n], vec![0i64; n]);
                map_ordered(&data, shift, &mut ma, SimdLevel::Scalar);
                map_ordered(&data, shift, &mut mb, lvl);
                assert_eq!(ma, mb, "map_ordered diverges at prec {prec}");
                let (mut ra, mut rb) = (vec![0u64; n], vec![0u64; n]);
                compute_residuals(&ma, dims, &mut ra, SimdLevel::Scalar);
                compute_residuals(&mb, dims, &mut rb, lvl);
                assert_eq!(ra, rb, "residuals diverge at prec {prec}");
            }
        });
    }

    #[test]
    fn streams_identical_across_dispatch() {
        let lvl = simd::detect();
        prop_cases(0xF21D, 6, |rng, _| {
            let dims = Dims3 { nx: 10, ny: 7, nz: 6 };
            let data: Vec<f32> = gen_floats(rng, dims.len());
            for &prec in &[32u8, 16] {
                let (mut a, mut b) = (Vec::new(), Vec::new());
                compress_with(&data, dims, prec, &mut a, SimdLevel::Scalar);
                compress_with(&data, dims, prec, &mut b, lvl);
                assert_eq!(a, b, "stream differs vs {lvl:?} at prec {prec}");
            }
        });
    }

    #[test]
    fn corrupt_header_errors() {
        assert!(decompress(&[9, 32, 0, 0]).is_err());
        let mut out = Vec::new();
        compress(&vec![1.0f32; 64], Dims3::cube(4), 32, &mut out);
        assert!(decompress(&out[..12]).is_err());
    }

    #[test]
    fn decompress_into_reuses_dirty_buffers() {
        let mut rng = Pcg32::new(14);
        let dims = Dims3 { nx: 8, ny: 6, nz: 5 };
        let data: Vec<f32> = gen_floats(&mut rng, dims.len());
        let mut comp = Vec::new();
        compress(&data, dims, 32, &mut comp);
        let (reference, _) = decompress(&comp).unwrap();
        let mut ints = vec![-7i64; 2]; // dirty + wrong size
        let mut buf = vec![0.5f32; 4096];
        for _ in 0..3 {
            let d = decompress_into(&comp, &mut ints, &mut buf).unwrap();
            assert_eq!(d, dims);
            for (a, b) in reference.iter().zip(&buf) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
