//! ZFP-like fixed-accuracy compressor (Lindstrom, "Fixed-Rate Compressed
//! Floating-Point Arrays", 2014), 3D f32.
//!
//! Pipeline per 4³ cell: block-floating-point alignment to the cell's max
//! exponent → integer decorrelating lifting transform along x/y/z →
//! total-sequency reorder → negabinary mapping → group-tested bit-plane
//! coding from the MSB plane down to the tolerance cutoff plane.
//!
//! Stream: `[u8 ver][f32 tol][u16 nx ny nz]` then per cell a 1-bit
//! zero flag, biased max-exponent byte and the bit planes.
use super::Dims3;
use crate::util::{BitReader, BitWriter};

const CELL: usize = 4;
const CELL_VOL: usize = 64;
/// Fixed-point precision: values scaled so |q| <= 2^FRAC.
const FRAC: i32 = 26;
/// Guard bits for transform range growth (the 3D transform can grow
/// values by a factor < 8) and the 1-bit lifting shifts.
const GUARD: i32 = 4;

/// Total-sequency reordering permutation for a 4³ cell (by x+y+z).
fn sequency_perm() -> [usize; CELL_VOL] {
    let mut idx: Vec<usize> = (0..CELL_VOL).collect();
    idx.sort_by_key(|&i| {
        let (x, y, z) = (i % 4, (i / 4) % 4, i / 16);
        (x + y + z, z, y, x)
    });
    let mut out = [0usize; CELL_VOL];
    out.copy_from_slice(&idx);
    out
}

#[inline]
fn fwd_lift(v: &mut [i64], base: usize, stride: usize) {
    let (mut x, mut y, mut z, mut w) =
        (v[base], v[base + stride], v[base + 2 * stride], v[base + 3 * stride]);
    // zfp's non-orthogonal lifting transform
    x += w;
    x >>= 1;
    w -= x;
    z += y;
    z >>= 1;
    y -= z;
    x += z;
    x >>= 1;
    z -= x;
    w += y;
    w >>= 1;
    y -= w;
    w += y >> 1;
    y -= w >> 1;
    v[base] = x;
    v[base + stride] = y;
    v[base + 2 * stride] = z;
    v[base + 3 * stride] = w;
}

#[inline]
fn inv_lift(v: &mut [i64], base: usize, stride: usize) {
    let (mut x, mut y, mut z, mut w) =
        (v[base], v[base + stride], v[base + 2 * stride], v[base + 3 * stride]);
    y += w >> 1;
    w -= y >> 1;
    y += w;
    w <<= 1;
    w -= y;
    z += x;
    x <<= 1;
    x -= z;
    y += z;
    z <<= 1;
    z -= y;
    w += x;
    x <<= 1;
    x -= w;
    v[base] = x;
    v[base + stride] = y;
    v[base + 2 * stride] = z;
    v[base + 3 * stride] = w;
}

/// i64 two's complement -> negabinary u64 (low 2*F+G bits meaningful).
#[inline]
fn to_negabinary(v: i64) -> u64 {
    const MASK: u64 = 0xaaaa_aaaa_aaaa_aaaa;
    ((v as u64).wrapping_add(MASK)) ^ MASK
}

#[inline]
fn from_negabinary(u: u64) -> i64 {
    const MASK: u64 = 0xaaaa_aaaa_aaaa_aaaa;
    (u ^ MASK).wrapping_sub(MASK) as i64
}

/// Number of bit planes used per cell.
const PLANES: i32 = FRAC + GUARD + 2; // highest plane index = PLANES-1

fn plane_min(tol: f32, e_max: i32) -> i32 {
    if tol <= 0.0 {
        return 0;
    }
    // dropping plane p costs ~2^(p - FRAC + e_max); require <= tol/8
    // (transform growth + superposition guard; validated by the
    // error_bounded_on_random_fields property test)
    let cutoff = (tol.log2() - 4.5 + FRAC as f32 - e_max as f32).floor() as i32;
    cutoff.clamp(0, PLANES)
}

/// Encode one 4³ cell of i64 coefficients (already in negabinary) from
/// plane `PLANES-1` down to `kmin` with zfp's group-testing scheme.
fn encode_planes(w: &mut BitWriter, data: &[u64; CELL_VOL], kmin: i32) {
    // `n` = significance frontier carried across planes: positions < n are
    // emitted verbatim, the rest is unary group-tested (canonical zfp).
    let mut n = 0usize;
    for k in (kmin..PLANES).rev() {
        // gather plane k (bit i = plane bit of coefficient i)
        let mut x: u64 = 0;
        for (i, &d) in data.iter().enumerate() {
            x |= ((d >> k) & 1) << i;
        }
        // step 1: emit the first n bits verbatim, consuming them from x
        let m = n.min(CELL_VOL);
        let mut emitted = 0;
        while emitted < m {
            let take = (m - emitted).min(57);
            w.write_bits(x & ((1u64 << take) - 1), take as u32);
            x >>= take;
            emitted += take;
        }
        // step 2: group-test the remainder
        let mut pos = m;
        while pos < CELL_VOL {
            let any = x != 0;
            w.write_bit(any);
            if !any {
                break;
            }
            // scan for the next set bit; the bit at the final position is
            // implied by the group test
            loop {
                let bit = (x & 1) != 0;
                x >>= 1;
                if pos == CELL_VOL - 1 {
                    pos += 1;
                    break;
                }
                w.write_bit(bit);
                pos += 1;
                if bit {
                    break;
                }
            }
        }
        n = n.max(pos);
    }
}

fn decode_planes(r: &mut BitReader, data: &mut [u64; CELL_VOL], kmin: i32) {
    data.fill(0);
    let mut n = 0usize;
    for k in (kmin..PLANES).rev() {
        let mut x: u64 = 0;
        let m = n.min(CELL_VOL);
        let mut got = 0;
        while got < m {
            let take = (m - got).min(57);
            x |= r.read_bits(take as u32) << got;
            got += take;
        }
        let mut pos = m;
        while pos < CELL_VOL {
            if !r.read_bit() {
                break;
            }
            loop {
                if pos == CELL_VOL - 1 {
                    x |= 1u64 << pos;
                    pos += 1;
                    break;
                }
                let bit = r.read_bit();
                pos += 1;
                if bit {
                    x |= 1u64 << (pos - 1);
                    break;
                }
            }
        }
        n = n.max(pos);
        for i in 0..CELL_VOL {
            data[i] |= ((x >> i) & 1) << k;
        }
    }
}

/// Compress a 3D f32 array (dims must be multiples of 4) with absolute
/// error tolerance `tol` (0 = near-lossless max precision), appending to
/// `out`.
pub fn compress(data: &[f32], dims: Dims3, tol: f32, out: &mut Vec<u8>) {
    assert_eq!(data.len(), dims.len());
    assert!(
        dims.nx % CELL == 0 && dims.ny % CELL == 0 && dims.nz % CELL == 0,
        "zfp dims must be multiples of 4"
    );
    out.push(1u8); // version
    out.extend_from_slice(&tol.to_le_bytes());
    out.extend_from_slice(&(dims.nx as u16).to_le_bytes());
    out.extend_from_slice(&(dims.ny as u16).to_le_bytes());
    out.extend_from_slice(&(dims.nz as u16).to_le_bytes());
    let perm = sequency_perm();
    let mut w = BitWriter::with_capacity(data.len());
    let mut cell = [0f32; CELL_VOL];
    let mut q = [0i64; CELL_VOL];
    let mut nb = [0u64; CELL_VOL];
    for cz in 0..dims.nz / CELL {
        for cy in 0..dims.ny / CELL {
            for cx in 0..dims.nx / CELL {
                // gather cell
                for z in 0..CELL {
                    for y in 0..CELL {
                        let src = ((cz * CELL + z) * dims.ny + cy * CELL + y) * dims.nx + cx * CELL;
                        let dst = (z * CELL + y) * CELL;
                        cell[dst..dst + CELL].copy_from_slice(&data[src..src + CELL]);
                    }
                }
                let maxabs = cell.iter().fold(0f32, |m, v| m.max(v.abs()));
                if maxabs == 0.0 {
                    w.write_bit(false);
                    continue;
                }
                w.write_bit(true);
                let e_max = maxabs.log2().floor() as i32;
                w.write_bits((e_max + 128) as u64, 8);
                // block floating point: scale into [-2^FRAC, 2^FRAC]
                let scale = (FRAC - e_max) as f32;
                let s = scale.exp2();
                for i in 0..CELL_VOL {
                    q[i] = (cell[i] * s).round() as i64;
                }
                // decorrelate: x lines, y lines, z lines
                for z in 0..CELL {
                    for y in 0..CELL {
                        fwd_lift(&mut q, (z * CELL + y) * CELL, 1);
                    }
                }
                for z in 0..CELL {
                    for x in 0..CELL {
                        fwd_lift(&mut q, z * CELL * CELL + x, CELL);
                    }
                }
                for y in 0..CELL {
                    for x in 0..CELL {
                        fwd_lift(&mut q, y * CELL + x, CELL * CELL);
                    }
                }
                for i in 0..CELL_VOL {
                    nb[i] = to_negabinary(q[perm[i]]);
                }
                encode_planes(&mut w, &nb, plane_min(tol, e_max));
            }
        }
    }
    out.extend_from_slice(&w.finish());
}

/// Decompress a zfp stream into a fresh array; returns (data, dims).
pub fn decompress(input: &[u8]) -> Result<(Vec<f32>, Dims3), String> {
    let mut out = Vec::new();
    let dims = decompress_into(input, &mut out)?;
    Ok((out, dims))
}

/// Decompress into a caller-owned buffer (cleared and resized), so
/// per-block decode loops reuse one allocation. Returns the dims.
pub fn decompress_into(input: &[u8], out: &mut Vec<f32>) -> Result<Dims3, String> {
    if input.len() < 11 {
        return Err("zfp stream too short".into());
    }
    if input[0] != 1 {
        return Err(format!("zfp version {}", input[0]));
    }
    let tol = f32::from_le_bytes(input[1..5].try_into().unwrap());
    let nx = u16::from_le_bytes(input[5..7].try_into().unwrap()) as usize;
    let ny = u16::from_le_bytes(input[7..9].try_into().unwrap()) as usize;
    let nz = u16::from_le_bytes(input[9..11].try_into().unwrap()) as usize;
    let dims = Dims3 { nx, ny, nz };
    if nx % CELL != 0 || ny % CELL != 0 || nz % CELL != 0 || dims.len() == 0 {
        return Err(format!("bad zfp dims {nx}x{ny}x{nz}"));
    }
    let perm = sequency_perm();
    // all-zero cells are skipped by the coder, so the buffer must be
    // zero-filled even when warm
    out.clear();
    out.resize(dims.len(), 0.0);
    let mut r = BitReader::new(&input[11..]);
    let mut q = [0i64; CELL_VOL];
    let mut nb = [0u64; CELL_VOL];
    for cz in 0..nz / CELL {
        for cy in 0..ny / CELL {
            for cx in 0..nx / CELL {
                if !r.read_bit() {
                    continue; // all-zero cell
                }
                let e_max = r.read_bits(8) as i32 - 128;
                decode_planes(&mut r, &mut nb, plane_min(tol, e_max));
                for i in 0..CELL_VOL {
                    q[perm[i]] = from_negabinary(nb[i]);
                }
                for y in 0..CELL {
                    for x in 0..CELL {
                        inv_lift(&mut q, y * CELL + x, CELL * CELL);
                    }
                }
                for z in 0..CELL {
                    for x in 0..CELL {
                        inv_lift(&mut q, z * CELL * CELL + x, CELL);
                    }
                }
                for z in 0..CELL {
                    for y in 0..CELL {
                        inv_lift(&mut q, (z * CELL + y) * CELL, 1);
                    }
                }
                let s = ((e_max - FRAC) as f32).exp2();
                for z in 0..CELL {
                    for y in 0..CELL {
                        let dst = ((cz * CELL + z) * ny + cy * CELL + y) * nx + cx * CELL;
                        let src = (z * CELL + y) * CELL;
                        for x in 0..CELL {
                            out[dst + x] = q[src + x] as f32 * s;
                        }
                    }
                }
            }
        }
    }
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::{gen_smooth_field, prop_cases};

    #[test]
    fn zero_field_is_tiny() {
        let dims = Dims3::cube(32);
        let data = vec![0f32; dims.len()];
        let mut out = Vec::new();
        compress(&data, dims, 1e-3, &mut out);
        // 512 cells, 1 bit each + header
        assert!(out.len() < 100, "len {}", out.len());
        let (back, d2) = decompress(&out).unwrap();
        assert_eq!(d2, dims);
        assert_eq!(back, data);
    }

    #[test]
    fn error_bounded_on_random_fields() {
        prop_cases(0x2F9, 8, |rng, _| {
            let dims = Dims3::cube(16);
            let mut data = vec![0f32; dims.len()];
            rng.fill_f32(&mut data, -100.0, 100.0);
            for tol in [1e-1f32, 1e-2, 1e-3] {
                let mut out = Vec::new();
                compress(&data, dims, tol, &mut out);
                let (back, _) = decompress(&out).unwrap();
                let maxerr = data
                    .iter()
                    .zip(&back)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(maxerr <= tol, "tol {tol} maxerr {maxerr}");
            }
        });
    }

    #[test]
    fn near_lossless_at_zero_tolerance() {
        let mut rng = Pcg32::new(7);
        let dims = Dims3::cube(8);
        let mut data = vec![0f32; dims.len()];
        rng.fill_f32(&mut data, -1.0, 1.0);
        let mut out = Vec::new();
        compress(&data, dims, 0.0, &mut out);
        let (back, _) = decompress(&out).unwrap();
        let maxerr = data
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // 26-bit fixed point: ~2^-25 relative to cell max
        assert!(maxerr < 1e-6, "maxerr {maxerr}");
    }

    #[test]
    fn smooth_fields_compress_much_better_than_raw() {
        let mut rng = Pcg32::new(8);
        let n = 32;
        let data = gen_smooth_field(&mut rng, n);
        let mut out = Vec::new();
        compress(&data, Dims3::cube(n), 1e-3 * 200.0, &mut out);
        let cr = (data.len() * 4) as f64 / out.len() as f64;
        assert!(cr > 4.0, "cr {cr}");
    }

    #[test]
    fn higher_tolerance_higher_ratio() {
        let mut rng = Pcg32::new(9);
        let n = 16;
        let data = gen_smooth_field(&mut rng, n);
        let sizes: Vec<usize> = [1e-4f32, 1e-2, 1e0]
            .iter()
            .map(|&tol| {
                let mut out = Vec::new();
                compress(&data, Dims3::cube(n), tol, &mut out);
                out.len()
            })
            .collect();
        assert!(sizes[0] > sizes[1] && sizes[1] > sizes[2], "{sizes:?}");
    }

    #[test]
    fn rejects_bad_dims() {
        let data = vec![0f32; 5 * 4 * 4];
        let mut out = Vec::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            compress(&data, Dims3 { nx: 5, ny: 4, nz: 4 }, 0.0, &mut out)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        assert!(decompress(&[1, 0, 0]).is_err());
    }

    #[test]
    fn decompress_into_reuses_dirty_buffers() {
        // the per-worker buffer arrives dirty and differently sized; the
        // into-variant must still match the allocating result exactly,
        // including the zero cells the coder skips
        let mut rng = Pcg32::new(10);
        let dims = Dims3::cube(8);
        let mut data = vec![0f32; dims.len()];
        rng.fill_f32(&mut data, -2.0, 2.0);
        for v in data.iter_mut().take(64) {
            *v = 0.0; // force an all-zero cell
        }
        let mut comp = Vec::new();
        compress(&data, dims, 1e-3, &mut comp);
        let (reference, _) = decompress(&comp).unwrap();
        let mut buf = vec![9.9f32; 7]; // dirty + wrong size
        for _ in 0..3 {
            let d = decompress_into(&comp, &mut buf).unwrap();
            assert_eq!(d, dims);
            assert_eq!(buf, reference);
        }
    }
}
