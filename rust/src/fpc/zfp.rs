//! ZFP-like fixed-accuracy compressor (Lindstrom, "Fixed-Rate Compressed
//! Floating-Point Arrays", 2014), 3D f32.
//!
//! Pipeline per 4³ cell: block-floating-point alignment to the cell's max
//! exponent → integer decorrelating lifting transform along x/y/z →
//! total-sequency reorder → negabinary mapping → group-tested bit-plane
//! coding from the MSB plane down to the tolerance cutoff plane.
//!
//! Stream: `[u8 ver][f32 tol][u16 nx ny nz]` then per cell a 1-bit
//! zero flag, biased max-exponent byte and the bit planes.
//!
//! Hot-loop vectorization (bit-exact, stream-identical — see
//! `crate::simd`): the per-plane 64-step bit gather/scatter is replaced
//! by one word-parallel 64x64 transpose per cell
//! (`simd::bitmat::transpose64`), and on AVX2 the lifting passes run
//! four independent lines per register (integer lane ops wrap exactly
//! like the scalar ops) with the negabinary map vectorized alongside.
//! The scalar loops remain the fallback and equivalence oracle.
use super::Dims3;
use crate::simd::bitmat::transpose64;
use crate::simd::{self, SimdLevel};
use crate::util::{BitReader, BitWriter};

const CELL: usize = 4;
const CELL_VOL: usize = 64;
/// Fixed-point precision: values scaled so |q| <= 2^FRAC.
const FRAC: i32 = 26;
/// Guard bits for transform range growth (the 3D transform can grow
/// values by a factor < 8) and the 1-bit lifting shifts.
const GUARD: i32 = 4;

/// Total-sequency reordering permutation for a 4³ cell (by x+y+z).
fn sequency_perm() -> [usize; CELL_VOL] {
    let mut idx: Vec<usize> = (0..CELL_VOL).collect();
    idx.sort_by_key(|&i| {
        let (x, y, z) = (i % 4, (i / 4) % 4, i / 16);
        (x + y + z, z, y, x)
    });
    let mut out = [0usize; CELL_VOL];
    out.copy_from_slice(&idx);
    out
}

#[inline]
fn fwd_lift(v: &mut [i64], base: usize, stride: usize) {
    let (mut x, mut y, mut z, mut w) =
        (v[base], v[base + stride], v[base + 2 * stride], v[base + 3 * stride]);
    // zfp's non-orthogonal lifting transform
    x += w;
    x >>= 1;
    w -= x;
    z += y;
    z >>= 1;
    y -= z;
    x += z;
    x >>= 1;
    z -= x;
    w += y;
    w >>= 1;
    y -= w;
    w += y >> 1;
    y -= w >> 1;
    v[base] = x;
    v[base + stride] = y;
    v[base + 2 * stride] = z;
    v[base + 3 * stride] = w;
}

#[inline]
fn inv_lift(v: &mut [i64], base: usize, stride: usize) {
    let (mut x, mut y, mut z, mut w) =
        (v[base], v[base + stride], v[base + 2 * stride], v[base + 3 * stride]);
    y += w >> 1;
    w -= y >> 1;
    y += w;
    w <<= 1;
    w -= y;
    z += x;
    x <<= 1;
    x -= z;
    y += z;
    z <<= 1;
    z -= y;
    w += x;
    x <<= 1;
    x -= w;
    v[base] = x;
    v[base + stride] = y;
    v[base + 2 * stride] = z;
    v[base + 3 * stride] = w;
}

const NEGA_MASK: u64 = 0xaaaa_aaaa_aaaa_aaaa;

/// i64 two's complement -> negabinary u64 (low 2*F+G bits meaningful).
#[inline]
fn to_negabinary(v: i64) -> u64 {
    ((v as u64).wrapping_add(NEGA_MASK)) ^ NEGA_MASK
}

#[inline]
fn from_negabinary(u: u64) -> i64 {
    (u ^ NEGA_MASK).wrapping_sub(NEGA_MASK) as i64
}

/// All 48 lifting applications of one cell: x lines, then y, then z
/// (zfp's forward order), four independent lines per register on AVX2.
#[inline]
fn fwd_lift_cell(q: &mut [i64; CELL_VOL], lvl: SimdLevel) {
    #[cfg(target_arch = "x86_64")]
    {
        if lvl == SimdLevel::Avx2 {
            // SAFETY: Avx2 is only dispatched when simd::detect() saw it
            unsafe { avx2::fwd_lift_cell(q) };
            return;
        }
    }
    let _ = lvl;
    for z in 0..CELL {
        for y in 0..CELL {
            fwd_lift(q, (z * CELL + y) * CELL, 1);
        }
    }
    for z in 0..CELL {
        for x in 0..CELL {
            fwd_lift(q, z * CELL * CELL + x, CELL);
        }
    }
    for y in 0..CELL {
        for x in 0..CELL {
            fwd_lift(q, y * CELL + x, CELL * CELL);
        }
    }
}

/// Inverse of [`fwd_lift_cell`]: z lines, then y, then x.
#[inline]
fn inv_lift_cell(q: &mut [i64; CELL_VOL], lvl: SimdLevel) {
    #[cfg(target_arch = "x86_64")]
    {
        if lvl == SimdLevel::Avx2 {
            // SAFETY: as for fwd_lift_cell
            unsafe { avx2::inv_lift_cell(q) };
            return;
        }
    }
    let _ = lvl;
    for y in 0..CELL {
        for x in 0..CELL {
            inv_lift(q, y * CELL + x, CELL * CELL);
        }
    }
    for z in 0..CELL {
        for x in 0..CELL {
            inv_lift(q, z * CELL * CELL + x, CELL);
        }
    }
    for z in 0..CELL {
        for y in 0..CELL {
            inv_lift(q, (z * CELL + y) * CELL, 1);
        }
    }
}

/// Sequency reorder + negabinary map: `nb[i] = negabinary(q[perm[i]])`.
#[inline]
fn negabinary_cell(
    q: &[i64; CELL_VOL],
    perm: &[usize; CELL_VOL],
    nb: &mut [u64; CELL_VOL],
    lvl: SimdLevel,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if lvl == SimdLevel::Avx2 {
            let mut t = [0u64; CELL_VOL];
            // SAFETY: as for fwd_lift_cell
            unsafe { avx2::to_negabinary_cell(q, &mut t) };
            for i in 0..CELL_VOL {
                nb[i] = t[perm[i]];
            }
            return;
        }
    }
    let _ = lvl;
    for i in 0..CELL_VOL {
        nb[i] = to_negabinary(q[perm[i]]);
    }
}

/// Inverse of [`negabinary_cell`]: `q[perm[i]] = from_negabinary(nb[i])`.
#[inline]
fn unnegabinary_cell(
    nb: &[u64; CELL_VOL],
    perm: &[usize; CELL_VOL],
    q: &mut [i64; CELL_VOL],
    lvl: SimdLevel,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if lvl == SimdLevel::Avx2 {
            let mut t = [0i64; CELL_VOL];
            // SAFETY: as for fwd_lift_cell
            unsafe { avx2::from_negabinary_cell(nb, &mut t) };
            for i in 0..CELL_VOL {
                q[perm[i]] = t[i];
            }
            return;
        }
    }
    let _ = lvl;
    for i in 0..CELL_VOL {
        q[perm[i]] = from_negabinary(nb[i]);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! 4-lane i64 cell kernels. Integer lane add/sub/shift wrap exactly
    //! like the (release-mode) scalar ops and the per-line op order is
    //! copied verbatim from the scalar lifts, so these are bit-exact by
    //! construction; the fuzzed tests compare them against the scalar
    //! oracle anyway.
    use super::{CELL_VOL, NEGA_MASK};
    use core::arch::x86_64::*;

    #[inline(always)]
    unsafe fn ld(p: *const i64) -> __m256i {
        _mm256_loadu_si256(p as *const __m256i)
    }

    #[inline(always)]
    unsafe fn st(p: *mut i64, v: __m256i) {
        _mm256_storeu_si256(p as *mut __m256i, v)
    }

    /// Arithmetic shift right by one over four i64 lanes (AVX2 has no
    /// vpsraq): logical shift, then restore the sign bit.
    #[inline(always)]
    unsafe fn sra1(v: __m256i) -> __m256i {
        let sign = _mm256_and_si256(v, _mm256_set1_epi64x(i64::MIN));
        _mm256_or_si256(_mm256_srli_epi64::<1>(v), sign)
    }

    /// zfp forward lift of four independent lines (lane l = line l);
    /// the op order matches `super::fwd_lift` exactly.
    #[inline(always)]
    unsafe fn fwd4(
        mut x: __m256i,
        mut y: __m256i,
        mut z: __m256i,
        mut w: __m256i,
    ) -> (__m256i, __m256i, __m256i, __m256i) {
        x = _mm256_add_epi64(x, w);
        x = sra1(x);
        w = _mm256_sub_epi64(w, x);
        z = _mm256_add_epi64(z, y);
        z = sra1(z);
        y = _mm256_sub_epi64(y, z);
        x = _mm256_add_epi64(x, z);
        x = sra1(x);
        z = _mm256_sub_epi64(z, x);
        w = _mm256_add_epi64(w, y);
        w = sra1(w);
        y = _mm256_sub_epi64(y, w);
        w = _mm256_add_epi64(w, sra1(y));
        y = _mm256_sub_epi64(y, sra1(w));
        (x, y, z, w)
    }

    /// Inverse lift of four independent lines, matching `super::inv_lift`.
    #[inline(always)]
    unsafe fn inv4(
        mut x: __m256i,
        mut y: __m256i,
        mut z: __m256i,
        mut w: __m256i,
    ) -> (__m256i, __m256i, __m256i, __m256i) {
        y = _mm256_add_epi64(y, sra1(w));
        w = _mm256_sub_epi64(w, sra1(y));
        y = _mm256_add_epi64(y, w);
        w = _mm256_slli_epi64::<1>(w);
        w = _mm256_sub_epi64(w, y);
        z = _mm256_add_epi64(z, x);
        x = _mm256_slli_epi64::<1>(x);
        x = _mm256_sub_epi64(x, z);
        y = _mm256_add_epi64(y, z);
        z = _mm256_slli_epi64::<1>(z);
        z = _mm256_sub_epi64(z, y);
        w = _mm256_add_epi64(w, x);
        x = _mm256_slli_epi64::<1>(x);
        x = _mm256_sub_epi64(x, w);
        (x, y, z, w)
    }

    /// 4x4 i64 transpose across four registers (unpack + 128-bit
    /// permute), used to turn the contiguous x-pass into lane form.
    #[inline(always)]
    unsafe fn transpose4(
        a: __m256i,
        b: __m256i,
        c: __m256i,
        d: __m256i,
    ) -> (__m256i, __m256i, __m256i, __m256i) {
        let t0 = _mm256_unpacklo_epi64(a, b);
        let t1 = _mm256_unpackhi_epi64(a, b);
        let t2 = _mm256_unpacklo_epi64(c, d);
        let t3 = _mm256_unpackhi_epi64(c, d);
        (
            _mm256_permute2x128_si256::<0x20>(t0, t2),
            _mm256_permute2x128_si256::<0x20>(t1, t3),
            _mm256_permute2x128_si256::<0x31>(t0, t2),
            _mm256_permute2x128_si256::<0x31>(t1, t3),
        )
    }

    /// # Safety
    /// AVX2 must be available (dispatch-checked by the caller).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn fwd_lift_cell(q: &mut [i64; CELL_VOL]) {
        // x-pass: transpose each z-slice so its four y-rows become lanes
        for s in 0..4 {
            let p = q.as_mut_ptr().add(16 * s);
            let (a, b, c, d) = transpose4(ld(p), ld(p.add(4)), ld(p.add(8)), ld(p.add(12)));
            let (a, b, c, d) = fwd4(a, b, c, d);
            let (a, b, c, d) = transpose4(a, b, c, d);
            st(p, a);
            st(p.add(4), b);
            st(p.add(8), c);
            st(p.add(12), d);
        }
        // y-pass: the four y-rows of a z-slice, four x-lanes at a time
        for s in 0..4 {
            let p = q.as_mut_ptr().add(16 * s);
            let (a, b, c, d) = fwd4(ld(p), ld(p.add(4)), ld(p.add(8)), ld(p.add(12)));
            st(p, a);
            st(p.add(4), b);
            st(p.add(8), c);
            st(p.add(12), d);
        }
        // z-pass: for each y, the four z-planes' rows sit 16 apart
        for y in 0..4 {
            let p = q.as_mut_ptr().add(4 * y);
            let (a, b, c, d) = fwd4(ld(p), ld(p.add(16)), ld(p.add(32)), ld(p.add(48)));
            st(p, a);
            st(p.add(16), b);
            st(p.add(32), c);
            st(p.add(48), d);
        }
    }

    /// # Safety
    /// AVX2 must be available (dispatch-checked by the caller).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn inv_lift_cell(q: &mut [i64; CELL_VOL]) {
        for y in 0..4 {
            let p = q.as_mut_ptr().add(4 * y);
            let (a, b, c, d) = inv4(ld(p), ld(p.add(16)), ld(p.add(32)), ld(p.add(48)));
            st(p, a);
            st(p.add(16), b);
            st(p.add(32), c);
            st(p.add(48), d);
        }
        for s in 0..4 {
            let p = q.as_mut_ptr().add(16 * s);
            let (a, b, c, d) = inv4(ld(p), ld(p.add(4)), ld(p.add(8)), ld(p.add(12)));
            st(p, a);
            st(p.add(4), b);
            st(p.add(8), c);
            st(p.add(12), d);
        }
        for s in 0..4 {
            let p = q.as_mut_ptr().add(16 * s);
            let (a, b, c, d) = transpose4(ld(p), ld(p.add(4)), ld(p.add(8)), ld(p.add(12)));
            let (a, b, c, d) = inv4(a, b, c, d);
            let (a, b, c, d) = transpose4(a, b, c, d);
            st(p, a);
            st(p.add(4), b);
            st(p.add(8), c);
            st(p.add(12), d);
        }
    }

    /// # Safety
    /// AVX2 must be available (dispatch-checked by the caller).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn to_negabinary_cell(q: &[i64; CELL_VOL], out: &mut [u64; CELL_VOL]) {
        let mask = _mm256_set1_epi64x(NEGA_MASK as i64);
        for c in 0..CELL_VOL / 4 {
            let v = ld(q.as_ptr().add(4 * c));
            let nb = _mm256_xor_si256(_mm256_add_epi64(v, mask), mask);
            st(out.as_mut_ptr().add(4 * c) as *mut i64, nb);
        }
    }

    /// # Safety
    /// AVX2 must be available (dispatch-checked by the caller).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn from_negabinary_cell(nb: &[u64; CELL_VOL], out: &mut [i64; CELL_VOL]) {
        let mask = _mm256_set1_epi64x(NEGA_MASK as i64);
        for c in 0..CELL_VOL / 4 {
            let v = ld(nb.as_ptr().add(4 * c) as *const i64);
            let q = _mm256_sub_epi64(_mm256_xor_si256(v, mask), mask);
            st(out.as_mut_ptr().add(4 * c), q);
        }
    }
}

/// Number of bit planes used per cell.
const PLANES: i32 = FRAC + GUARD + 2; // highest plane index = PLANES-1

fn plane_min(tol: f32, e_max: i32) -> i32 {
    if tol <= 0.0 {
        return 0;
    }
    // dropping plane p costs ~2^(p - FRAC + e_max); require <= tol/8
    // (transform growth + superposition guard; validated by the
    // error_bounded_on_random_fields property test)
    let cutoff = (tol.log2() - 4.5 + FRAC as f32 - e_max as f32).floor() as i32;
    cutoff.clamp(0, PLANES)
}

/// Encode one 4³ cell from plane `PLANES-1` down to `kmin` with zfp's
/// group-testing scheme. `planes` is the cell's 64x64 bit matrix already
/// transposed ([`transpose64`]): `planes[k]` bit `i` = bit `k` of
/// negabinary coefficient `i`.
fn encode_planes(w: &mut BitWriter, planes: &[u64; CELL_VOL], kmin: i32) {
    // `n` = significance frontier carried across planes: positions < n are
    // emitted verbatim, the rest is unary group-tested (canonical zfp).
    let mut n = 0usize;
    for k in (kmin..PLANES).rev() {
        let mut x: u64 = planes[k as usize];
        // step 1: emit the first n bits verbatim, consuming them from x
        let m = n.min(CELL_VOL);
        let mut emitted = 0;
        while emitted < m {
            let take = (m - emitted).min(57);
            w.write_bits(x & ((1u64 << take) - 1), take as u32);
            x >>= take;
            emitted += take;
        }
        // step 2: group-test the remainder
        let mut pos = m;
        while pos < CELL_VOL {
            let any = x != 0;
            w.write_bit(any);
            if !any {
                break;
            }
            // scan for the next set bit; the bit at the final position is
            // implied by the group test
            loop {
                let bit = (x & 1) != 0;
                x >>= 1;
                if pos == CELL_VOL - 1 {
                    pos += 1;
                    break;
                }
                w.write_bit(bit);
                pos += 1;
                if bit {
                    break;
                }
            }
        }
        n = n.max(pos);
    }
}

/// Decode into negabinary coefficients: planes are collected as rows of
/// the bit matrix and un-transposed once at the end (the inverse of the
/// [`encode_planes`] layout — [`transpose64`] is an involution).
fn decode_planes(r: &mut BitReader, data: &mut [u64; CELL_VOL], kmin: i32) {
    data.fill(0);
    let mut n = 0usize;
    for k in (kmin..PLANES).rev() {
        let mut x: u64 = 0;
        let m = n.min(CELL_VOL);
        let mut got = 0;
        while got < m {
            let take = (m - got).min(57);
            x |= r.read_bits(take as u32) << got;
            got += take;
        }
        let mut pos = m;
        while pos < CELL_VOL {
            if !r.read_bit() {
                break;
            }
            loop {
                if pos == CELL_VOL - 1 {
                    x |= 1u64 << pos;
                    pos += 1;
                    break;
                }
                let bit = r.read_bit();
                pos += 1;
                if bit {
                    x |= 1u64 << (pos - 1);
                    break;
                }
            }
        }
        n = n.max(pos);
        data[k as usize] = x;
    }
    transpose64(data);
}

/// Compress a 3D f32 array (dims must be multiples of 4) with absolute
/// error tolerance `tol` (0 = near-lossless max precision), appending to
/// `out`.
pub fn compress(data: &[f32], dims: Dims3, tol: f32, out: &mut Vec<u8>) {
    compress_with(data, dims, tol, out, simd::level());
}

/// [`compress`] with an explicit dispatch level (tests pin the level
/// without touching the process-wide setting; the stream is identical
/// at every level).
fn compress_with(data: &[f32], dims: Dims3, tol: f32, out: &mut Vec<u8>, lvl: SimdLevel) {
    assert_eq!(data.len(), dims.len());
    assert!(
        dims.nx % CELL == 0 && dims.ny % CELL == 0 && dims.nz % CELL == 0,
        "zfp dims must be multiples of 4"
    );
    out.push(1u8); // version
    out.extend_from_slice(&tol.to_le_bytes());
    out.extend_from_slice(&(dims.nx as u16).to_le_bytes());
    out.extend_from_slice(&(dims.ny as u16).to_le_bytes());
    out.extend_from_slice(&(dims.nz as u16).to_le_bytes());
    let perm = sequency_perm();
    let mut w = BitWriter::with_capacity(data.len());
    let mut cell = [0f32; CELL_VOL];
    let mut q = [0i64; CELL_VOL];
    let mut nb = [0u64; CELL_VOL];
    for cz in 0..dims.nz / CELL {
        for cy in 0..dims.ny / CELL {
            for cx in 0..dims.nx / CELL {
                // gather cell
                for z in 0..CELL {
                    for y in 0..CELL {
                        let src = ((cz * CELL + z) * dims.ny + cy * CELL + y) * dims.nx + cx * CELL;
                        let dst = (z * CELL + y) * CELL;
                        cell[dst..dst + CELL].copy_from_slice(&data[src..src + CELL]);
                    }
                }
                let maxabs = cell.iter().fold(0f32, |m, v| m.max(v.abs()));
                if maxabs == 0.0 {
                    w.write_bit(false);
                    continue;
                }
                w.write_bit(true);
                let e_max = maxabs.log2().floor() as i32;
                w.write_bits((e_max + 128) as u64, 8);
                // block floating point: scale into [-2^FRAC, 2^FRAC]
                let scale = (FRAC - e_max) as f32;
                let s = scale.exp2();
                for i in 0..CELL_VOL {
                    q[i] = (cell[i] * s).round() as i64;
                }
                fwd_lift_cell(&mut q, lvl);
                negabinary_cell(&q, &perm, &mut nb, lvl);
                transpose64(&mut nb);
                encode_planes(&mut w, &nb, plane_min(tol, e_max));
            }
        }
    }
    out.extend_from_slice(&w.finish());
}

/// Decompress a zfp stream into a fresh array; returns (data, dims).
pub fn decompress(input: &[u8]) -> Result<(Vec<f32>, Dims3), String> {
    let mut out = Vec::new();
    let dims = decompress_into(input, &mut out)?;
    Ok((out, dims))
}

/// Decompress into a caller-owned buffer (cleared and resized), so
/// per-block decode loops reuse one allocation. Returns the dims.
pub fn decompress_into(input: &[u8], out: &mut Vec<f32>) -> Result<Dims3, String> {
    decompress_into_with(input, out, simd::level())
}

fn decompress_into_with(input: &[u8], out: &mut Vec<f32>, lvl: SimdLevel) -> Result<Dims3, String> {
    if input.len() < 11 {
        return Err("zfp stream too short".into());
    }
    if input[0] != 1 {
        return Err(format!("zfp version {}", input[0]));
    }
    let tol = f32::from_le_bytes(input[1..5].try_into().unwrap());
    let nx = u16::from_le_bytes(input[5..7].try_into().unwrap()) as usize;
    let ny = u16::from_le_bytes(input[7..9].try_into().unwrap()) as usize;
    let nz = u16::from_le_bytes(input[9..11].try_into().unwrap()) as usize;
    let dims = Dims3 { nx, ny, nz };
    if nx % CELL != 0 || ny % CELL != 0 || nz % CELL != 0 || dims.len() == 0 {
        return Err(format!("bad zfp dims {nx}x{ny}x{nz}"));
    }
    let perm = sequency_perm();
    // all-zero cells are skipped by the coder, so the buffer must be
    // zero-filled even when warm
    out.clear();
    out.resize(dims.len(), 0.0);
    let mut r = BitReader::new(&input[11..]);
    let mut q = [0i64; CELL_VOL];
    let mut nb = [0u64; CELL_VOL];
    for cz in 0..nz / CELL {
        for cy in 0..ny / CELL {
            for cx in 0..nx / CELL {
                if !r.read_bit() {
                    continue; // all-zero cell
                }
                let e_max = r.read_bits(8) as i32 - 128;
                decode_planes(&mut r, &mut nb, plane_min(tol, e_max));
                unnegabinary_cell(&nb, &perm, &mut q, lvl);
                inv_lift_cell(&mut q, lvl);
                let s = ((e_max - FRAC) as f32).exp2();
                for z in 0..CELL {
                    for y in 0..CELL {
                        let dst = ((cz * CELL + z) * ny + cy * CELL + y) * nx + cx * CELL;
                        let src = (z * CELL + y) * CELL;
                        for x in 0..CELL {
                            out[dst + x] = q[src + x] as f32 * s;
                        }
                    }
                }
            }
        }
    }
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::{gen_smooth_field, prop_cases};

    #[test]
    fn zero_field_is_tiny() {
        let dims = Dims3::cube(32);
        let data = vec![0f32; dims.len()];
        let mut out = Vec::new();
        compress(&data, dims, 1e-3, &mut out);
        // 512 cells, 1 bit each + header
        assert!(out.len() < 100, "len {}", out.len());
        let (back, d2) = decompress(&out).unwrap();
        assert_eq!(d2, dims);
        assert_eq!(back, data);
    }

    #[test]
    fn error_bounded_on_random_fields() {
        prop_cases(0x2F9, 8, |rng, _| {
            let dims = Dims3::cube(16);
            let mut data = vec![0f32; dims.len()];
            rng.fill_f32(&mut data, -100.0, 100.0);
            for tol in [1e-1f32, 1e-2, 1e-3] {
                let mut out = Vec::new();
                compress(&data, dims, tol, &mut out);
                let (back, _) = decompress(&out).unwrap();
                let maxerr = data
                    .iter()
                    .zip(&back)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(maxerr <= tol, "tol {tol} maxerr {maxerr}");
            }
        });
    }

    #[test]
    fn near_lossless_at_zero_tolerance() {
        let mut rng = Pcg32::new(7);
        let dims = Dims3::cube(8);
        let mut data = vec![0f32; dims.len()];
        rng.fill_f32(&mut data, -1.0, 1.0);
        let mut out = Vec::new();
        compress(&data, dims, 0.0, &mut out);
        let (back, _) = decompress(&out).unwrap();
        let maxerr = data
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // 26-bit fixed point: ~2^-25 relative to cell max
        assert!(maxerr < 1e-6, "maxerr {maxerr}");
    }

    #[test]
    fn smooth_fields_compress_much_better_than_raw() {
        let mut rng = Pcg32::new(8);
        let n = 32;
        let data = gen_smooth_field(&mut rng, n);
        let mut out = Vec::new();
        compress(&data, Dims3::cube(n), 1e-3 * 200.0, &mut out);
        let cr = (data.len() * 4) as f64 / out.len() as f64;
        assert!(cr > 4.0, "cr {cr}");
    }

    #[test]
    fn higher_tolerance_higher_ratio() {
        let mut rng = Pcg32::new(9);
        let n = 16;
        let data = gen_smooth_field(&mut rng, n);
        let sizes: Vec<usize> = [1e-4f32, 1e-2, 1e0]
            .iter()
            .map(|&tol| {
                let mut out = Vec::new();
                compress(&data, Dims3::cube(n), tol, &mut out);
                out.len()
            })
            .collect();
        assert!(sizes[0] > sizes[1] && sizes[1] > sizes[2], "{sizes:?}");
    }

    #[test]
    fn rejects_bad_dims() {
        let data = vec![0f32; 5 * 4 * 4];
        let mut out = Vec::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            compress(&data, Dims3 { nx: 5, ny: 4, nz: 4 }, 0.0, &mut out)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        assert!(decompress(&[1, 0, 0]).is_err());
    }

    #[test]
    fn cell_kernels_match_scalar_oracle() {
        let lvl = simd::detect();
        if lvl == SimdLevel::Scalar {
            return; // nothing to compare on this host
        }
        let perm = sequency_perm();
        prop_cases(0x5AFE, 40, |rng, _| {
            let mut q = [0i64; CELL_VOL];
            for v in q.iter_mut() {
                // bounded to +-2^31 so the transform's guarded growth
                // cannot overflow the debug-mode scalar lifts
                *v = rng.next_u32() as i64 - (1i64 << 31);
            }
            let (mut a, mut b) = (q, q);
            fwd_lift_cell(&mut a, SimdLevel::Scalar);
            fwd_lift_cell(&mut b, lvl);
            assert_eq!(a, b, "fwd_lift_cell diverges under {lvl:?}");
            let (mut na, mut nv) = ([0u64; CELL_VOL], [0u64; CELL_VOL]);
            negabinary_cell(&a, &perm, &mut na, SimdLevel::Scalar);
            negabinary_cell(&b, &perm, &mut nv, lvl);
            assert_eq!(na, nv, "negabinary_cell diverges under {lvl:?}");
            let (mut qa, mut qb) = ([0i64; CELL_VOL], [0i64; CELL_VOL]);
            unnegabinary_cell(&na, &perm, &mut qa, SimdLevel::Scalar);
            unnegabinary_cell(&nv, &perm, &mut qb, lvl);
            assert_eq!(qa, qb, "unnegabinary_cell diverges under {lvl:?}");
            inv_lift_cell(&mut qa, SimdLevel::Scalar);
            inv_lift_cell(&mut qb, lvl);
            assert_eq!(qa, qb, "inv_lift_cell diverges under {lvl:?}");
        });
    }

    #[test]
    fn streams_identical_across_dispatch() {
        // whole-codec bit-identity: scalar and vector paths must produce
        // the same bytes and decode each other's streams to the same bits
        let lvl = simd::detect();
        prop_cases(0xD15A, 6, |rng, _| {
            let dims = Dims3::cube(16);
            let mut data = vec![0f32; dims.len()];
            rng.fill_f32(&mut data, -50.0, 50.0);
            for v in data.iter_mut().take(CELL_VOL) {
                *v = 0.0; // keep an all-zero cell in the mix
            }
            let (mut a, mut b) = (Vec::new(), Vec::new());
            compress_with(&data, dims, 1e-3, &mut a, SimdLevel::Scalar);
            compress_with(&data, dims, 1e-3, &mut b, lvl);
            assert_eq!(a, b, "stream differs between Scalar and {lvl:?}");
            let (mut da, mut db) = (Vec::new(), Vec::new());
            decompress_into_with(&a, &mut da, lvl).unwrap();
            decompress_into_with(&b, &mut db, SimdLevel::Scalar).unwrap();
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&da), bits(&db), "decode differs across dispatch");
        });
    }

    #[test]
    fn decompress_into_reuses_dirty_buffers() {
        // the per-worker buffer arrives dirty and differently sized; the
        // into-variant must still match the allocating result exactly,
        // including the zero cells the coder skips
        let mut rng = Pcg32::new(10);
        let dims = Dims3::cube(8);
        let mut data = vec![0f32; dims.len()];
        rng.fill_f32(&mut data, -2.0, 2.0);
        for v in data.iter_mut().take(64) {
            *v = 0.0; // force an all-zero cell
        }
        let mut comp = Vec::new();
        compress(&data, dims, 1e-3, &mut comp);
        let (reference, _) = decompress(&comp).unwrap();
        let mut buf = vec![9.9f32; 7]; // dirty + wrong size
        for _ in 0..3 {
            let d = decompress_into(&comp, &mut buf).unwrap();
            assert_eq!(d, dims);
            assert_eq!(buf, reference);
        }
    }
}
