//! The real PJRT CPU engine (`--cfg pjrt_runtime` builds only): loads the
//! AOT HLO-text artifacts and executes the batched Pallas wavelet kernels
//! through the external `xla` crate (add it to rust/Cargo.toml when
//! enabling this cfg; the offline image deliberately omits it).
use super::{ARTIFACT_BS, ARTIFACT_BATCHES};
use crate::anyhow;
use crate::pipeline::WaveletEngine;
use crate::util::error::{Context, Result};
use crate::wavelet::WaveletKind;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct VariantKey {
    kind: u8,
    inverse: bool,
    batch: usize,
}

struct Inner {
    client: xla::PjRtClient,
    // lazily compiled executables
    exes: HashMap<VariantKey, xla::PjRtLoadedExecutable>,
}

// SAFETY: the xla crate wraps PJRT handles in `Rc`, making them !Send/!Sync
// even though the underlying PJRT C API is thread-safe. We never let the
// Rc refcounts race: ALL access to `Inner` (client, executables, literals)
// happens under the single `Mutex` below, so at most one thread touches
// any xla object at a time.
unsafe impl Send for Inner {}
unsafe impl Sync for Inner {}

/// PJRT CPU engine executing the AOT-lowered Pallas wavelet kernels.
pub struct PjrtEngine {
    dir: PathBuf,
    inner: Mutex<Inner>,
}

impl PjrtEngine {
    /// Create a CPU PJRT engine over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(anyhow!(
                "artifacts directory {} missing — run `make artifacts`",
                dir.display()
            ));
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!(e))?;
        Ok(Self { dir, inner: Mutex::new(Inner { client, exes: HashMap::new() }) })
    }

    pub fn platform(&self) -> String {
        self.inner.lock().unwrap().client.platform_name()
    }

    fn artifact_path(&self, key: VariantKey) -> PathBuf {
        let kind = WaveletKind::from_id(key.kind).unwrap();
        let dir_tag = if key.inverse { "inv" } else { "fwd" };
        self.dir.join(format!(
            "wavelet_{dir_tag}_{}_b{ARTIFACT_BS}_n{}.hlo.txt",
            kind.artifact_tag(),
            key.batch
        ))
    }

    fn run_variant(&self, key: VariantKey, io: &mut [f32]) -> Result<()> {
        let vol = ARTIFACT_BS * ARTIFACT_BS * ARTIFACT_BS;
        debug_assert_eq!(io.len(), key.batch * vol);
        let mut inner = self.inner.lock().unwrap();
        if !inner.exes.contains_key(&key) {
            let path = self.artifact_path(key);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner.client.compile(&comp).map_err(|e| anyhow!(e))?;
            inner.exes.insert(key, exe);
        }
        let exe = inner.exes.get(&key).unwrap();
        let b = ARTIFACT_BS as i64;
        let x = xla::Literal::vec1(io)
            .reshape(&[key.batch as i64, b, b, b])
            .map_err(|e| anyhow!(e))?;
        let result = exe.execute::<xla::Literal>(&[x]).map_err(|e| anyhow!(e))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!(e))?;
        let out = result.to_tuple1().map_err(|e| anyhow!(e))?;
        let values = out.to_vec::<f32>().map_err(|e| anyhow!(e))?;
        if values.len() != io.len() {
            return Err(anyhow!("pjrt output length {} != {}", values.len(), io.len()));
        }
        io.copy_from_slice(&values);
        Ok(())
    }

    /// Transform a batch of contiguous 32³ blocks through the compiled
    /// executables (16-wide chunks + single-block remainder).
    pub fn transform(&self, kind: WaveletKind, inverse: bool, blocks: &mut [f32]) -> Result<()> {
        let vol = ARTIFACT_BS * ARTIFACT_BS * ARTIFACT_BS;
        if blocks.len() % vol != 0 {
            return Err(anyhow!("batch length {} not a multiple of 32^3", blocks.len()));
        }
        let n = blocks.len() / vol;
        let wide = ARTIFACT_BATCHES[0];
        let mut i = 0usize;
        while i < n {
            let take = if n - i >= wide { wide } else { 1 };
            let key = VariantKey { kind: kind.id(), inverse, batch: take };
            self.run_variant(key, &mut blocks[i * vol..(i + take) * vol])?;
            i += take;
        }
        Ok(())
    }
}

impl WaveletEngine for PjrtEngine {
    fn forward_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
        // artifacts are compiled for bs=32 / full levels; anything else
        // falls back to the native engine (identical spec)
        if bs != ARTIFACT_BS || levels != crate::wavelet::max_levels(bs) {
            crate::wavelet::transform3d::forward_batch(kind, blocks, bs, levels);
            return;
        }
        if let Err(e) = self.transform(kind, false, blocks) {
            panic!("pjrt forward failed: {e}");
        }
    }

    fn inverse_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
        if bs != ARTIFACT_BS || levels != crate::wavelet::max_levels(bs) {
            crate::wavelet::transform3d::inverse_batch(kind, blocks, bs, levels);
            return;
        }
        if let Err(e) = self.transform(kind, true, blocks) {
            panic!("pjrt inverse failed: {e}");
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
