//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text — see the aot docstring for why text, not protos) and executes
//! the batched Pallas wavelet transform from the Rust hot path.
//!
//! Python never runs at request time: `make artifacts` is the only point
//! where jax executes; afterwards the `czb` binary is self-contained.
//!
//! The real engine needs the external `xla` crate, which the offline image
//! does not ship. It is therefore gated behind `--cfg pjrt_runtime` (see
//! `rust/Cargo.toml`); the default build exports a stub [`PjrtEngine`]
//! whose constructor fails with an explanatory message and whose
//! [`WaveletEngine`] impl falls back to the native transform. Everything
//! artifact-dependent (tests, benches, examples) already probes
//! availability and skips gracefully, so a clean checkout stays green.
use std::path::PathBuf;

/// Block size the artifacts are compiled for.
pub const ARTIFACT_BS: usize = 32;
/// Batch sizes available as compiled executables.
pub const ARTIFACT_BATCHES: [usize; 2] = [16, 1];

/// Default artifacts directory: `$CUBISMZ_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("CUBISMZ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(pjrt_runtime)]
mod pjrt_xla;
#[cfg(pjrt_runtime)]
pub use pjrt_xla::PjrtEngine;

#[cfg(not(pjrt_runtime))]
mod pjrt_stub;
#[cfg(not(pjrt_runtime))]
pub use pjrt_stub::PjrtEngine;
