//! Stub PJRT engine for builds without `--cfg pjrt_runtime` (the default
//! on the offline image, which has no `xla` crate). Construction always
//! fails — callers that probe with `.ok()`/`match` fall back to the
//! native engine — and a directly-constructed stub behaves as the native
//! engine so nothing can panic.
use crate::anyhow;
use crate::pipeline::WaveletEngine;
use crate::util::error::Result;
use crate::wavelet::WaveletKind;
use std::path::Path;

/// Placeholder for the xla/PJRT-backed engine (see `runtime/pjrt_xla.rs`).
pub struct PjrtEngine;

impl PjrtEngine {
    /// Always fails in this build: the PJRT runtime is compiled out.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Err(anyhow!(
            "PJRT runtime not compiled into this build (artifacts dir {}); \
             rebuild with RUSTFLAGS=\"--cfg pjrt_runtime\" and the `xla` crate \
             added to rust/Cargo.toml",
            artifacts_dir.as_ref().display()
        ))
    }

    pub fn platform(&self) -> String {
        "pjrt-unavailable".to_string()
    }
}

impl WaveletEngine for PjrtEngine {
    fn forward_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
        crate::wavelet::transform3d::forward_batch(kind, blocks, bs, levels);
    }

    fn inverse_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
        crate::wavelet::transform3d::inverse_batch(kind, blocks, bs, levels);
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructor_reports_unavailable() {
        let e = PjrtEngine::new("artifacts").err().expect("stub must fail");
        assert!(e.to_string().contains("pjrt_runtime"), "{e}");
    }

    #[test]
    fn stub_engine_matches_native() {
        use crate::pipeline::NativeEngine;
        use crate::util::prng::Pcg32;
        use crate::wavelet::max_levels;
        let bs = 8;
        let mut rng = Pcg32::new(3);
        let mut a = vec![0f32; bs * bs * bs];
        rng.fill_f32(&mut a, -1.0, 1.0);
        let mut b = a.clone();
        PjrtEngine.forward_batch(WaveletKind::Avg3, &mut a, bs, max_levels(bs));
        NativeEngine.forward_batch(WaveletKind::Avg3, &mut b, bs, max_levels(bs));
        assert_eq!(a, b);
    }
}
