//! Canonical, length-limited Huffman coding over LSB-first bit I/O
//! (codes are bit-reversed like DEFLATE so the decoder can peek LSB-first).
//! Shared by `czlib` and the SZ-like quantization-code entropy stage.
use crate::util::{BitReader, BitWriter};

/// Maximum code length; also the decode-table width.
pub const MAX_BITS: usize = 12;

/// Compute length-limited canonical code lengths from symbol frequencies.
/// Zero-frequency symbols get length 0 (no code). Uses the zlib trick of
/// halving frequencies until the tree fits the length limit.
pub fn code_lengths(freqs: &[u32]) -> Vec<u8> {
    let n = freqs.len();
    let mut f: Vec<u64> = freqs.iter().map(|&x| x as u64).collect();
    loop {
        let lens = huffman_lengths(&f);
        let maxlen = lens.iter().cloned().max().unwrap_or(0);
        if (maxlen as usize) <= MAX_BITS {
            return lens;
        }
        // flatten the distribution and retry
        for v in f.iter_mut() {
            if *v > 0 {
                *v = (*v + 1) / 2;
            }
        }
        let _ = n;
    }
}

/// Plain (unlimited) Huffman code lengths via pairwise merge.
fn huffman_lengths(freqs: &[u64]) -> Vec<u8> {
    #[derive(Clone)]
    struct Node {
        freq: u64,
        left: i32,
        right: i32,
        sym: i32,
    }
    let mut nodes: Vec<Node> = Vec::new();
    let mut heap: Vec<usize> = Vec::new(); // indices into nodes
    for (i, &f) in freqs.iter().enumerate() {
        if f > 0 {
            nodes.push(Node { freq: f, left: -1, right: -1, sym: i as i32 });
            heap.push(nodes.len() - 1);
        }
    }
    let mut lens = vec![0u8; freqs.len()];
    match heap.len() {
        0 => return lens,
        1 => {
            lens[nodes[heap[0]].sym as usize] = 1;
            return lens;
        }
        _ => {}
    }
    // simple O(n log n) via sort-based merging (n <= a few hundred symbols)
    heap.sort_by_key(|&i| std::cmp::Reverse(nodes[i].freq));
    while heap.len() > 1 {
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        nodes.push(Node { freq: nodes[a].freq + nodes[b].freq, left: a as i32, right: b as i32, sym: -1 });
        let ni = nodes.len() - 1;
        // insertion keeping descending order
        let pos = heap
            .binary_search_by(|&i| nodes[i].freq.cmp(&nodes[ni].freq).reverse().then(std::cmp::Ordering::Less))
            .unwrap_or_else(|p| p);
        heap.insert(pos, ni);
    }
    // walk depths iteratively
    let root = heap[0];
    let mut stack = vec![(root, 0u8)];
    while let Some((i, depth)) = stack.pop() {
        let node = nodes[i].clone();
        if node.sym >= 0 {
            lens[node.sym as usize] = depth.max(1);
        } else {
            stack.push((node.left as usize, depth + 1));
            stack.push((node.right as usize, depth + 1));
        }
    }
    lens
}

/// Canonical codes (LSB-first/bit-reversed) from code lengths.
pub fn canonical_codes(lens: &[u8]) -> Vec<u16> {
    let mut bl_count = [0u32; MAX_BITS + 1];
    for &l in lens {
        bl_count[l as usize] += 1;
    }
    bl_count[0] = 0;
    let mut next_code = [0u32; MAX_BITS + 1];
    let mut code = 0u32;
    for bits in 1..=MAX_BITS {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    lens.iter()
        .map(|&l| {
            if l == 0 {
                return 0;
            }
            let c = next_code[l as usize];
            next_code[l as usize] += 1;
            reverse_bits(c as u16, l as u32)
        })
        .collect()
}

#[inline]
fn reverse_bits(v: u16, n: u32) -> u16 {
    v.reverse_bits() >> (16 - n)
}

/// Encoder: symbol -> (reversed code, length).
pub struct Encoder {
    codes: Vec<u16>,
    lens: Vec<u8>,
}

impl Encoder {
    pub fn from_lengths(lens: &[u8]) -> Self {
        Self { codes: canonical_codes(lens), lens: lens.to_vec() }
    }

    #[inline]
    pub fn write(&self, w: &mut BitWriter, sym: usize) {
        debug_assert!(self.lens[sym] > 0, "symbol {sym} has no code");
        w.write_bits(self.codes[sym] as u64, self.lens[sym] as u32);
    }

    pub fn lens(&self) -> &[u8] {
        &self.lens
    }
}

/// Table-driven decoder: one flat table of 2^MAX_BITS entries mapping the
/// next MAX_BITS peeked bits to (symbol, length).
pub struct Decoder {
    table: Vec<u16>, // (sym << 4) | len
}

impl Decoder {
    pub fn from_lengths(lens: &[u8]) -> Result<Self, String> {
        let codes = canonical_codes(lens);
        let mut table = vec![0u16; 1 << MAX_BITS];
        let mut used = 0u64;
        for (sym, (&len, &code)) in lens.iter().zip(&codes).enumerate() {
            if len == 0 {
                continue;
            }
            let len = len as usize;
            if len > MAX_BITS {
                return Err(format!("code length {len} > {MAX_BITS}"));
            }
            used += 1u64 << (MAX_BITS - len);
            // fill all entries whose low `len` bits equal `code`
            let step = 1usize << len;
            let mut idx = code as usize;
            while idx < (1 << MAX_BITS) {
                table[idx] = ((sym as u16) << 4) | len as u16;
                idx += step;
            }
        }
        if used > (1u64 << MAX_BITS) {
            return Err("over-subscribed code".into());
        }
        Ok(Self { table })
    }

    /// Decode one symbol.
    #[inline]
    pub fn read(&self, r: &mut BitReader) -> Result<usize, String> {
        let peek = r.peek16() as usize & ((1 << MAX_BITS) - 1);
        let e = self.table[peek];
        let len = (e & 0xf) as u32;
        if len == 0 {
            return Err("invalid huffman code".into());
        }
        r.consume(len);
        Ok((e >> 4) as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;

    fn roundtrip(freq_gen: impl Fn(&mut Pcg32, usize) -> u32, seed: u64) {
        prop_cases(seed, 10, |rng, _| {
            let nsyms = 2 + rng.below(300) as usize;
            let freqs: Vec<u32> = (0..nsyms).map(|i| freq_gen(rng, i)).collect();
            let total: u32 = freqs.iter().sum();
            if total == 0 {
                return;
            }
            let lens = code_lengths(&freqs);
            // Kraft inequality holds
            let kraft: f64 = lens
                .iter()
                .filter(|&&l| l > 0)
                .map(|&l| 2f64.powi(-(l as i32)))
                .sum();
            assert!(kraft <= 1.0 + 1e-9, "kraft {kraft}");
            let enc = Encoder::from_lengths(&lens);
            let dec = Decoder::from_lengths(&lens).unwrap();
            // encode a random message drawn from the alphabet
            let msg: Vec<usize> = (0..2000)
                .map(|_| loop {
                    let s = rng.below(nsyms as u32) as usize;
                    if freqs[s] > 0 {
                        break s;
                    }
                })
                .collect();
            let mut w = crate::util::BitWriter::new();
            for &s in &msg {
                enc.write(&mut w, s);
            }
            let bytes = w.finish();
            let mut r = crate::util::BitReader::new(&bytes);
            for &s in &msg {
                assert_eq!(dec.read(&mut r).unwrap(), s);
            }
        });
    }

    #[test]
    fn roundtrip_uniform() {
        roundtrip(|rng, _| 1 + rng.below(100), 0x11);
    }

    #[test]
    fn roundtrip_skewed() {
        // geometric-ish distribution would exceed MAX_BITS without limiting
        roundtrip(|rng, i| if i == 0 { 1 << 20 } else { 1 + rng.below(3) }, 0x22);
    }

    #[test]
    fn roundtrip_sparse() {
        roundtrip(|rng, _| if rng.below(4) == 0 { 1 + rng.below(50) } else { 0 }, 0x33);
    }

    #[test]
    fn single_symbol_alphabet() {
        let freqs = vec![0, 5, 0];
        let lens = code_lengths(&freqs);
        assert_eq!(lens[1], 1);
        let enc = Encoder::from_lengths(&lens);
        let dec = Decoder::from_lengths(&lens).unwrap();
        let mut w = crate::util::BitWriter::new();
        for _ in 0..10 {
            enc.write(&mut w, 1);
        }
        let bytes = w.finish();
        let mut r = crate::util::BitReader::new(&bytes);
        for _ in 0..10 {
            assert_eq!(dec.read(&mut r).unwrap(), 1);
        }
    }

    #[test]
    fn lengths_respect_limit() {
        // pathological fibonacci-like frequencies force deep trees
        let mut freqs = vec![0u32; 40];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freqs.iter_mut() {
            *f = a as u32;
            let c = (a + b).min(u32::MAX as u64);
            a = b;
            b = c;
        }
        let lens = code_lengths(&freqs);
        assert!(lens.iter().all(|&l| (l as usize) <= MAX_BITS));
        // and decoding still works
        assert!(Decoder::from_lengths(&lens).is_ok());
    }

    #[test]
    fn skewed_codes_are_shorter_for_frequent_symbols() {
        let freqs = vec![1000, 10, 10, 10];
        let lens = code_lengths(&freqs);
        assert!(lens[0] < lens[1]);
    }
}
