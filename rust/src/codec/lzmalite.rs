//! `lzmalite`: LZ77 (deep, 1 MiB window) + adaptive binary range coder
//! with order-1 literal contexts — the LZMA family's design point: best
//! compression ratio in the suite, slowest (paper §2.3: "LZMA provides
//! slightly better compression than ZLIB ... but it is considerably
//! slower").
//!
//! Model:
//! * `is_match` bit, context = previous-token kind
//! * literals: 8-bit bit-tree, 256 contexts keyed by the previous byte
//! * match length: 8-bit bit-tree (len - 3, capped at 258)
//! * match distance: 6-bit slot bit-tree + direct (uncoded) extra bits
use super::lz77::{MatchFinder, Params, Token};

const PROB_BITS: u32 = 11;
const PROB_ONE: u16 = 1 << PROB_BITS;
const MOVE_BITS: u32 = 5;
const TOP: u32 = 1 << 24;

/// Adaptive bit probability (11-bit, LZMA-style shift update).
#[derive(Clone, Copy)]
struct Prob(u16);

impl Prob {
    fn new() -> Self {
        Prob(PROB_ONE / 2)
    }
}

struct RangeEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl RangeEncoder {
    fn new() -> Self {
        Self { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: Vec::new() }
    }

    fn shift_low(&mut self) {
        if self.low < 0xff00_0000 || self.low > 0xffff_ffff {
            let carry = (self.low >> 32) as u8;
            let mut c = self.cache;
            loop {
                self.out.push(c.wrapping_add(carry));
                c = 0xff;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xffff_ffff;
    }

    fn encode_bit(&mut self, p: &mut Prob, bit: u32) {
        let bound = (self.range >> PROB_BITS) * p.0 as u32;
        if bit == 0 {
            self.range = bound;
            p.0 += (PROB_ONE - p.0) >> MOVE_BITS;
        } else {
            self.low += bound as u64;
            self.range -= bound;
            p.0 -= p.0 >> MOVE_BITS;
        }
        while self.range < TOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    /// Direct (uniform) bits, MSB first.
    fn encode_direct(&mut self, v: u32, nbits: u32) {
        for i in (0..nbits).rev() {
            self.range >>= 1;
            let bit = (v >> i) & 1;
            if bit != 0 {
                self.low += self.range as u64;
            }
            while self.range < TOP {
                self.range <<= 8;
                self.shift_low();
            }
        }
    }

    fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

struct RangeDecoder<'a> {
    code: u32,
    range: u32,
    input: &'a [u8],
    pos: usize,
}

impl<'a> RangeDecoder<'a> {
    fn new(input: &'a [u8]) -> Result<Self, String> {
        if input.is_empty() {
            return Err("empty range stream".into());
        }
        let mut d = Self { code: 0, range: u32::MAX, input, pos: 1 };
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte();
        }
        Ok(d)
    }

    #[inline]
    fn next_byte(&mut self) -> u32 {
        let b = self.input.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b as u32
    }

    fn decode_bit(&mut self, p: &mut Prob) -> u32 {
        let bound = (self.range >> PROB_BITS) * p.0 as u32;
        let bit;
        if self.code < bound {
            self.range = bound;
            p.0 += (PROB_ONE - p.0) >> MOVE_BITS;
            bit = 0;
        } else {
            self.code -= bound;
            self.range -= bound;
            p.0 -= p.0 >> MOVE_BITS;
            bit = 1;
        }
        while self.range < TOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte();
        }
        bit
    }

    fn decode_direct(&mut self, nbits: u32) -> u32 {
        let mut v = 0u32;
        for _ in 0..nbits {
            self.range >>= 1;
            let bit = if self.code >= self.range {
                self.code -= self.range;
                1
            } else {
                0
            };
            v = (v << 1) | bit;
            while self.range < TOP {
                self.range <<= 8;
                self.code = (self.code << 8) | self.next_byte();
            }
        }
        v
    }
}

/// 2^n-leaf bit tree of adaptive probabilities (MSB-first traversal).
struct BitTree {
    probs: Vec<Prob>,
    nbits: u32,
}

impl BitTree {
    fn new(nbits: u32) -> Self {
        Self { probs: vec![Prob::new(); 1 << nbits], nbits }
    }

    fn encode(&mut self, enc: &mut RangeEncoder, v: u32) {
        let mut node = 1usize;
        for i in (0..self.nbits).rev() {
            let bit = (v >> i) & 1;
            enc.encode_bit(&mut self.probs[node], bit);
            node = (node << 1) | bit as usize;
        }
    }

    fn decode(&mut self, dec: &mut RangeDecoder) -> u32 {
        let mut node = 1usize;
        for _ in 0..self.nbits {
            let bit = dec.decode_bit(&mut self.probs[node]);
            node = (node << 1) | bit as usize;
        }
        (node as u32) - (1 << self.nbits)
    }
}

struct Model {
    is_match: [Prob; 2],
    literals: Vec<BitTree>, // 256 contexts x 8-bit trees
    len_tree: BitTree,      // len - MIN (0..255)
    slot_tree: BitTree,     // 6-bit distance slot
}

impl Model {
    fn new() -> Self {
        Self {
            is_match: [Prob::new(); 2],
            literals: (0..256).map(|_| BitTree::new(8)).collect(),
            len_tree: BitTree::new(8),
            slot_tree: BitTree::new(6),
        }
    }
}

#[inline]
fn dist_slot(dist: u32) -> (u32, u32, u32) {
    // slot for dist >= 1: slots 0..3 are exact 1..4, then (extra bits)
    if dist <= 4 {
        (dist - 1, 0, 0)
    } else {
        let log = 31 - (dist - 1).leading_zeros();
        let extra_bits = log - 1;
        let top_bit = 1u32 << log;
        let second = ((dist - 1) >> (log - 1)) & 1;
        let slot = 2 + 2 * log + second - 2; // 4,5 for log=2, ...
        let base = top_bit + second * (1 << (log - 1)) + 1;
        (slot, extra_bits, dist - base)
    }
}

#[inline]
fn slot_base(slot: u32) -> (u32, u32) {
    if slot < 4 {
        (slot + 1, 0)
    } else {
        let log = (slot - 2) / 2 + 1;
        let second = (slot - 2) % 2;
        let extra_bits = log - 1;
        let base = (1u32 << log) + second * (1 << (log - 1)) + 1;
        (base, extra_bits)
    }
}

/// Compress `input`, appending to `out`: `[u32 raw_len][range stream]`.
pub fn compress(input: &[u8], out: &mut Vec<u8>) {
    out.extend_from_slice(&(input.len() as u32).to_le_bytes());
    if input.is_empty() {
        return;
    }
    let mut mf = MatchFinder::new(Params::deep());
    let mut tokens = Vec::with_capacity(input.len() / 4 + 8);
    mf.tokenize(input, |t| tokens.push(t));

    let mut model = Model::new();
    let mut enc = RangeEncoder::new();
    let mut prev_byte = 0u8;
    let mut pos = 0usize;
    for t in tokens {
        match t {
            Token::Literal(b) => {
                enc.encode_bit(&mut model.is_match[0], 0);
                model.literals[prev_byte as usize].encode(&mut enc, b as u32);
                prev_byte = b;
                pos += 1;
            }
            Token::Match { len, dist } => {
                enc.encode_bit(&mut model.is_match[0], 1);
                model.len_tree.encode(&mut enc, len - 3);
                let (slot, ebits, extra) = dist_slot(dist);
                model.slot_tree.encode(&mut enc, slot);
                if ebits > 0 {
                    enc.encode_direct(extra, ebits);
                }
                pos += len as usize;
                prev_byte = input[pos - 1];
            }
        }
    }
    out.extend_from_slice(&enc.finish());
}

/// Decompress a full lzmalite stream, appending to `out`.
pub fn decompress(input: &[u8], out: &mut Vec<u8>) -> Result<(), String> {
    if input.len() < 4 {
        return Err("missing header".into());
    }
    let raw_len = u32::from_le_bytes(input[0..4].try_into().unwrap()) as usize;
    if raw_len == 0 {
        return Ok(());
    }
    let mut dec = RangeDecoder::new(&input[4..])?;
    let mut model = Model::new();
    let out_start = out.len();
    out.reserve(raw_len);
    let mut prev_byte = 0u8;
    while out.len() - out_start < raw_len {
        if dec.decode_bit(&mut model.is_match[0]) == 0 {
            let b = model.literals[prev_byte as usize].decode(&mut dec) as u8;
            out.push(b);
            prev_byte = b;
        } else {
            let len = model.len_tree.decode(&mut dec) as usize + 3;
            let slot = model.slot_tree.decode(&mut dec);
            let (base, ebits) = slot_base(slot);
            let dist = (base + if ebits > 0 { dec.decode_direct(ebits) } else { 0 }) as usize;
            if dist > out.len() - out_start {
                return Err(format!("distance {dist} out of range"));
            }
            if out.len() - out_start + len > raw_len {
                return Err("match overruns output".into());
            }
            let start = out.len() - dist;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
            prev_byte = *out.last().unwrap();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;

    fn roundtrip(data: &[u8]) -> usize {
        let mut comp = Vec::new();
        compress(data, &mut comp);
        let mut back = Vec::new();
        decompress(&comp, &mut back).unwrap();
        assert_eq!(back, data, "len {}", data.len());
        comp.len()
    }

    #[test]
    fn slot_base_inverts_dist_slot() {
        for dist in 1u32..100_000 {
            let (slot, ebits, extra) = dist_slot(dist);
            let (base, ebits2) = slot_base(slot);
            assert_eq!(ebits, ebits2, "dist {dist}");
            assert_eq!(base + extra, dist, "dist {dist} slot {slot}");
            assert!(extra < (1 << ebits) || ebits == 0, "dist {dist}");
        }
        // and the full window
        for dist in [1u32 << 18, 1 << 19, (1 << 20) - 1, 1 << 20] {
            let (slot, ebits, extra) = dist_slot(dist);
            let (base, _) = slot_base(slot);
            assert_eq!(base + extra, dist);
            assert!(slot < 64, "slot {slot} must fit the 6-bit tree");
            let _ = ebits;
        }
    }

    #[test]
    fn basic_cases() {
        roundtrip(b"");
        roundtrip(b"x");
        roundtrip(b"abcabcabcabcabcabc");
        roundtrip(&vec![9u8; 50_000]);
    }

    #[test]
    fn text_compresses_well() {
        let data: Vec<u8> = b"it was the best of times, it was the worst of times. "
            .iter()
            .cycle()
            .take(80_000)
            .cloned()
            .collect();
        let size = roundtrip(&data);
        assert!(size < data.len() / 40, "size {size}");
    }

    #[test]
    fn prop_roundtrip() {
        prop_cases(0x1224, 12, |rng, _| {
            let n = rng.below(60_000) as usize;
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                match rng.below(4) {
                    0 if data.len() > 8 => {
                        let back = 1 + rng.below(data.len() as u32) as usize;
                        let len = (3 + rng.below(60) as usize).min(n - data.len());
                        let start = data.len() - back;
                        for k in 0..len {
                            let b = data[start + k.min(back - 1) % back];
                            data.push(b);
                        }
                    }
                    1 => data.push(0),
                    _ => data.push(rng.next_u32() as u8),
                }
            }
            roundtrip(&data);
        });
    }

    #[test]
    fn truncated_stream_is_handled() {
        let mut comp = Vec::new();
        compress(&vec![7u8; 10_000], &mut comp);
        let mut out = Vec::new();
        // decoder reads zeros past the end; it must terminate (length-bounded)
        // with either an error or a short/garbled output, never hang or panic
        let _ = decompress(&comp[..comp.len() / 2], &mut out);
        assert!(out.len() <= 10_000);
    }
}
