//! `czlib`: from-scratch DEFLATE-family codec — LZ77 hash-chain match
//! finding + canonical length-limited Huffman coding, with per-block code
//! tables and a stored-block fallback. Stands in for ZLIB in the paper
//! (Z/DEF and Z/BEST levels); a fast wide-window profile stands in for
//! ZSTD (`zstdlite` positioning: zlib-class ratio at higher speed).
//!
//! Stream format (little endian):
//! `[u32 raw_len]` then blocks: `[u32 block_raw_len][u8 btype]` where
//! btype 0 = stored (raw bytes follow), 1 = huffman:
//! `[u8 n_dist_codes][nibble-packed lit lens (285)][nibble-packed dist lens]`
//! followed by the LSB-first bitstream of tokens. No explicit EOB: the
//! decoder stops when `block_raw_len` bytes have been produced.
use super::huffman::{code_lengths, Decoder, Encoder};
use super::lz77::{MatchFinder, Params, Token, MAX_MATCH, MIN_MATCH};
use crate::util::{BitReader, BitWriter};

/// Effort levels (paper: Z/DEF, Z/BEST; Fast = zstdlite profile).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    Fast,
    Default,
    Best,
}

impl Level {
    fn params(&self) -> Params {
        match self {
            Level::Fast => Params::fast(),
            Level::Default => Params::default_level(),
            Level::Best => Params::best(),
        }
    }
    fn block_size(&self) -> usize {
        match self {
            Level::Fast => 256 << 10,
            _ => 128 << 10,
        }
    }
    fn max_window(&self) -> usize {
        self.params().window
    }
}

const N_LEN_CODES: usize = 29;
const N_LIT: usize = 256 + N_LEN_CODES; // 285

/// Deflate length-code table: (base, extra_bits) for codes 0..29.
const LEN_BASE: [u16; N_LEN_CODES] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u8; N_LEN_CODES] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];

#[inline]
fn len_code(len: usize) -> usize {
    debug_assert!((MIN_MATCH..=MAX_MATCH).contains(&len));
    // binary search over the 29 bases
    match LEN_BASE.binary_search(&(len as u16)) {
        Ok(i) => i,
        Err(i) => i - 1,
    }
}

/// Distance slots: 1,2,3,4 then pairs per extra-bit count (deflate-style),
/// generated out to `window`. Returns (bases, extra_bits).
fn dist_table(window: usize) -> (Vec<u32>, Vec<u8>) {
    let mut bases = vec![1u32, 2, 3, 4];
    let mut extra = vec![0u8, 0, 0, 0];
    let mut e = 1u8;
    loop {
        let b0 = *bases.last().unwrap() + (1 << (e - 1)).max(1);
        if b0 as usize > window {
            break;
        }
        bases.push(b0);
        extra.push(e);
        let b1 = b0 + (1 << e);
        if (b1 as usize) <= window {
            bases.push(b1);
            extra.push(e);
        }
        e += 1;
    }
    (bases, extra)
}

#[inline]
fn dist_code(bases: &[u32], dist: u32) -> usize {
    match bases.binary_search(&dist) {
        Ok(i) => i,
        Err(i) => i - 1,
    }
}

fn write_nibbles(out: &mut Vec<u8>, lens: &[u8]) {
    let mut i = 0;
    while i < lens.len() {
        let lo = lens[i] & 0xf;
        let hi = if i + 1 < lens.len() { lens[i + 1] & 0xf } else { 0 };
        out.push(lo | (hi << 4));
        i += 2;
    }
}

fn read_nibbles(buf: &[u8], n: usize) -> Result<(Vec<u8>, usize), String> {
    let nbytes = n.div_ceil(2);
    if buf.len() < nbytes {
        return Err("truncated code lengths".into());
    }
    let mut lens = Vec::with_capacity(n);
    for i in 0..n {
        let b = buf[i / 2];
        lens.push(if i % 2 == 0 { b & 0xf } else { b >> 4 });
    }
    Ok((lens, nbytes))
}

/// Compress `input` at `level`, appending the stream to `out`.
pub fn compress(input: &[u8], level: Level, out: &mut Vec<u8>) {
    out.extend_from_slice(&(input.len() as u32).to_le_bytes());
    if input.is_empty() {
        return;
    }
    let (dist_bases, dist_extra) = dist_table(level.max_window());
    let mut mf = MatchFinder::new(level.params());
    let mut tokens: Vec<Token> = Vec::with_capacity(input.len() / 3 + 16);
    mf.tokenize(input, |t| tokens.push(t));

    // split tokens into blocks covering <= block_size raw bytes each
    let bsz = level.block_size();
    let mut tok_i = 0usize;
    let mut raw_pos = 0usize;
    while raw_pos < input.len() {
        let block_start = raw_pos;
        let tok_start = tok_i;
        while tok_i < tokens.len() && raw_pos - block_start < bsz {
            raw_pos += match tokens[tok_i] {
                Token::Literal(_) => 1,
                Token::Match { len, .. } => len as usize,
            };
            tok_i += 1;
        }
        let block_raw = raw_pos - block_start;
        encode_block(
            &tokens[tok_start..tok_i],
            &input[block_start..raw_pos],
            &dist_bases,
            &dist_extra,
            out,
        );
        let _ = block_raw;
    }
}

fn encode_block(
    tokens: &[Token],
    raw: &[u8],
    dist_bases: &[u32],
    dist_extra: &[u8],
    out: &mut Vec<u8>,
) {
    // frequencies
    let mut lit_freq = vec![0u32; N_LIT];
    let mut dist_freq = vec![0u32; dist_bases.len()];
    for t in tokens {
        match *t {
            Token::Literal(b) => lit_freq[b as usize] += 1,
            Token::Match { len, dist } => {
                lit_freq[256 + len_code(len as usize)] += 1;
                dist_freq[dist_code(dist_bases, dist)] += 1;
            }
        }
    }
    let lit_lens = code_lengths(&lit_freq);
    let dist_lens = code_lengths(&dist_freq);
    let lit_enc = Encoder::from_lengths(&lit_lens);
    let dist_enc = Encoder::from_lengths(&dist_lens);

    let mut w = BitWriter::with_capacity(raw.len() / 2);
    for t in tokens {
        match *t {
            Token::Literal(b) => lit_enc.write(&mut w, b as usize),
            Token::Match { len, dist } => {
                let lc = len_code(len as usize);
                lit_enc.write(&mut w, 256 + lc);
                w.write_bits((len - LEN_BASE[lc] as u32) as u64, LEN_EXTRA[lc] as u32);
                let dc = dist_code(dist_bases, dist);
                dist_enc.write(&mut w, dc);
                w.write_bits((dist - dist_bases[dc]) as u64, dist_extra[dc] as u32);
            }
        }
    }
    let payload = w.finish();
    let header_len = 1 + N_LIT.div_ceil(2) + dist_bases.len().div_ceil(2);

    out.extend_from_slice(&(raw.len() as u32).to_le_bytes());
    if header_len + payload.len() >= raw.len() {
        // stored fallback
        out.push(0u8);
        out.extend_from_slice(raw);
    } else {
        out.push(1u8);
        out.push(dist_bases.len() as u8);
        write_nibbles(out, &lit_lens);
        write_nibbles(out, &dist_lens);
        out.extend_from_slice(&payload);
    }
}

/// Decompress a full czlib stream from `input`, appending to `out`.
pub fn decompress(input: &[u8], out: &mut Vec<u8>) -> Result<(), String> {
    if input.len() < 4 {
        return Err("missing stream header".into());
    }
    let raw_len = u32::from_le_bytes(input[0..4].try_into().unwrap()) as usize;
    let mut pos = 4usize;
    let out_start = out.len();
    out.reserve(raw_len);
    while out.len() - out_start < raw_len {
        if input.len() < pos + 5 {
            return Err("truncated block header".into());
        }
        let block_raw = u32::from_le_bytes(input[pos..pos + 4].try_into().unwrap()) as usize;
        let btype = input[pos + 4];
        pos += 5;
        match btype {
            0 => {
                if input.len() < pos + block_raw {
                    return Err("truncated stored block".into());
                }
                out.extend_from_slice(&input[pos..pos + block_raw]);
                pos += block_raw;
            }
            1 => {
                if input.len() < pos + 1 {
                    return Err("truncated huffman header".into());
                }
                let n_dist = input[pos] as usize;
                pos += 1;
                let (lit_lens, used) = read_nibbles(&input[pos..], N_LIT)?;
                pos += used;
                let (dist_lens, used) = read_nibbles(&input[pos..], n_dist)?;
                pos += used;
                let lit_dec = Decoder::from_lengths(&lit_lens)?;
                let dist_dec = Decoder::from_lengths(&dist_lens)?;
                // distance tables must match the encoder's window; rebuild
                // large enough to cover any encoded slot
                let (dist_bases, dist_extra) = dist_table(1 << 20);
                let mut r = BitReader::new(&input[pos..]);
                let target = out.len() + block_raw;
                while out.len() < target {
                    let sym = lit_dec.read(&mut r)?;
                    if sym < 256 {
                        out.push(sym as u8);
                    } else {
                        let lc = sym - 256;
                        if lc >= N_LEN_CODES {
                            return Err(format!("bad length code {lc}"));
                        }
                        let len =
                            LEN_BASE[lc] as usize + r.read_bits(LEN_EXTRA[lc] as u32) as usize;
                        let dc = dist_dec.read(&mut r)?;
                        if dc >= dist_bases.len() || dc >= n_dist {
                            return Err(format!("bad distance code {dc}"));
                        }
                        let dist =
                            dist_bases[dc] as usize + r.read_bits(dist_extra[dc] as u32) as usize;
                        if dist == 0 || dist > out.len() - out_start {
                            return Err(format!("distance {dist} out of range"));
                        }
                        if out.len() + len > target {
                            return Err("match overruns block".into());
                        }
                        let start = out.len() - dist;
                        for k in 0..len {
                            let b = out[start + k];
                            out.push(b);
                        }
                    }
                }
                pos += r.bytes_consumed();
            }
            t => return Err(format!("bad block type {t}")),
        }
    }
    if out.len() - out_start != raw_len {
        return Err("stream length mismatch".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;

    fn roundtrip(level: Level, data: &[u8]) -> usize {
        let mut comp = Vec::new();
        compress(data, level, &mut comp);
        let mut back = Vec::new();
        decompress(&comp, &mut back).unwrap();
        assert_eq!(back, data);
        comp.len()
    }

    #[test]
    fn empty_and_tiny() {
        for level in [Level::Fast, Level::Default, Level::Best] {
            roundtrip(level, b"");
            roundtrip(level, b"a");
            roundtrip(level, b"ab");
            roundtrip(level, b"abc");
        }
    }

    #[test]
    fn compresses_repetitive_text() {
        let data: Vec<u8> = b"the quick brown fox jumps over the lazy dog. "
            .iter()
            .cycle()
            .take(100_000)
            .cloned()
            .collect();
        let size = roundtrip(Level::Default, &data);
        assert!(size < data.len() / 20, "size {size}");
    }

    #[test]
    fn best_not_worse_than_default() {
        let mut rng = Pcg32::new(1);
        let mut data = Vec::new();
        for _ in 0..20_000 {
            let v = ((rng.next_f32() * 20.0) as i32).to_le_bytes();
            data.extend_from_slice(&v);
        }
        let mut cd = Vec::new();
        compress(&data, Level::Default, &mut cd);
        let mut cb = Vec::new();
        compress(&data, Level::Best, &mut cb);
        assert!(cb.len() <= cd.len() + cd.len() / 100, "best {} def {}", cb.len(), cd.len());
    }

    #[test]
    fn incompressible_random_stays_stored() {
        let mut rng = Pcg32::new(2);
        let data: Vec<u8> = (0..50_000).map(|_| rng.next_u32() as u8).collect();
        let size = roundtrip(Level::Default, &data);
        // stored fallback: tiny overhead only
        assert!(size < data.len() + data.len() / 100 + 32);
    }

    #[test]
    fn multiblock_streams() {
        let mut rng = Pcg32::new(3);
        // > 2 blocks with cross-block matches
        let mut data = vec![0u8; 300_000];
        for (i, b) in data.iter_mut().enumerate() {
            *b = ((i / 1000) % 251) as u8 ^ (rng.below(4) as u8);
        }
        for level in [Level::Fast, Level::Default, Level::Best] {
            roundtrip(level, &data);
        }
    }

    #[test]
    fn corrupt_stream_is_error_not_panic() {
        let mut comp = Vec::new();
        compress(b"some reasonable data some reasonable data", Level::Default, &mut comp);
        // flip bits in the middle; decoder must error or produce wrong data,
        // never panic
        for i in (4..comp.len()).step_by(3) {
            let mut bad = comp.clone();
            bad[i] ^= 0x55;
            let mut out = Vec::new();
            let _ = decompress(&bad, &mut out);
        }
        // truncation must error
        let mut out = Vec::new();
        assert!(decompress(&comp[..comp.len() / 2], &mut out).is_err() || out.len() < 42);
    }

    #[test]
    fn random_structured_roundtrip_prop() {
        prop_cases(0xCAFE, 10, |rng, _| {
            let n = rng.below(150_000) as usize;
            let mut data = vec![0u8; n];
            let mut i = 0;
            while i < n {
                let mode = rng.below(3);
                let run = ((rng.below(200) + 1) as usize).min(n - i);
                match mode {
                    0 => {
                        let b = rng.next_u32() as u8;
                        data[i..i + run].fill(b);
                    }
                    1 => {
                        for j in 0..run {
                            data[i + j] = (j % 7) as u8;
                        }
                    }
                    _ => {
                        for j in 0..run {
                            data[i + j] = rng.next_u32() as u8;
                        }
                    }
                }
                i += run;
            }
            for level in [Level::Fast, Level::Default, Level::Best] {
                roundtrip(level, &data);
            }
        });
    }

    #[test]
    fn dist_table_covers_window() {
        for window in [1 << 15, 1 << 17, 1 << 20] {
            let (bases, extra) = dist_table(window);
            assert_eq!(bases.len(), extra.len());
            assert!(*bases.last().unwrap() as usize <= window);
            // every distance in [1, window] maps to a slot whose range
            // contains it
            for d in [1u32, 2, 3, 4, 5, 100, 1000, window as u32 / 2, window as u32] {
                let c = dist_code(&bases, d);
                assert!(bases[c] <= d);
                assert!(d - bases[c] < (1 << extra[c]) as u32);
            }
        }
    }

    #[test]
    fn len_codes_cover_range() {
        for len in MIN_MATCH..=MAX_MATCH {
            let c = len_code(len);
            assert!(LEN_BASE[c] as usize <= len);
            assert!((len - LEN_BASE[c] as usize) < (1usize << LEN_EXTRA[c]));
        }
    }
}
