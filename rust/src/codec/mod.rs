//! Substage-2 lossless layer (paper §2.3 "Lossless compression"),
//! organized around the [`stage2::Stage2Codec`] trait + registry.
//!
//! # Architecture
//!
//! * [`stage2`] — the dispatch layer. Every back-end implements
//!   [`stage2::Stage2Codec`] (`compress_into` / limit-checked
//!   `decompress_into`, `name`/`id`/`aliases`/`effort`) and registers in
//!   [`stage2::REGISTRY`]; the pipeline resolves a `&'static dyn
//!   Stage2Codec` once per file and never matches on a codec enum. The
//!   module also owns the *framed* chunk container (`compress_framed` /
//!   `decompress_framed`): fixed-arithmetic sub-frames that let one
//!   chunk's stage-2 work fan out across the worker pool while the
//!   serialized bytes stay thread-count independent.
//! * [`Codec`] — the thin wire identifier those registrations map to.
//!   It survives only because `.czb` headers serialize a codec id; its
//!   convenience methods delegate straight to the registry.
//!
//! # Back-ends (all implemented from scratch)
//!
//! * [`czlib`]  — LZ77 (hash-chain) + canonical Huffman; DEFLATE-family.
//!   Two effort levels mirroring ZLIB's default/best (`Z/DEF`, `Z/BEST`).
//! * [`lz4lite`] — greedy byte-aligned LZ (LZ4 family): fastest, lower CR.
//! * [`zstdlite` profile] — the czlib engine with a 4× window and greedy
//!   matching: ZLIB-class ratio at higher speed (ZSTD's positioning in
//!   the paper); registered as `zstd`.
//! * [`lzmalite`] — LZ + adaptive binary range coder with order-1 literal
//!   contexts and a 1 MiB window: best ratio, slowest (LZMA's positioning).
//! * [`shuffle`] — byte/bit shuffling preconditioners (BLOSC-style),
//!   reached from the pipeline as `ShuffleMode::Byte4` / `Bit4` chunk
//!   preconditioners. The bit kernel uses a word-parallel 8×8 bit-matrix
//!   transpose (`benches/codec_suite` reports CR and kernel throughput
//!   head-to-head).
//!
//! The real `flate2` (zlib) and `zstd` crates are wrapped as *reference
//! baselines* to validate the from-scratch implementations in tests and
//! benches; they are never used by the pipeline itself, and they are only
//! compiled under `--cfg reference_codecs` (the offline image does not
//! ship those crates — see `rust/Cargo.toml`).
pub mod czlib;
pub mod huffman;
pub mod lz4lite;
pub mod lz77;
pub mod lzmalite;
#[cfg(reference_codecs)]
pub mod reference;
pub mod shuffle;
pub mod stage2;

pub use stage2::{Effort, Stage2Codec};

/// Identifies a substage-2 lossless scheme in file headers and CLIs.
/// Dispatch lives behind [`Codec::codec`] → the [`stage2`] registry; this
/// enum is only the serialized wire id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Codec {
    /// No stage-2 compression (direct copy).
    None,
    /// czlib at default effort (paper's Z/DEF).
    ZlibDef,
    /// czlib at best effort (paper's Z/BEST).
    ZlibBest,
    /// lz4lite.
    Lz4,
    /// zstdlite.
    Zstd,
    /// lzmalite.
    Lzma,
}

impl Codec {
    pub const ALL: [Codec; 6] =
        [Codec::None, Codec::ZlibDef, Codec::ZlibBest, Codec::Lz4, Codec::Zstd, Codec::Lzma];

    pub fn id(&self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::ZlibDef => 1,
            Codec::ZlibBest => 2,
            Codec::Lz4 => 3,
            Codec::Zstd => 4,
            Codec::Lzma => 5,
        }
    }

    /// The registered back-end serving this wire id.
    pub fn codec(&self) -> &'static dyn Stage2Codec {
        stage2::by_id(self.id()).expect("every Codec variant has a registered Stage2Codec")
    }

    pub fn name(&self) -> &'static str {
        self.codec().name()
    }

    pub fn from_id(id: u8) -> Option<Self> {
        Self::ALL.into_iter().find(|c| c.id() == id)
    }

    /// Resolve a CLI spelling through the registry: canonical names,
    /// aliases (`zlib-def`, `z/best`, ...), case-insensitive — every name
    /// `czb info` prints round-trips back into `czb compress --stage2`.
    pub fn from_name(name: &str) -> Option<Self> {
        stage2::by_name(name).and_then(|c| Self::from_id(c.id()))
    }

    /// Compress `input`, appending to `out` (registry convenience).
    pub fn compress(&self, input: &[u8], out: &mut Vec<u8>) {
        self.codec().compress_into(input, out);
    }

    /// Decompress `input` (must contain a whole stream), appending to
    /// `out`. Unbounded-limit convenience: pipeline paths that know the
    /// expected size call the registry with an exact limit instead.
    pub fn decompress(&self, input: &[u8], out: &mut Vec<u8>) -> Result<(), String> {
        self.codec().decompress_into(input, usize::MAX, out)
    }

    /// Convenience: compress into a fresh vector.
    pub fn compress_vec(&self, input: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(input.len() / 2 + 64);
        self.compress(input, &mut out);
        out
    }

    /// Convenience: decompress into a fresh vector.
    pub fn decompress_vec(&self, input: &[u8]) -> Result<Vec<u8>, String> {
        let mut out = Vec::with_capacity(input.len() * 3 + 64);
        self.decompress(input, &mut out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;

    fn sample_inputs() -> Vec<Vec<u8>> {
        let mut rng = Pcg32::new(0xC0DEC);
        let mut v: Vec<Vec<u8>> = vec![
            vec![],
            vec![0],
            vec![7; 1],
            b"hello hello hello hello".to_vec(),
            vec![0; 100_000],
            (0..=255u8).cycle().take(70_000).collect(),
        ];
        // compressible structured data
        let mut structured = Vec::new();
        for i in 0..30_000u32 {
            structured.extend_from_slice(&(i / 7).to_le_bytes());
        }
        v.push(structured);
        // incompressible random data
        let mut rnd = vec![0u8; 50_000];
        for b in rnd.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        v.push(rnd);
        v
    }

    #[test]
    fn all_codecs_roundtrip_samples() {
        for codec in Codec::ALL {
            for input in sample_inputs() {
                let comp = codec.compress_vec(&input);
                let back = codec.decompress_vec(&comp).unwrap_or_else(|e| {
                    panic!("{} failed on len {}: {e}", codec.name(), input.len())
                });
                assert_eq!(back, input, "{} roundtrip len {}", codec.name(), input.len());
            }
        }
    }

    #[test]
    fn all_codecs_roundtrip_random_lz_structure() {
        // strings with repeated substrings at random distances exercise the
        // match finders harder than uniform noise
        prop_cases(0x5EED, 20, |rng, _| {
            let n = 1000 + rng.below(60_000) as usize;
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                if rng.below(3) == 0 && data.len() > 16 {
                    let back = 1 + rng.below(data.len().min(40_000) as u32) as usize;
                    let len = (3 + rng.below(80) as usize).min(back).min(n - data.len());
                    let start = data.len() - back;
                    for i in 0..len {
                        let b = data[start + i];
                        data.push(b);
                    }
                } else {
                    data.push(rng.next_u32() as u8);
                }
            }
            for codec in Codec::ALL {
                let comp = codec.compress_vec(&data);
                let back = codec.decompress_vec(&comp).unwrap();
                assert_eq!(back, data, "{}", codec.name());
            }
        });
    }

    #[test]
    fn ratio_ordering_on_float_like_data() {
        // shuffled wavelet-coefficient-like data: lzma >= zlib-best >= zlib
        // >= lz4 in ratio (allowing small slack), which is the paper's
        // qualitative ordering (§2.3 Lossless compression)
        let mut rng = Pcg32::new(0x0DDBA11);
        let mut data = Vec::new();
        for _ in 0..40_000 {
            let v = (rng.next_f32() * 0.001).to_le_bytes();
            data.extend_from_slice(&v);
        }
        let shuffled = shuffle::byte_shuffle(&data, 4);
        let size = |c: Codec| c.compress_vec(&shuffled).len() as f64;
        let (lzma, zbest, zdef, zstd, lz4) = (
            size(Codec::Lzma),
            size(Codec::ZlibBest),
            size(Codec::ZlibDef),
            size(Codec::Zstd),
            size(Codec::Lz4),
        );
        assert!(lzma <= zbest * 1.02, "lzma {lzma} vs zlib-best {zbest}");
        assert!(zbest <= zdef * 1.01, "zlib-best {zbest} vs zlib {zdef}");
        assert!(zdef <= lz4 * 1.05, "zlib {zdef} vs lz4 {lz4}");
        assert!(zstd <= lz4 * 1.05, "zstd {zstd} vs lz4 {lz4}");
    }

    #[test]
    fn ids_roundtrip() {
        for c in Codec::ALL {
            assert_eq!(Codec::from_id(c.id()), Some(c));
            assert_eq!(Codec::from_name(c.name()), Some(c));
        }
        assert_eq!(Codec::from_id(99), None);
    }

    #[test]
    fn info_printed_names_round_trip_with_aliases() {
        // the fix for the CLI round-trip: every spelling `--help` or
        // `info` ever shows must parse back, in any case
        for (spelling, want) in [
            ("zlib", Codec::ZlibDef),
            ("zlib-def", Codec::ZlibDef),
            ("ZLIB-DEF", Codec::ZlibDef),
            ("z/def", Codec::ZlibDef),
            ("zlib-best", Codec::ZlibBest),
            ("Z/BEST", Codec::ZlibBest),
            ("LZ4", Codec::Lz4),
            ("Zstd", Codec::Zstd),
            ("lzma", Codec::Lzma),
            ("none", Codec::None),
            ("NONE", Codec::None),
        ] {
            assert_eq!(Codec::from_name(spelling), Some(want), "{spelling}");
        }
        assert_eq!(Codec::from_name("deflate64"), None);
    }
}
