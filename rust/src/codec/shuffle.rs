//! Byte and bit shuffling preconditioners (BLOSC-style, paper §2.3):
//! regrouping the i-th byte (bit) of every element exposes the "boring"
//! high-order bytes/sign planes to the downstream lossless coder.
//!
//! The f32 byte shuffle (`stride == 4`, the `ShuffleMode::Byte4` hot
//! path) dispatches to vector kernels — an AVX2 in-register 8x4 byte
//! transpose or a NEON `vld4`/`vst4` de/interleave — with the scalar
//! per-plane loops retained as the fallback and equivalence oracle
//! (see `crate::simd`). Output bytes are identical across paths.

use crate::simd::{self, SimdLevel};

/// Byte shuffle into a caller-owned buffer (cleared and resized): output
/// groups all 0th bytes, then all 1st bytes, ... Trailing bytes
/// (len % stride) are appended unshuffled. The pipeline hot path reuses
/// one `out` per worker so the steady state allocates nothing.
pub fn byte_shuffle_into(data: &[u8], stride: usize, out: &mut Vec<u8>) {
    assert!(stride > 0);
    let n = data.len() / stride;
    // resize without clear: every byte below is overwritten (planes + tail),
    // so a warm buffer skips the redundant zero-fill
    out.resize(data.len(), 0);
    byte_shuffle_planes(data, stride, n, out, simd::level());
    out[n * stride..].copy_from_slice(&data[n * stride..]);
}

/// Plane gather at an explicit dispatch level (tests force both paths).
fn byte_shuffle_planes(data: &[u8], stride: usize, n: usize, out: &mut [u8], lvl: SimdLevel) {
    #[cfg(target_arch = "x86_64")]
    {
        if stride == 4 && lvl == SimdLevel::Avx2 {
            // SAFETY: Avx2 is only dispatched when simd::detect() saw it
            unsafe { byte_shuffle4_avx2(data, n, out) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if stride == 4 && lvl == SimdLevel::Neon {
            // SAFETY: NEON is baseline on aarch64
            unsafe { byte_shuffle4_neon(data, n, out) };
            return;
        }
    }
    let _ = lvl;
    for s in 0..stride {
        let plane = &mut out[s * n..(s + 1) * n];
        for (i, b) in plane.iter_mut().enumerate() {
            *b = data[i * stride + s];
        }
    }
}

/// Byte shuffle with element size `stride` (4 for f32), allocating.
pub fn byte_shuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = Vec::new();
    byte_shuffle_into(data, stride, &mut out);
    out
}

/// Inverse of [`byte_shuffle`] into a caller-owned buffer (cleared and
/// resized; see [`byte_shuffle_into`] for why).
pub fn byte_unshuffle_into(data: &[u8], stride: usize, out: &mut Vec<u8>) {
    assert!(stride > 0);
    let n = data.len() / stride;
    // see byte_shuffle_into: every output byte is overwritten below
    out.resize(data.len(), 0);
    byte_unshuffle_planes(data, stride, n, out, simd::level());
    out[n * stride..].copy_from_slice(&data[n * stride..]);
}

/// Plane scatter at an explicit dispatch level (tests force both paths).
fn byte_unshuffle_planes(data: &[u8], stride: usize, n: usize, out: &mut [u8], lvl: SimdLevel) {
    #[cfg(target_arch = "x86_64")]
    {
        if stride == 4 && lvl == SimdLevel::Avx2 {
            // SAFETY: as for byte_shuffle_planes
            unsafe { byte_unshuffle4_avx2(data, n, out) };
            return;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if stride == 4 && lvl == SimdLevel::Neon {
            // SAFETY: NEON is baseline on aarch64
            unsafe { byte_unshuffle4_neon(data, n, out) };
            return;
        }
    }
    let _ = lvl;
    for s in 0..stride {
        let plane = &data[s * n..(s + 1) * n];
        for (i, &b) in plane.iter().enumerate() {
            out[i * stride + s] = b;
        }
    }
}

/// Stride-4 byte shuffle, 8 elements (32 bytes) per iteration: a per-lane
/// 4x4 byte transpose (`vpshufb`) followed by a cross-lane dword gather
/// (`vpermd`) leaves plane p of all 8 elements in qword p; four 8-byte
/// stores land them in their planes. The `n % 8` remainder runs the
/// scalar loop.
///
/// # Safety
/// AVX2 must be available; `data` holds at least `4 * n` bytes and `out`
/// at least `4 * n`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn byte_shuffle4_avx2(data: &[u8], n: usize, out: &mut [u8]) {
    use core::arch::x86_64::*;
    debug_assert!(data.len() >= n * 4 && out.len() >= n * 4);
    #[rustfmt::skip]
    let tr = _mm256_setr_epi8(
        0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10, 14, 3, 7, 11, 15,
        0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10, 14, 3, 7, 11, 15,
    );
    let gather = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
    let groups = n / 8;
    for g in 0..groups {
        let v = _mm256_loadu_si256(data.as_ptr().add(g * 32) as *const __m256i);
        let p = _mm256_permutevar8x32_epi32(_mm256_shuffle_epi8(v, tr), gather);
        let lo = _mm256_castsi256_si128(p);
        let hi = _mm256_extracti128_si256::<1>(p);
        let o = out.as_mut_ptr().add(g * 8);
        _mm_storel_epi64(o as *mut __m128i, lo);
        _mm_storel_epi64(o.add(n) as *mut __m128i, _mm_unpackhi_epi64(lo, lo));
        _mm_storel_epi64(o.add(2 * n) as *mut __m128i, hi);
        _mm_storel_epi64(o.add(3 * n) as *mut __m128i, _mm_unpackhi_epi64(hi, hi));
    }
    for i in groups * 8..n {
        for s in 0..4 {
            out[s * n + i] = data[i * 4 + s];
        }
    }
}

/// Inverse of [`byte_shuffle4_avx2`]: gather 8 bytes from each plane,
/// reverse the dword permute, then the same per-lane byte transpose
/// reassembles 8 elements for one 32-byte store.
///
/// # Safety
/// As for [`byte_shuffle4_avx2`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn byte_unshuffle4_avx2(data: &[u8], n: usize, out: &mut [u8]) {
    use core::arch::x86_64::*;
    debug_assert!(data.len() >= n * 4 && out.len() >= n * 4);
    #[rustfmt::skip]
    let tr = _mm256_setr_epi8(
        0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10, 14, 3, 7, 11, 15,
        0, 4, 8, 12, 1, 5, 9, 13, 2, 6, 10, 14, 3, 7, 11, 15,
    );
    let scatter = _mm256_setr_epi32(0, 2, 4, 6, 1, 3, 5, 7);
    let groups = n / 8;
    for g in 0..groups {
        let p = data.as_ptr().add(g * 8);
        let p0 = _mm_loadl_epi64(p as *const __m128i);
        let p1 = _mm_loadl_epi64(p.add(n) as *const __m128i);
        let p2 = _mm_loadl_epi64(p.add(2 * n) as *const __m128i);
        let p3 = _mm_loadl_epi64(p.add(3 * n) as *const __m128i);
        let v = _mm256_set_m128i(_mm_unpacklo_epi64(p2, p3), _mm_unpacklo_epi64(p0, p1));
        let e = _mm256_shuffle_epi8(_mm256_permutevar8x32_epi32(v, scatter), tr);
        _mm256_storeu_si256(out.as_mut_ptr().add(g * 32) as *mut __m256i, e);
    }
    for i in groups * 8..n {
        for s in 0..4 {
            out[i * 4 + s] = data[s * n + i];
        }
    }
}

/// Stride-4 byte shuffle on NEON: `vld4` deinterleaves 16 elements per
/// iteration straight into their four byte planes.
///
/// # Safety
/// aarch64 only (NEON is baseline); `data` holds at least `4 * n` bytes
/// and `out` at least `4 * n`.
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
unsafe fn byte_shuffle4_neon(data: &[u8], n: usize, out: &mut [u8]) {
    use core::arch::aarch64::*;
    debug_assert!(data.len() >= n * 4 && out.len() >= n * 4);
    let groups = n / 16;
    for g in 0..groups {
        let v = vld4q_u8(data.as_ptr().add(g * 64));
        let o = out.as_mut_ptr().add(g * 16);
        vst1q_u8(o, v.0);
        vst1q_u8(o.add(n), v.1);
        vst1q_u8(o.add(2 * n), v.2);
        vst1q_u8(o.add(3 * n), v.3);
    }
    for i in groups * 16..n {
        for s in 0..4 {
            out[s * n + i] = data[i * 4 + s];
        }
    }
}

/// Inverse of [`byte_shuffle4_neon`]: `vst4` re-interleaves the planes.
///
/// # Safety
/// As for [`byte_shuffle4_neon`].
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
unsafe fn byte_unshuffle4_neon(data: &[u8], n: usize, out: &mut [u8]) {
    use core::arch::aarch64::*;
    debug_assert!(data.len() >= n * 4 && out.len() >= n * 4);
    let groups = n / 16;
    for g in 0..groups {
        let p = data.as_ptr().add(g * 16);
        let v = uint8x16x4_t(
            vld1q_u8(p),
            vld1q_u8(p.add(n)),
            vld1q_u8(p.add(2 * n)),
            vld1q_u8(p.add(3 * n)),
        );
        vst4q_u8(out.as_mut_ptr().add(g * 64), v);
    }
    for i in groups * 16..n {
        for s in 0..4 {
            out[i * 4 + s] = data[s * n + i];
        }
    }
}

/// Inverse of [`byte_shuffle`], allocating.
pub fn byte_unshuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = Vec::new();
    byte_unshuffle_into(data, stride, &mut out);
    out
}

/// 8×8 bit-matrix transpose (Hacker's Delight delta swaps): byte `r` of
/// the input is row `r`, bit `c` within a byte is column `c`; the result
/// has bit `(8r + c)` equal to input bit `(8c + r)`. An involution — the
/// same kernel serves shuffle and unshuffle.
#[inline]
fn transpose8(mut x: u64) -> u64 {
    let t = (x ^ (x >> 7)) & 0x00AA_00AA_00AA_00AA;
    x ^= t ^ (t << 7);
    let t = (x ^ (x >> 14)) & 0x0000_CCCC_0000_CCCC;
    x ^= t ^ (t << 14);
    let t = (x ^ (x >> 28)) & 0x0000_0000_F0F0_F0F0;
    x ^= t ^ (t << 28);
    x
}

/// Bit shuffle into a caller-owned buffer (cleared and resized): plane b
/// of the output collects bit b of every `stride`-byte element
/// (BLOSC2-style). The element count is padded up to a byte multiple, so
/// the shuffled stream is `stride * 8 * ceil(n/8)` plane bytes; trailing
/// bytes (`len % stride`) are appended unshuffled, mirroring
/// [`byte_shuffle_into`]. This is the `ShuffleMode::Bit4` chunk
/// preconditioner, so one `out` per worker keeps the hot path
/// allocation-free.
///
/// Word-parallel kernel: each group of 8 elements × 1 byte position is an
/// 8×8 bit matrix gathered into a `u64`, transposed with [`transpose8`],
/// and scattered as one byte per bit plane — ~an order of magnitude fewer
/// operations than the bit-at-a-time loop it replaced (the `~30x slower
/// than byte_shuffle` ROADMAP item). The `< 8` element remainder falls
/// back to the naive per-bit loop.
pub fn bit_shuffle_into(data: &[u8], stride: usize, out: &mut Vec<u8>) {
    assert!(stride > 0);
    let n = data.len() / stride; // number of whole elements
    let nbits = stride * 8;
    let plane_bytes = n.div_ceil(8);
    // the remainder plane bytes are built with ORs, so a warm buffer must
    // be re-zeroed (the word loop overwrites its group bytes fully)
    out.clear();
    out.resize(nbits * plane_bytes + (data.len() - n * stride), 0);
    let groups = n / 8;
    for g in 0..groups {
        let i0 = g * 8;
        for p in 0..stride {
            // rows = elements i0..i0+8, columns = bits of their byte p
            let mut x = 0u64;
            for k in 0..8 {
                x |= (data[(i0 + k) * stride + p] as u64) << (8 * k);
            }
            let y = transpose8(x);
            // byte j of y = plane (8p + j)'s bits for these 8 elements
            for j in 0..8 {
                out[(p * 8 + j) * plane_bytes + g] = (y >> (8 * j)) as u8;
            }
        }
    }
    for i in groups * 8..n {
        for b in 0..nbits {
            let bit = (data[i * stride + b / 8] >> (b % 8)) & 1;
            if bit != 0 {
                out[b * plane_bytes + i / 8] |= 1 << (i % 8);
            }
        }
    }
    out[nbits * plane_bytes..].copy_from_slice(&data[n * stride..]);
}

/// Bit shuffle with element size `stride` (4 for f32), allocating.
pub fn bit_shuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let mut out = Vec::new();
    bit_shuffle_into(data, stride, &mut out);
    out
}

/// Size in bytes of the [`bit_shuffle_into`] output for an input of
/// `len` bytes (planes + unshuffled tail). The decode path uses this to
/// validate a shuffled chunk before unshuffling.
pub fn bit_shuffled_len(len: usize, stride: usize) -> usize {
    let n = len / stride;
    stride * 8 * n.div_ceil(8) + (len - n * stride)
}

/// Inverse of [`bit_shuffle_into`] into a caller-owned buffer (cleared
/// and resized); `n` is the original element count. `data` must be
/// exactly [`bit_shuffled_len`]`(n * stride + tail, stride)` bytes, where
/// the tail is whatever follows the planes. Word-parallel like the
/// forward kernel: [`transpose8`] is an involution, so the same 8×8
/// transpose maps plane bytes back to element bytes.
pub fn bit_unshuffle_into(data: &[u8], stride: usize, n: usize, out: &mut Vec<u8>) {
    let nbits = stride * 8;
    let plane_bytes = n.div_ceil(8);
    assert!(data.len() >= nbits * plane_bytes, "shuffled stream shorter than its planes");
    let tail = data.len() - nbits * plane_bytes;
    // remainder elements are rebuilt with ORs, so a warm buffer must be
    // re-zeroed (the word loop overwrites its group bytes fully)
    out.clear();
    out.resize(n * stride + tail, 0);
    let groups = n / 8;
    for g in 0..groups {
        let i0 = g * 8;
        for p in 0..stride {
            // rows = planes 8p..8p+8, columns = elements i0..i0+8
            let mut x = 0u64;
            for j in 0..8 {
                x |= (data[(p * 8 + j) * plane_bytes + g] as u64) << (8 * j);
            }
            let y = transpose8(x);
            // byte k of y = byte p of element i0+k
            for k in 0..8 {
                out[(i0 + k) * stride + p] = (y >> (8 * k)) as u8;
            }
        }
    }
    for i in groups * 8..n {
        for b in 0..nbits {
            let bit = (data[b * plane_bytes + i / 8] >> (i % 8)) & 1;
            if bit != 0 {
                out[i * stride + b / 8] |= 1 << (b % 8);
            }
        }
    }
    out[n * stride..].copy_from_slice(&data[nbits * plane_bytes..]);
}

/// Inverse of [`bit_shuffle`]; `n` is the original element count.
pub fn bit_unshuffle(data: &[u8], stride: usize, n: usize) -> Vec<u8> {
    let mut out = Vec::new();
    bit_unshuffle_into(data, stride, n, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;

    /// The original bit-at-a-time kernel, kept as the equivalence oracle
    /// for the word-parallel transpose.
    fn bit_shuffle_naive(data: &[u8], stride: usize) -> Vec<u8> {
        let n = data.len() / stride;
        let nbits = stride * 8;
        let plane_bytes = n.div_ceil(8);
        let mut out = vec![0u8; nbits * plane_bytes + (data.len() - n * stride)];
        for i in 0..n {
            for b in 0..nbits {
                let bit = (data[i * stride + b / 8] >> (b % 8)) & 1;
                if bit != 0 {
                    out[b * plane_bytes + i / 8] |= 1 << (i % 8);
                }
            }
        }
        out[nbits * plane_bytes..].copy_from_slice(&data[n * stride..]);
        out
    }

    /// Bit-at-a-time inverse, the oracle for the word-parallel unshuffle.
    fn bit_unshuffle_naive(data: &[u8], stride: usize, n: usize) -> Vec<u8> {
        let nbits = stride * 8;
        let plane_bytes = n.div_ceil(8);
        let tail = data.len() - nbits * plane_bytes;
        let mut out = vec![0u8; n * stride + tail];
        for i in 0..n {
            for b in 0..nbits {
                let bit = (data[b * plane_bytes + i / 8] >> (i % 8)) & 1;
                if bit != 0 {
                    out[i * stride + b / 8] |= 1 << (b % 8);
                }
            }
        }
        out[n * stride..].copy_from_slice(&data[nbits * plane_bytes..]);
        out
    }

    #[test]
    fn transpose8_is_a_bit_matrix_transpose() {
        // spot vectors: identity diagonal, single bits, and the involution
        // property on random words
        assert_eq!(transpose8(0), 0);
        assert_eq!(transpose8(u64::MAX), u64::MAX);
        for r in 0..8u64 {
            for c in 0..8u64 {
                let x = 1u64 << (8 * r + c);
                assert_eq!(transpose8(x), 1u64 << (8 * c + r), "bit ({r},{c})");
            }
        }
        let mut rng = Pcg32::new(0x78A95);
        for _ in 0..200 {
            let x = ((rng.next_u32() as u64) << 32) | rng.next_u32() as u64;
            assert_eq!(transpose8(transpose8(x)), x);
        }
    }

    #[test]
    fn word_parallel_bit_kernels_match_naive() {
        // the satellite's equivalence test: every stride, element-count
        // remainder (n % 8) and tail shape must produce exactly the naive
        // kernel's bytes in both directions
        let mut rng = Pcg32::new(0xB17B17);
        let mut shuf = Vec::new();
        let mut unshuf = Vec::new();
        for stride in [1usize, 2, 4, 8] {
            for extra in 0..10usize {
                let n = (rng.below(700) as usize) + extra; // element count
                let tail = rng.below(stride as u32) as usize;
                let len = n * stride + tail;
                let data: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
                let expect = bit_shuffle_naive(&data, stride);
                bit_shuffle_into(&data, stride, &mut shuf);
                assert_eq!(shuf, expect, "shuffle stride {stride} n {n} tail {tail}");
                let back_expect = bit_unshuffle_naive(&shuf, stride, n);
                bit_unshuffle_into(&shuf, stride, n, &mut unshuf);
                assert_eq!(unshuf, back_expect, "unshuffle stride {stride} n {n} tail {tail}");
                assert_eq!(unshuf, data, "roundtrip stride {stride} n {n} tail {tail}");
            }
        }
    }

    #[test]
    fn byte_shuffle4_vector_kernels_match_scalar() {
        // fuzzed oracle check: random element counts exercise the
        // group remainder; both directions must equal the scalar plane
        // loops byte for byte
        let lvl = crate::simd::detect();
        if lvl == SimdLevel::Scalar {
            return; // no vector path to compare on this host
        }
        prop_cases(0xB45E, 40, |rng, _| {
            let n = rng.below(3_000) as usize;
            let data: Vec<u8> = (0..n * 4).map(|_| rng.next_u32() as u8).collect();
            let mut a = vec![0xAAu8; n * 4];
            let mut b = vec![0x55u8; n * 4];
            byte_shuffle_planes(&data, 4, n, &mut a, SimdLevel::Scalar);
            byte_shuffle_planes(&data, 4, n, &mut b, lvl);
            assert_eq!(a, b, "forward n={n}");
            let mut ua = vec![0x11u8; n * 4];
            let mut ub = vec![0x22u8; n * 4];
            byte_unshuffle_planes(&a, 4, n, &mut ua, SimdLevel::Scalar);
            byte_unshuffle_planes(&a, 4, n, &mut ub, lvl);
            assert_eq!(ua, ub, "inverse n={n}");
            assert_eq!(ua, data, "roundtrip n={n}");
        });
    }

    #[test]
    fn byte_shuffle_is_involution() {
        prop_cases(0x5F, 20, |rng, _| {
            let n = rng.below(10_000) as usize;
            let data: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            for stride in [1usize, 2, 4, 8] {
                let sh = byte_shuffle(&data, stride);
                assert_eq!(sh.len(), data.len());
                assert_eq!(byte_unshuffle(&sh, stride), data, "stride {stride} n {n}");
            }
        });
    }

    #[test]
    fn byte_shuffle_groups_bytes() {
        // elements 0x04030201, 0x08070605 -> low bytes first
        let data = [1u8, 2, 3, 4, 5, 6, 7, 8];
        let sh = byte_shuffle(&data, 4);
        assert_eq!(sh, [1, 5, 2, 6, 3, 7, 4, 8]);
    }

    #[test]
    fn into_variants_reuse_dirty_buffers() {
        // the per-worker buffers arrive dirty and differently sized; the
        // into-variants must still produce exactly the allocating result
        let mut rng = Pcg32::new(0xD1127);
        let mut shuf_buf = vec![0xAAu8; 17];
        let mut unshuf_buf = vec![0x55u8; 999];
        for _ in 0..20 {
            let n = rng.below(5_000) as usize;
            let data: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            for stride in [1usize, 3, 4] {
                byte_shuffle_into(&data, stride, &mut shuf_buf);
                assert_eq!(shuf_buf, byte_shuffle(&data, stride));
                byte_unshuffle_into(&shuf_buf, stride, &mut unshuf_buf);
                assert_eq!(unshuf_buf, data, "stride {stride} n {n}");
            }
        }
    }

    #[test]
    fn bit_shuffle_roundtrip() {
        prop_cases(0x8F, 15, |rng, _| {
            let n = rng.below(600) as usize;
            let stride = 4;
            let data: Vec<u8> = (0..n * stride).map(|_| rng.next_u32() as u8).collect();
            let sh = bit_shuffle(&data, stride);
            assert_eq!(bit_unshuffle(&sh, stride, n), data);
        });
    }

    #[test]
    fn bit_shuffle_roundtrips_with_tails_and_dirty_buffers() {
        // chunk streams are not a multiple of 4 bytes in general: the
        // trailing `len % stride` bytes ride along unshuffled
        let mut rng = Pcg32::new(0xB1751);
        let mut shuf = vec![0x3Cu8; 11]; // dirty + wrong size
        let mut unshuf = vec![0xC3u8; 777];
        for _ in 0..20 {
            let len = rng.below(4_000) as usize;
            let data: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            for stride in [1usize, 4, 8] {
                let n = len / stride;
                bit_shuffle_into(&data, stride, &mut shuf);
                assert_eq!(shuf.len(), bit_shuffled_len(len, stride), "len {len} stride {stride}");
                bit_unshuffle_into(&shuf, stride, n, &mut unshuf);
                assert_eq!(unshuf, data, "len {len} stride {stride}");
            }
        }
    }

    #[test]
    fn bit_shuffle_groups_bit_planes() {
        // elements 0x01, 0x03 (stride 1): bit plane 0 = 0b11, plane 1 = 0b10
        let sh = bit_shuffle(&[0x01u8, 0x03], 1);
        assert_eq!(sh.len(), 8);
        assert_eq!(sh[0], 0b11);
        assert_eq!(sh[1], 0b10);
        assert!(sh[2..].iter().all(|&b| b == 0));
    }

    #[test]
    fn shuffle_improves_compression_of_similar_floats() {
        // floats in a narrow range share exponent bytes -> shuffling makes
        // those byte planes constant and highly compressible
        let mut rng = Pcg32::new(0xF10A7);
        let mut data = Vec::new();
        for _ in 0..10_000 {
            data.extend_from_slice(&(1.0f32 + rng.next_f32() * 1e-3).to_le_bytes());
        }
        let c_plain = crate::codec::Codec::ZlibDef.compress_vec(&data).len();
        let c_shuf = crate::codec::Codec::ZlibDef
            .compress_vec(&byte_shuffle(&data, 4))
            .len();
        assert!(
            (c_shuf as f64) < 0.9 * c_plain as f64,
            "shuffled {c_shuf} vs plain {c_plain}"
        );
    }
}
