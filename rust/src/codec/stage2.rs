//! The substage-2 lossless codec registry and the framed chunk container.
//!
//! # Registry
//!
//! Every lossless back-end sits behind the [`Stage2Codec`] trait — the
//! stage-2 mirror of `pipeline::stage1::Stage1Codec`. The pipeline holds
//! a `&'static dyn Stage2Codec` resolved once per file via [`by_id`] and
//! never matches on the [`super::Codec`] enum again; the enum survives
//! purely as the wire identifier the `.czb` header serializes.
//! Registering a new back-end means implementing the trait, appending it
//! to [`REGISTRY`], and adding a `Codec` variant for its wire id —
//! `compressor.rs`/`decompressor.rs` stay untouched.
//!
//! [`by_name`] resolves CLI spellings: canonical names, per-codec aliases
//! (e.g. the paper's `z/def`), all case-insensitively, so every name the
//! tool ever prints round-trips back into `--stage2`.
//!
//! # Framed container
//!
//! A chunk's stage-2 payload is split into fixed-raw-size *sub-frames*,
//! each an independent compressed stream (the paper's "independent
//! deflate blocks", §2.3, generalized to all registered codecs):
//!
//! ```text
//! u32 nframes | nframes x u32 frame_csize | compressed frames back-to-back
//! ```
//!
//! Frame boundaries are pure arithmetic on the uncompressed length
//! ([`frame_spans`]): frame `i` covers bytes `i*frame_raw ..
//! min((i+1)*frame_raw, len)`. Nothing about the split depends on the
//! worker count, which keeps the serialized archive byte-identical across
//! thread counts while letting one chunk's frames compress and decompress
//! concurrently on the worker pool. The decoder knows every frame's exact
//! raw length up front, so fuzzed frame tables are rejected before any
//! allocation is sized by them and decoded frames are length-checked.
use super::{czlib, lz4lite, lzmalite};
use std::ops::Range;

/// Rough speed/ratio class of a registered codec (the paper's qualitative
/// ordering: LZ4 fastest, LZMA best ratio).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Effort {
    /// Throughput-first (lz4lite, zstdlite, direct copy).
    Fast,
    /// The production middle ground (czlib at default effort).
    Balanced,
    /// Ratio-first (czlib best effort, lzmalite).
    Best,
}

/// One substage-2 lossless back-end behind a uniform interface.
/// Implementations are stateless statics; per-call buffers are always
/// caller-owned so the pipeline hot paths stay allocation-free.
pub trait Stage2Codec: Sync {
    /// Wire id serialized in `.czb` headers (matches [`super::Codec::id`]).
    fn id(&self) -> u8;
    /// Canonical name (matches [`super::Codec::name`]).
    fn name(&self) -> &'static str;
    /// Alternative CLI spellings accepted by [`by_name`].
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }
    /// Speed/ratio class, for CLI listings and tuning heuristics.
    fn effort(&self) -> Effort;

    /// Compress `input` as one self-contained stream, appending to `out`.
    fn compress_into(&self, input: &[u8], out: &mut Vec<u8>);

    /// Decompress one whole stream, appending to `out`. `limit` is the
    /// caller's upper bound on the decoded size: implementations must
    /// error — before reserving memory — on streams that claim more, so
    /// a fuzzed length prefix can never drive an allocation.
    fn decompress_into(&self, input: &[u8], limit: usize, out: &mut Vec<u8>)
        -> Result<(), String>;
}

/// The u32 raw-length prefix all from-scratch streams carry, validated
/// against the caller's `limit` before anything is reserved.
fn claimed_len(input: &[u8], limit: usize) -> Result<usize, String> {
    if input.len() < 4 {
        return Err("missing stream header".into());
    }
    let claimed = u32::from_le_bytes(input[0..4].try_into().unwrap()) as usize;
    if claimed > limit {
        return Err(format!("stream claims {claimed} bytes, limit {limit}"));
    }
    Ok(claimed)
}

/// Direct copy (no stage-2 compression).
pub struct NoneCodec;

impl Stage2Codec for NoneCodec {
    fn id(&self) -> u8 {
        0
    }
    fn name(&self) -> &'static str {
        "none"
    }
    fn aliases(&self) -> &'static [&'static str] {
        &["copy", "raw"]
    }
    fn effort(&self) -> Effort {
        Effort::Fast
    }
    fn compress_into(&self, input: &[u8], out: &mut Vec<u8>) {
        out.extend_from_slice(input);
    }
    fn decompress_into(
        &self,
        input: &[u8],
        limit: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), String> {
        if input.len() > limit {
            return Err(format!("stream of {} bytes exceeds limit {limit}", input.len()));
        }
        out.extend_from_slice(input);
        Ok(())
    }
}

/// czlib at default effort (the paper's Z/DEF).
pub struct ZlibDefCodec;

impl Stage2Codec for ZlibDefCodec {
    fn id(&self) -> u8 {
        1
    }
    fn name(&self) -> &'static str {
        "zlib"
    }
    fn aliases(&self) -> &'static [&'static str] {
        &["zlib-def", "z/def", "zdef"]
    }
    fn effort(&self) -> Effort {
        Effort::Balanced
    }
    fn compress_into(&self, input: &[u8], out: &mut Vec<u8>) {
        czlib::compress(input, czlib::Level::Default, out);
    }
    fn decompress_into(
        &self,
        input: &[u8],
        limit: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), String> {
        claimed_len(input, limit)?;
        czlib::decompress(input, out)
    }
}

/// czlib at best effort (the paper's Z/BEST).
pub struct ZlibBestCodec;

impl Stage2Codec for ZlibBestCodec {
    fn id(&self) -> u8 {
        2
    }
    fn name(&self) -> &'static str {
        "zlib-best"
    }
    fn aliases(&self) -> &'static [&'static str] {
        &["zlib-best", "z/best", "zbest"]
    }
    fn effort(&self) -> Effort {
        Effort::Best
    }
    fn compress_into(&self, input: &[u8], out: &mut Vec<u8>) {
        czlib::compress(input, czlib::Level::Best, out);
    }
    fn decompress_into(
        &self,
        input: &[u8],
        limit: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), String> {
        claimed_len(input, limit)?;
        czlib::decompress(input, out)
    }
}

/// lz4lite: fastest, lower ratio.
pub struct Lz4Codec;

impl Stage2Codec for Lz4Codec {
    fn id(&self) -> u8 {
        3
    }
    fn name(&self) -> &'static str {
        "lz4"
    }
    fn effort(&self) -> Effort {
        Effort::Fast
    }
    fn compress_into(&self, input: &[u8], out: &mut Vec<u8>) {
        lz4lite::compress(input, out);
    }
    fn decompress_into(
        &self,
        input: &[u8],
        limit: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), String> {
        claimed_len(input, limit)?;
        lz4lite::decompress(input, out)
    }
}

/// zstdlite: the czlib engine in its fast wide-window profile (ZSTD's
/// positioning in the paper — zlib-class ratio at higher speed).
pub struct ZstdCodec;

impl Stage2Codec for ZstdCodec {
    fn id(&self) -> u8 {
        4
    }
    fn name(&self) -> &'static str {
        "zstd"
    }
    fn effort(&self) -> Effort {
        Effort::Fast
    }
    fn compress_into(&self, input: &[u8], out: &mut Vec<u8>) {
        czlib::compress(input, czlib::Level::Fast, out);
    }
    fn decompress_into(
        &self,
        input: &[u8],
        limit: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), String> {
        claimed_len(input, limit)?;
        czlib::decompress(input, out)
    }
}

/// lzmalite: best ratio, slowest.
pub struct LzmaCodec;

impl Stage2Codec for LzmaCodec {
    fn id(&self) -> u8 {
        5
    }
    fn name(&self) -> &'static str {
        "lzma"
    }
    fn effort(&self) -> Effort {
        Effort::Best
    }
    fn compress_into(&self, input: &[u8], out: &mut Vec<u8>) {
        lzmalite::compress(input, out);
    }
    fn decompress_into(
        &self,
        input: &[u8],
        limit: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), String> {
        claimed_len(input, limit)?;
        lzmalite::decompress(input, out)
    }
}

/// All registered substage-2 codecs, one per [`super::Codec`] wire id.
pub static REGISTRY: [&'static dyn Stage2Codec; 6] =
    [&NoneCodec, &ZlibDefCodec, &ZlibBestCodec, &Lz4Codec, &ZstdCodec, &LzmaCodec];

/// Look a codec up by its wire id.
pub fn by_id(id: u8) -> Option<&'static dyn Stage2Codec> {
    REGISTRY.iter().copied().find(|c| c.id() == id)
}

/// Look a codec up by canonical name or alias, case-insensitively.
pub fn by_name(name: &str) -> Option<&'static dyn Stage2Codec> {
    REGISTRY.iter().copied().find(|c| {
        c.name().eq_ignore_ascii_case(name)
            || c.aliases().iter().any(|a| a.eq_ignore_ascii_case(name))
    })
}

/// Number of sub-frames a `raw_len`-byte stream splits into (at least 1,
/// so even an empty chunk has a well-formed table).
pub fn frame_count(raw_len: usize, frame_raw: usize) -> usize {
    debug_assert!(frame_raw > 0);
    raw_len.div_ceil(frame_raw).max(1)
}

/// The fixed, worker-count-independent raw byte range of frame `i`.
pub fn frame_span(raw_len: usize, frame_raw: usize, i: usize) -> Range<usize> {
    let lo = (i * frame_raw).min(raw_len);
    lo..(lo + frame_raw).min(raw_len)
}

/// Compress `input` as a framed container (frame table + independently
/// compressed sub-frames), appending to `out`. Deterministic: the split
/// depends only on `input.len()` and `frame_raw`. Streams each frame
/// straight into `out` and back-patches the table — byte-identical to
/// [`assemble_framed`] over individually compressed frames (tested), so
/// parallel sealers can compress frames into separate buffers and
/// assemble without re-encoding the layout.
pub fn compress_framed(
    codec: &dyn Stage2Codec,
    input: &[u8],
    frame_raw: usize,
    out: &mut Vec<u8>,
) {
    let n = frame_count(input.len(), frame_raw);
    out.reserve(4 + 4 * n + input.len() / 2 + 64);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    let table = out.len();
    out.resize(table + 4 * n, 0);
    for i in 0..n {
        let span = frame_span(input.len(), frame_raw, i);
        let start = out.len();
        codec.compress_into(&input[span], out);
        let csize = (out.len() - start) as u32;
        out[table + 4 * i..table + 4 * (i + 1)].copy_from_slice(&csize.to_le_bytes());
    }
}

/// Assemble the framed-container wire layout from already-compressed
/// frame payloads (in frame order). This is the writer the parallel
/// sealer uses after fanning frame compression out across workers; its
/// bytes are identical to [`compress_framed`]'s for the same frames.
pub fn assemble_framed(frames: &[Vec<u8>], out: &mut Vec<u8>) {
    let total: usize = frames.iter().map(|f| f.len()).sum();
    out.reserve(4 + 4 * frames.len() + total);
    out.extend_from_slice(&(frames.len() as u32).to_le_bytes());
    for f in frames {
        out.extend_from_slice(&(f.len() as u32).to_le_bytes());
    }
    for f in frames {
        out.extend_from_slice(f);
    }
}

/// One parsed sub-frame: where its compressed bytes sit in the chunk
/// payload and which raw bytes it decodes to.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FrameEntry {
    /// Byte range of the compressed frame inside the chunk payload.
    pub payload: Range<usize>,
    /// Byte range of the decoded frame inside the uncompressed stream.
    pub raw: Range<usize>,
}

/// Parse and fully validate a framed chunk payload's frame table against
/// the raw length the chunk index promises. Every inconsistency — frame
/// count mismatch, table larger than the payload, sizes that do not sum
/// to the payload — is an error before any frame is touched, so a fuzzed
/// table can neither panic nor size an allocation.
pub fn parse_frame_table(
    payload: &[u8],
    raw_len: usize,
    frame_raw: usize,
) -> Result<Vec<FrameEntry>, String> {
    if frame_raw == 0 {
        return Err("frame_raw must be positive for framed payloads".into());
    }
    if payload.len() < 4 {
        return Err("framed payload shorter than its frame count".into());
    }
    let n = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    let expect = frame_count(raw_len, frame_raw);
    if n != expect {
        return Err(format!(
            "frame table claims {n} frames, raw length {raw_len} at {frame_raw}-byte frames needs {expect}"
        ));
    }
    let table_end = 4usize
        .checked_add(4 * n)
        .filter(|&e| e <= payload.len())
        .ok_or_else(|| "frame table overruns payload".to_string())?;
    let mut frames = Vec::with_capacity(n);
    let mut pos = table_end;
    for i in 0..n {
        let csize =
            u32::from_le_bytes(payload[4 + 4 * i..8 + 4 * i].try_into().unwrap()) as usize;
        let end = pos
            .checked_add(csize)
            .filter(|&e| e <= payload.len())
            .ok_or_else(|| format!("frame {i} overruns payload"))?;
        frames.push(FrameEntry { payload: pos..end, raw: frame_span(raw_len, frame_raw, i) });
        pos = end;
    }
    if pos != payload.len() {
        return Err(format!(
            "framed payload has {} trailing bytes after the last frame",
            payload.len() - pos
        ));
    }
    Ok(frames)
}

/// Decompress a framed payload (inverse of [`compress_framed`]),
/// appending exactly `raw_len` bytes to `out`. Each decoded frame is
/// length-checked against its fixed span.
pub fn decompress_framed(
    codec: &dyn Stage2Codec,
    payload: &[u8],
    raw_len: usize,
    frame_raw: usize,
    out: &mut Vec<u8>,
) -> Result<(), String> {
    let frames = parse_frame_table(payload, raw_len, frame_raw)?;
    out.reserve(raw_len);
    for (i, f) in frames.iter().enumerate() {
        let want = f.raw.len();
        let before = out.len();
        codec.decompress_into(&payload[f.payload.clone()], want, out)?;
        if out.len() - before != want {
            return Err(format!(
                "frame {i} decoded to {} bytes, expected {want}",
                out.len() - before
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Codec;
    use crate::util::prng::Pcg32;

    fn sample_data(rng: &mut Pcg32, n: usize) -> Vec<u8> {
        // mix of runs and noise so every codec has matches to find
        let mut v = Vec::with_capacity(n);
        while v.len() < n {
            if rng.below(2) == 0 {
                let b = rng.next_u32() as u8;
                for _ in 0..(1 + rng.below(40)) {
                    v.push(b);
                }
            } else {
                v.push(rng.next_u32() as u8);
            }
        }
        v.truncate(n);
        v
    }

    #[test]
    fn registry_matches_codec_enum() {
        assert_eq!(REGISTRY.len(), Codec::ALL.len());
        for c in Codec::ALL {
            let s = by_id(c.id()).expect("every Codec variant registered");
            assert_eq!(s.name(), c.name());
            assert_eq!(by_name(c.name()).unwrap().id(), c.id());
        }
        assert!(by_id(99).is_none());
        assert!(by_name("brotli").is_none());
    }

    #[test]
    fn aliases_and_case_resolve() {
        for (alias, want) in [
            ("zlib-def", 1u8),
            ("ZLIB-DEF", 1),
            ("z/def", 1),
            ("Zlib", 1),
            ("z/best", 2),
            ("ZLIB-BEST", 2),
            ("LZ4", 3),
            ("copy", 0),
            ("Lzma", 5),
        ] {
            let c = by_name(alias).unwrap_or_else(|| panic!("alias {alias} must resolve"));
            assert_eq!(c.id(), want, "{alias}");
        }
    }

    #[test]
    fn registry_roundtrips_all_codecs() {
        let mut rng = Pcg32::new(0x57A6E2);
        for n in [0usize, 1, 1000, 70_000] {
            let data = sample_data(&mut rng, n);
            for codec in REGISTRY {
                let mut comp = Vec::new();
                codec.compress_into(&data, &mut comp);
                let mut back = Vec::new();
                codec
                    .decompress_into(&comp, data.len(), &mut back)
                    .unwrap_or_else(|e| panic!("{} len {n}: {e}", codec.name()));
                assert_eq!(back, data, "{} len {n}", codec.name());
            }
        }
    }

    #[test]
    fn framed_roundtrips_at_every_boundary_shape() {
        let mut rng = Pcg32::new(0xF2A3E5);
        // exact multiple, tail, below one frame, empty
        for (n, frame_raw) in
            [(0usize, 64usize), (1, 64), (64, 64), (128, 64), (100, 64), (65, 64), (5000, 512)]
        {
            let data = sample_data(&mut rng, n);
            for codec in REGISTRY {
                let mut comp = Vec::new();
                compress_framed(codec, &data, frame_raw, &mut comp);
                // table is self-consistent
                let frames = parse_frame_table(&comp, n, frame_raw).unwrap();
                assert_eq!(frames.len(), frame_count(n, frame_raw));
                let mut back = Vec::new();
                decompress_framed(codec, &comp, n, frame_raw, &mut back)
                    .unwrap_or_else(|e| panic!("{} n {n} fr {frame_raw}: {e}", codec.name()));
                assert_eq!(back, data, "{} n {n} fr {frame_raw}", codec.name());
            }
        }
    }

    #[test]
    fn assemble_framed_matches_compress_framed() {
        // the two container writers must never drift: streaming+patch and
        // assemble-from-parts produce the same bytes
        let mut rng = Pcg32::new(0xA55E);
        for (n, frame_raw) in [(0usize, 64usize), (64, 64), (100, 64), (5000, 512)] {
            let data = sample_data(&mut rng, n);
            for codec in REGISTRY {
                let mut streamed = Vec::new();
                compress_framed(codec, &data, frame_raw, &mut streamed);
                let frames: Vec<Vec<u8>> = (0..frame_count(n, frame_raw))
                    .map(|i| {
                        let mut f = Vec::new();
                        codec.compress_into(&data[frame_span(n, frame_raw, i)], &mut f);
                        f
                    })
                    .collect();
                let mut assembled = Vec::new();
                assemble_framed(&frames, &mut assembled);
                assert_eq!(assembled, streamed, "{} n {n} fr {frame_raw}", codec.name());
            }
        }
    }

    #[test]
    fn frame_spans_tile_the_stream() {
        for (len, fr) in [(0usize, 8usize), (7, 8), (8, 8), (9, 8), (1000, 128)] {
            let n = frame_count(len, fr);
            let mut covered = 0usize;
            for i in 0..n {
                let s = frame_span(len, fr, i);
                assert_eq!(s.start, covered);
                covered = s.end;
            }
            assert_eq!(covered, len, "len {len} fr {fr}");
        }
    }

    #[test]
    fn fuzzed_frame_tables_error_not_panic() {
        let mut rng = Pcg32::new(0xBAD7AB);
        let data = sample_data(&mut rng, 4000);
        for codec in REGISTRY {
            let mut comp = Vec::new();
            compress_framed(codec, &data, 512, &mut comp);
            // wrong frame count
            let mut bad = comp.clone();
            bad[0] ^= 0xFF;
            assert!(
                decompress_framed(codec, &bad, data.len(), 512, &mut Vec::new()).is_err(),
                "{}: corrupt frame count must error",
                codec.name()
            );
            // frame size pointing past the payload
            let mut bad = comp.clone();
            bad[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
            assert!(
                decompress_framed(codec, &bad, data.len(), 512, &mut Vec::new()).is_err(),
                "{}: overlong frame size must error",
                codec.name()
            );
            // truncated mid-frame
            for cut in [comp.len() / 2, comp.len() - 1, 5, 3, 0] {
                assert!(
                    decompress_framed(codec, &comp[..cut], data.len(), 512, &mut Vec::new())
                        .is_err(),
                    "{}: truncation at {cut} must error",
                    codec.name()
                );
            }
            // random garbage bytes must never panic (error or garbage-free
            // success are both acceptable outcomes for the None codec)
            for _ in 0..50 {
                let n = rng.below(200) as usize;
                let garbage: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
                let _ = decompress_framed(codec, &garbage, data.len(), 512, &mut Vec::new());
            }
        }
    }

    #[test]
    fn huge_claimed_length_is_rejected_before_allocating() {
        // a 4-byte prefix claiming 4 GiB must be refused by the limit
        // check, not reserved for
        let mut crafted = u32::MAX.to_le_bytes().to_vec();
        crafted.extend_from_slice(&[0u8; 64]);
        for codec in REGISTRY {
            if codec.id() == 0 {
                continue; // copy codec has no length prefix
            }
            let err = codec
                .decompress_into(&crafted, 1 << 20, &mut Vec::new())
                .expect_err("oversized claim must error");
            assert!(err.contains("limit"), "{}: {err}", codec.name());
        }
        // the copy codec enforces the limit on its actual length
        let big = vec![0u8; 2048];
        assert!(NoneCodec.decompress_into(&big, 100, &mut Vec::new()).is_err());
    }

    #[test]
    fn effort_classes_cover_the_paper_ordering() {
        assert_eq!(by_name("lz4").unwrap().effort(), Effort::Fast);
        assert_eq!(by_name("zlib").unwrap().effort(), Effort::Balanced);
        assert_eq!(by_name("lzma").unwrap().effort(), Effort::Best);
        assert_eq!(by_name("zlib-best").unwrap().effort(), Effort::Best);
    }
}
