//! Hash-chain LZ77 match finder shared by `czlib`, `zstdlite` and
//! `lzmalite`. Produces (literal-run, match) token streams.
pub const MIN_MATCH: usize = 3;
pub const MAX_MATCH: usize = 258;

/// One LZ77 token: either a literal byte or a back-reference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Token {
    Literal(u8),
    Match { len: u32, dist: u32 },
}

/// Match-finder configuration (the codec "effort level").
#[derive(Clone, Copy, Debug)]
pub struct Params {
    /// Window size (power of two).
    pub window: usize,
    /// Max hash-chain entries examined per position.
    pub max_chain: usize,
    /// Lazy matching: defer a match if the next position matches longer.
    pub lazy: bool,
    /// Stop searching early once a match of this length is found.
    pub good_enough: usize,
    /// Minimum match length to accept (>= MIN_MATCH).
    pub min_match: usize,
}

impl Params {
    pub fn fast() -> Self {
        Self { window: 1 << 16, max_chain: 4, lazy: false, good_enough: 32, min_match: 3 }
    }
    pub fn default_level() -> Self {
        Self { window: 1 << 15, max_chain: 16, lazy: false, good_enough: 64, min_match: 3 }
    }
    pub fn best() -> Self {
        Self { window: 1 << 15, max_chain: 512, lazy: true, good_enough: MAX_MATCH, min_match: 3 }
    }
    pub fn deep() -> Self {
        Self { window: 1 << 20, max_chain: 256, lazy: true, good_enough: MAX_MATCH, min_match: 3 }
    }
}

const HASH_BITS: usize = 16;
const HASH_SIZE: usize = 1 << HASH_BITS;

#[inline(always)]
fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

/// Reusable hash-chain state (no allocation per call after the first).
pub struct MatchFinder {
    head: Vec<i32>,
    prev: Vec<i32>,
    params: Params,
}

impl MatchFinder {
    pub fn new(params: Params) -> Self {
        Self { head: vec![-1; HASH_SIZE], prev: Vec::new(), params }
    }

    /// Find the longest match at position `i` of `data`; returns (len, dist).
    #[inline]
    fn longest_match(&self, data: &[u8], i: usize) -> (usize, usize) {
        let p = &self.params;
        let end = data.len();
        let max_len = (end - i).min(MAX_MATCH);
        if max_len < p.min_match {
            return (0, 0);
        }
        let mut best_len = p.min_match - 1;
        let mut best_dist = 0usize;
        let mut cand = self.head[hash4(data, i)];
        let min_pos = i.saturating_sub(p.window) as i64;
        let mut chain = p.max_chain;
        while cand >= 0 && (cand as i64) >= min_pos && chain > 0 {
            let c = cand as usize;
            // quick reject on the byte just past the current best
            if i + best_len < end && data[c + best_len] == data[i + best_len] {
                let mut l = 0usize;
                while l < max_len && data[c + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = i - c;
                    if l >= p.good_enough {
                        break;
                    }
                }
            }
            cand = self.prev[c];
            chain -= 1;
        }
        if best_len >= p.min_match {
            (best_len, best_dist)
        } else {
            (0, 0)
        }
    }

    #[inline(always)]
    fn insert(&mut self, data: &[u8], i: usize) {
        if i + 4 <= data.len() {
            let h = hash4(data, i);
            self.prev[i] = self.head[h];
            self.head[h] = i as i32;
        }
    }

    /// Tokenize `data`, invoking `emit` for each token in order.
    pub fn tokenize(&mut self, data: &[u8], mut emit: impl FnMut(Token)) {
        self.head.fill(-1);
        self.prev.clear();
        self.prev.resize(data.len(), -1);
        let n = data.len();
        let mut i = 0usize;
        while i < n {
            if i + 4 > n {
                emit(Token::Literal(data[i]));
                i += 1;
                continue;
            }
            let (mut len, mut dist) = self.longest_match(data, i);
            if len == 0 {
                self.insert(data, i);
                emit(Token::Literal(data[i]));
                i += 1;
                continue;
            }
            if self.params.lazy && i + 1 + 4 <= n {
                // peek one ahead: if strictly longer there, emit literal now
                self.insert(data, i);
                let (len2, dist2) = self.longest_match(data, i + 1);
                if len2 > len {
                    emit(Token::Literal(data[i]));
                    i += 1;
                    len = len2;
                    dist = dist2;
                }
            } else {
                self.insert(data, i);
            }
            emit(Token::Match { len: len as u32, dist: dist as u32 });
            // insert positions covered by the match (bounded for speed)
            let insert_to = (i + len).min(n.saturating_sub(4));
            let mut j = i + 1;
            let step_limit = 64; // cap chain maintenance inside long matches
            while j < insert_to && j < i + step_limit {
                self.insert(data, j);
                j += 1;
            }
            i += len;
        }
    }
}

/// Reconstruct bytes from a token stream (shared by all LZ decoders).
pub fn expand(tokens: impl IntoIterator<Item = Token>, out: &mut Vec<u8>) -> Result<(), String> {
    for t in tokens {
        match t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let dist = dist as usize;
                let len = len as usize;
                if dist == 0 || dist > out.len() {
                    return Err(format!("bad distance {dist} at out len {}", out.len()));
                }
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;

    fn roundtrip(params: Params, data: &[u8]) {
        let mut mf = MatchFinder::new(params);
        let mut tokens = Vec::new();
        mf.tokenize(data, |t| tokens.push(t));
        let mut out = Vec::new();
        expand(tokens, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn tokenize_roundtrips_all_levels() {
        let data = b"abcabcabcabcabc hello hello hello world world";
        for p in [Params::fast(), Params::default_level(), Params::best(), Params::deep()] {
            roundtrip(p, data);
        }
    }

    #[test]
    fn finds_long_repeats() {
        let mut data = vec![0u8; 0];
        data.extend_from_slice(b"0123456789abcdef");
        for _ in 0..100 {
            data.extend_from_slice(b"0123456789abcdef");
        }
        let mut mf = MatchFinder::new(Params::default_level());
        let mut matches = 0usize;
        let mut literals = 0usize;
        mf.tokenize(&data, |t| match t {
            Token::Literal(_) => literals += 1,
            Token::Match { .. } => matches += 1,
        });
        assert!(literals <= 16 + 3, "literals {literals}");
        assert!(matches >= 4);
    }

    #[test]
    fn random_data_roundtrips() {
        prop_cases(0x77, 15, |rng, _| {
            let n = rng.below(30_000) as usize;
            let mut data = vec![0u8; n];
            // mix of random and repetitive sections
            let mut i = 0;
            while i < n {
                if rng.below(2) == 0 {
                    let run = (rng.below(100) as usize).min(n - i);
                    let b = rng.next_u32() as u8;
                    for _ in 0..run {
                        data[i] = b;
                        i += 1;
                    }
                } else {
                    data[i] = rng.next_u32() as u8;
                    i += 1;
                }
            }
            roundtrip(Params::default_level(), &data);
            roundtrip(Params::best(), &data);
        });
    }

    #[test]
    fn expand_rejects_bad_distance() {
        let mut out = Vec::new();
        assert!(expand([Token::Match { len: 3, dist: 5 }], &mut out).is_err());
    }

    #[test]
    fn overlapping_match_expands_correctly() {
        // RLE-style: dist 1, len 10
        let mut out = vec![b'x'];
        expand([Token::Match { len: 10, dist: 1 }], &mut out).unwrap();
        assert_eq!(out, vec![b'x'; 11]);
    }
}
