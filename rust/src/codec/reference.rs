//! Reference baselines: the real zlib (`flate2`) and zstd crates, used
//! ONLY to validate the from-scratch codecs (ratio and speed sanity in
//! tests/benches). The compression pipeline never calls these.
use std::io::{Read, Write};

/// Real zlib deflate at the given level (default 6, best 9).
pub fn zlib_compress(input: &[u8], level: u32) -> Vec<u8> {
    let mut e = flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::new(level));
    e.write_all(input).unwrap();
    e.finish().unwrap()
}

/// Real zlib inflate.
pub fn zlib_decompress(input: &[u8]) -> Vec<u8> {
    let mut d = flate2::read::ZlibDecoder::new(input);
    let mut out = Vec::new();
    d.read_to_end(&mut out).unwrap();
    out
}

/// Real zstd at the given level (default 3).
pub fn zstd_compress(input: &[u8], level: i32) -> Vec<u8> {
    zstd::bulk::compress(input, level).unwrap()
}

/// Real zstd decompress (capacity must be known or bounded).
pub fn zstd_decompress(input: &[u8], capacity: usize) -> Vec<u8> {
    zstd::bulk::decompress(input, capacity).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Codec;
    use crate::util::prng::Pcg32;

    fn float_like_payload() -> Vec<u8> {
        let mut rng = Pcg32::new(0xFEED);
        let mut data = Vec::new();
        let mut v = 0.0f32;
        for _ in 0..60_000 {
            v += rng.next_f32() * 0.01 - 0.005;
            data.extend_from_slice(&v.to_le_bytes());
        }
        crate::codec::shuffle::byte_shuffle(&data, 4)
    }

    #[test]
    fn reference_roundtrips() {
        let data = float_like_payload();
        assert_eq!(zlib_decompress(&zlib_compress(&data, 6)), data);
        assert_eq!(zstd_decompress(&zstd_compress(&data, 3), data.len()), data);
    }

    #[test]
    fn czlib_ratio_within_2x_of_real_zlib() {
        // the from-scratch codec must land in the same ratio class as the
        // library it stands in for
        let data = float_like_payload();
        let ours = Codec::ZlibDef.compress_vec(&data).len() as f64;
        let real = zlib_compress(&data, 6).len() as f64;
        assert!(
            ours < real * 1.5,
            "czlib {ours} bytes vs real zlib {real} bytes"
        );
        assert!(
            ours > real * 0.5,
            "suspiciously better than zlib: czlib {ours} vs {real}"
        );
    }

    #[test]
    fn lzma_beats_real_zlib_default() {
        let data = float_like_payload();
        let lzma = Codec::Lzma.compress_vec(&data).len() as f64;
        let real = zlib_compress(&data, 6).len() as f64;
        assert!(lzma < real * 1.1, "lzmalite {lzma} vs real zlib {real}");
    }
}
