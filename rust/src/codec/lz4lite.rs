//! `lz4lite`: from-scratch byte-aligned greedy LZ (the LZ4 family's
//! format): fastest codec in the suite, lower compression ratio — the
//! paper's positioning for LZ4 (§2.3).
//!
//! Sequence format (after a `u32` raw length header):
//! `[token: hi=literal run, lo=match len-4][run ext*][literals][u16 offset][len ext*]`
//! Extension bytes add 255 each, terminated by a byte < 255. A final
//! sequence may have match length 0 (token low nibble 0xF is still a
//! match of >= 19; a trailing literal-only sequence ends with offset 0).

const MIN_MATCH: usize = 4;
const HASH_BITS: usize = 16;

#[inline(always)]
fn hash4(v: u32) -> usize {
    (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

#[inline(always)]
fn read_u32(data: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]])
}

fn write_len(out: &mut Vec<u8>, mut v: usize) {
    while v >= 255 {
        out.push(255);
        v -= 255;
    }
    out.push(v as u8);
}

fn read_len(input: &[u8], pos: &mut usize) -> Result<usize, String> {
    let mut v = 0usize;
    loop {
        let b = *input.get(*pos).ok_or("truncated length")?;
        *pos += 1;
        v += b as usize;
        if b < 255 {
            return Ok(v);
        }
    }
}

/// Compress `input`, appending to `out`.
pub fn compress(input: &[u8], out: &mut Vec<u8>) {
    out.extend_from_slice(&(input.len() as u32).to_le_bytes());
    let n = input.len();
    if n == 0 {
        return;
    }
    let mut head = vec![-1i64; 1 << HASH_BITS];
    let mut i = 0usize;
    let mut lit_start = 0usize;
    // match window limited to u16 offsets
    const WINDOW: usize = 65535;
    while i + MIN_MATCH <= n {
        let h = hash4(read_u32(input, i));
        let cand = head[h];
        head[h] = i as i64;
        let ok = cand >= 0
            && i - cand as usize <= WINDOW
            && read_u32(input, cand as usize) == read_u32(input, i);
        if !ok {
            i += 1;
            continue;
        }
        let c = cand as usize;
        let mut len = MIN_MATCH;
        while i + len < n && input[c + len] == input[i + len] {
            len += 1;
        }
        // emit sequence: literals [lit_start, i) + match (len, dist)
        let lit_len = i - lit_start;
        let dist = i - c;
        let token_lit = lit_len.min(15);
        let token_match = (len - MIN_MATCH).min(15);
        out.push(((token_lit as u8) << 4) | token_match as u8);
        if token_lit == 15 {
            write_len(out, lit_len - 15);
        }
        out.extend_from_slice(&input[lit_start..i]);
        out.extend_from_slice(&(dist as u16).to_le_bytes());
        if token_match == 15 {
            write_len(out, len - MIN_MATCH - 15);
        }
        // insert a few positions inside the match to help the next search
        let insert_to = (i + len).min(n - MIN_MATCH);
        let mut j = i + 1;
        while j < insert_to && j < i + 16 {
            head[hash4(read_u32(input, j))] = j as i64;
            j += 1;
        }
        i += len;
        lit_start = i;
    }
    // trailing literal-only sequence (offset 0 marks "no match")
    let lit_len = n - lit_start;
    let token_lit = lit_len.min(15);
    out.push((token_lit as u8) << 4);
    if token_lit == 15 {
        write_len(out, lit_len - 15);
    }
    out.extend_from_slice(&input[lit_start..]);
    out.extend_from_slice(&0u16.to_le_bytes());
}

/// Decompress a full lz4lite stream, appending to `out`.
pub fn decompress(input: &[u8], out: &mut Vec<u8>) -> Result<(), String> {
    if input.len() < 4 {
        return Err("missing header".into());
    }
    let raw_len = u32::from_le_bytes(input[0..4].try_into().unwrap()) as usize;
    let out_start = out.len();
    out.reserve(raw_len);
    let mut pos = 4usize;
    loop {
        if out.len() - out_start == raw_len && pos == input.len() {
            return Ok(());
        }
        let token = *input.get(pos).ok_or("truncated token")?;
        pos += 1;
        let mut lit_len = (token >> 4) as usize;
        if lit_len == 15 {
            lit_len += read_len(input, &mut pos)?;
        }
        if input.len() < pos + lit_len {
            return Err("truncated literals".into());
        }
        out.extend_from_slice(&input[pos..pos + lit_len]);
        pos += lit_len;
        if input.len() < pos + 2 {
            return Err("truncated offset".into());
        }
        let dist = u16::from_le_bytes(input[pos..pos + 2].try_into().unwrap()) as usize;
        pos += 2;
        if dist == 0 {
            // terminal literal-only sequence
            if out.len() - out_start != raw_len {
                return Err("length mismatch at terminator".into());
            }
            return Ok(());
        }
        let mut mlen = (token & 0xf) as usize;
        if mlen == 15 {
            mlen += read_len(input, &mut pos)?;
        }
        let mlen = mlen + MIN_MATCH;
        if dist > out.len() - out_start {
            return Err(format!("distance {dist} out of range"));
        }
        if out.len() - out_start + mlen > raw_len {
            return Err("match overruns output".into());
        }
        let start = out.len() - dist;
        for k in 0..mlen {
            let b = out[start + k];
            out.push(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::prop::prop_cases;

    fn roundtrip(data: &[u8]) -> usize {
        let mut comp = Vec::new();
        compress(data, &mut comp);
        let mut back = Vec::new();
        decompress(&comp, &mut back).unwrap();
        assert_eq!(back, data);
        comp.len()
    }

    #[test]
    fn basic_cases() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abcd");
        roundtrip(b"aaaaaaaaaaaaaaaaaaaaaaaaaaaa");
        roundtrip(&vec![0u8; 1_000_000]);
    }

    #[test]
    fn compresses_runs_hard() {
        let size = roundtrip(&vec![42u8; 100_000]);
        assert!(size < 600, "size {size}");
    }

    #[test]
    fn long_literal_runs() {
        let mut rng = Pcg32::new(4);
        let data: Vec<u8> = (0..70_000).map(|_| rng.next_u32() as u8).collect();
        let size = roundtrip(&data);
        // incompressible: bounded expansion
        assert!(size <= data.len() + data.len() / 250 + 64);
    }

    #[test]
    fn prop_roundtrip_mixed() {
        prop_cases(0x44, 20, |rng, _| {
            let n = rng.below(80_000) as usize;
            let mut data = Vec::with_capacity(n);
            while data.len() < n {
                if rng.below(2) == 0 && data.len() > 8 {
                    let back = 1 + rng.below(data.len().min(60_000) as u32) as usize;
                    let len = (4 + rng.below(40) as usize).min(n - data.len());
                    let start = data.len() - back;
                    for k in 0..len {
                        let b = data[(start + k).min(data.len() - 1)];
                        data.push(b);
                    }
                } else {
                    data.push(rng.below(7) as u8);
                }
            }
            roundtrip(&data);
        });
    }

    #[test]
    fn corrupt_input_errors_not_panics() {
        let mut comp = Vec::new();
        compress(b"hello world hello world hello world", &mut comp);
        for i in 0..comp.len() {
            let mut bad = comp.clone();
            bad[i] = bad[i].wrapping_add(13);
            let mut out = Vec::new();
            let _ = decompress(&bad, &mut out);
        }
        // truncation either errors or (if only the 3-byte terminator was
        // cut) still yields the complete output — never panics
        let orig = b"hello world hello world hello world";
        for cut in 1..comp.len().min(8) {
            let mut out = Vec::new();
            match decompress(&comp[..comp.len() - cut], &mut out) {
                Ok(()) => assert_eq!(out, orig),
                Err(_) => {}
            }
        }
    }
}
