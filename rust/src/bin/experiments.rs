//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §3 for the index and §4 for the scaled
//! sizes). Each subcommand prints paper-style rows; `all` runs everything.
//!
//! Usage: `cargo run --release --bin experiments -- <table1|fig3|...|all>
//!         [--size N]`
use cubismz::codec::Codec;
use cubismz::core::{Field3, FieldStats};
use cubismz::io::throughput;
use cubismz::metrics::psnr;
use cubismz::pipeline::{
    compress_field, decompress_field, CoeffCodec, NativeEngine, PipelineConfig, ShuffleMode,
    Stage1,
};
use cubismz::scaling::{self, Calibration, Platform};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::util::Timer;
use cubismz::wavelet::WaveletKind;

const WAVELETS: [WaveletKind; 3] = [WaveletKind::Interp4, WaveletKind::Lift4, WaveletKind::Avg3];

fn wavelet_cfg(kind: WaveletKind, eps: f32) -> PipelineConfig {
    PipelineConfig::new(
        32,
        Stage1::Wavelet { kind, eps_rel: eps, zbits: 0, coeff: CoeffCodec::None },
        Codec::ZlibDef,
    )
    .with_shuffle(ShuffleMode::Byte4)
}

/// Compress + decompress, returning (CR, PSNR, comp secs, decomp secs).
fn run_cfg(f: &Field3, cfg: &PipelineConfig) -> (f64, f64, f64, f64) {
    let t = Timer::start();
    let (bytes, st) = compress_field(f, "q", cfg, &NativeEngine);
    let tc = t.secs();
    let t = Timer::start();
    let (back, _) = decompress_field(&bytes, &NativeEngine).expect("decompress");
    let td = t.secs();
    (st.ratio(), psnr(&f.data, &back.data).expect("psnr defined"), tc, td)
}

fn table1(n: usize) {
    println!("== Table 1: QoI statistics (n={n}^3; paper: 512^3, 70 bubbles) ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    for step in [5000usize, 10000] {
        println!("after {step} steps:");
        println!("{:>5} {:>10} {:>10} {:>10} {:>10}", "QoI", "Min", "Max", "Mean", "StDev");
        for q in Qoi::ALL {
            let f = sim.field(q, step_to_time(step));
            let s = FieldStats::compute(&f.data);
            println!(
                "{:>5} {:>10.1e} {:>10.1e} {:>10.1e} {:>10.1e}",
                q.name(),
                s.min,
                s.max,
                s.mean,
                s.stddev
            );
        }
    }
}

fn fig3(n: usize) {
    println!("== Fig 3: CR + PSNR vs simulation step, 3 wavelet types, eps=1e-3 (n={n}^3) ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    for q in Qoi::ALL {
        println!("--- QoI {} ---", q.name());
        println!(
            "{:>6} {:>10} | {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8}",
            "step", "peak p", "CR W4", "CR W4li", "CR W3ai", "dB W4", "dB W4li", "dB W3ai"
        );
        for step in (1000..=12000).step_by(1000) {
            let t = step_to_time(step);
            let f = sim.field(q, t);
            let mut crs = Vec::new();
            let mut dbs = Vec::new();
            for kind in WAVELETS {
                let (cr, db, _, _) = run_cfg(&f, &wavelet_cfg(kind, 1e-3));
                crs.push(cr);
                dbs.push(db);
            }
            println!(
                "{:>6} {:>10.1} | {:>8.1} {:>8.1} {:>8.1} | {:>8.1} {:>8.1} {:>8.1}",
                step,
                sim.peak_pressure(t),
                crs[0],
                crs[1],
                crs[2],
                dbs[0],
                dbs[1],
                dbs[2]
            );
        }
    }
}

fn fig4(n: usize) {
    println!("== Fig 4 / Exp 1: CR vs PSNR per wavelet type, p & rho at 10k (n={n}^3) ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    for q in [Qoi::Pressure, Qoi::Density] {
        let f = sim.field(q, step_to_time(10000));
        println!("--- QoI {} ---", q.name());
        println!("{:>6} {:>10} {:>10} {:>10}", "type", "eps", "CR", "PSNR dB");
        for kind in WAVELETS {
            for eps in [1e-1f32, 1e-2, 1e-3, 1e-4, 1e-5] {
                let (cr, db, _, _) = run_cfg(&f, &wavelet_cfg(kind, eps));
                println!("{:>6} {:>10.0e} {:>10.2} {:>10.1}", kind.name(), eps, cr, db);
            }
        }
    }
}

fn fig5(n: usize) {
    println!("== Fig 5 / Exp 2: shuffle + bit zeroing (W3ai), p & rho at 10k (n={n}^3) ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    for q in [Qoi::Pressure, Qoi::Density] {
        let f = sim.field(q, step_to_time(10000));
        println!("--- QoI {} ---", q.name());
        println!("{:>12} {:>10} {:>10} {:>10}", "variant", "eps", "CR", "PSNR dB");
        for eps in [1e-2f32, 1e-3, 1e-4] {
            for (label, zbits, shuffle) in [
                ("plain", 0u8, ShuffleMode::None),
                ("shuf", 0, ShuffleMode::Byte4),
                ("shuf+Z4", 4, ShuffleMode::Byte4),
                ("shuf+Z8", 8, ShuffleMode::Byte4),
            ] {
                let cfg = PipelineConfig::new(
                    32,
                    Stage1::Wavelet {
                        kind: WaveletKind::Avg3,
                        eps_rel: eps,
                        zbits,
                        coeff: CoeffCodec::None,
                    },
                    Codec::ZlibDef,
                )
                .with_shuffle(shuffle);
                let (cr, db, _, _) = run_cfg(&f, &cfg);
                println!("{:>12} {:>10.0e} {:>10.2} {:>10.1}", label, eps, cr, db);
            }
        }
    }
}

fn fig6(n: usize) {
    println!("== Fig 6 / Exp 3: block size effect (W3ai+shuf+zlib), p & rho at 10k (n={n}^3) ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    for q in [Qoi::Pressure, Qoi::Density] {
        let f = sim.field(q, step_to_time(10000));
        println!("--- QoI {} ---", q.name());
        println!("{:>6} {:>10} {:>10} {:>10}", "bs", "eps", "CR", "PSNR dB");
        for bs in [8usize, 16, 32, 64] {
            for eps in [1e-2f32, 1e-3, 1e-4] {
                let mut cfg = wavelet_cfg(WaveletKind::Avg3, eps);
                cfg.bs = bs;
                let (cr, db, _, _) = run_cfg(&f, &cfg);
                println!("{:>6} {:>10.0e} {:>10.2} {:>10.1}", bs, eps, cr, db);
            }
        }
    }
}

fn methods_sweep(f: &Field3) {
    println!("{:>10} {:>12} {:>10} {:>10}", "method", "param", "CR", "PSNR dB");
    for eps in [1e-1f32, 1e-2, 1e-3, 1e-4, 1e-5] {
        let (cr, db, _, _) = run_cfg(f, &wavelet_cfg(WaveletKind::Avg3, eps));
        println!("{:>10} {:>12.0e} {:>10.2} {:>10.1}", "wavelets", eps, cr, db);
    }
    for tol in [1e-1f32, 1e-2, 1e-3, 1e-4, 1e-5] {
        let cfg = PipelineConfig::new(32, Stage1::Zfp { tol_rel: tol }, Codec::None);
        let (cr, db, _, _) = run_cfg(f, &cfg);
        println!("{:>10} {:>12.0e} {:>10.2} {:>10.1}", "zfp", tol, cr, db);
    }
    for eb in [1e-1f32, 1e-2, 1e-3, 1e-4, 1e-5] {
        let cfg = PipelineConfig::new(32, Stage1::Sz { eb_rel: eb }, Codec::None);
        let (cr, db, _, _) = run_cfg(f, &cfg);
        println!("{:>10} {:>12.0e} {:>10.2} {:>10.1}", "sz", eb, cr, db);
    }
    for prec in [12u8, 16, 20, 24, 28] {
        let cfg = PipelineConfig::new(32, Stage1::Fpzip { prec }, Codec::None);
        let (cr, db, _, _) = run_cfg(f, &cfg);
        println!("{:>10} {:>12} {:>10.2} {:>10.1}", "fpzip", prec, cr, db);
    }
}

fn fig7(n: usize) {
    println!("== Fig 7: PSNR vs CR for all methods, 4 QoIs at 5k and 10k (n={n}^3) ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    for step in [5000usize, 10000] {
        for q in Qoi::ALL {
            println!("--- {} after {step} steps ---", q.name());
            let f = sim.field(q, step_to_time(step));
            methods_sweep(&f);
        }
    }
}

fn fig8(n: usize) {
    // paper: 1024^3 and 2048^3 vs Fig 7's 512^3; here resolution doubles
    // from the fig7 baseline (DESIGN.md §4 scaling)
    println!("== Fig 8: resolution effect (paper 1024^3/2048^3 -> here {n}^3 & {}^3) ==", 2 * n);
    for res in [n, 2 * n] {
        let sim = CloudSim::new(CloudConfig::paper(res));
        for q in [Qoi::Pressure, Qoi::Density] {
            println!("--- {} at {res}^3, 10k steps ---", q.name());
            let f = sim.field(q, step_to_time(10000));
            methods_sweep(&f);
        }
    }
}

fn table2(n: usize) {
    println!("== Table 2: FP compression of wavelet coefficients (W3ai, p at 10k, n={n}^3) ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    println!("{:>14} {:>12} {:>10} {:>10}", "variant", "eps", "CR", "PSNR dB");
    for eps in [1e-4f32, 1e-3, 1e-2] {
        for (label, coeff, shuffle) in [
            ("+FPZIP+ZLIB", CoeffCodec::Fpzip, ShuffleMode::None),
            ("+SZ+ZLIB", CoeffCodec::Sz, ShuffleMode::None),
            ("+SPDP+ZLIB", CoeffCodec::Spdp, ShuffleMode::None),
            ("+ZLIB", CoeffCodec::None, ShuffleMode::None),
            ("+SHUF+ZLIB", CoeffCodec::None, ShuffleMode::Byte4),
        ] {
            let cfg = PipelineConfig::new(
                32,
                Stage1::Wavelet { kind: WaveletKind::Avg3, eps_rel: eps, zbits: 0, coeff },
                Codec::ZlibDef,
            )
            .with_shuffle(shuffle);
            let (cr, db, _, _) = run_cfg(&f, &cfg);
            println!("{:>14} {:>12.0e} {:>10.2} {:>10.1}", label, eps, cr, db);
        }
    }
}

fn table3(n: usize) {
    println!("== Table 3: CR + comp/decomp speed (MB/s), p at 10k (n={n}^3), PSNR-matched ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    let mb = f.nbytes() as f64 / 1e6;
    println!(
        "{:>22} {:>8} {:>10} {:>10} {:>10}",
        "scheme", "CR", "comp MB/s", "dec MB/s", "PSNR dB"
    );
    let w = |stage2, shuffle| {
        PipelineConfig::new(
            32,
            Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 1e-3,
                zbits: 0,
                coeff: CoeffCodec::None,
            },
            stage2,
        )
        .with_shuffle(shuffle)
    };
    let rows: Vec<(&str, PipelineConfig)> = vec![
        ("W3ai+ZLIB", w(Codec::ZlibDef, ShuffleMode::None)),
        ("W3ai+SHUF+ZLIB", w(Codec::ZlibDef, ShuffleMode::Byte4)),
        ("W3ai+SHUF+ZSTD", w(Codec::Zstd, ShuffleMode::Byte4)),
        ("W3ai+SHUF+LZ4", w(Codec::Lz4, ShuffleMode::Byte4)),
        ("ZFP", PipelineConfig::new(32, Stage1::Zfp { tol_rel: 8e-4 }, Codec::None)),
        ("SZ", PipelineConfig::new(32, Stage1::Sz { eb_rel: 8e-4 }, Codec::None)),
        ("FPZIP (prec 20)", PipelineConfig::new(32, Stage1::Fpzip { prec: 20 }, Codec::None)),
        (
            "SHUF+ZLIB (lossless)",
            PipelineConfig::new(32, Stage1::Copy, Codec::ZlibDef).with_shuffle(ShuffleMode::Byte4),
        ),
        (
            "SHUF+ZSTD (lossless)",
            PipelineConfig::new(32, Stage1::Copy, Codec::Zstd).with_shuffle(ShuffleMode::Byte4),
        ),
    ];
    for (label, cfg) in rows {
        let (cr, db, tc, td) = run_cfg(&f, &cfg);
        println!(
            "{:>22} {:>8.2} {:>10.0} {:>10.0} {:>10.1}",
            label,
            cr,
            mb / tc,
            mb / td,
            db
        );
    }
}

fn table4(n: usize) {
    println!("== Table 4: W3ai + Z/DEF vs Z/BEST (p at 10k, n={n}^3) ==");
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    println!(
        "{:>10} {:>10} | {:>8} {:>8} | {:>8} {:>8}",
        "eps", "PSNR dB", "CR(def)", "T1 s", "CR(best)", "T1 s"
    );
    for eps in [1e-4f32, 1e-3, 1e-2] {
        let mut row = Vec::new();
        let mut db_out = 0.0;
        for level in [Codec::ZlibDef, Codec::ZlibBest] {
            let cfg = PipelineConfig::new(
                32,
                Stage1::Wavelet {
                    kind: WaveletKind::Avg3,
                    eps_rel: eps,
                    zbits: 0,
                    coeff: CoeffCodec::None,
                },
                level,
            )
            .with_shuffle(ShuffleMode::Byte4);
            let (cr, db, tc, _) = run_cfg(&f, &cfg);
            row.push((cr, tc));
            db_out = db;
        }
        println!(
            "{:>10.0e} {:>10.1} | {:>8.2} {:>8.2} | {:>8.2} {:>8.2}",
            eps, db_out, row[0].0, row[0].1, row[1].0, row[1].1
        );
    }
}

/// Calibrate the scaling model from a real single-core run.
fn calibrate(n: usize, eps: f32) -> (Calibration, usize) {
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    let cfg = wavelet_cfg(WaveletKind::Avg3, eps);
    let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
    let nblocks = st.nblocks;
    let stage1_bytes: f64 = {
        // raw chunk bytes before stage 2
        let (file, _) = cubismz::pipeline::CzbFile::parse_header(&bytes).unwrap();
        file.chunks.iter().map(|c| c.rawsize as f64).sum::<f64>() / nblocks as f64
    };
    (
        Calibration {
            t1_per_block: st.t_stage1 / nblocks as f64,
            t2_per_byte: st.t_stage2 / (stage1_bytes * nblocks as f64).max(1.0),
            stage1_bytes_per_block: stage1_bytes,
            mem_bound_frac: 0.35,
        },
        nblocks,
    )
}

fn fig9(n: usize) {
    println!("== Fig 9: multicore scaling, wavelets+zlib (calibrated model; n={n}^3) ==");
    println!("(1-core costs measured on the real pipeline; >1 core replays the");
    println!(" OpenMP static schedule through the DESIGN.md S10 cost model)");
    let disk = throughput::measure_write(&std::env::temp_dir().join("czb_bw.bin"), 32 << 20)
        .map(|s| s.bytes as f64 / s.secs)
        .unwrap_or(500e6);
    let plat = Platform::daint_like(disk);
    for eps in [1e-4f32, 1e-3] {
        let (cal, nblocks) = calibrate(n, eps);
        println!("--- eps = {eps:.0e} ({nblocks} blocks) ---");
        println!("{:>7} {:>12} {:>9}", "cores", "time s", "speedup");
        for (p, t, s) in scaling::speedups(&cal, &plat, nblocks, &[1, 2, 4, 6, 8, 12]) {
            println!("{:>7} {:>12.4} {:>9.2}", p, t, s);
        }
    }
}

fn fig10(n: usize) {
    println!("== Fig 10: multi-process scaling of the four methods (model; n={n}^3) ==");
    let plat = Platform::daint_like(500e6);
    let sim = CloudSim::new(CloudConfig::paper(n));
    let f = sim.field(Qoi::Pressure, step_to_time(10000));
    let schemes: Vec<(&str, PipelineConfig)> = vec![
        ("wavelets", wavelet_cfg(WaveletKind::Avg3, 1e-3)),
        ("zfp", PipelineConfig::new(32, Stage1::Zfp { tol_rel: 1e-3 }, Codec::None)),
        ("sz", PipelineConfig::new(32, Stage1::Sz { eb_rel: 1e-3 }, Codec::None)),
        ("fpzip", PipelineConfig::new(32, Stage1::Fpzip { prec: 20 }, Codec::None)),
    ];
    println!("{:>10} {:>7} {:>12} {:>9}", "method", "procs", "time s", "speedup");
    for (label, cfg) in schemes {
        let t = Timer::start();
        let (_bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        let t1 = t.secs();
        let cal = Calibration {
            t1_per_block: t1 / st.nblocks as f64,
            t2_per_byte: 0.0,
            stage1_bytes_per_block: 0.0,
            mem_bound_frac: 0.35,
        };
        for (p, tm, s) in scaling::speedups(&cal, &plat, st.nblocks, &[1, 2, 4, 8]) {
            println!("{:>10} {:>7} {:>12.4} {:>9.2}", label, p, tm, s);
        }
    }
}

fn fig11(n: usize) {
    println!("== Fig 11: weak scaling to 512 nodes (model over measured 1-node costs) ==");
    // per node: paper compresses 4 GB (1024^3); we measure an n^3 slab and
    // scale the cost linearly to 4 GB of cells
    let disk = throughput::measure_write(&std::env::temp_dir().join("czb_bw2.bin"), 64 << 20)
        .map(|s| s.bytes as f64 / s.secs)
        .unwrap_or(500e6);
    let plat = Platform::daint_like(disk);
    println!("measured node write bandwidth: {:.0} MB/s", disk / 1e6);
    for eps in [1e-3f32, 1e-4] {
        let sim = CloudSim::new(CloudConfig::paper(n));
        let f = sim.field(Qoi::Pressure, step_to_time(5000));
        let cfg = wavelet_cfg(WaveletKind::Avg3, eps);
        let t = Timer::start();
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        let t_comp_1core = t.secs();
        // the paper's node runs 12 OpenMP threads: apply the Fig 9 model
        let cal = Calibration {
            t1_per_block: (st.t_stage1 + st.t_stage2) / st.nblocks as f64,
            t2_per_byte: 0.0,
            stage1_bytes_per_block: 0.0,
            mem_bound_frac: 0.35,
        };
        let sp12 = {
            let s = scaling::speedups(&cal, &plat, st.nblocks, &[12]);
            s[0].2
        };
        let t_comp = t_comp_1core / sp12;
        let raw = f.nbytes() as f64;
        let scale_to_4gb = 4e9 / raw;
        let comp_per_node = bytes.len() as f64 * scale_to_4gb;
        println!(
            "--- eps {eps:.0e}: CR {:.1}, {:.1} MB compressed per 4 GB node (12-thread model, x{:.1}) ---",
            raw / bytes.len() as f64,
            comp_per_node / 1e6,
            sp12
        );
        println!(
            "{:>7} {:>12} {:>12} {:>14} | {:>14}",
            "nodes", "comp s", "write s", "GB/s (equiv)", "HACC-IO GB/s"
        );
        for (with, base) in scaling::weak_scaling(
            &plat,
            t_comp * scale_to_4gb,
            4e9,
            comp_per_node,
            &[1, 2, 8, 32, 128, 512],
        ) {
            println!(
                "{:>7} {:>12.2} {:>12.2} {:>14.2} | {:>14.2}",
                with.nodes,
                with.compress_secs,
                with.write_secs,
                with.equiv_throughput / 1e9,
                base.equiv_throughput / 1e9
            );
        }
    }
}

fn fig12(n: usize) {
    println!(
        "== Fig 12: production-run CR over time (n={n}^3, 600 bubbles; paper: O(10^11) cells, 12500) =="
    );
    let sim = CloudSim::new(CloudConfig::production(n, 600));
    // paper dumps p, a2, |U|; we have no velocity field -> E stands in
    // (DESIGN.md §4); eps tuned per QoI as in the production run
    let qois = [(Qoi::Pressure, 1e-3f32), (Qoi::Alpha2, 1e-3), (Qoi::Energy, 1e-3)];
    println!("{:>6} {:>10} | {:>9} {:>9} {:>9}", "step", "peak p", "CR p", "CR a2", "CR E");
    let mut total_raw = 0u64;
    let mut total_comp = 0u64;
    for step in (500..=12000).step_by(500) {
        let t = step_to_time(step);
        let mut crs = Vec::new();
        for (q, eps) in qois {
            let f = sim.field(q, t);
            let cfg = wavelet_cfg(WaveletKind::Avg3, eps);
            let (bytes, st) = compress_field(&f, q.name(), &cfg, &NativeEngine);
            total_raw += st.raw_bytes as u64;
            total_comp += bytes.len() as u64;
            crs.push(st.ratio());
        }
        println!(
            "{:>6} {:>10.1} | {:>9.1} {:>9.1} {:>9.1}",
            step,
            sim.peak_pressure(t),
            crs[0],
            crs[1],
            crs[2]
        );
    }
    println!(
        "cumulative: {:.2} GB -> {:.3} GB (overall CR {:.1}x)",
        total_raw as f64 / 1e9,
        total_comp as f64 / 1e9,
        total_raw as f64 / total_comp as f64
    );
    // restart snapshots: lossless FPZIP over all solution fields
    let mut raw = 0usize;
    let mut comp = 0usize;
    for q in Qoi::ALL {
        let f = sim.field(q, step_to_time(10000));
        let cfg = PipelineConfig::new(32, Stage1::Fpzip { prec: 32 }, Codec::None);
        let (bytes, st) = compress_field(&f, q.name(), &cfg, &NativeEngine);
        raw += st.raw_bytes;
        comp += bytes.len();
    }
    println!(
        "restart snapshot (lossless FPZIP, all fields): CR {:.2}x (paper: 2.62-4.25x)",
        raw as f64 / comp as f64
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let which = args.first().map(|s| s.as_str()).unwrap_or("all");
    let size_flag = args
        .iter()
        .position(|a| a == "--size")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<usize>().ok());
    let n = size_flag.unwrap_or(96);
    let t = Timer::start();
    match which {
        "table1" => table1(size_flag.unwrap_or(128)),
        "fig3" => fig3(n),
        "fig4" => fig4(n),
        "fig5" => fig5(n),
        "fig6" => fig6(size_flag.unwrap_or(128)),
        "fig7" => fig7(n),
        "fig8" => fig8(n),
        "table2" => table2(n),
        "table3" => table3(size_flag.unwrap_or(128)),
        "table4" => table4(size_flag.unwrap_or(128)),
        "fig9" => fig9(size_flag.unwrap_or(128)),
        "fig10" => fig10(n),
        "fig11" => fig11(n),
        "fig12" => fig12(n),
        "all" => {
            table1(size_flag.unwrap_or(128));
            fig3(n);
            fig4(n);
            fig5(n);
            fig6(size_flag.unwrap_or(128));
            fig7(n);
            fig8(n);
            table2(n);
            table3(size_flag.unwrap_or(128));
            table4(size_flag.unwrap_or(128));
            fig9(size_flag.unwrap_or(128));
            fig10(n);
            fig11(n);
            fig12(n);
        }
        other => {
            eprintln!("unknown experiment {other}");
            eprintln!(
                "available: table1 fig3 fig4 fig5 fig6 fig7 fig8 table2 table3 table4 fig9 fig10 fig11 fig12 all"
            );
            std::process::exit(2);
        }
    }
    eprintln!("[experiments {which} done in {:.1}s]", t.secs());
}
