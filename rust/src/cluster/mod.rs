//! Cluster layer (paper §2.1): domain decomposition and inter-rank
//! coordination. The paper uses MPI; this build runs all "ranks" as
//! threads in one process behind the [`Comm`] trait, implementing the
//! collectives the I/O path needs (barrier, exclusive prefix sum,
//! gather). The communication *pattern* is identical to the MPI code:
//! each rank owns an equal contiguous partition of the block grid and
//! computes its file offset with an exscan over compressed sizes.
//!
//! The node layer's intra-rank parallelism also lives here: a shared
//! atomic work queue ([`SpanQueue`]) plus two interchangeable worker
//! executors behind the [`Execute`] trait — the one-shot scoped pool
//! ([`ScopedExec`], what [`run_workers`] uses) and the persistent
//! [`WorkerPool`] owned by a long-lived `pipeline::Engine` session. The
//! compression and decompression pipelines are executor-agnostic: they
//! pull spans off the queue inside whatever executor drives them, so one
//! scheduling mechanism serves both directions and both lifetimes.
//!
//! The pool is *multi-generation*: every [`Execute::execute`] call
//! registers a submission (its job plus per-submission worker-index
//! queue) in a shared injector, idle workers steal indices across the
//! live submissions oldest-first, and each submitting thread also drains
//! its own submission — so several streams compress or decompress
//! concurrently on one pool, a small request keeps making progress on
//! its submitter while a large one streams on the workers, and a
//! panicked submission is re-raised on its own submitter without
//! touching its siblings. Each submission's job still drives its own
//! [`SpanQueue`], which is what keeps every stream's bytes independent
//! of scheduling.
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Shared atomic work queue over an index range `0..total`: workers pull
/// contiguous spans of `span` indices via a single `fetch_add` cursor.
/// Spans are fixed by index arithmetic — which worker pulls a given span
/// is dynamic (work-stealing-style load balance) but the span boundaries
/// themselves never depend on the worker count, which is what keeps the
/// compressed stream byte-identical across thread counts.
pub struct SpanQueue {
    cursor: AtomicUsize,
    total: usize,
    span: usize,
}

impl SpanQueue {
    pub fn new(total: usize, span: usize) -> Self {
        assert!(span > 0, "span must be positive");
        Self { cursor: AtomicUsize::new(0), total, span }
    }

    /// Claim the next span; `None` once the range is exhausted.
    pub fn next_span(&self) -> Option<Range<usize>> {
        let lo = self.cursor.fetch_add(self.span, Ordering::Relaxed);
        if lo >= self.total {
            return None;
        }
        Some(lo..(lo + self.span).min(self.total))
    }

    pub fn total(&self) -> usize {
        self.total
    }
}

/// Worker executor: runs `job(0), job(1), ..., job(n-1)` and returns once
/// every index has completed. Implementations may cap `n` at their own
/// concurrency, run the job inline when `n <= 1`, and — when the executor
/// is busy with other submissions — run several indices *sequentially* on
/// one thread; callers must only rely on every index executing exactly
/// once before the call returns, so jobs must never block waiting for a
/// sibling index to start (the pipelines' drain-a-shared-queue workers
/// satisfy this by construction). A panic inside the job propagates to
/// the caller. Executors may be driven from several threads at once;
/// each call's completion is tracked independently.
pub trait Execute: Sync {
    fn execute(&self, n: usize, job: &(dyn Fn(usize) + Sync));

    /// Upper bound on useful concurrency (worker indices handed out).
    /// `usize::MAX` for executors that spawn on demand.
    fn max_workers(&self) -> usize {
        usize::MAX
    }
}

/// One-shot executor: spawns `n` scoped threads per call (the pre-session
/// behaviour of [`run_workers`]). Zero setup cost, but repeated calls —
/// e.g. one per quantity of an in-situ dump — re-pay the spawn latency
/// that a persistent [`WorkerPool`] amortizes away.
pub struct ScopedExec;

impl Execute for ScopedExec {
    fn execute(&self, n: usize, job: &(dyn Fn(usize) + Sync)) {
        if n <= 1 {
            job(0);
            return;
        }
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..n).map(|t| s.spawn(move || job(t))).collect();
            for h in handles {
                h.join().expect("worker thread panicked");
            }
        });
    }
}

/// Run `nthreads` workers on `exec` and collect their results in
/// worker-id order. Workers typically drain a shared [`SpanQueue`]; the
/// executor is oblivious to the work shape.
pub fn run_on<R: Send>(
    exec: &dyn Execute,
    nthreads: usize,
    worker: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    let n = nthreads.max(1).min(exec.max_workers().max(1));
    if n == 1 {
        return vec![worker(0)];
    }
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    exec.execute(n, &|t| {
        *slots[t].lock().unwrap() = Some(worker(t));
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker index did not run"))
        .collect()
}

/// Run `nthreads` scoped workers and collect their results (in worker-id
/// order). One-shot convenience over [`ScopedExec`]; sessions that
/// compress repeatedly should hold a [`WorkerPool`] instead.
pub fn run_workers<R: Send>(nthreads: usize, worker: impl Fn(usize) -> R + Sync) -> Vec<R> {
    run_on(&ScopedExec, nthreads, worker)
}

/// A job handed to pool workers: a borrowed closure whose lifetime is
/// erased. Soundness: `WorkerPool::execute` blocks until every index of
/// its submission has completed (observed under the pool lock), so the
/// borrow outlives every call through the pointer.
type ErasedJob = &'static (dyn Fn(usize) + Sync);

/// One live submission in the pool's injector: an erased job plus the
/// claim/completion state of its `n` worker indices. Indices are the
/// per-submission work queue — workers claim them one at a time under
/// the pool lock, so a submission's concurrency grows and shrinks with
/// the pool's load instead of being fixed at post time.
struct Submission {
    id: u64,
    job: ErasedJob,
    /// Worker indices this submission hands out (`job(0..n)`).
    n: usize,
    /// Next unclaimed worker index.
    next: usize,
    /// Indices claimed-or-unclaimed that have not finished yet; the
    /// submission is complete when this reaches zero.
    remaining: usize,
    /// Set when the job panicked under any index (re-thrown by the
    /// submitter; siblings are unaffected).
    panicked: bool,
}

impl Submission {
    /// Claim the next unclaimed index, if any.
    fn claim(&mut self) -> Option<(u64, ErasedJob, usize)> {
        if self.next >= self.n {
            return None;
        }
        let c = (self.id, self.job, self.next);
        self.next += 1;
        Some(c)
    }
}

struct PoolState {
    /// Live submissions, oldest first (pushed at the back). A completed
    /// entry is removed by its submitter once observed drained.
    subs: Vec<Submission>,
    next_id: u64,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Wakes workers when a submission (or shutdown) is posted.
    work_cv: Condvar,
    /// Wakes submitters when some submission fully drains.
    done_cv: Condvar,
}

/// Mark one claimed index of submission `id` finished; wakes submitters
/// when the submission drains.
fn complete_index(shared: &PoolShared, id: u64, panicked: bool) {
    let mut g = shared.state.lock().unwrap();
    let sub = g
        .subs
        .iter_mut()
        .find(|s| s.id == id)
        .expect("submission stays registered until its submitter retires it");
    sub.remaining -= 1;
    if panicked {
        sub.panicked = true;
    }
    if sub.remaining == 0 {
        shared.done_cv.notify_all();
    }
}

/// Run one claimed index, containing any panic to its submission.
fn run_index(shared: &PoolShared, id: u64, job: ErasedJob, index: usize) {
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(index)));
    complete_index(shared, id, r.is_err());
}

/// Persistent worker pool: `threads` long-lived OS threads parked on a
/// condvar between submissions. Each [`Execute::execute`] call registers
/// one *submission* — a job with `n` worker indices — in the shared
/// injector and returns once all of its indices have completed, which is
/// what makes handing workers a *borrowed* closure sound. Submissions
/// from different threads overlap freely (the pool is multi-generation):
/// idle workers steal indices across the live submissions oldest-first,
/// and the submitting thread itself drains its own submission's indices,
/// so every submission makes progress even while an older one has all
/// pool workers streaming — a small request finishes on its submitter
/// instead of queueing behind a large neighbour. A panic inside one
/// submission re-raises on that submission's submitter only; dropping
/// the pool joins the threads.
///
/// This replaces per-field scoped spawning for session use: an in-situ
/// code dumping ~7 quantities per step pays thread creation once per run
/// instead of once per quantity — and several such sessions' callers can
/// now share the one pool concurrently.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `threads` workers (at least 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { subs: Vec::new(), next_id: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|t| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("cz-pool-{t}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, handles }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Claim the next unclaimed index of submission `id` (the caller's
    /// own submission, which stays registered until the caller retires
    /// it in [`Execute::execute`]).
    fn claim_own(&self, id: u64) -> Option<(ErasedJob, usize)> {
        let mut g = self.shared.state.lock().unwrap();
        g.subs
            .iter_mut()
            .find(|s| s.id == id)
            .expect("own submission is live until its submitter retires it")
            .claim()
            .map(|(_, job, index)| (job, index))
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        // steal an index from the oldest live submission that still has
        // unclaimed ones; park when none (claimable work only appears
        // with a new submission, so work_cv is the only wake source)
        let (id, job, index) = {
            let mut g = shared.state.lock().unwrap();
            loop {
                if let Some(c) = g.subs.iter_mut().find_map(|s| s.claim()) {
                    break c;
                }
                if g.shutdown {
                    return;
                }
                g = shared.work_cv.wait(g).unwrap();
            }
        };
        run_index(shared, id, job, index);
    }
}

impl Execute for WorkerPool {
    fn execute(&self, n: usize, job: &(dyn Fn(usize) + Sync)) {
        let n = n.min(self.threads());
        if n <= 1 {
            // run inline: cheaper than a wakeup round-trip, and semantics
            // (every index once, done on return) are unchanged
            job(0);
            return;
        }
        // SAFETY: only the lifetime is erased; this function does not
        // return until every index of this submission has completed, so
        // the borrow is live for every call through the pointer.
        let erased: ErasedJob =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), ErasedJob>(job) };
        let id = {
            let mut g = self.shared.state.lock().unwrap();
            let id = g.next_id;
            g.next_id += 1;
            g.subs.push(Submission { id, job: erased, n, next: 0, remaining: n, panicked: false });
            self.shared.work_cv.notify_all();
            id
        };
        // help drain our own submission: run whatever indices the pool
        // workers have not claimed yet on this thread. This is what keeps
        // a submission live when every worker is busy with an older one
        // (and makes a nested submission from inside a job finite).
        while let Some((job, index)) = self.claim_own(id) {
            run_index(&self.shared, id, job, index);
        }
        // wait for stolen indices to finish, then retire the submission
        let panicked = {
            let mut g = self.shared.state.lock().unwrap();
            loop {
                let pos = g
                    .subs
                    .iter()
                    .position(|s| s.id == id)
                    .expect("own submission is live until retired here");
                if g.subs[pos].remaining == 0 {
                    break g.subs.remove(pos).panicked;
                }
                g = self.shared.done_cv.wait(g).unwrap();
            }
        };
        if panicked {
            panic!("worker thread panicked");
        }
    }

    fn max_workers(&self) -> usize {
        self.threads()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.state.lock().unwrap();
            g.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Communicator over a fixed group of ranks.
pub trait Comm: Send + Sync {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;
    /// Block until every rank has entered the barrier.
    fn barrier(&self);
    /// Exclusive prefix sum: rank r receives sum of `v` from ranks < r.
    fn exscan_u64(&self, v: u64) -> u64;
    /// Gather `v` from all ranks (every rank receives the full vector).
    fn allgather_u64(&self, v: u64) -> Vec<u64>;
}

/// Single-process, single-rank communicator (ex-situ tool default).
pub struct SelfComm;

impl Comm for SelfComm {
    fn rank(&self) -> usize {
        0
    }
    fn size(&self) -> usize {
        1
    }
    fn barrier(&self) {}
    fn exscan_u64(&self, _v: u64) -> u64 {
        0
    }
    fn allgather_u64(&self, v: u64) -> Vec<u64> {
        vec![v]
    }
}

struct RoundState {
    generation: u64,
    arrived: usize,
    /// ranks that still have to read the published result of the current
    /// generation before the next round may start
    readers: usize,
    slots: Vec<u64>,
    published: Vec<u64>,
}

struct Shared {
    state: Mutex<RoundState>,
    cv: Condvar,
    size: usize,
}

/// In-process communicator: `size` ranks backed by threads.
pub struct InProcComm {
    shared: Arc<Shared>,
    rank: usize,
}

impl InProcComm {
    /// Create communicators for all ranks of a group of `size`.
    pub fn group(size: usize) -> Vec<InProcComm> {
        assert!(size >= 1);
        let shared = Arc::new(Shared {
            state: Mutex::new(RoundState {
                generation: 0,
                arrived: 0,
                readers: 0,
                slots: vec![0u64; size],
                published: vec![0u64; size],
            }),
            cv: Condvar::new(),
            size,
        });
        (0..size).map(|rank| InProcComm { shared: shared.clone(), rank }).collect()
    }

    /// Run one collective round: deposit `v`, wait for all, read the slots.
    /// The previous round must fully drain (all ranks read the published
    /// result) before a new round may deposit — prevents a fast rank from
    /// overwriting results a slow rank has not read yet.
    fn round(&self, v: u64) -> Vec<u64> {
        let sh = &self.shared;
        let mut g = sh.state.lock().unwrap();
        while g.readers > 0 {
            g = sh.cv.wait(g).unwrap();
        }
        g.slots[self.rank] = v;
        g.arrived += 1;
        if g.arrived == sh.size {
            // last arrival: publish and advance the generation
            let slots = g.slots.clone();
            g.published = slots;
            g.arrived = 0;
            g.readers = sh.size - 1;
            g.generation += 1;
            sh.cv.notify_all();
            return g.published.clone();
        }
        let my_gen = g.generation;
        while g.generation == my_gen {
            g = sh.cv.wait(g).unwrap();
        }
        let out = g.published.clone();
        g.readers -= 1;
        if g.readers == 0 {
            sh.cv.notify_all();
        }
        out
    }
}

impl Comm for InProcComm {
    fn rank(&self) -> usize {
        self.rank
    }
    fn size(&self) -> usize {
        self.shared.size
    }
    fn barrier(&self) {
        self.round(0);
    }
    fn exscan_u64(&self, v: u64) -> u64 {
        let all = self.round(v);
        all[..self.rank].iter().sum()
    }
    fn allgather_u64(&self, v: u64) -> Vec<u64> {
        self.round(v)
    }
}

/// Contiguous block partition for `rank` of `size` over `nblocks`
/// (paper: "MPI ranks must be assigned equal-sized partitions").
pub fn partition(nblocks: usize, rank: usize, size: usize) -> (usize, usize) {
    let span = nblocks.div_ceil(size);
    let lo = (rank * span).min(nblocks);
    let hi = ((rank + 1) * span).min(nblocks);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_comm_laws() {
        let c = SelfComm;
        assert_eq!(c.size(), 1);
        assert_eq!(c.exscan_u64(42), 0);
        assert_eq!(c.allgather_u64(7), vec![7]);
    }

    #[test]
    fn exscan_matches_prefix_sums() {
        for size in [1usize, 2, 3, 8] {
            let comms = InProcComm::group(size);
            let results: Vec<(usize, u64)> = std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .into_iter()
                    .map(|c| {
                        s.spawn(move || {
                            let v = (c.rank() as u64 + 1) * 10;
                            (c.rank(), c.exscan_u64(v))
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (rank, got) in results {
                let expect: u64 = (0..rank).map(|r| (r as u64 + 1) * 10).sum();
                assert_eq!(got, expect, "size {size} rank {rank}");
            }
        }
    }

    #[test]
    fn allgather_consistent_across_ranks() {
        let comms = InProcComm::group(4);
        let results: Vec<Vec<u64>> = std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| s.spawn(move || c.allgather_u64(c.rank() as u64 * 3)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &results {
            assert_eq!(r, &vec![0, 3, 6, 9]);
        }
    }

    #[test]
    fn repeated_collectives_do_not_deadlock() {
        let comms = InProcComm::group(3);
        std::thread::scope(|s| {
            for c in comms {
                s.spawn(move || {
                    for i in 0..200u64 {
                        let all = c.allgather_u64(i);
                        assert_eq!(all, vec![i; 3]);
                        c.barrier();
                    }
                });
            }
        });
    }

    #[test]
    fn span_queue_tiles_range_single_threaded() {
        let q = SpanQueue::new(10, 4);
        assert_eq!(q.next_span(), Some(0..4));
        assert_eq!(q.next_span(), Some(4..8));
        assert_eq!(q.next_span(), Some(8..10));
        assert_eq!(q.next_span(), None);
        assert_eq!(q.next_span(), None);
        assert_eq!(q.total(), 10);
        // empty range yields nothing
        assert_eq!(SpanQueue::new(0, 3).next_span(), None);
    }

    #[test]
    fn span_queue_covers_each_index_once_under_contention() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let n = 10_000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let q = SpanQueue::new(n, 7);
        let pulls = run_workers(8, |_| {
            let mut count = 0usize;
            while let Some(span) = q.next_span() {
                for i in span {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
                count += 1;
            }
            count
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(pulls.iter().sum::<usize>(), n.div_ceil(7));
    }

    #[test]
    fn run_workers_returns_in_worker_order() {
        let out = run_workers(4, |t| t * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
        assert_eq!(run_workers(1, |t| t + 1), vec![1]);
    }

    #[test]
    fn worker_pool_runs_jobs_and_collects_results() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        // repeated generations reuse the same threads
        for round in 0..50usize {
            let out = run_on(&pool, 4, |t| t * 10 + round);
            assert_eq!(out, vec![round, 10 + round, 20 + round, 30 + round]);
        }
        // fewer participants than pool threads
        assert_eq!(run_on(&pool, 2, |t| t), vec![0, 1]);
        // n == 1 runs inline
        assert_eq!(run_on(&pool, 1, |t| t + 7), vec![7]);
    }

    #[test]
    fn worker_pool_caps_at_pool_size() {
        let pool = WorkerPool::new(2);
        // requesting more workers than threads must cap, not hang
        let out = run_on(&pool, 8, |t| t);
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn worker_pool_drains_span_queue_like_scoped() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let pool = WorkerPool::new(8);
        let n = 10_000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let q = SpanQueue::new(n, 7);
        run_on(&pool, 8, |_| {
            while let Some(span) = q.next_span() {
                for i in span {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worker_pool_survives_concurrent_submitters() {
        let pool = std::sync::Arc::new(WorkerPool::new(3));
        std::thread::scope(|s| {
            for k in 0..4usize {
                let pool = pool.clone();
                s.spawn(move || {
                    for i in 0..30usize {
                        let out = run_on(&*pool, 3, |t| k * 1000 + i * 10 + t);
                        assert_eq!(
                            out,
                            vec![k * 1000 + i * 10, k * 1000 + i * 10 + 1, k * 1000 + i * 10 + 2]
                        );
                    }
                });
            }
        });
    }

    #[test]
    fn worker_pool_propagates_panics() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.execute(2, &|t| {
                if t == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic in a pool worker must reach the submitter");
        // the pool must still be usable after a panicked generation
        assert_eq!(run_on(&pool, 2, |t| t), vec![0, 1]);
    }

    #[test]
    fn concurrent_submissions_make_independent_progress() {
        // liveness of the multi-generation injector: submission A spins
        // until a LATER submission B runs. The one-generation pool
        // deadlocked here (B queued behind A's submit gate); now B rides
        // its own submitter even with every pool worker parked inside A.
        use std::sync::atomic::AtomicBool;
        let pool = WorkerPool::new(2);
        let flag = AtomicBool::new(false);
        std::thread::scope(|s| {
            let a = s.spawn(|| {
                run_on(&pool, 2, |_| {
                    while !flag.load(Ordering::Acquire) {
                        std::thread::yield_now();
                    }
                })
            });
            // regardless of arrival order, B must complete and unblock A
            run_on(&pool, 2, |t| {
                if t == 0 {
                    flag.store(true, Ordering::Release);
                }
            });
            a.join().expect("submission A must finish once B ran");
        });
    }

    #[test]
    fn small_submission_finishes_while_large_one_streams() {
        // throughput shape of the tentpole: a large submission holds the
        // whole pool; a small one submitted later must still complete
        // (the large one's spans only finish after the small one did)
        use std::sync::atomic::AtomicBool;
        let pool = WorkerPool::new(4);
        let small_done = AtomicBool::new(false);
        std::thread::scope(|s| {
            let large = s.spawn(|| {
                let q = SpanQueue::new(64, 1);
                run_on(&pool, 4, |_| {
                    while let Some(_span) = q.next_span() {
                        while !small_done.load(Ordering::Acquire) {
                            std::thread::yield_now();
                        }
                    }
                })
            });
            let out = run_on(&pool, 4, |t| t + 1);
            small_done.store(true, Ordering::Release);
            assert_eq!(out, vec![1, 2, 3, 4]);
            large.join().expect("large submission finishes after the small one");
        });
    }

    #[test]
    fn panicked_submission_does_not_poison_siblings() {
        let pool = WorkerPool::new(4);
        std::thread::scope(|s| {
            let bad = s.spawn(|| {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    pool.execute(2, &|t| {
                        if t == 1 {
                            panic!("boom");
                        }
                    });
                }))
            });
            // a sibling keeps streaming generations throughout
            for i in 0..50usize {
                let out = run_on(&pool, 3, |t| t * 100 + i);
                assert_eq!(out, vec![i, 100 + i, 200 + i]);
            }
            let r = bad.join().expect("submitter thread itself must not die");
            assert!(r.is_err(), "panic must reach the panicking submission's submitter");
        });
        // the pool stays usable afterwards
        assert_eq!(run_on(&pool, 2, |t| t), vec![0, 1]);
    }

    #[test]
    fn pool_drop_waits_for_queued_submissions() {
        use std::sync::atomic::AtomicUsize;
        // main drops its handle while submissions are still in flight on
        // other threads: every index must still run exactly once and the
        // final drop (last Arc) must join cleanly, not hang or abandon
        let pool = std::sync::Arc::new(WorkerPool::new(2));
        let hits = std::sync::Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = pool.clone();
                let hits = hits.clone();
                std::thread::spawn(move || {
                    run_on(&*pool, 2, |_| {
                        hits.fetch_add(1, Ordering::Relaxed);
                    });
                })
            })
            .collect();
        drop(pool);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn partition_tiles_range() {
        for (n, size) in [(100, 7), (8, 8), (5, 2), (1, 4)] {
            let mut covered = 0;
            for r in 0..size {
                let (lo, hi) = partition(n, r, size);
                assert!(lo <= hi);
                covered += hi - lo;
            }
            assert_eq!(covered, n, "n {n} size {size}");
        }
    }
}
