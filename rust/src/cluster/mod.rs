//! Cluster layer (paper §2.1): domain decomposition and inter-rank
//! coordination. The paper uses MPI; this build runs all "ranks" as
//! threads in one process behind the [`Comm`] trait, implementing the
//! collectives the I/O path needs (barrier, exclusive prefix sum,
//! gather). The communication *pattern* is identical to the MPI code:
//! each rank owns an equal contiguous partition of the block grid and
//! computes its file offset with an exscan over compressed sizes.
//!
//! The node layer's intra-rank parallelism also lives here: a shared
//! atomic work queue ([`SpanQueue`]) plus a scoped worker pool
//! ([`run_workers`]) that the compression and decompression pipelines
//! both pull from, so one scheduling mechanism serves both directions.
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Shared atomic work queue over an index range `0..total`: workers pull
/// contiguous spans of `span` indices via a single `fetch_add` cursor.
/// Spans are fixed by index arithmetic — which worker pulls a given span
/// is dynamic (work-stealing-style load balance) but the span boundaries
/// themselves never depend on the worker count, which is what keeps the
/// compressed stream byte-identical across thread counts.
pub struct SpanQueue {
    cursor: AtomicUsize,
    total: usize,
    span: usize,
}

impl SpanQueue {
    pub fn new(total: usize, span: usize) -> Self {
        assert!(span > 0, "span must be positive");
        Self { cursor: AtomicUsize::new(0), total, span }
    }

    /// Claim the next span; `None` once the range is exhausted.
    pub fn next_span(&self) -> Option<Range<usize>> {
        let lo = self.cursor.fetch_add(self.span, Ordering::Relaxed);
        if lo >= self.total {
            return None;
        }
        Some(lo..(lo + self.span).min(self.total))
    }

    pub fn total(&self) -> usize {
        self.total
    }
}

/// Run `nthreads` scoped workers and collect their results (in worker-id
/// order). Workers typically drain a shared [`SpanQueue`]; the pool itself
/// is oblivious to the work shape.
pub fn run_workers<R: Send>(nthreads: usize, worker: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let nthreads = nthreads.max(1);
    if nthreads == 1 {
        return vec![worker(0)];
    }
    std::thread::scope(|s| {
        let worker = &worker;
        let handles: Vec<_> = (0..nthreads).map(|t| s.spawn(move || worker(t))).collect();
        handles.into_iter().map(|h| h.join().expect("worker thread panicked")).collect()
    })
}

/// Communicator over a fixed group of ranks.
pub trait Comm: Send + Sync {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;
    /// Block until every rank has entered the barrier.
    fn barrier(&self);
    /// Exclusive prefix sum: rank r receives sum of `v` from ranks < r.
    fn exscan_u64(&self, v: u64) -> u64;
    /// Gather `v` from all ranks (every rank receives the full vector).
    fn allgather_u64(&self, v: u64) -> Vec<u64>;
}

/// Single-process, single-rank communicator (ex-situ tool default).
pub struct SelfComm;

impl Comm for SelfComm {
    fn rank(&self) -> usize {
        0
    }
    fn size(&self) -> usize {
        1
    }
    fn barrier(&self) {}
    fn exscan_u64(&self, _v: u64) -> u64 {
        0
    }
    fn allgather_u64(&self, v: u64) -> Vec<u64> {
        vec![v]
    }
}

struct RoundState {
    generation: u64,
    arrived: usize,
    /// ranks that still have to read the published result of the current
    /// generation before the next round may start
    readers: usize,
    slots: Vec<u64>,
    published: Vec<u64>,
}

struct Shared {
    state: Mutex<RoundState>,
    cv: Condvar,
    size: usize,
}

/// In-process communicator: `size` ranks backed by threads.
pub struct InProcComm {
    shared: Arc<Shared>,
    rank: usize,
}

impl InProcComm {
    /// Create communicators for all ranks of a group of `size`.
    pub fn group(size: usize) -> Vec<InProcComm> {
        assert!(size >= 1);
        let shared = Arc::new(Shared {
            state: Mutex::new(RoundState {
                generation: 0,
                arrived: 0,
                readers: 0,
                slots: vec![0u64; size],
                published: vec![0u64; size],
            }),
            cv: Condvar::new(),
            size,
        });
        (0..size).map(|rank| InProcComm { shared: shared.clone(), rank }).collect()
    }

    /// Run one collective round: deposit `v`, wait for all, read the slots.
    /// The previous round must fully drain (all ranks read the published
    /// result) before a new round may deposit — prevents a fast rank from
    /// overwriting results a slow rank has not read yet.
    fn round(&self, v: u64) -> Vec<u64> {
        let sh = &self.shared;
        let mut g = sh.state.lock().unwrap();
        while g.readers > 0 {
            g = sh.cv.wait(g).unwrap();
        }
        g.slots[self.rank] = v;
        g.arrived += 1;
        if g.arrived == sh.size {
            // last arrival: publish and advance the generation
            let slots = g.slots.clone();
            g.published = slots;
            g.arrived = 0;
            g.readers = sh.size - 1;
            g.generation += 1;
            sh.cv.notify_all();
            return g.published.clone();
        }
        let my_gen = g.generation;
        while g.generation == my_gen {
            g = sh.cv.wait(g).unwrap();
        }
        let out = g.published.clone();
        g.readers -= 1;
        if g.readers == 0 {
            sh.cv.notify_all();
        }
        out
    }
}

impl Comm for InProcComm {
    fn rank(&self) -> usize {
        self.rank
    }
    fn size(&self) -> usize {
        self.shared.size
    }
    fn barrier(&self) {
        self.round(0);
    }
    fn exscan_u64(&self, v: u64) -> u64 {
        let all = self.round(v);
        all[..self.rank].iter().sum()
    }
    fn allgather_u64(&self, v: u64) -> Vec<u64> {
        self.round(v)
    }
}

/// Contiguous block partition for `rank` of `size` over `nblocks`
/// (paper: "MPI ranks must be assigned equal-sized partitions").
pub fn partition(nblocks: usize, rank: usize, size: usize) -> (usize, usize) {
    let span = nblocks.div_ceil(size);
    let lo = (rank * span).min(nblocks);
    let hi = ((rank + 1) * span).min(nblocks);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_comm_laws() {
        let c = SelfComm;
        assert_eq!(c.size(), 1);
        assert_eq!(c.exscan_u64(42), 0);
        assert_eq!(c.allgather_u64(7), vec![7]);
    }

    #[test]
    fn exscan_matches_prefix_sums() {
        for size in [1usize, 2, 3, 8] {
            let comms = InProcComm::group(size);
            let results: Vec<(usize, u64)> = std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .into_iter()
                    .map(|c| {
                        s.spawn(move || {
                            let v = (c.rank() as u64 + 1) * 10;
                            (c.rank(), c.exscan_u64(v))
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (rank, got) in results {
                let expect: u64 = (0..rank).map(|r| (r as u64 + 1) * 10).sum();
                assert_eq!(got, expect, "size {size} rank {rank}");
            }
        }
    }

    #[test]
    fn allgather_consistent_across_ranks() {
        let comms = InProcComm::group(4);
        let results: Vec<Vec<u64>> = std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| s.spawn(move || c.allgather_u64(c.rank() as u64 * 3)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &results {
            assert_eq!(r, &vec![0, 3, 6, 9]);
        }
    }

    #[test]
    fn repeated_collectives_do_not_deadlock() {
        let comms = InProcComm::group(3);
        std::thread::scope(|s| {
            for c in comms {
                s.spawn(move || {
                    for i in 0..200u64 {
                        let all = c.allgather_u64(i);
                        assert_eq!(all, vec![i; 3]);
                        c.barrier();
                    }
                });
            }
        });
    }

    #[test]
    fn span_queue_tiles_range_single_threaded() {
        let q = SpanQueue::new(10, 4);
        assert_eq!(q.next_span(), Some(0..4));
        assert_eq!(q.next_span(), Some(4..8));
        assert_eq!(q.next_span(), Some(8..10));
        assert_eq!(q.next_span(), None);
        assert_eq!(q.next_span(), None);
        assert_eq!(q.total(), 10);
        // empty range yields nothing
        assert_eq!(SpanQueue::new(0, 3).next_span(), None);
    }

    #[test]
    fn span_queue_covers_each_index_once_under_contention() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let n = 10_000;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let q = SpanQueue::new(n, 7);
        let pulls = run_workers(8, |_| {
            let mut count = 0usize;
            while let Some(span) = q.next_span() {
                for i in span {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
                count += 1;
            }
            count
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(pulls.iter().sum::<usize>(), n.div_ceil(7));
    }

    #[test]
    fn run_workers_returns_in_worker_order() {
        let out = run_workers(4, |t| t * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
        assert_eq!(run_workers(1, |t| t + 1), vec![1]);
    }

    #[test]
    fn partition_tiles_range() {
        for (n, size) in [(100, 7), (8, 8), (5, 2), (1, 4)] {
            let mut covered = 0;
            for r in 0..size {
                let (lo, hi) = partition(n, r, size);
                assert!(lo <= hi);
                covered += hi - lo;
            }
            assert_eq!(covered, n, "n {n} size {size}");
        }
    }
}
