//! Cubic grid blocks: the unit of parallel granularity in the framework.
//! A field is decomposed into `bs³` blocks; each OpenMP-style worker
//! processes one block at a time through the compression pipeline.
use super::field::Field3;

/// Index of a block within the Cartesian block grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockIndex {
    pub bx: usize,
    pub by: usize,
    pub bz: usize,
}

/// A cubic block of `bs³` cells copied out of a [`Field3`].
#[derive(Clone, Debug)]
pub struct Block {
    pub bs: usize,
    pub data: Vec<f32>,
}

impl Block {
    pub fn zeros(bs: usize) -> Self {
        assert!(bs.is_power_of_two() && bs >= 4, "block size must be a power of 2, >= 4");
        Self { bs, data: vec![0.0; bs * bs * bs] }
    }

    pub fn from_vec(bs: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), bs * bs * bs);
        Self { bs, data }
    }

    #[inline]
    pub fn idx(&self, x: usize, y: usize, z: usize) -> usize {
        (z * self.bs + y) * self.bs + x
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize, z: usize) -> f32 {
        self.data[self.idx(x, y, z)]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, z: usize, v: f32) {
        let i = self.idx(x, y, z);
        self.data[i] = v;
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Decomposition of a [`Field3`] into cubic blocks of side `bs`.
/// Field dims must be divisible by `bs` (the paper requires equal-size
/// partitions; production grids are powers of two).
#[derive(Clone, Debug)]
pub struct BlockGrid {
    pub bs: usize,
    pub nbx: usize,
    pub nby: usize,
    pub nbz: usize,
}

impl BlockGrid {
    pub fn new(field: &Field3, bs: usize) -> Self {
        assert!(
            field.nx % bs == 0 && field.ny % bs == 0 && field.nz % bs == 0,
            "field dims ({},{},{}) must be divisible by block size {}",
            field.nx,
            field.ny,
            field.nz,
            bs
        );
        Self { bs, nbx: field.nx / bs, nby: field.ny / bs, nbz: field.nz / bs }
    }

    pub fn nblocks(&self) -> usize {
        self.nbx * self.nby * self.nbz
    }

    /// Linear block id -> 3D block index (x-fastest).
    pub fn block_index(&self, id: usize) -> BlockIndex {
        debug_assert!(id < self.nblocks());
        let bx = id % self.nbx;
        let by = (id / self.nbx) % self.nby;
        let bz = id / (self.nbx * self.nby);
        BlockIndex { bx, by, bz }
    }

    pub fn block_id(&self, bi: BlockIndex) -> usize {
        (bi.bz * self.nby + bi.by) * self.nbx + bi.bx
    }

    /// Flattened-field addressing of block `id`: the row of bs cells at
    /// local coordinates `(z, y)` starts at
    /// [`BlockLayout::row_offset`]`(z, y)`. Single source of truth for
    /// the raw-pointer scatter in the parallel decompressor and the safe
    /// [`BlockGrid::extract`]/[`BlockGrid::insert`] copies.
    pub fn layout(&self, id: usize) -> BlockLayout {
        let bi = self.block_index(id);
        let bs = self.bs;
        let (nx, ny) = (self.nbx * bs, self.nby * bs);
        BlockLayout {
            base: ((bi.bz * bs) * ny + bi.by * bs) * nx + bi.bx * bs,
            row: nx,
            slab: nx * ny,
        }
    }

    /// Copy block `id` out of the field into `out` (AoS gather; the paper's
    /// per-thread dedicated buffer copy).
    pub fn extract(&self, field: &Field3, id: usize, out: &mut Block) {
        debug_assert_eq!(out.bs, self.bs);
        debug_assert_eq!((field.nx, field.ny), (self.nbx * self.bs, self.nby * self.bs));
        let layout = self.layout(id);
        let bs = self.bs;
        for z in 0..bs {
            for y in 0..bs {
                let src = layout.row_offset(z, y);
                let dst = (z * bs + y) * bs;
                out.data[dst..dst + bs].copy_from_slice(&field.data[src..src + bs]);
            }
        }
    }

    /// Scatter a block back into the field (decompression path).
    pub fn insert(&self, field: &mut Field3, id: usize, block: &Block) {
        debug_assert_eq!(block.bs, self.bs);
        debug_assert_eq!((field.nx, field.ny), (self.nbx * self.bs, self.nby * self.bs));
        let layout = self.layout(id);
        let bs = self.bs;
        for z in 0..bs {
            for y in 0..bs {
                let dst = layout.row_offset(z, y);
                let src = (z * bs + y) * bs;
                field.data[dst..dst + bs].copy_from_slice(&block.data[src..src + bs]);
            }
        }
    }
}

/// Row-addressing of one block inside the flattened field array
/// (x-fastest layout), produced by [`BlockGrid::layout`].
#[derive(Clone, Copy, Debug)]
pub struct BlockLayout {
    /// Offset of the block's first cell.
    pub base: usize,
    /// Stride between consecutive y-rows (the field's nx).
    pub row: usize,
    /// Stride between consecutive z-slabs (the field's nx * ny).
    pub slab: usize,
}

impl BlockLayout {
    /// Offset of the first cell of the block row at local `(z, y)`.
    #[inline]
    pub fn row_offset(&self, z: usize, y: usize) -> usize {
        self.base + z * self.slab + y * self.row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn block_ids_roundtrip() {
        let f = Field3::zeros(32, 16, 8);
        let g = BlockGrid::new(&f, 8);
        assert_eq!(g.nblocks(), 4 * 2 * 1);
        for id in 0..g.nblocks() {
            assert_eq!(g.block_id(g.block_index(id)), id);
        }
    }

    #[test]
    fn extract_insert_roundtrip() {
        let mut rng = Pcg32::new(12);
        let mut f = Field3::zeros(16, 16, 16);
        rng.fill_f32(&mut f.data, -5.0, 5.0);
        let g = BlockGrid::new(&f, 8);
        let mut out = Field3::zeros(16, 16, 16);
        let mut b = Block::zeros(8);
        for id in 0..g.nblocks() {
            g.extract(&f, id, &mut b);
            g.insert(&mut out, id, &b);
        }
        assert_eq!(f.data, out.data);
    }

    #[test]
    fn extract_reads_correct_cells() {
        let mut f = Field3::zeros(8, 8, 8);
        // mark cell (4, 5, 6) — block (1,1,1) for bs=4, local (0,1,2)
        f.set(4, 5, 6, 9.0);
        let g = BlockGrid::new(&f, 4);
        let id = g.block_id(BlockIndex { bx: 1, by: 1, bz: 1 });
        let mut b = Block::zeros(4);
        g.extract(&f, id, &mut b);
        assert_eq!(b.get(0, 1, 2), 9.0);
    }

    #[test]
    #[should_panic]
    fn indivisible_dims_rejected() {
        let f = Field3::zeros(10, 8, 8);
        BlockGrid::new(&f, 8);
    }

    #[test]
    fn layout_matches_field_indexing() {
        let f = Field3::zeros(32, 16, 8);
        let g = BlockGrid::new(&f, 4);
        for id in [0usize, 1, 7, 8, 31, g.nblocks() - 1] {
            let bi = g.block_index(id);
            let l = g.layout(id);
            for z in 0..4 {
                for y in 0..4 {
                    assert_eq!(
                        l.row_offset(z, y),
                        f.idx(bi.bx * 4, bi.by * 4 + y, bi.bz * 4 + z),
                        "block {id} z {z} y {y}"
                    );
                }
            }
        }
    }
}
