//! Core layer (the paper's Cubism substrate): block-structured grid,
//! block extraction/insertion, field statistics.
pub mod block;
pub mod field;
pub mod stats;

pub use block::{Block, BlockIndex};
pub use field::Field3;
pub use stats::FieldStats;
