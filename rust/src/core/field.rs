//! Dense 3D scalar field in x-fastest (row-major z,y,x) order — the layout
//! of the simulation dumps the framework compresses.

/// A dense 3D single-precision scalar field.
#[derive(Clone, Debug)]
pub struct Field3 {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    pub data: Vec<f32>,
}

impl Field3 {
    pub fn zeros(nx: usize, ny: usize, nz: usize) -> Self {
        Self { nx, ny, nz, data: vec![0.0; nx * ny * nz] }
    }

    pub fn from_vec(nx: usize, ny: usize, nz: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), nx * ny * nz, "data length must match dims");
        Self { nx, ny, nz, data }
    }

    pub fn cube(n: usize) -> Self {
        Self::zeros(n, n, n)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size of the raw data in bytes.
    pub fn nbytes(&self) -> usize {
        self.data.len() * 4
    }

    #[inline]
    pub fn idx(&self, x: usize, y: usize, z: usize) -> usize {
        debug_assert!(x < self.nx && y < self.ny && z < self.nz);
        (z * self.ny + y) * self.nx + x
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize, z: usize) -> f32 {
        self.data[self.idx(x, y, z)]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, z: usize, v: f32) {
        let i = self.idx(x, y, z);
        self.data[i] = v;
    }

    /// (min, max) over the field. Returns (0, 0) for empty fields.
    pub fn range(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if lo > hi {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }

    /// Maximum value (the paper overlays "local peak pressure").
    pub fn max(&self) -> f32 {
        self.range().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_x_fastest() {
        let mut f = Field3::zeros(4, 3, 2);
        f.set(1, 0, 0, 1.0);
        assert_eq!(f.data[1], 1.0);
        f.set(0, 1, 0, 2.0);
        assert_eq!(f.data[4], 2.0);
        f.set(0, 0, 1, 3.0);
        assert_eq!(f.data[12], 3.0);
    }

    #[test]
    fn range_and_max() {
        let f = Field3::from_vec(2, 2, 1, vec![-1.0, 5.0, 0.0, 2.0]);
        assert_eq!(f.range(), (-1.0, 5.0));
        assert_eq!(f.max(), 5.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        Field3::from_vec(2, 2, 2, vec![0.0; 7]);
    }
}
