//! Field statistics (paper Table 1: Min / Max / Mean / StDev per QoI).

/// Summary statistics of a scalar field.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FieldStats {
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
    pub n: usize,
}

impl FieldStats {
    /// Single-pass Welford computation (numerically stable).
    pub fn compute(data: &[f32]) -> Self {
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut mean = 0.0f64;
        let mut m2 = 0.0f64;
        let mut n = 0usize;
        for &v in data {
            let v = v as f64;
            n += 1;
            min = min.min(v);
            max = max.max(v);
            let d = v - mean;
            mean += d / n as f64;
            m2 += d * (v - mean);
        }
        if n == 0 {
            return Self { min: 0.0, max: 0.0, mean: 0.0, stddev: 0.0, n: 0 };
        }
        Self { min, max, mean, stddev: (m2 / n as f64).sqrt(), n }
    }

    /// Value range (max - min); the PSNR normalization in paper eq. (1).
    pub fn range(&self) -> f64 {
        self.max - self.min
    }

    /// Format a paper-style row: Min Max Mean StDev in %.1e.
    pub fn row(&self) -> String {
        format!(
            "{:>9.1e} {:>9.1e} {:>9.1e} {:>9.1e}",
            self.min, self.max, self.mean, self.stddev
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_stats() {
        let s = FieldStats::compute(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.mean - 2.5).abs() < 1e-12);
        let expected_sd = (1.25f64).sqrt();
        assert!((s.stddev - expected_sd).abs() < 1e-12);
        assert_eq!(s.range(), 3.0);
    }

    #[test]
    fn empty_is_zeroed() {
        let s = FieldStats::compute(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn welford_matches_two_pass_on_large_offset() {
        // mean ~1e6 with small variance: naive accumulation would lose bits
        let data: Vec<f32> = (0..1000).map(|i| 1e6 + (i % 7) as f32).collect();
        let s = FieldStats::compute(&data);
        let mean2 = data.iter().map(|&v| v as f64).sum::<f64>() / 1000.0;
        assert!((s.mean - mean2).abs() < 1e-6);
        assert!(s.stddev > 0.0 && s.stddev < 10.0);
    }
}
