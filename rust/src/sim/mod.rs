//! Synthetic cloud-cavitation data generator — the stand-in for the
//! Cubism-MPCF production datasets (DESIGN.md §4 substitution table).
//!
//! Models a cloud of gas bubbles (lognormal radii, uniformly placed in a
//! sphere) in liquid. Time is normalized so the cloud collapse happens at
//! `t = 1` (paper: step ≈ 7000 of 10k, peak at ~7 µs):
//! * pre-collapse: bubbles shrink Rayleigh–Plesset-like, ambient pressure
//!   rises — the α₂ field "empties", its compression ratio climbs (Fig 3);
//! * collapse: outward-propagating shock shells with a sharp local peak
//!   pressure — p/ρ/E become hard to compress (CR dip, Fig 3/12);
//! * post-collapse: rebound — bubbles re-expand to a fraction of R₀,
//!   shocks leave the domain, CR recovers partially.
//!
//! The generated QoIs (p, ρ, E, α₂) have paper-like ranges (Table 1) and,
//! critically, the same structure classes: smooth far field, localized
//! sharp interfaces, and propagating discontinuities.
use crate::core::Field3;
use crate::util::prng::Pcg32;

/// One spherical bubble.
#[derive(Clone, Copy, Debug)]
pub struct Bubble {
    pub cx: f32,
    pub cy: f32,
    pub cz: f32,
    pub r0: f32,
}

/// Cloud configuration (paper §3.1: 70 bubbles in a sphere, lognormal radii,
/// 512³ cells; Fig 12: 12500 bubbles).
#[derive(Clone, Copy, Debug)]
pub struct CloudConfig {
    pub n: usize,
    pub n_bubbles: usize,
    pub seed: u64,
    /// Cloud sphere radius as a fraction of the domain (default 0.35).
    pub cloud_radius: f32,
    /// Lognormal parameters of bubble radii in cells (defaults give
    /// radii ~2% of the domain).
    pub r_mu: f32,
    pub r_sigma: f32,
}

impl CloudConfig {
    /// The paper's §3.1 setup scaled to `n`³ cells.
    pub fn paper(n: usize) -> Self {
        Self {
            n,
            n_bubbles: 70,
            seed: 0xC10D,
            cloud_radius: 0.35,
            r_mu: (0.022 * n as f32).ln(),
            r_sigma: 0.35,
        }
    }

    /// Fig-12-like production cloud (many small bubbles, smaller cloud
    /// coverage -> higher compression ratios, as the paper notes).
    pub fn production(n: usize, n_bubbles: usize) -> Self {
        Self {
            n,
            n_bubbles,
            seed: 0xB16C__10D,
            cloud_radius: 0.25,
            r_mu: (0.008 * n as f32).ln(),
            r_sigma: 0.30,
        }
    }
}

/// The four quantities of interest of §3.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Qoi {
    Pressure,
    Density,
    Energy,
    Alpha2,
}

impl Qoi {
    pub const ALL: [Qoi; 4] = [Qoi::Pressure, Qoi::Density, Qoi::Energy, Qoi::Alpha2];

    pub fn name(&self) -> &'static str {
        match self {
            Qoi::Pressure => "p",
            Qoi::Density => "rho",
            Qoi::Energy => "E",
            Qoi::Alpha2 => "a2",
        }
    }

    pub fn from_name(s: &str) -> Option<Qoi> {
        Self::ALL.into_iter().find(|q| q.name() == s)
    }
}

/// Simulator state: bubble cloud + physical constants.
pub struct CloudSim {
    pub cfg: CloudConfig,
    pub bubbles: Vec<Bubble>,
    /// Ambient liquid pressure (bar-ish units to match Table 1 ranges).
    pub p_inf: f32,
    pub rho_liquid: f32,
    pub rho_gas: f32,
    pub gamma: f32,
}

/// Map the paper's "simulation steps" to normalized time (collapse at
/// step 7000 <=> t = 1).
pub fn step_to_time(step: usize) -> f32 {
    step as f32 / 7000.0
}

impl CloudSim {
    pub fn new(cfg: CloudConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed);
        let n = cfg.n as f32;
        let cr = cfg.cloud_radius * n;
        let (c0, c1, c2) = (0.5 * n, 0.5 * n, 0.5 * n);
        let mut bubbles = Vec::with_capacity(cfg.n_bubbles);
        while bubbles.len() < cfg.n_bubbles {
            // uniform in the cloud sphere (rejection)
            let x = rng.range_f64(-1.0, 1.0);
            let y = rng.range_f64(-1.0, 1.0);
            let z = rng.range_f64(-1.0, 1.0);
            if x * x + y * y + z * z > 1.0 {
                continue;
            }
            let r0 = rng.next_lognormal(cfg.r_mu as f64, cfg.r_sigma as f64) as f32;
            bubbles.push(Bubble {
                cx: c0 + cr * x as f32,
                cy: c1 + cr * y as f32,
                cz: c2 + cr * z as f32,
                r0: r0.clamp(1.5, 0.45 * n),
            });
        }
        Self { cfg, bubbles, p_inf: 100.0, rho_liquid: 1000.0, rho_gas: 1.0, gamma: 1.4 }
    }

    /// Bubble radius scale factor at normalized time `t`.
    fn radius_factor(&self, t: f32) -> f32 {
        if t < 1.0 {
            // Rayleigh-Plesset-like (1 - t)^(2/5) shrink, floored
            ((1.0 - t).max(0.0).powf(0.4)).max(0.12)
        } else {
            // rebound to ~40% of R0 with an exponential approach
            0.12 + 0.28 * (1.0 - (-6.0 * (t - 1.0)).exp())
        }
    }

    /// Local peak pressure curve (Fig 3/12 thin solid line): sharp spike
    /// at collapse, decaying afterwards.
    pub fn peak_pressure(&self, t: f32) -> f32 {
        let base = self.p_inf * (1.0 + 0.5 * t * t);
        let spike = 9.0 * self.p_inf * (-18.0 * (t - 1.0) * (t - 1.0)).exp();
        base + spike
    }

    /// Generate one QoI field at normalized time `t`.
    pub fn field(&self, qoi: Qoi, t: f32) -> Field3 {
        let n = self.cfg.n;
        let rf = self.radius_factor(t);
        let nf = n as f32;
        let center = 0.5 * nf;
        let cs = 0.6 * nf; // shock speed: crosses the domain in ~1.7 t-units
        let shock_width = 0.012 * nf + 1.5;
        let iw = 1.2f32; // interface width in cells

        // alpha2 accumulated from bubbles (bounded support per bubble)
        let mut a2 = vec![0f32; n * n * n];
        for b in &self.bubbles {
            let r = b.r0 * rf;
            let reach = r + 5.0 * iw;
            let lo = |c: f32| ((c - reach).floor().max(0.0)) as usize;
            let hi = |c: f32| ((c + reach).ceil().min(nf - 1.0)) as usize;
            for z in lo(b.cz)..=hi(b.cz) {
                for y in lo(b.cy)..=hi(b.cy) {
                    for x in lo(b.cx)..=hi(b.cx) {
                        let dx = x as f32 - b.cx;
                        let dy = y as f32 - b.cy;
                        let dz = z as f32 - b.cz;
                        let d = (dx * dx + dy * dy + dz * dz).sqrt();
                        let v = 0.5 * (1.0 - ((d - r) / iw).tanh());
                        let idx = (z * n + y) * n + x;
                        a2[idx] = (a2[idx] + v).min(1.0);
                    }
                }
            }
        }
        if qoi == Qoi::Alpha2 {
            return Field3::from_vec(n, n, n, a2);
        }

        // Pressure is CONTINUOUS across material interfaces (pressure
        // equilibrium); its discontinuities come only from the collapse
        // shocks. Around each bubble the field dips smoothly toward the
        // gas pressure; the dip deepens as the collapse intensifies
        // (early field is smooth -> high CR, Fig 3 left side).
        let ppeak = self.peak_pressure(t);
        let drive = self.p_inf * (1.0 + 0.5 * t * t);
        let dip_amp = 0.25 + 0.70 * t.min(1.0) * t.min(1.0);
        let mut dip = vec![0f32; n * n * n]; // multiplicative dip in (0, 1]
        for b in &self.bubbles {
            let r = (b.r0 * rf).max(1.0);
            let ell = r.max(2.5); // resolved decay length in cells
            let reach = r + 8.0 * ell;
            let lo = |c: f32| ((c - reach).floor().max(0.0)) as usize;
            let hi = |c: f32| ((c + reach).ceil().min(nf - 1.0)) as usize;
            for z in lo(b.cz)..=hi(b.cz) {
                for y in lo(b.cy)..=hi(b.cy) {
                    for x in lo(b.cx)..=hi(b.cx) {
                        let dx = x as f32 - b.cx;
                        let dy = y as f32 - b.cy;
                        let dz = z as f32 - b.cz;
                        let d = (dx * dx + dy * dy + dz * dz).sqrt();
                        let f = if d <= r { 1.0 } else { (-(d - r) / ell).exp() };
                        let idx = (z * n + y) * n + x;
                        dip[idx] = (dip[idx] + dip_amp * f).min(0.97);
                    }
                }
            }
        }
        // Collapse emits a burst of staggered shock shells (individual
        // bubble collapses) with angular fine structure; behind the front
        // a decaying acoustic wake keeps the field broadband for a while.
        let shell_times = [1.0f32, 1.015, 1.035, 1.06, 1.09, 1.13, 1.18];
        let mut out = vec![0f32; n * n * n];
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let idx = (z * n + y) * n + x;
                    let dx = x as f32 - center;
                    let dy = y as f32 - center;
                    let dz = z as f32 - center;
                    let d = (dx * dx + dy * dy + dz * dz).sqrt();
                    // cell-scale angular texture (resolution-dependent
                    // sharpness, like a real captured shock)
                    let ang = (0.9 * x as f32 + 1.3 * y as f32).sin()
                        * (1.1 * z as f32 - 0.7 * x as f32).sin();
                    let mut sh = 0.0f32;
                    for (k, &tk) in shell_times.iter().enumerate() {
                        if t <= tk {
                            continue;
                        }
                        let r_front = cs * (t - tk);
                        let w = shock_width * (1.0 + 0.4 * k as f32);
                        let xq = (d - r_front) / w;
                        let amp = 1.0 / ((1.0 + 0.30 * r_front) * (1.0 + k as f32));
                        sh += (-xq * xq).exp() * amp * (1.0 + 0.6 * ang);
                    }
                    // collapse core: colliding shocklets fill the cloud
                    // interior around t = 1 (the violent phase)
                    let tc = (t - 1.03) / 0.04;
                    if tc.abs() < 4.0 {
                        let cloud_r = self.cfg.cloud_radius * nf;
                        let fr = d / (0.75 * cloud_r);
                        let falloff = (-fr * fr).exp();
                        let ang2 = (0.33 * x as f32 + 0.47 * y as f32).sin()
                            * (0.41 * z as f32 - 0.29 * x as f32).sin();
                        sh += (-tc * tc).exp() * falloff * (0.040 * ang2 + 0.016 * ang);
                    }
                    // wake behind the leading front (decays quickly)
                    if t > 1.0 {
                        let r_lead = cs * (t - 1.0);
                        if d < r_lead {
                            let decay = (-(5.0) * (t - 1.0)).exp();
                            sh += 0.05 * decay * ang * (1.0 + (0.05 * d).sin());
                        }
                    }
                    // smooth pressure halo around the cloud pre-collapse
                    let halo = 0.25
                        * self.p_inf
                        * t
                        * (-(d / (0.5 * nf)) * (d / (0.5 * nf))).exp();
                    let p = (drive + halo) * (1.0 - dip[idx]) + (ppeak - drive) * sh;
                    out[idx] = p.max(1.0);
                }
            }
        }
        match qoi {
            Qoi::Pressure => Field3::from_vec(n, n, n, out),
            Qoi::Density => {
                let mut rho = out;
                for (i, r) in rho.iter_mut().enumerate() {
                    let a = a2[i];
                    let p = *r;
                    // liquid with slight compressibility + gas mixture
                    let liquid = self.rho_liquid * (1.0 + 2e-4 * (p - self.p_inf));
                    *r = liquid * (1.0 - a) + self.rho_gas * a;
                }
                Field3::from_vec(n, n, n, rho)
            }
            Qoi::Energy => {
                let mut e = out;
                for (i, v) in e.iter_mut().enumerate() {
                    let a = a2[i];
                    let p = *v;
                    let liquid = self.rho_liquid * (1.0 + 2e-4 * (p - self.p_inf));
                    let rho = liquid * (1.0 - a) + self.rho_gas * a;
                    // E = p/(gamma-1) + kinetic proxy coupled to the shock
                    *v = p / (self.gamma - 1.0) + 0.5e-3 * rho * p;
                }
                Field3::from_vec(n, n, n, e)
            }
            Qoi::Alpha2 => unreachable!(),
        }
    }

    /// All four QoIs at a simulation step (paper's snapshots).
    pub fn snapshot(&self, step: usize) -> Vec<(Qoi, Field3)> {
        let t = step_to_time(step);
        Qoi::ALL.iter().map(|&q| (q, self.field(q, t))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::FieldStats;

    fn sim(n: usize) -> CloudSim {
        CloudSim::new(CloudConfig::paper(n))
    }

    #[test]
    fn bubbles_inside_cloud() {
        let s = sim(64);
        assert_eq!(s.bubbles.len(), 70);
        let c = 32.0f32;
        for b in &s.bubbles {
            let d = ((b.cx - c).powi(2) + (b.cy - c).powi(2) + (b.cz - c).powi(2)).sqrt();
            assert!(d <= 0.35 * 64.0 + 1e-3, "bubble at distance {d}");
            assert!(b.r0 >= 1.5);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = sim(32).field(Qoi::Pressure, 0.5);
        let b = sim(32).field(Qoi::Pressure, 0.5);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn alpha2_in_unit_range_and_shrinks() {
        let s = sim(64);
        let early = s.field(Qoi::Alpha2, step_to_time(1000));
        let late = s.field(Qoi::Alpha2, step_to_time(6500));
        for &v in &early.data {
            assert!((0.0..=1.0).contains(&v));
        }
        let vol = |f: &Field3| f.data.iter().map(|&v| v as f64).sum::<f64>();
        assert!(
            vol(&late) < 0.6 * vol(&early),
            "gas volume must shrink toward collapse: {} vs {}",
            vol(&late),
            vol(&early)
        );
        // rebound re-expands
        let rebound = s.field(Qoi::Alpha2, step_to_time(10000));
        assert!(vol(&rebound) > vol(&late));
    }

    #[test]
    fn paper_like_ranges() {
        // Table 1 magnitudes: p O(1e2..1e3), rho up to ~1e3, E up to ~8e3,
        // a2 in [0, 1]
        let s = sim(64);
        for (step, _) in [(5000, ()), (10000, ())] {
            let t = step_to_time(step);
            let p = FieldStats::compute(&s.field(Qoi::Pressure, t).data);
            assert!(p.min > 0.0 && p.max < 2000.0, "p range {:?}", (p.min, p.max));
            let rho = FieldStats::compute(&s.field(Qoi::Density, t).data);
            assert!(rho.min >= 0.5 && rho.max < 1500.0, "rho range {:?}", (rho.min, rho.max));
            let e = FieldStats::compute(&s.field(Qoi::Energy, t).data);
            assert!(e.max > 100.0 && e.max < 50000.0, "E range {:?}", (e.min, e.max));
        }
    }

    #[test]
    fn peak_pressure_spikes_at_collapse() {
        let s = sim(32);
        let before = s.peak_pressure(0.5);
        let at = s.peak_pressure(1.0);
        let after = s.peak_pressure(1.4);
        assert!(at > 3.0 * before, "peak {at} vs before {before}");
        assert!(at > 2.0 * after, "peak {at} vs after {after}");
    }

    #[test]
    fn shock_travels_outward() {
        let s = sim(64);
        let t1 = 1.05f32;
        let t2 = 1.3f32;
        let p1 = s.field(Qoi::Pressure, t1);
        let p2 = s.field(Qoi::Pressure, t2);
        // radial profile argmax along +x from center
        let front = |f: &Field3| {
            let (mut best, mut arg) = (0f32, 0usize);
            for x in 34..64 {
                let v = f.get(x, 32, 32);
                if v > best {
                    best = v;
                    arg = x;
                }
            }
            arg
        };
        assert!(front(&p2) > front(&p1), "front {} -> {}", front(&p1), front(&p2));
    }

    #[test]
    fn compressibility_drops_at_collapse() {
        // the headline Fig 3 behaviour: wavelet CR of p is much lower just
        // after collapse (shock present) than pre-collapse
        use crate::pipeline::{compress_field, NativeEngine, PipelineConfig};
        let s = sim(96);
        let cfg = PipelineConfig::paper_default(1e-3);
        let ratio = |step: usize| {
            let f = s.field(Qoi::Pressure, step_to_time(step));
            compress_field(&f, "p", &cfg, &NativeEngine).1.ratio()
        };
        let pre = ratio(3000);
        let dip = ratio(7200);
        let late = ratio(10000);
        assert!(dip < 0.7 * pre, "CR must dip at collapse: pre {pre} dip {dip}");
        // paper 3.3: "compression ratios are lower for the datasets
        // generated after 10k timesteps"
        assert!(late < pre, "late {late} must stay below pre-collapse {pre}");
    }
}
