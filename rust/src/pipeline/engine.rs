//! The session-based compression API: a long-lived [`Engine`] owning a
//! persistent worker pool.
//!
//! The free functions ([`super::compress_field`],
//! [`super::decompress_field_mt`]) spawn scoped worker threads per call —
//! fine for one-shot tool use, wasteful for the paper's in-situ scenario
//! where a simulation dumps ~7 quantities every few thousand steps. An
//! `Engine` is built once:
//!
//! ```no_run
//! use cubismz::pipeline::{CompressParams, Engine};
//! let engine = Engine::builder().threads(8).chunk_bytes(4 << 20).build();
//! let params = CompressParams::paper_default(1e-3);
//! # let field = cubismz::core::Field3::zeros(32, 32, 32);
//! let mut sink: Vec<u8> = Vec::new();
//! let stats = engine.compress(&field, "p", &params, &mut sink).unwrap();
//! let (back, _file) = engine.decompress(&mut sink.as_slice()).unwrap();
//! ```
//!
//! and every `compress`/`decompress` call reuses the same
//! [`crate::cluster::WorkerPool`] workers, streaming to any
//! `io::Write`/`io::Read` instead of returning whole `Vec`s. The
//! `.czb` bytes an `Engine` produces are byte-identical to the free
//! functions' output for every thread count — both drive the same
//! span-queue core, which fixes chunk boundaries by block-id arithmetic.
//!
//! `Engine` is `Send + Sync` and every entry point takes `&self`: any
//! number of threads may call `compress`, `decompress` and
//! `decompress_dataset` on one session concurrently, with no external
//! locking. Each call is one *submission* on the multi-generation pool —
//! idle workers steal across live submissions oldest-first while each
//! submitting thread drains its own, so a small request completes while
//! a large one streams, and per-submission error/abort state keeps a
//! corrupt stream from poisoning its neighbours. Every stream's bytes
//! are identical to what a lone submission produces, at every thread
//! count and under any interleaving.
use super::compressor::{
    compress_field_core, CompressStats, NativeEngine, PipelineConfig, WaveletEngine,
    DEFAULT_FRAME_BYTES,
};
use super::dataset::Dataset;
use super::decompressor::{
    decompress_field_core, decompress_field_salvage_core, decompress_sections, DecodeReport,
    SectionJob,
};
use super::format::{CzbFile, ShuffleMode, Stage1};
use super::quality::Bound;
use crate::cluster::WorkerPool;
use crate::codec::Codec;
use crate::core::Field3;
use crate::metrics::registry::Registry;
use std::io::{Read, Write};
use std::sync::Arc;

/// Per-call compression parameters: what to compress *with*, as opposed
/// to the session-level knobs (threads, chunk budget, batch size) fixed
/// at [`Engine`] build time. Mirrors the format-affecting subset of
/// [`PipelineConfig`].
#[derive(Clone, Copy, Debug)]
pub struct CompressParams {
    pub bs: usize,
    pub stage1: Stage1,
    pub stage2: Codec,
    pub shuffle: ShuffleMode,
    /// Error-bound contract ([`Bound::None`] by default). When set, the
    /// stage-1 knob is resolved from it per field and the contract plus
    /// the achieved per-chunk quality are recorded in the `.czb` v5
    /// header. The stage-1 codec must honor the bound's kind
    /// ([`super::stage1::Stage1Codec::honors`]) — callers validate the
    /// pairing when building the params.
    pub bound: Bound,
}

impl CompressParams {
    pub fn new(bs: usize, stage1: Stage1, stage2: Codec) -> Self {
        Self { bs, stage1, stage2, shuffle: ShuffleMode::None, bound: Bound::None }
    }

    /// The paper's production scheme: W³ai + shuffle + ZLIB.
    pub fn paper_default(eps_rel: f32) -> Self {
        Self::from_config(&PipelineConfig::paper_default(eps_rel))
    }

    pub fn with_shuffle(mut self, s: ShuffleMode) -> Self {
        self.shuffle = s;
        self
    }

    pub fn with_bound(mut self, b: Bound) -> Self {
        self.bound = b;
        self
    }

    /// The format-affecting subset of a legacy [`PipelineConfig`].
    pub fn from_config(cfg: &PipelineConfig) -> Self {
        Self {
            bs: cfg.bs,
            stage1: cfg.stage1,
            stage2: cfg.stage2,
            shuffle: cfg.shuffle,
            bound: cfg.bound,
        }
    }
}

/// Builds an [`Engine`]: `Engine::builder().threads(8).build()`.
pub struct EngineBuilder {
    threads: usize,
    chunk_bytes: usize,
    frame_bytes: usize,
    batch: usize,
    wavelet_engine: Box<dyn WaveletEngine>,
    metrics: Option<Arc<Registry>>,
}

impl EngineBuilder {
    fn new() -> Self {
        Self {
            threads: 0,
            chunk_bytes: 4 << 20,
            frame_bytes: DEFAULT_FRAME_BYTES,
            batch: 16,
            wavelet_engine: Box::new(NativeEngine),
            metrics: None,
        }
    }

    /// Worker threads owned by the session (0 = all hardware threads,
    /// the default).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Private per-worker buffer capacity before stage 2 runs, and the
    /// scheduling granularity (paper: 4 MB). Format-affecting: archives
    /// written with different chunk budgets differ byte-wise.
    pub fn chunk_bytes(mut self, n: usize) -> Self {
        self.chunk_bytes = n.max(1);
        self
    }

    /// Raw bytes per stage-2 sub-frame of each sealed chunk (default
    /// 256 KiB; 0 keeps the default rather than degenerating to 1-byte
    /// frames). Format-affecting, like `chunk_bytes`. Smaller frames
    /// expose more intra-chunk parallelism at a slight ratio cost.
    pub fn frame_bytes(mut self, n: usize) -> Self {
        self.frame_bytes = if n == 0 { DEFAULT_FRAME_BYTES } else { n };
        self
    }

    /// Blocks per wavelet-transform batch (matches the PJRT executable's
    /// batch dimension).
    pub fn batch(mut self, n: usize) -> Self {
        self.batch = n.max(1);
        self
    }

    /// Executor for the batched wavelet transform (native Rust by
    /// default; `runtime::PjrtEngine` for the Pallas kernel build).
    pub fn wavelet_engine(mut self, engine: Box<dyn WaveletEngine>) -> Self {
        self.wavelet_engine = engine;
        self
    }

    /// Live metric registry the session reports into: every
    /// `compress`/`decompress*` call adds its byte totals and stage
    /// wall-times (relaxed atomic adds — no effect on the hot path when
    /// unset). The service front-end shares one registry between the
    /// engine and its `/metrics`-style `stat` exporter.
    pub fn metrics(mut self, registry: Arc<Registry>) -> Self {
        self.metrics = Some(registry);
        self
    }

    pub fn build(self) -> Engine {
        let threads = match self.threads {
            0 => std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
            n => n,
        };
        Engine {
            pool: WorkerPool::new(threads),
            threads,
            chunk_bytes: self.chunk_bytes,
            frame_bytes: self.frame_bytes,
            batch: self.batch,
            wavelet_engine: self.wavelet_engine,
            metrics: self.metrics,
        }
    }
}

/// A compression session: persistent worker pool + wavelet-transform
/// executor + session-level pipeline knobs. Build once via
/// [`Engine::builder`], then compress/decompress any number of
/// quantities. `Engine` is `Send + Sync`: threads submit concurrently
/// through `&Engine` (or an `Arc<Engine>`) with no external locking —
/// each call is an independent submission on the multi-generation pool,
/// scheduled work-stealing across all live submissions.
pub struct Engine {
    pool: WorkerPool,
    threads: usize,
    chunk_bytes: usize,
    frame_bytes: usize,
    batch: usize,
    wavelet_engine: Box<dyn WaveletEngine>,
    metrics: Option<Arc<Registry>>,
}

/// Compile-time guarantee that sessions stay shareable and movable
/// across submitting threads (the concurrency contract above).
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
};

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn chunk_bytes(&self) -> usize {
        self.chunk_bytes
    }

    pub fn frame_bytes(&self) -> usize {
        self.frame_bytes
    }

    /// The session's wavelet-transform executor (shared with
    /// `BlockReader` for random access into session-produced archives).
    pub fn wavelet_engine(&self) -> &dyn WaveletEngine {
        self.wavelet_engine.as_ref()
    }

    /// The full pipeline configuration a [`CompressParams`] resolves to
    /// under this session's knobs.
    pub fn config_for(&self, params: &CompressParams) -> PipelineConfig {
        let mut cfg = PipelineConfig::new(params.bs, params.stage1, params.stage2);
        cfg.shuffle = params.shuffle;
        cfg.bound = params.bound;
        cfg.chunk_bytes = self.chunk_bytes;
        cfg.frame_bytes = self.frame_bytes;
        cfg.batch = self.batch;
        cfg.nthreads = self.threads;
        cfg
    }

    /// Compress `field` and stream the `.czb` bytes to `sink`. The bytes
    /// are identical to [`super::compress_field`] with the same
    /// format-affecting parameters, for every thread count.
    pub fn compress(
        &self,
        field: &Field3,
        name: &str,
        params: &CompressParams,
        sink: &mut dyn Write,
    ) -> std::io::Result<CompressStats> {
        let cfg = self.config_for(params);
        let cs = compress_field_core(&self.pool, field, name, &cfg, self.wavelet_engine.as_ref());
        let mut header = Vec::with_capacity(CzbFile::header_size(name.len(), cs.payloads.len()));
        cs.czb.write_header(&mut header);
        sink.write_all(&header)?;
        for p in &cs.payloads {
            sink.write_all(p)?;
        }
        if let Some(m) = &self.metrics {
            m.engine_compress_calls.inc();
            m.engine_raw_bytes.add(cs.stats.raw_bytes as u64);
            m.engine_compressed_bytes.add(cs.stats.compressed_bytes as u64);
            m.stage1_micros.add((cs.stats.t_stage1 * 1e6) as u64);
            m.stage2_micros.add((cs.stats.t_stage2 * 1e6) as u64);
        }
        Ok(cs.stats)
    }

    /// Compress into a fresh `Vec` (convenience mirror of
    /// [`super::compress_field`]).
    pub fn compress_vec(
        &self,
        field: &Field3,
        name: &str,
        params: &CompressParams,
    ) -> (Vec<u8>, CompressStats) {
        let mut out = Vec::new();
        let stats = self
            .compress(field, name, params, &mut out)
            .expect("writing to a Vec cannot fail");
        (out, stats)
    }

    /// Read a whole `.czb` stream from `src` and decompress it on the
    /// session pool (chunk-parallel, bit-identical to the serial path).
    pub fn decompress(&self, src: &mut dyn Read) -> Result<(Field3, CzbFile), String> {
        let mut bytes = Vec::new();
        src.read_to_end(&mut bytes).map_err(|e| format!("reading czb stream: {e}"))?;
        self.decompress_bytes(&bytes)
    }

    /// Decompress an in-memory `.czb` stream on the session pool.
    pub fn decompress_bytes(&self, bytes: &[u8]) -> Result<(Field3, CzbFile), String> {
        let r = decompress_field_core(&self.pool, bytes, self.wavelet_engine.as_ref(), self.threads);
        if let (Some(m), Ok((field, _))) = (&self.metrics, &r) {
            m.engine_decompress_calls.inc();
            m.engine_decoded_bytes.add(field.nbytes() as u64);
        }
        r
    }

    /// Salvage-decompress an in-memory `.czb` stream on the session
    /// pool: every intact chunk decodes (bit-identical to
    /// [`Engine::decompress_bytes`]), every corrupt chunk's blocks are
    /// zero-filled, and the [`DecodeReport`] enumerates exactly what was
    /// lost. `Err` only for unreadable streams (header/index damage) —
    /// the CLI's `czb decompress --salvage` mode.
    pub fn decompress_salvage(
        &self,
        bytes: &[u8],
    ) -> Result<(Field3, CzbFile, DecodeReport), String> {
        decompress_field_salvage_core(&self.pool, bytes, self.wavelet_engine.as_ref(), self.threads)
    }

    /// Salvage-decompress quantities of a `.czs` archive (all of them,
    /// or the `names` subset in the given order): each quantity is
    /// decoded with [`Engine::decompress_salvage`] in turn
    /// (chunk-parallel within), and — unlike the strict
    /// [`Engine::decompress_dataset`] — one damaged quantity never
    /// fails its siblings: its per-quantity `Result` carries the error
    /// while every other quantity still comes back, possibly with
    /// salvaged holes of its own. The section-wide trailer digest is
    /// deliberately bypassed here — the per-chunk checksums inside each
    /// section localize payload damage, so a section the strict path
    /// refuses outright salvages everything but its broken chunks;
    /// only genuinely unreadable sections (header/index damage) come
    /// back as that quantity's `Err`.
    pub fn decompress_dataset_salvage(
        &self,
        dataset: &Dataset,
        names: Option<&[&str]>,
    ) -> Result<Vec<(String, Result<(Field3, CzbFile, DecodeReport), String>)>, String> {
        let indices: Vec<usize> = match names {
            None => (0..dataset.entries().len()).collect(),
            Some(ns) => ns
                .iter()
                .map(|n| dataset.index_of(n))
                .collect::<Result<_, _>>()?,
        };
        let mut out = Vec::with_capacity(indices.len());
        for idx in indices {
            let name = dataset.entries()[idx].name.clone();
            let r = dataset
                .section_at_unverified(idx)
                .and_then(|section| self.decompress_salvage(section));
            out.push((name, r));
        }
        Ok(out)
    }

    /// Decompress every quantity of a `.czs` archive (or the `names`
    /// subset, in the given order) concurrently on the session pool.
    ///
    /// All requested quantities are scheduled onto the one worker pool
    /// at once: quantity *i+1*'s section I/O (lazy on file-backed
    /// archives) and stage-2 inflate overlap quantity *i*'s block
    /// decode, and idle workers steal chunk spans from whichever
    /// quantity still has work — no per-quantity barriers. Decoded
    /// chunks go through the archive's shared [`super::ChunkCache`].
    /// Output is bit-identical to decoding each quantity alone, at
    /// every thread count. Returns `(archive entry name, field, parsed
    /// header)` per quantity.
    ///
    /// Scheduling is chunk-granular: when several sections together
    /// have fewer chunks than workers (tiny or huge-chunk archives),
    /// some workers idle — a single requested quantity instead falls
    /// back to the intra-chunk wide path via the same route
    /// [`Dataset::read_quantity`] takes.
    pub fn decompress_dataset(
        &self,
        dataset: &Dataset,
        names: Option<&[&str]>,
    ) -> Result<Vec<(String, Field3, CzbFile)>, String> {
        let indices: Vec<usize> = match names {
            None => (0..dataset.entries().len()).collect(),
            Some(ns) => ns
                .iter()
                .map(|n| dataset.index_of(n))
                .collect::<Result<_, _>>()?,
        };
        // one quantity has no cross-section work to overlap; route it
        // through the single-section path, which can go wide inside
        // starved chunks
        let results = if indices.len() == 1 {
            vec![self.decompress_section(dataset, indices[0])]
        } else {
            self.decompress_sections_of(dataset, &indices)
        };
        let mut out = Vec::with_capacity(indices.len());
        for (&idx, r) in indices.iter().zip(results) {
            let name = &dataset.entries()[idx].name;
            let (field, file) = r.map_err(|e| format!("quantity {name}: {e}"))?;
            out.push((name.clone(), field, file));
        }
        Ok(out)
    }

    /// Decompress one section of a `.czs` archive on the session pool
    /// (what [`Dataset::read_quantity`] drives). Sections with at least
    /// as many chunks as workers decode chunk-granular through the
    /// archive's shared chunk cache; a lone *starved* section (fewer
    /// chunks than workers) takes the intra-chunk wide path instead —
    /// chunk-granular cache routing could keep only one worker per
    /// chunk busy, losing the single-chunk scaling the framed format
    /// exists for. Both paths are bit-identical.
    pub(crate) fn decompress_section(
        &self,
        dataset: &Dataset,
        idx: usize,
    ) -> Result<(Field3, CzbFile), String> {
        let section = dataset.section_at(idx)?;
        let (file, _) = CzbFile::parse_header(section)?;
        if file.chunks.len() < self.threads {
            return self.decompress_bytes(section);
        }
        self.decompress_sections_of(dataset, &[idx])
            .pop()
            .expect("one job yields one result")
    }

    fn decompress_sections_of(
        &self,
        dataset: &Dataset,
        indices: &[usize],
    ) -> Vec<Result<(Field3, CzbFile), String>> {
        let jobs: Vec<SectionJob<'_>> = indices
            .iter()
            .map(|&i| SectionJob {
                load: Box::new(move || dataset.section_at(i)),
                cache: dataset.chunk_cache().clone(),
                stream: dataset.stream_of(i),
            })
            .collect();
        decompress_sections(&self.pool, &jobs, self.wavelet_engine.as_ref(), self.threads)
    }
}

impl Default for Engine {
    /// A session with all hardware threads and paper-default knobs.
    fn default() -> Self {
        Engine::builder().build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::compressor::compress_field;
    use crate::pipeline::decompressor::{decompress_field, decompress_field_mt};
    use crate::util::prng::Pcg32;

    fn smooth_field(n: usize, seed: u64) -> Field3 {
        let mut rng = Pcg32::new(seed);
        Field3::from_vec(n, n, n, crate::util::prop::gen_smooth_field(&mut rng, n))
    }

    #[test]
    fn engine_bytes_match_legacy_for_every_thread_count() {
        let f = smooth_field(64, 91);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 32 << 10; // several spans so pulls interleave
        let params = CompressParams::from_config(&cfg);
        let (reference, st) = compress_field(&f, "p", &cfg.with_threads(1), &NativeEngine);
        assert!(st.nchunks > 1);
        for threads in [1usize, 2, 3, 8] {
            let engine =
                Engine::builder().threads(threads).chunk_bytes(cfg.chunk_bytes).build();
            let (bytes, stats) = engine.compress_vec(&f, "p", &params);
            assert_eq!(bytes, reference, "threads {threads}");
            assert_eq!(stats.compressed_bytes, reference.len());
            // decompress on the same session, against the serial path
            let (back, file) = engine.decompress_bytes(&bytes).unwrap();
            let (serial, _) = decompress_field(&bytes, &NativeEngine).unwrap();
            assert_eq!(file.name, "p");
            assert!(back
                .data
                .iter()
                .zip(&serial.data)
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn one_session_compresses_many_quantities() {
        // the in-situ shape: one pool, repeated dumps; streams must be
        // independent of session reuse
        let engine = Engine::builder().threads(4).chunk_bytes(64 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        for seed in [1u64, 2, 3, 4, 5] {
            let f = smooth_field(64, seed);
            let (bytes, st) = engine.compress_vec(&f, "q", &params);
            let mut cfg = engine.config_for(&params);
            cfg.nthreads = 1;
            let (reference, _) = compress_field(&f, "q", &cfg, &NativeEngine);
            assert_eq!(bytes, reference, "seed {seed}");
            assert!(st.ratio() > 1.0);
        }
    }

    #[test]
    fn streaming_sinks_and_sources_roundtrip() {
        let f = smooth_field(32, 9);
        let engine = Engine::builder().threads(2).build();
        let params = CompressParams::paper_default(1e-3);
        // write through the io::Write path
        let mut sink: Vec<u8> = Vec::new();
        let stats = engine.compress(&f, "rho", &params, &mut sink).unwrap();
        assert_eq!(stats.compressed_bytes, sink.len());
        // read back through the io::Read path
        let (back, file) = engine.decompress(&mut sink.as_slice()).unwrap();
        assert_eq!(file.name, "rho");
        let (expected, _) = decompress_field_mt(&sink, &NativeEngine, 2).unwrap();
        assert!(back
            .data
            .iter()
            .zip(&expected.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn decompress_dataset_fans_out_and_matches_per_quantity() {
        use crate::pipeline::dataset::{Dataset, DatasetWriter};
        let engine = Engine::builder().threads(4).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let fields: Vec<(String, Field3)> =
            (0..5u64).map(|i| (format!("q{i}"), smooth_field(32, 40 + i))).collect();
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        for (name, f) in &fields {
            w.write_quantity(&engine, f, name, &params).unwrap();
        }
        let ds = Dataset::from_bytes(w.finish().unwrap()).unwrap();
        // all quantities, archive order
        let all = engine.decompress_dataset(&ds, None).unwrap();
        assert_eq!(
            all.iter().map(|(n, ..)| n.as_str()).collect::<Vec<_>>(),
            vec!["q0", "q1", "q2", "q3", "q4"]
        );
        for (name, field, file) in &all {
            assert_eq!(&file.name, name);
            let (expected, _) = engine.decompress_bytes(ds.section(name).unwrap()).unwrap();
            assert!(
                field.data.iter().zip(&expected.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{name}"
            );
        }
        // subset, caller order
        let some = engine.decompress_dataset(&ds, Some(&["q3", "q0"])).unwrap();
        assert_eq!(some.len(), 2);
        assert_eq!(some[0].0, "q3");
        assert_eq!(some[1].0, "q0");
        // unknown quantity errors
        assert!(engine.decompress_dataset(&ds, Some(&["nope"])).is_err());
        // empty selection is a no-op
        assert!(engine.decompress_dataset(&ds, Some(&[])).unwrap().is_empty());
    }

    #[test]
    fn starved_sections_take_the_wide_path_bit_exact() {
        use crate::pipeline::dataset::{Dataset, DatasetWriter};
        // one framed chunk, more threads than chunks: read_quantity must
        // fall back to the intra-chunk wide path and stay bit-identical
        let engine = Engine::builder()
            .threads(8)
            .chunk_bytes(64 << 20)
            .frame_bytes(2 << 10)
            .build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(64, 60);
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        w.write_quantity(&engine, &f, "p", &params).unwrap();
        let ds = Dataset::from_bytes(w.finish().unwrap()).unwrap();
        let section = ds.section("p").unwrap().to_vec();
        let (file, _) = CzbFile::parse_header(&section).unwrap();
        assert_eq!(file.chunks.len(), 1, "section must be single-chunk for this test");
        let (serial, _) = decompress_field(&section, &NativeEngine).unwrap();
        let (wide, _) = ds.read_quantity("p", &engine).unwrap();
        assert!(wide
            .data
            .iter()
            .zip(&serial.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn decompress_dataset_reports_the_corrupt_quantity() {
        use crate::pipeline::dataset::{Dataset, DatasetWriter};
        let engine = Engine::builder().threads(3).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        for (i, seed) in [50u64, 51, 52].iter().enumerate() {
            w.write_quantity(&engine, &smooth_field(32, *seed), &format!("q{i}"), &params)
                .unwrap();
        }
        let mut bytes = w.finish().unwrap();
        let ds0 = Dataset::from_bytes(bytes.clone()).unwrap();
        // smash q1's .czb magic so its open fails deterministically
        let off = ds0.entries()[1].offset as usize;
        bytes[off..off + 4].copy_from_slice(b"XXXX");
        let ds = Dataset::from_bytes(bytes).unwrap();
        let err = engine.decompress_dataset(&ds, None).unwrap_err();
        assert!(err.contains("q1"), "{err}");
        // the healthy sibling still decodes on its own
        assert!(ds.read_quantity("q0", &engine).is_ok());
        assert!(ds.read_quantity("q2", &engine).is_ok());
    }

    #[test]
    fn dataset_salvage_isolates_damage_per_quantity() {
        use crate::pipeline::dataset::{Dataset, DatasetWriter};
        let engine = Engine::builder().threads(3).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        for (i, seed) in [60u64, 61, 62].iter().enumerate() {
            w.write_quantity(&engine, &smooth_field(32, *seed), &format!("q{i}"), &params)
                .unwrap();
        }
        let clean_bytes = w.finish().unwrap();
        let clean_ds = Dataset::from_bytes(clean_bytes.clone()).unwrap();
        let clean = engine.decompress_dataset(&clean_ds, None).unwrap();
        // damage q0's czb header (unreadable) and one payload byte deep
        // inside q1 (salvageable); q2 stays pristine
        let mut bytes = clean_bytes.clone();
        let q0 = clean_ds.entries()[0].clone();
        let q1 = clean_ds.entries()[1].clone();
        bytes[q0.offset as usize..q0.offset as usize + 4].copy_from_slice(b"XXXX");
        bytes[(q1.offset + q1.len - 5) as usize] ^= 0x04;
        let ds = Dataset::from_bytes(bytes).unwrap();
        // strict decode fails the archive; salvage triages per quantity
        assert!(engine.decompress_dataset(&ds, None).is_err());
        let results = engine.decompress_dataset_salvage(&ds, None).unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].0, "q0");
        assert!(results[0].1.is_err(), "header damage is unreadable");
        let (field, _, rep) = results[1].1.as_ref().unwrap();
        assert_eq!(rep.corrupt_chunks.len(), 1, "one damaged chunk in q1");
        assert!(rep.salvaged_chunks() > 0);
        assert!(!field.data.is_empty());
        let (f2, _, rep2) = results[2].1.as_ref().unwrap();
        assert!(rep2.is_clean());
        // the pristine quantity salvages bit-identically to the strict
        // decode of the clean archive
        assert!(f2
            .data
            .iter()
            .zip(&clean[2].1.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        // name subsetting works and keeps the requested order
        let sub = engine.decompress_dataset_salvage(&ds, Some(&["q2", "q1"])).unwrap();
        assert_eq!(sub[0].0, "q2");
        assert_eq!(sub[1].0, "q1");
        assert!(engine.decompress_dataset_salvage(&ds, Some(&["nope"])).is_err());
    }

    #[test]
    fn concurrent_submissions_are_byte_identical_per_stream() {
        // the tentpole invariant: several threads submitting at once
        // through one session must each get exactly the bytes (and bits)
        // a lone submission produces — work stealing across submissions
        // must never leak into any stream
        let engine = Engine::builder().threads(4).chunk_bytes(32 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let fields: Vec<Field3> = (0..4u64).map(|i| smooth_field(64, 200 + i)).collect();
        let references: Vec<Vec<u8>> = fields
            .iter()
            .map(|f| {
                let mut cfg = engine.config_for(&params);
                cfg.nthreads = 1;
                compress_field(f, "q", &cfg, &NativeEngine).0
            })
            .collect();
        let engine = &engine;
        for _round in 0..3 {
            let outputs: Vec<Vec<u8>> = std::thread::scope(|s| {
                let handles: Vec<_> = fields
                    .iter()
                    .map(|f| s.spawn(move || engine.compress_vec(f, "q", &params).0))
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (k, (got, expect)) in outputs.iter().zip(&references).enumerate() {
                assert_eq!(got, expect, "stream {k}");
            }
            // concurrent decompression of the four streams, against the
            // serial decoder
            let decoded: Vec<Field3> = std::thread::scope(|s| {
                let handles: Vec<_> = references
                    .iter()
                    .map(|bytes| s.spawn(move || engine.decompress_bytes(bytes).unwrap().0))
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (k, (got, bytes)) in decoded.iter().zip(&references).enumerate() {
                let (serial, _) = decompress_field(bytes, &NativeEngine).unwrap();
                assert!(
                    got.data.iter().zip(&serial.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "stream {k}"
                );
            }
        }
    }

    #[test]
    fn errored_submission_does_not_poison_streaming_sibling() {
        // one tenant repeatedly feeds the session corrupt streams; a
        // sibling compressing at the same time must still produce
        // byte-identical archives
        let engine = Engine::builder().threads(4).chunk_bytes(32 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(64, 77);
        let (reference, _) = {
            let mut cfg = engine.config_for(&params);
            cfg.nthreads = 1;
            compress_field(&f, "q", &cfg, &NativeEngine)
        };
        let mut corrupt = reference.clone();
        let lo = corrupt.len() / 2;
        for b in &mut corrupt[lo..] {
            *b = 0xAB;
        }
        std::thread::scope(|s| {
            let bad = s.spawn(|| {
                for _ in 0..20 {
                    assert!(engine.decompress_bytes(&corrupt).is_err());
                    assert!(engine.decompress_bytes(b"not a czb").is_err());
                }
            });
            for _ in 0..10 {
                let (bytes, _) = engine.compress_vec(&f, "q", &params);
                assert_eq!(bytes, reference, "sibling stream drifted");
            }
            bad.join().unwrap();
        });
        // the session stays fully usable afterwards
        let (back, _) = engine.decompress_bytes(&reference).unwrap();
        assert_eq!(back.data.len(), f.data.len());
    }

    #[test]
    fn zero_length_inputs_submitted_concurrently() {
        // degenerate tenants must neither wedge the pool nor disturb a
        // real stream: an empty field (zero blocks) roundtrips, an empty
        // byte stream errors
        let engine = Engine::builder().threads(4).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(64, 88);
        let (reference, _) = engine.compress_vec(&f, "q", &params);
        let empty = Field3::zeros(0, 0, 0);
        std::thread::scope(|s| {
            let z1 = s.spawn(|| {
                for _ in 0..10 {
                    let (bytes, st) = engine.compress_vec(&empty, "void", &params);
                    assert_eq!(st.nblocks, 0);
                    assert_eq!(st.nchunks, 0);
                    let (back, file) = engine.decompress_bytes(&bytes).unwrap();
                    assert_eq!(file.name, "void");
                    assert!(back.data.is_empty());
                }
            });
            let z2 = s.spawn(|| {
                for _ in 0..10 {
                    assert!(engine.decompress_bytes(&[]).is_err());
                }
            });
            for _ in 0..5 {
                let (bytes, _) = engine.compress_vec(&f, "q", &params);
                assert_eq!(bytes, reference);
            }
            z1.join().unwrap();
            z2.join().unwrap();
        });
    }

    #[test]
    fn engine_dropped_while_submissions_queued() {
        // the owner's handle goes away while tenants still stream: the
        // session must survive until the last submission retires (Arc),
        // then shut the pool down cleanly
        let engine = std::sync::Arc::new(Engine::builder().threads(2).chunk_bytes(32 << 10).build());
        let params = CompressParams::paper_default(1e-3);
        let mut handles = Vec::new();
        for seed in 0..4u64 {
            let engine = engine.clone();
            handles.push(std::thread::spawn(move || {
                let f = smooth_field(64, 300 + seed);
                let (bytes, _) = engine.compress_vec(&f, "q", &params);
                let (back, _) = engine.decompress_bytes(&bytes).unwrap();
                assert_eq!(back.data.len(), f.data.len());
            }));
        }
        drop(engine);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn metrics_registry_records_engine_totals() {
        use crate::metrics::registry::Registry;
        let reg = std::sync::Arc::new(Registry::new());
        let engine = Engine::builder().threads(2).metrics(reg.clone()).build();
        let f = smooth_field(32, 12);
        let params = CompressParams::paper_default(1e-3);
        let (bytes, stats) = engine.compress_vec(&f, "p", &params);
        assert_eq!(reg.engine_compress_calls.get(), 1);
        assert_eq!(reg.engine_raw_bytes.get(), stats.raw_bytes as u64);
        assert_eq!(reg.engine_compressed_bytes.get(), stats.compressed_bytes as u64);
        let (back, _) = engine.decompress_bytes(&bytes).unwrap();
        assert_eq!(reg.engine_decompress_calls.get(), 1);
        assert_eq!(reg.engine_decoded_bytes.get(), back.nbytes() as u64);
        // failed decodes are not counted as decompressions
        assert!(engine.decompress_bytes(b"junk").is_err());
        assert_eq!(reg.engine_decompress_calls.get(), 1);
    }

    #[test]
    fn bound_contract_is_recorded_and_respected() {
        use crate::pipeline::quality::Bound;
        let engine = Engine::builder().threads(3).chunk_bytes(32 << 10).build();
        let f = smooth_field(64, 33);
        // sz honors Rel: the resolved knob must keep the recorded
        // achieved error inside the stated contract
        let params = CompressParams::new(32, Stage1::Sz { eb_rel: 0.0 }, Codec::ZlibDef)
            .with_shuffle(ShuffleMode::Byte4)
            .with_bound(Bound::Rel(1e-3));
        let (bytes, stats) = engine.compress_vec(&f, "p", &params);
        let (file, _) = CzbFile::parse_header(&bytes).unwrap();
        assert_eq!(file.bound, Bound::Rel(1e-3));
        assert_eq!(file.chunk_quality.len(), file.chunks.len());
        let achieved = file.achieved_quality().expect("v5 records quality");
        file.bound.check(&achieved).expect("contract must hold");
        assert!(achieved.max_rel_err > 0.0, "sz at 1e-3 is genuinely lossy");
        assert_eq!(stats.quality, achieved, "stats and header agree");
        // the stream still roundtrips and is byte-identical across
        // thread counts
        let (back, _) = engine.decompress_bytes(&bytes).unwrap();
        assert_eq!(back.data.len(), f.data.len());
        let single = Engine::builder().threads(1).chunk_bytes(32 << 10).build();
        let (bytes1, _) = single.compress_vec(&f, "p", &params);
        assert_eq!(bytes, bytes1);
        // a lossless contract on fpzip measures exactly zero error
        let params = CompressParams::new(32, Stage1::Fpzip { prec: 32 }, Codec::ZlibDef)
            .with_bound(Bound::Lossless);
        let (bytes, stats) = engine.compress_vec(&f, "p", &params);
        let (file, _) = CzbFile::parse_header(&bytes).unwrap();
        let achieved = file.achieved_quality().unwrap();
        assert_eq!(achieved.max_abs_err, 0.0);
        assert_eq!(achieved.psnr_db, f64::INFINITY);
        file.bound.check(&achieved).unwrap();
        assert_eq!(stats.quality.max_abs_err, 0.0);
    }

    #[test]
    fn decompress_errors_are_strings_not_panics() {
        let engine = Engine::builder().threads(2).build();
        assert!(engine.decompress_bytes(b"not a czb").is_err());
        let f = smooth_field(32, 10);
        let (bytes, _) = engine.compress_vec(&f, "p", &CompressParams::paper_default(1e-3));
        assert!(engine.decompress_bytes(&bytes[..bytes.len() - 5]).is_err());
    }
}
