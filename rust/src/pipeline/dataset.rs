//! `.czs` multi-quantity dataset container: one archive per simulation
//! step, holding every compressed quantity (the paper's multi-QoI CFD
//! workflow dumps ~7 per step).
//!
//! Layout (see the format overview in [`super::format`]): an 8-byte
//! header, each quantity as a complete `.czb` section, and a trailer
//! index written last — so a [`DatasetWriter`] streams to any
//! `io::Write` without seeking, and [`Dataset::open`] finds every
//! section from the fixed-size trailer tail. Sections are independent
//! `.czb` streams: whole-quantity decode and random block access
//! ([`Dataset::block_reader`]) never touch the other quantities.
//!
//! Random access shares one sharded [`ChunkCache`] across every reader
//! the archive hands out: each quantity gets a [`StreamId`] at parse
//! time, so two readers over the same quantity reuse each other's
//! decoded chunks while readers over different quantities never collide
//! — and none of them serialize on a single cache lock.
use super::chunk_cache::{ChunkCache, StreamId};
use super::compressor::{CompressStats, WaveletEngine};
use super::decompressor::BlockReader;
use super::engine::{CompressParams, Engine};
use super::format::CzbFile;
use crate::core::Field3;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

/// Decoded chunks the archive-wide shared cache holds across all
/// quantities (a visualization session touches a few hot chunks per
/// quantity at a time).
const DATASET_CACHE_CHUNKS: usize = 32;

/// Archive magic ("CubismZ Step").
pub const CZS_MAGIC: &[u8; 4] = b"CZS1";
/// Trailer magic, the last four bytes of every archive.
pub const CZS_TRAILER_MAGIC: &[u8; 4] = b"CZSE";
const HEADER_LEN: usize = 8;
const TRAILER_TAIL: usize = 12; // u32 count | u32 table_bytes | magic

/// One quantity's location inside a `.czs` archive.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuantityEntry {
    pub name: String,
    /// Byte offset of the quantity's `.czb` section.
    pub offset: u64,
    /// Length of the section in bytes.
    pub len: u64,
}

/// Streaming `.czs` writer: sections go out as they are compressed, the
/// index goes out on [`DatasetWriter::finish`]. Dropping a writer
/// without `finish` leaves a trailer-less (unreadable) archive.
pub struct DatasetWriter<W: Write> {
    sink: W,
    pos: u64,
    entries: Vec<QuantityEntry>,
}

impl<W: Write> DatasetWriter<W> {
    /// Start an archive on any byte sink.
    pub fn new(mut sink: W) -> std::io::Result<Self> {
        let mut header = [0u8; HEADER_LEN];
        header[..4].copy_from_slice(CZS_MAGIC);
        header[4] = 1; // version
        sink.write_all(&header)?;
        Ok(Self { sink, pos: HEADER_LEN as u64, entries: Vec::new() })
    }

    /// Compress `field` on `engine`'s session pool and append it as the
    /// quantity `name`.
    pub fn write_quantity(
        &mut self,
        engine: &Engine,
        field: &Field3,
        name: &str,
        params: &CompressParams,
    ) -> std::io::Result<CompressStats> {
        self.check_name(name)?;
        let offset = self.pos;
        let mut counter = CountingWriter { inner: &mut self.sink, written: 0 };
        let result = engine.compress(field, name, params, &mut counter);
        let len = counter.written;
        match result {
            Ok(stats) => {
                self.push_entry(name, offset, len);
                Ok(stats)
            }
            Err(e) => {
                // the partial section stays in the sink as dead space; keep
                // `pos` in sync with the bytes actually emitted so a caller
                // that skips the failed quantity still records correct
                // offsets for the rest
                self.pos += len;
                Err(e)
            }
        }
    }

    /// Append an already-serialized `.czb` stream as the quantity `name`
    /// (e.g. repackaging existing single-quantity files).
    pub fn write_section(&mut self, name: &str, czb: &[u8]) -> std::io::Result<()> {
        self.check_name(name)?;
        let offset = self.pos;
        self.sink.write_all(czb)?;
        self.push_entry(name, offset, czb.len() as u64);
        Ok(())
    }

    fn check_name(&self, name: &str) -> std::io::Result<()> {
        if name.is_empty() || name.len() > 255 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("quantity name length {} not in 1..=255", name.len()),
            ));
        }
        if self.entries.iter().any(|e| e.name == name) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("duplicate quantity {name}"),
            ));
        }
        Ok(())
    }

    fn push_entry(&mut self, name: &str, offset: u64, len: u64) {
        self.pos += len;
        self.entries.push(QuantityEntry { name: name.to_string(), offset, len });
    }

    /// Quantities written so far.
    pub fn entries(&self) -> &[QuantityEntry] {
        &self.entries
    }

    /// Write the trailer index and flush; returns the sink.
    pub fn finish(mut self) -> std::io::Result<W> {
        let mut table = Vec::new();
        for e in &self.entries {
            table.push(e.name.len() as u8);
            table.extend_from_slice(e.name.as_bytes());
            table.extend_from_slice(&e.offset.to_le_bytes());
            table.extend_from_slice(&e.len.to_le_bytes());
        }
        self.sink.write_all(&table)?;
        self.sink.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        self.sink.write_all(&(table.len() as u32).to_le_bytes())?;
        self.sink.write_all(CZS_TRAILER_MAGIC)?;
        self.sink.flush()?;
        Ok(self.sink)
    }
}

/// Counts bytes on their way to the shared sink, so section lengths
/// don't require a seekable writer.
struct CountingWriter<'a, W: Write> {
    inner: &'a mut W,
    written: u64,
}

impl<W: Write> Write for CountingWriter<'_, W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.written += n as u64;
        Ok(n)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// A parsed, fully-loaded `.czs` archive with random access to
/// quantities and blocks.
pub struct Dataset {
    bytes: Vec<u8>,
    entries: Vec<QuantityEntry>,
    /// Shared across every [`BlockReader`] this archive hands out.
    cache: Arc<ChunkCache>,
    /// One stream identity per quantity, same order as `entries`.
    streams: Vec<StreamId>,
}

impl Dataset {
    /// Start writing an archive at `path` (convenience for
    /// [`DatasetWriter::new`] over a buffered file).
    pub fn create(path: &Path) -> std::io::Result<DatasetWriter<std::io::BufWriter<std::fs::File>>> {
        DatasetWriter::new(std::io::BufWriter::new(std::fs::File::create(path)?))
    }

    /// Open an archive from disk.
    pub fn open(path: &Path) -> Result<Self, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::from_bytes(bytes)
    }

    /// Parse an in-memory archive.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self, String> {
        if bytes.len() < HEADER_LEN + TRAILER_TAIL {
            return Err("czs archive too short".into());
        }
        if &bytes[..4] != CZS_MAGIC {
            return Err("bad czs magic".into());
        }
        if bytes[4] != 1 {
            return Err(format!("bad czs version {}", bytes[4]));
        }
        let tail = bytes.len() - TRAILER_TAIL;
        if &bytes[tail + 8..] != CZS_TRAILER_MAGIC {
            return Err("missing czs trailer (archive not finished?)".into());
        }
        let count = u32::from_le_bytes(bytes[tail..tail + 4].try_into().unwrap()) as usize;
        let table_bytes = u32::from_le_bytes(bytes[tail + 4..tail + 8].try_into().unwrap()) as usize;
        let table_start = tail
            .checked_sub(table_bytes)
            .ok_or_else(|| "czs trailer table larger than archive".to_string())?;
        if table_start < HEADER_LEN {
            return Err("czs trailer table overlaps header".into());
        }
        let table = &bytes[table_start..tail];
        // every entry serializes to >= 17 bytes (name_len + u64 offset +
        // u64 len), so a count the table cannot hold is corrupt — reject
        // it before sizing any allocation by it
        if count > table.len() / 17 {
            return Err(format!(
                "czs entry count {count} impossible for a {}-byte table",
                table.len()
            ));
        }
        let mut entries = Vec::with_capacity(count);
        let mut pos = 0usize;
        for _ in 0..count {
            if table.len() < pos + 1 {
                return Err("truncated czs table entry".into());
            }
            let nl = table[pos] as usize;
            pos += 1;
            if table.len() < pos + nl + 16 {
                return Err("truncated czs table entry".into());
            }
            let name = String::from_utf8_lossy(&table[pos..pos + nl]).into_owned();
            pos += nl;
            let offset = u64::from_le_bytes(table[pos..pos + 8].try_into().unwrap());
            let len = u64::from_le_bytes(table[pos + 8..pos + 16].try_into().unwrap());
            pos += 16;
            let end = offset
                .checked_add(len)
                .ok_or_else(|| "czs section overflow".to_string())?;
            if (offset as usize) < HEADER_LEN || end as usize > table_start {
                return Err(format!("czs section {name} out of bounds"));
            }
            entries.push(QuantityEntry { name, offset, len });
        }
        if pos != table.len() {
            return Err("czs trailer table has trailing garbage".into());
        }
        let cache = Arc::new(ChunkCache::new(DATASET_CACHE_CHUNKS));
        let streams = entries.iter().map(|_| cache.register_stream()).collect();
        Ok(Self { bytes, entries, cache, streams })
    }

    /// Quantities in archive order.
    pub fn entries(&self) -> &[QuantityEntry] {
        &self.entries
    }

    /// Quantity names in archive order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// The raw `.czb` section bytes of the entry at `idx` (single home of
    /// the offset arithmetic).
    fn section_at(&self, idx: usize) -> &[u8] {
        let e = &self.entries[idx];
        &self.bytes[e.offset as usize..(e.offset + e.len) as usize]
    }

    /// The raw `.czb` section of a quantity.
    pub fn section(&self, name: &str) -> Option<&[u8]> {
        let idx = self.entries.iter().position(|e| e.name == name)?;
        Some(self.section_at(idx))
    }

    /// Parse a quantity's `.czb` header without decompressing anything.
    pub fn quantity_header(&self, name: &str) -> Result<CzbFile, String> {
        let section = self.section(name).ok_or_else(|| format!("quantity {name} not found"))?;
        Ok(CzbFile::parse_header(section)?.0)
    }

    /// Decompress one whole quantity on `engine`'s session pool; the
    /// other sections are never touched.
    pub fn read_quantity(&self, name: &str, engine: &Engine) -> Result<(Field3, CzbFile), String> {
        let section = self.section(name).ok_or_else(|| format!("quantity {name} not found"))?;
        engine.decompress_bytes(section)
    }

    /// Random block access into one quantity via a chunk-cached
    /// [`BlockReader`] (paper §2.3): decodes only the chunks the caller
    /// touches. Every reader the archive hands out shares the
    /// archive-wide sharded [`ChunkCache`] — fan out one reader per
    /// thread and they reuse each other's decodes without serializing on
    /// a single lock.
    pub fn block_reader<'a>(
        &'a self,
        name: &str,
        wavelet_engine: &'a dyn WaveletEngine,
    ) -> Result<BlockReader<'a>, String> {
        let idx = self
            .entries
            .iter()
            .position(|e| e.name == name)
            .ok_or_else(|| format!("quantity {name} not found"))?;
        Ok(BlockReader::new(self.section_at(idx), wavelet_engine)?
            .with_shared_cache(self.cache.clone(), self.streams[idx]))
    }

    /// The archive-wide chunk cache shared by all
    /// [`Dataset::block_reader`] handles.
    pub fn chunk_cache(&self) -> &Arc<ChunkCache> {
        &self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn smooth_field(n: usize, seed: u64) -> Field3 {
        let mut rng = Pcg32::new(seed);
        Field3::from_vec(n, n, n, crate::util::prop::gen_smooth_field(&mut rng, n))
    }

    #[test]
    fn in_memory_archive_roundtrips_quantities() {
        let engine = Engine::builder().threads(2).chunk_bytes(32 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let fields: Vec<(String, Field3)> =
            (0..3u64).map(|i| (format!("q{i}"), smooth_field(32, 100 + i))).collect();
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        for (name, f) in &fields {
            let st = w.write_quantity(&engine, f, name, &params).unwrap();
            assert!(st.ratio() > 1.0);
        }
        assert_eq!(w.entries().len(), 3);
        let bytes = w.finish().unwrap();
        let ds = Dataset::from_bytes(bytes).unwrap();
        assert_eq!(ds.names(), vec!["q0", "q1", "q2"]);
        for (name, f) in &fields {
            // section bytes must be exactly the engine's .czb stream
            let (direct, _) = engine.compress_vec(f, name, &params);
            assert_eq!(ds.section(name).unwrap(), &direct[..], "{name}");
            let (back, file) = ds.read_quantity(name, &engine).unwrap();
            assert_eq!(&file.name, name);
            let (expected, _) = engine.decompress_bytes(&direct).unwrap();
            assert!(back
                .data
                .iter()
                .zip(&expected.data)
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
        assert!(ds.section("nope").is_none());
        assert!(ds.read_quantity("nope", &engine).is_err());
    }

    #[test]
    fn parallel_readers_share_the_archive_cache() {
        // the fan-out visualization shape: one reader per quantity, all
        // decoding concurrently against the shared sharded cache; every
        // block must match the whole-quantity decode
        let engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let fields: Vec<(String, Field3)> =
            (0..4u64).map(|i| (format!("q{i}"), smooth_field(64, 300 + i))).collect();
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        for (name, f) in &fields {
            w.write_quantity(&engine, f, name, &params).unwrap();
        }
        let ds = Dataset::from_bytes(w.finish().unwrap()).unwrap();
        let wav = crate::pipeline::NativeEngine;
        std::thread::scope(|s| {
            for (name, f) in &fields {
                let ds = &ds;
                let wav = &wav;
                let engine = &engine;
                s.spawn(move || {
                    let (full, file) = ds.read_quantity(name, engine).unwrap();
                    let bs = file.bs as usize;
                    let grid = crate::core::block::BlockGrid::new(f, bs);
                    let mut reader = ds.block_reader(name, wav).unwrap();
                    let mut blk = vec![0f32; bs * bs * bs];
                    let mut expected = crate::core::block::Block::zeros(bs);
                    // two passes so the shared cache serves hits under
                    // concurrent access from the sibling quantities
                    for id in (0..file.nblocks).chain(0..file.nblocks) {
                        reader.read_block(id, &mut blk).unwrap();
                        grid.extract(&full, id as usize, &mut expected);
                        assert_eq!(blk, expected.data, "{name} block {id}");
                    }
                });
            }
        });
        assert!(ds.chunk_cache().hits() > 0, "second passes must hit the shared cache");
        // a second reader over the same quantity reuses the first's work
        let mut r = ds.block_reader("q0", &wav).unwrap();
        let bs = r.file.bs as usize;
        let mut blk = vec![0f32; bs * bs * bs];
        r.read_block(0, &mut blk).unwrap();
        assert!(
            r.cache_hits == 1 || r.cache_misses == 1,
            "block 0 either still cached or re-decoded after eviction"
        );
    }

    #[test]
    fn writer_rejects_duplicate_and_bad_names() {
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 5);
        let mut w = DatasetWriter::new(Vec::<u8>::new()).unwrap();
        w.write_quantity(&engine, &f, "p", &params).unwrap();
        assert!(w.write_quantity(&engine, &f, "p", &params).is_err());
        assert!(w.write_section("", b"x").is_err());
    }

    #[test]
    fn unfinished_and_corrupt_archives_error() {
        assert!(Dataset::from_bytes(b"CZS1".to_vec()).is_err());
        assert!(Dataset::from_bytes(b"XXXX0123456789abcdef0123".to_vec()).is_err());
        // header-only archive (no trailer)
        let w = DatasetWriter::new(Vec::new()).unwrap();
        assert!(Dataset::from_bytes(w.sink).is_err());
        // empty but finished archive parses with zero quantities
        let bytes = DatasetWriter::new(Vec::new()).unwrap().finish().unwrap();
        let ds = Dataset::from_bytes(bytes).unwrap();
        assert!(ds.entries().is_empty());
        // a crafted trailer claiming u32::MAX entries must be rejected
        // up front, not allocated for
        let mut crafted = DatasetWriter::new(Vec::new()).unwrap().finish().unwrap();
        let tail = crafted.len() - 12;
        crafted[tail..tail + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = Dataset::from_bytes(crafted).unwrap_err();
        assert!(err.contains("entry count"), "{err}");
    }
}
