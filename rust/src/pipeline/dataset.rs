//! `.czs` multi-quantity dataset container: one archive per simulation
//! step, holding every compressed quantity (the paper's multi-QoI CFD
//! workflow dumps ~7 per step).
//!
//! The byte-level layout and v1–v3 version history live in
//! `docs/FORMATS.md`; this module is the reference implementation. The
//! shape that drives the architecture: an 8-byte header, each quantity
//! as a complete `.czb` section, and a trailer index written last — so
//! a [`DatasetWriter`] streams to any `io::Write` without seeking, and
//! a reader can map an archive of any size from three small reads
//! (header, fixed-size trailer tail, entry table). The trailer is
//! validated strictly (UTF-8 unique names, in-range sections), and
//! [`DatasetWriter::write_section`] validates repackaged sections up
//! front instead of deferring the failure to read time.
//!
//! # Streaming opens
//!
//! Section bytes come from a [`SectionSource`]: either an in-memory
//! buffer ([`Dataset::from_bytes`], everything resident up front) or a
//! file handle with lazy positioned reads ([`Dataset::open`]). A lazy
//! open parses only the trailer; each section's bytes are fetched the
//! first time a decode touches that quantity and stay cached on the
//! handle, so the archive-resident footprint is bounded by the sections
//! actually used ([`Dataset::resident_bytes`]) — post-hoc analysis that
//! reads one field of a many-GB step archive never pulls the rest in.
//! [`Dataset::quantity_header`] reads only a section *prefix* on
//! file-backed archives, so `czb info`-style inspection stays cheap too.
//! Open-time knobs (the shared cache size) live on [`DatasetOptions`].
//!
//! # Shared chunk cache and concurrent decode
//!
//! Every reader the archive hands out — random-access
//! [`Dataset::block_reader`] handles *and* whole-quantity decodes via
//! [`Dataset::read_quantity`] / `Engine::decompress_dataset` — shares
//! one sharded [`ChunkCache`]: each quantity gets a [`StreamId`] at
//! parse time, so readers over the same quantity reuse each other's
//! decoded chunks while different quantities never collide, and none of
//! them serialize on a single cache lock. Cross-quantity parallel decode
//! (all requested quantities scheduled onto one worker pool, section
//! I/O overlapping sibling block decode) is
//! `Engine::decompress_dataset`; see [`super::engine`].
use super::chunk_cache::{ChunkCache, StreamId};
use super::compressor::{CompressStats, WaveletEngine};
use super::decompressor::BlockReader;
use super::engine::{CompressParams, Engine};
use super::format::{CzbFile, ERR_TRUNCATED_HEADER};
use super::quality::{AchievedQuality, Bound, ACHIEVED_WIRE_LEN, BOUND_WIRE_LEN};
use crate::core::Field3;
use std::io::Write;
use std::path::{Path, PathBuf};
#[cfg(not(unix))]
use std::sync::Mutex;
use std::sync::{Arc, OnceLock};

/// Decoded chunks the archive-wide shared cache holds across all
/// quantities by default (a visualization session touches a few hot
/// chunks per quantity at a time). Override per archive with
/// [`DatasetOptions::cache_chunks`] or the CLI `--cache-chunks` flag.
pub const DEFAULT_DATASET_CACHE_CHUNKS: usize = 32;

/// Archive magic ("CubismZ Step").
pub const CZS_MAGIC: &[u8; 4] = b"CZS1";
/// Trailer magic, the last four bytes of every archive.
pub const CZS_TRAILER_MAGIC: &[u8; 4] = b"CZSE";
/// Container version the writer emits (history in `docs/FORMATS.md`).
/// Readers accept v1..=v3; fields older trailers predate parse to
/// `crc: None` / `bound: Bound::None, quality: None`.
pub const CZS_VERSION: u8 = 3;
const HEADER_LEN: usize = 8;
const TRAILER_TAIL: usize = 12; // u32 count | u32 table_bytes | magic
/// Transient-error retry budget for positioned file reads.
const READ_RETRIES: u32 = 8;

/// One quantity's location inside a `.czs` archive.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantityEntry {
    pub name: String,
    /// Byte offset of the quantity's `.czb` section.
    pub offset: u64,
    /// Length of the section in bytes.
    pub len: u64,
    /// CRC32C of the whole section (v≥2 trailers); `None` on v1
    /// archives, which carry no digests.
    pub crc: Option<u32>,
    /// Error-bound contract the section was compressed under (v≥3
    /// trailers; [`Bound::None`] on older archives and unbounded
    /// sections).
    pub bound: Bound,
    /// Achieved-quality summary folded from the section's recorded
    /// per-chunk column (v≥3 trailers); `None` on older archives and on
    /// repackaged sections whose `.czb` predates v5.
    pub quality: Option<AchievedQuality>,
}

/// Streaming `.czs` writer: sections go out as they are compressed, the
/// index goes out on [`DatasetWriter::finish`]. Dropping a writer
/// without `finish` leaves a trailer-less (unreadable) archive — the
/// coordinator's file entry point builds archives at a temp path and
/// renames on success for exactly that reason.
pub struct DatasetWriter<W: Write> {
    sink: W,
    pos: u64,
    entries: Vec<QuantityEntry>,
}

impl<W: Write> DatasetWriter<W> {
    /// Start an archive on any byte sink.
    pub fn new(mut sink: W) -> std::io::Result<Self> {
        let mut header = [0u8; HEADER_LEN];
        header[..4].copy_from_slice(CZS_MAGIC);
        header[4] = CZS_VERSION;
        sink.write_all(&header)?;
        Ok(Self { sink, pos: HEADER_LEN as u64, entries: Vec::new() })
    }

    /// Compress `field` on `engine`'s session pool and append it as the
    /// quantity `name`.
    pub fn write_quantity(
        &mut self,
        engine: &Engine,
        field: &Field3,
        name: &str,
        params: &CompressParams,
    ) -> std::io::Result<CompressStats> {
        self.check_name(name)?;
        let offset = self.pos;
        let mut counter = CountingWriter {
            inner: &mut self.sink,
            written: 0,
            crc: crate::util::crc32c::Crc32c::new(),
        };
        let result = engine.compress(field, name, params, &mut counter);
        let len = counter.written;
        let crc = counter.crc.finish();
        match result {
            Ok(stats) => {
                self.push_entry(name, offset, len, crc, params.bound, Some(stats.quality));
                Ok(stats)
            }
            Err(e) => {
                // the partial section stays in the sink as dead space; keep
                // `pos` in sync with the bytes actually emitted so a caller
                // that skips the failed quantity still records correct
                // offsets for the rest
                self.pos += len;
                Err(e)
            }
        }
    }

    /// Append an already-serialized `.czb` stream as the quantity `name`
    /// (e.g. repackaging existing single-quantity files). The bytes must
    /// start with a parseable `.czb` header — a section that would only
    /// fail at read time, possibly on a far-away machine, is rejected
    /// here instead.
    pub fn write_section(&mut self, name: &str, czb: &[u8]) -> std::io::Result<()> {
        self.check_name(name)?;
        let file = match CzbFile::parse_header(czb) {
            Ok((file, _)) => file,
            Err(e) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("section {name} is not a valid .czb stream: {e}"),
                ))
            }
        };
        let offset = self.pos;
        self.sink.write_all(czb)?;
        // trailer metadata comes from the section's own header, so
        // repackaged legacy (v≤4) streams record bound None / no quality
        self.push_entry(
            name,
            offset,
            czb.len() as u64,
            crate::util::crc32c::crc32c(czb),
            file.bound,
            file.achieved_quality(),
        );
        Ok(())
    }

    fn check_name(&self, name: &str) -> std::io::Result<()> {
        if name.is_empty() || name.len() > 255 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("quantity name length {} not in 1..=255", name.len()),
            ));
        }
        if self.entries.iter().any(|e| e.name == name) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("duplicate quantity {name}"),
            ));
        }
        Ok(())
    }

    fn push_entry(
        &mut self,
        name: &str,
        offset: u64,
        len: u64,
        crc: u32,
        bound: Bound,
        quality: Option<AchievedQuality>,
    ) {
        self.pos += len;
        self.entries.push(QuantityEntry {
            name: name.to_string(),
            offset,
            len,
            crc: Some(crc),
            bound,
            quality,
        });
    }

    /// Quantities written so far.
    pub fn entries(&self) -> &[QuantityEntry] {
        &self.entries
    }

    /// Write the trailer index and flush; returns the sink.
    pub fn finish(mut self) -> std::io::Result<W> {
        let mut table = Vec::new();
        for e in &self.entries {
            table.push(e.name.len() as u8);
            table.extend_from_slice(e.name.as_bytes());
            table.extend_from_slice(&e.offset.to_le_bytes());
            table.extend_from_slice(&e.len.to_le_bytes());
            let crc = e.crc.expect("writer entries always carry a digest");
            table.extend_from_slice(&crc.to_le_bytes());
            // v3 quality metadata: the contract, then a presence byte and
            // a fixed-size achieved summary (zeroed when absent, keeping
            // the entries fixed-width per version)
            table.extend_from_slice(&e.bound.encode());
            match &e.quality {
                Some(q) => {
                    table.push(1);
                    table.extend_from_slice(&q.encode());
                }
                None => {
                    table.push(0);
                    table.extend_from_slice(&[0u8; ACHIEVED_WIRE_LEN]);
                }
            }
        }
        self.sink.write_all(&table)?;
        self.sink.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        self.sink.write_all(&(table.len() as u32).to_le_bytes())?;
        self.sink.write_all(CZS_TRAILER_MAGIC)?;
        self.sink.flush()?;
        Ok(self.sink)
    }
}

/// Counts bytes on their way to the shared sink and accumulates the
/// section digest as they stream by, so neither lengths nor CRCs
/// require a seekable writer.
struct CountingWriter<'a, W: Write> {
    inner: &'a mut W,
    written: u64,
    crc: crate::util::crc32c::Crc32c,
}

impl<W: Write> Write for CountingWriter<'_, W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.written += n as u64;
        self.crc.update(&buf[..n]);
        Ok(n)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// File-backed lazy section reads: positioned reads off one shared
/// handle, so concurrent readers never serialize on a seek cursor.
pub struct FileSource {
    file: std::fs::File,
    len: u64,
    path: PathBuf,
    /// Scripted faults armed on every positioned read
    /// ([`DatasetOptions::open_with_faults`]); `None` in production
    /// opens. Sits on the real I/O boundary so the retry loop and the
    /// checksum layers above are exercised exactly as a flaky disk
    /// would.
    faults: Option<crate::io::fault::FaultPlan>,
    /// Non-unix fallback: without `pread`, positioned reads share a
    /// seek cursor and need a lock.
    #[cfg(not(unix))]
    lock: Mutex<()>,
}

impl FileSource {
    fn new(file: std::fs::File, len: u64, path: PathBuf) -> Self {
        Self {
            file,
            len,
            path,
            faults: None,
            #[cfg(not(unix))]
            lock: Mutex::new(()),
        }
    }

    /// One positioned read attempt, routed through the fault plan when
    /// one is armed. Returns the bytes actually read (0 = end of file),
    /// which may be fewer than asked — exactly the `pread(2)` contract
    /// the retry loop above is written against.
    fn read_at_once(&self, buf: &mut [u8], offset: u64) -> std::io::Result<usize> {
        let mut want = buf.len();
        if let Some(plan) = &self.faults {
            let visible = plan.visible_len(self.len);
            if offset >= visible {
                return Ok(0);
            }
            want = want.min((visible - offset) as usize);
            want = plan.before_read(offset, want)?;
        }
        let n = {
            #[cfg(unix)]
            {
                use std::os::unix::fs::FileExt;
                self.file.read_at(&mut buf[..want], offset)?
            }
            #[cfg(not(unix))]
            {
                use std::io::{Read, Seek, SeekFrom};
                let _g = self.lock.lock().unwrap();
                let mut f = &self.file;
                f.seek(SeekFrom::Start(offset))?;
                f.read(&mut buf[..want])?
            }
        };
        if let Some(plan) = &self.faults {
            plan.after_read(offset, &mut buf[..n]);
        }
        Ok(n)
    }

    /// Positioned exact read with bounded retry: transient
    /// `Interrupted` / `WouldBlock` errors (signal delivery, saturated
    /// network filesystems) are retried up to [`READ_RETRIES`] times —
    /// `WouldBlock` with a short growing backoff, `Interrupted`
    /// immediately — and short reads continue where they left off. A
    /// successful partial read resets the budget; anything persistent
    /// or genuine (EOF mid-read, real I/O error) surfaces.
    fn read_exact_at(&self, mut buf: &mut [u8], mut offset: u64) -> std::io::Result<()> {
        let mut retries = 0u32;
        while !buf.is_empty() {
            match self.read_at_once(buf, offset) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "short read (file truncated?)",
                    ))
                }
                Ok(n) => {
                    let rest = std::mem::take(&mut buf);
                    buf = &mut rest[n..];
                    offset += n as u64;
                    retries = 0;
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::Interrupted | std::io::ErrorKind::WouldBlock
                    ) =>
                {
                    retries += 1;
                    if retries > READ_RETRIES {
                        return Err(std::io::Error::new(
                            e.kind(),
                            format!(
                                "read at {offset} still failing after {READ_RETRIES} retries: {e}"
                            ),
                        ));
                    }
                    if e.kind() == std::io::ErrorKind::WouldBlock {
                        std::thread::sleep(std::time::Duration::from_micros(
                            50 << retries.min(8),
                        ));
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }
}

/// Where a [`Dataset`]'s bytes come from: a fully resident in-memory
/// buffer, or a file handle that reads each section on first touch.
pub enum SectionSource {
    /// The whole archive is resident (what [`Dataset::from_bytes`] uses).
    Memory(Vec<u8>),
    /// Sections load lazily with positioned reads (what
    /// [`Dataset::open`] uses).
    File(FileSource),
}

impl SectionSource {
    /// Total archive length in bytes.
    pub fn len(&self) -> u64 {
        match self {
            SectionSource::Memory(b) => b.len() as u64,
            SectionSource::File(f) => f.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read an exact byte range into a fresh buffer (trailer parsing and
    /// header-prefix reads; section loads go through [`Dataset`]'s
    /// per-section cache instead).
    fn read_range(&self, offset: u64, len: usize) -> Result<Vec<u8>, String> {
        match self {
            SectionSource::Memory(bytes) => {
                let lo = offset as usize;
                bytes
                    .get(lo..lo + len)
                    .map(|s| s.to_vec())
                    .ok_or_else(|| "czs read past end of buffer".to_string())
            }
            SectionSource::File(f) => {
                let mut buf = vec![0u8; len];
                f.read_exact_at(&mut buf, offset).map_err(|e| {
                    format!("reading {len} bytes at {offset} from {}: {e}", f.path.display())
                })?;
                Ok(buf)
            }
        }
    }
}

/// Validate the 8-byte archive header and return its version (1..=3 —
/// the version decides the trailer entry layout).
fn check_archive_header(head: &[u8]) -> Result<u8, String> {
    if &head[..4] != CZS_MAGIC {
        return Err("bad czs magic".into());
    }
    if !(1..=CZS_VERSION).contains(&head[4]) {
        return Err(format!("bad czs version {}", head[4]));
    }
    Ok(head[4])
}

fn parse_trailer_tail(tail: &[u8]) -> Result<(usize, usize), String> {
    debug_assert_eq!(tail.len(), TRAILER_TAIL);
    if &tail[8..] != CZS_TRAILER_MAGIC {
        return Err("missing czs trailer (archive not finished?)".into());
    }
    let count = u32::from_le_bytes(tail[0..4].try_into().unwrap()) as usize;
    let table_bytes = u32::from_le_bytes(tail[4..8].try_into().unwrap()) as usize;
    Ok((count, table_bytes))
}

/// Walk the trailer's entry table. Strict by design: names must be valid
/// UTF-8 (a lossy decode could alias two corrupt names to the same
/// replacement string and silently resolve `section(name)` to the wrong
/// quantity) and unique, and every section must lie between the header
/// and the table.
fn parse_entry_table(
    table: &[u8],
    count: usize,
    table_start: u64,
    version: u8,
) -> Result<Vec<QuantityEntry>, String> {
    // v1 entries: u8 name_len | name | u64 offset | u64 len; v2 appends
    // a u32 section CRC; v3 appends the bound contract, a presence byte
    // and the fixed-width achieved-quality summary
    let fixed = if version >= 3 {
        20 + BOUND_WIRE_LEN + 1 + ACHIEVED_WIRE_LEN
    } else if version >= 2 {
        20
    } else {
        16
    };
    // every entry serializes to >= 1 + fixed bytes, so a count the
    // table cannot hold is corrupt — reject it before sizing any
    // allocation by it
    if count > table.len() / (1 + fixed) {
        return Err(format!(
            "czs entry count {count} impossible for a {}-byte table",
            table.len()
        ));
    }
    let mut entries: Vec<QuantityEntry> = Vec::with_capacity(count);
    let mut seen: std::collections::HashSet<&str> =
        std::collections::HashSet::with_capacity(count);
    let mut pos = 0usize;
    for i in 0..count {
        if table.len() < pos + 1 {
            return Err("truncated czs table entry".into());
        }
        let nl = table[pos] as usize;
        pos += 1;
        if table.len() < pos + nl + fixed {
            return Err("truncated czs table entry".into());
        }
        let name = std::str::from_utf8(&table[pos..pos + nl])
            .map_err(|_| format!("czs entry {i} name is not valid UTF-8"))?;
        pos += nl;
        let offset = u64::from_le_bytes(table[pos..pos + 8].try_into().unwrap());
        let len = u64::from_le_bytes(table[pos + 8..pos + 16].try_into().unwrap());
        pos += 16;
        let crc = if version >= 2 {
            let c = u32::from_le_bytes(table[pos..pos + 4].try_into().unwrap());
            pos += 4;
            Some(c)
        } else {
            None
        };
        let (bound, quality) = if version >= 3 {
            let bound =
                Bound::decode(table[pos..pos + BOUND_WIRE_LEN].try_into().unwrap())
                    .map_err(|e| format!("czs entry {name}: {e}"))?;
            pos += BOUND_WIRE_LEN;
            let present = table[pos];
            pos += 1;
            let qbytes: &[u8; ACHIEVED_WIRE_LEN] =
                table[pos..pos + ACHIEVED_WIRE_LEN].try_into().unwrap();
            pos += ACHIEVED_WIRE_LEN;
            let quality = match present {
                0 => {
                    // absent quality must leave its slot zeroed, so a
                    // flipped presence byte cannot hide stale data
                    if qbytes.iter().any(|&b| b != 0) {
                        return Err(format!(
                            "czs entry {name}: nonzero quality bytes marked absent"
                        ));
                    }
                    None
                }
                1 => Some(
                    AchievedQuality::decode(qbytes)
                        .map_err(|e| format!("czs entry {name}: {e}"))?,
                ),
                p => return Err(format!("czs entry {name}: bad quality presence byte {p}")),
            };
            (bound, quality)
        } else {
            (Bound::None, None)
        };
        let end = offset
            .checked_add(len)
            .ok_or_else(|| "czs section overflow".to_string())?;
        if offset < HEADER_LEN as u64 || end > table_start {
            return Err(format!("czs section {name} out of bounds"));
        }
        if !seen.insert(name) {
            return Err(format!("duplicate czs quantity name {name}"));
        }
        entries.push(QuantityEntry { name: name.to_string(), offset, len, crc, bound, quality });
    }
    if pos != table.len() {
        return Err("czs trailer table has trailing garbage".into());
    }
    Ok(entries)
}

/// Open-time knobs for a [`Dataset`]:
/// `DatasetOptions::new().cache_chunks(64).open(path)`.
#[derive(Clone, Copy, Debug)]
pub struct DatasetOptions {
    cache_chunks: usize,
}

impl DatasetOptions {
    pub fn new() -> Self {
        Self { cache_chunks: DEFAULT_DATASET_CACHE_CHUNKS }
    }

    /// Decoded chunks the archive-wide shared [`ChunkCache`] holds
    /// across all quantities (default
    /// [`DEFAULT_DATASET_CACHE_CHUNKS`]).
    pub fn cache_chunks(mut self, n: usize) -> Self {
        self.cache_chunks = n.max(1);
        self
    }

    /// Lazily open an archive: only the trailer is read here; section
    /// bytes load on first touch.
    pub fn open(&self, path: &Path) -> Result<Dataset, String> {
        let file = std::fs::File::open(path)
            .map_err(|e| format!("opening {}: {e}", path.display()))?;
        let len = file
            .metadata()
            .map_err(|e| format!("stat {}: {e}", path.display()))?
            .len();
        Dataset::from_source(
            SectionSource::File(FileSource::new(file, len, path.to_path_buf())),
            self.cache_chunks,
        )
    }

    /// Lazily open an archive with a scripted fault plan armed on every
    /// positioned read — the test seam the end-to-end fault-injection
    /// harness ([`crate::io::fault`]) drives. Production opens never
    /// pay for it: [`DatasetOptions::open`] leaves the plan `None`.
    pub fn open_with_faults(
        &self,
        path: &Path,
        faults: crate::io::fault::FaultPlan,
    ) -> Result<Dataset, String> {
        let file = std::fs::File::open(path)
            .map_err(|e| format!("opening {}: {e}", path.display()))?;
        let len = file
            .metadata()
            .map_err(|e| format!("stat {}: {e}", path.display()))?
            .len();
        let mut src = FileSource::new(file, len, path.to_path_buf());
        src.faults = Some(faults);
        Dataset::from_source(SectionSource::File(src), self.cache_chunks)
    }

    /// Parse an in-memory archive (everything resident up front).
    pub fn from_bytes(&self, bytes: Vec<u8>) -> Result<Dataset, String> {
        Dataset::from_source(SectionSource::Memory(bytes), self.cache_chunks)
    }
}

impl Default for DatasetOptions {
    fn default() -> Self {
        Self::new()
    }
}

/// A parsed `.czs` archive with random access to quantities and blocks.
/// File-backed handles ([`Dataset::open`]) load section bytes lazily;
/// in-memory handles ([`Dataset::from_bytes`]) slice their buffer.
///
/// `Dataset` is `Send + Sync` (asserted below): concurrent readers —
/// several threads calling [`crate::pipeline::Engine::decompress_dataset`]
/// or [`Dataset::read_quantity`] on one handle — share the lazy section
/// slots (first toucher loads, `OnceLock`) and the archive-wide chunk
/// cache, so parallel tenants reuse rather than repeat each other's
/// section I/O and stage-2 work.
pub struct Dataset {
    source: SectionSource,
    entries: Vec<QuantityEntry>,
    /// Lazily loaded section bytes, one slot per entry (file-backed
    /// sources only; in-memory archives slice the backing buffer). A
    /// load error is cached like a payload so a truncated section fails
    /// consistently instead of re-reading.
    sections: Vec<OnceLock<Result<Vec<u8>, String>>>,
    /// Lazily verified section digests (czs v2 trailers), one slot per
    /// entry: the first decode to touch a section pays one CRC32C pass
    /// over it, every later touch reuses the verdict. `crc: None`
    /// entries (v1 archives) skip the check entirely.
    digests: Vec<OnceLock<Result<(), String>>>,
    /// Shared across every [`BlockReader`] and whole-quantity decode
    /// this archive hands out.
    cache: Arc<ChunkCache>,
    /// One stream identity per quantity, same order as `entries`.
    streams: Vec<StreamId>,
}

/// Compile-time guarantee of the concurrent-reader contract above.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Dataset>();
};

impl Dataset {
    /// Start writing an archive at `path` (convenience for
    /// [`DatasetWriter::new`] over a buffered file).
    pub fn create(path: &Path) -> std::io::Result<DatasetWriter<std::io::BufWriter<std::fs::File>>> {
        DatasetWriter::new(std::io::BufWriter::new(std::fs::File::create(path)?))
    }

    /// Lazily open an archive from disk with default options: seeks the
    /// fixed-size trailer tail, parses the index, and defers every
    /// section read until a decode touches that quantity.
    pub fn open(path: &Path) -> Result<Self, String> {
        DatasetOptions::new().open(path)
    }

    /// Parse an in-memory archive.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self, String> {
        DatasetOptions::new().from_bytes(bytes)
    }

    fn from_source(source: SectionSource, cache_chunks: usize) -> Result<Self, String> {
        let total = source.len();
        if total < (HEADER_LEN + TRAILER_TAIL) as u64 {
            return Err("czs archive too short".into());
        }
        let head = source.read_range(0, HEADER_LEN)?;
        let version = check_archive_header(&head)?;
        let tail_pos = total - TRAILER_TAIL as u64;
        let tail = source.read_range(tail_pos, TRAILER_TAIL)?;
        let (count, table_bytes) = parse_trailer_tail(&tail)?;
        let table_start = tail_pos
            .checked_sub(table_bytes as u64)
            .ok_or_else(|| "czs trailer table larger than archive".to_string())?;
        if table_start < HEADER_LEN as u64 {
            return Err("czs trailer table overlaps header".into());
        }
        let table = source.read_range(table_start, table_bytes)?;
        let entries = parse_entry_table(&table, count, table_start, version)?;
        let cache = Arc::new(ChunkCache::new(cache_chunks));
        let streams = entries.iter().map(|_| cache.register_stream()).collect();
        let sections = entries.iter().map(|_| OnceLock::new()).collect();
        let digests = entries.iter().map(|_| OnceLock::new()).collect();
        Ok(Self { source, entries, sections, digests, cache, streams })
    }

    /// Quantities in archive order.
    pub fn entries(&self) -> &[QuantityEntry] {
        &self.entries
    }

    /// Quantity names in archive order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// True when sections load lazily from a file handle rather than an
    /// in-memory buffer.
    pub fn is_file_backed(&self) -> bool {
        matches!(self.source, SectionSource::File(_))
    }

    /// Faults the armed [`crate::io::fault::FaultPlan`] has fired so
    /// far — `None` unless the archive came from
    /// [`DatasetOptions::open_with_faults`]. The harness's proof that a
    /// scripted fault actually ran through the real I/O path.
    pub fn faults_injected(&self) -> Option<usize> {
        match &self.source {
            SectionSource::File(f) => f.faults.as_ref().map(|p| p.injected()),
            SectionSource::Memory(_) => None,
        }
    }

    /// Total serialized archive size in bytes.
    pub fn archive_bytes(&self) -> u64 {
        self.source.len()
    }

    /// Archive bytes currently resident in memory: the whole buffer for
    /// in-memory handles, the sum of lazily loaded sections for
    /// file-backed ones — the gauge that a streaming open only pays for
    /// the sections actually touched.
    pub fn resident_bytes(&self) -> usize {
        match &self.source {
            SectionSource::Memory(b) => b.len(),
            SectionSource::File(_) => self
                .sections
                .iter()
                .filter_map(|s| s.get())
                .map(|r| r.as_ref().map(|b| b.len()).unwrap_or(0))
                .sum(),
        }
    }

    pub(crate) fn index_of(&self, name: &str) -> Result<usize, String> {
        self.entries
            .iter()
            .position(|e| e.name == name)
            .ok_or_else(|| format!("quantity {name} not found"))
    }

    pub(crate) fn stream_of(&self, idx: usize) -> StreamId {
        self.streams[idx]
    }

    /// Section bytes already resident, without triggering a load.
    fn resident_section(&self, idx: usize) -> Option<&[u8]> {
        match &self.source {
            SectionSource::Memory(bytes) => {
                let e = &self.entries[idx];
                Some(&bytes[e.offset as usize..(e.offset + e.len) as usize])
            }
            SectionSource::File(_) => match self.sections[idx].get() {
                Some(Ok(b)) => Some(b.as_slice()),
                _ => None,
            },
        }
    }

    /// The raw `.czb` section bytes of the entry at `idx`, loading them
    /// on first touch for file-backed sources (single home of the
    /// offset arithmetic). When the trailer carries a section digest
    /// (czs v2), the first touch also verifies it — one CRC pass per
    /// section per handle, catching damage anywhere in the section
    /// before any decode interprets the bytes.
    pub(crate) fn section_at(&self, idx: usize) -> Result<&[u8], String> {
        let e = &self.entries[idx];
        let bytes = self.section_at_unverified(idx)?;
        if let Some(want) = e.crc {
            self.digests[idx]
                .get_or_init(|| {
                    let got = crate::util::crc32c::crc32c(bytes);
                    if got == want {
                        Ok(())
                    } else {
                        Err(format!(
                            "section {}: digest mismatch (stored {want:#010x}, computed {got:#010x})",
                            e.name
                        ))
                    }
                })
                .clone()?;
        }
        Ok(bytes)
    }

    /// [`Dataset::section_at`] minus the trailer-digest gate: salvage
    /// decodes want the bytes even when the section-wide digest already
    /// failed, because the per-chunk checksums inside the section
    /// localize damage far more precisely than one section-wide
    /// verdict.
    pub(crate) fn section_at_unverified(&self, idx: usize) -> Result<&[u8], String> {
        let e = &self.entries[idx];
        let bytes: &[u8] = match &self.source {
            SectionSource::Memory(bytes) => {
                // bounds proven at parse time: offset >= header, end <= table
                &bytes[e.offset as usize..(e.offset + e.len) as usize]
            }
            SectionSource::File(f) => {
                let slot = self.sections[idx].get_or_init(|| {
                    let mut buf = vec![0u8; e.len as usize];
                    f.read_exact_at(&mut buf, e.offset).map_err(|err| {
                        format!(
                            "reading section {} ({} bytes at {}) from {}: {err}",
                            e.name,
                            e.len,
                            e.offset,
                            f.path.display()
                        )
                    })?;
                    Ok(buf)
                });
                match slot {
                    Ok(b) => b.as_slice(),
                    Err(err) => return Err(err.clone()),
                }
            }
        };
        Ok(bytes)
    }

    /// The raw `.czb` section of a quantity, loading it on first touch
    /// for file-backed archives.
    pub fn section(&self, name: &str) -> Result<&[u8], String> {
        self.section_at(self.index_of(name)?)
    }

    /// Parse a quantity's `.czb` header without decompressing anything.
    /// On a file-backed archive whose section is not yet resident this
    /// reads only a growing header *prefix* (headers are a few KiB even
    /// with large chunk tables), so `info`-style inspection of a huge
    /// archive never pulls payloads in.
    pub fn quantity_header(&self, name: &str) -> Result<CzbFile, String> {
        let idx = self.index_of(name)?;
        if let Some(section) = self.resident_section(idx) {
            return Ok(CzbFile::parse_header(section)?.0);
        }
        let e = &self.entries[idx];
        let len = e.len as usize;
        let mut want = 4096.min(len);
        loop {
            let buf = self.source.read_range(e.offset, want)?;
            match CzbFile::parse_header(&buf) {
                Ok((file, _)) => return Ok(file),
                Err(err) => {
                    // only a too-short prefix earns a bigger read; any
                    // other parse error is genuine corruption and must
                    // not escalate to reading the whole section
                    if want == len || err != ERR_TRUNCATED_HEADER {
                        return Err(err);
                    }
                    want = (want * 4).min(len);
                }
            }
        }
    }

    /// Decompress one whole quantity on `engine`'s session pool. When
    /// the section has at least as many chunks as the session has
    /// workers, the decode goes through the archive-wide shared
    /// [`ChunkCache`]: chunks a [`Dataset::block_reader`] already
    /// inflated are reused and the full decode leaves its chunks behind
    /// for later random access. A *starved* section (fewer chunks than
    /// workers) takes the cache-free intra-chunk wide path instead —
    /// thread scaling beats cache reuse there. Other sections are never
    /// touched (or, on file-backed archives, even read).
    pub fn read_quantity(&self, name: &str, engine: &Engine) -> Result<(Field3, CzbFile), String> {
        engine.decompress_section(self, self.index_of(name)?)
    }

    /// Random block access into one quantity via a chunk-cached
    /// [`BlockReader`] (paper §2.3): decodes only the chunks the caller
    /// touches. Every reader the archive hands out shares the
    /// archive-wide sharded [`ChunkCache`] — fan out one reader per
    /// thread and they reuse each other's decodes without serializing on
    /// a single lock.
    pub fn block_reader<'a>(
        &'a self,
        name: &str,
        wavelet_engine: &'a dyn WaveletEngine,
    ) -> Result<BlockReader<'a>, String> {
        let idx = self.index_of(name)?;
        Ok(BlockReader::new(self.section_at(idx)?, wavelet_engine)?
            .with_shared_cache(self.cache.clone(), self.streams[idx]))
    }

    /// The archive-wide chunk cache shared by all readers and
    /// whole-quantity decodes.
    pub fn chunk_cache(&self) -> &Arc<ChunkCache> {
        &self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn smooth_field(n: usize, seed: u64) -> Field3 {
        let mut rng = Pcg32::new(seed);
        Field3::from_vec(n, n, n, crate::util::prop::gen_smooth_field(&mut rng, n))
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("cubismz_dataset_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    fn bits_equal(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn in_memory_archive_roundtrips_quantities() {
        let engine = Engine::builder().threads(2).chunk_bytes(32 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let fields: Vec<(String, Field3)> =
            (0..3u64).map(|i| (format!("q{i}"), smooth_field(32, 100 + i))).collect();
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        for (name, f) in &fields {
            let st = w.write_quantity(&engine, f, name, &params).unwrap();
            assert!(st.ratio() > 1.0);
        }
        assert_eq!(w.entries().len(), 3);
        let bytes = w.finish().unwrap();
        let ds = Dataset::from_bytes(bytes).unwrap();
        assert_eq!(ds.names(), vec!["q0", "q1", "q2"]);
        assert!(!ds.is_file_backed());
        for (name, f) in &fields {
            // section bytes must be exactly the engine's .czb stream
            let (direct, _) = engine.compress_vec(f, name, &params);
            assert_eq!(ds.section(name).unwrap(), &direct[..], "{name}");
            let (back, file) = ds.read_quantity(name, &engine).unwrap();
            assert_eq!(&file.name, name);
            let (expected, _) = engine.decompress_bytes(&direct).unwrap();
            assert!(bits_equal(&back.data, &expected.data));
        }
        assert!(ds.section("nope").is_err());
        assert!(ds.read_quantity("nope", &engine).is_err());
    }

    #[test]
    fn parallel_readers_share_the_archive_cache() {
        // the fan-out visualization shape: one reader per quantity, all
        // decoding concurrently against the shared sharded cache; every
        // block must match the whole-quantity decode
        let engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let fields: Vec<(String, Field3)> =
            (0..4u64).map(|i| (format!("q{i}"), smooth_field(64, 300 + i))).collect();
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        for (name, f) in &fields {
            w.write_quantity(&engine, f, name, &params).unwrap();
        }
        let ds = Dataset::from_bytes(w.finish().unwrap()).unwrap();
        let wav = crate::pipeline::NativeEngine;
        std::thread::scope(|s| {
            for (name, f) in &fields {
                let ds = &ds;
                let wav = &wav;
                let engine = &engine;
                s.spawn(move || {
                    let (full, file) = ds.read_quantity(name, engine).unwrap();
                    let bs = file.bs as usize;
                    let grid = crate::core::block::BlockGrid::new(f, bs);
                    let mut reader = ds.block_reader(name, wav).unwrap();
                    let mut blk = vec![0f32; bs * bs * bs];
                    let mut expected = crate::core::block::Block::zeros(bs);
                    // two passes so the shared cache serves hits under
                    // concurrent access from the sibling quantities
                    for id in (0..file.nblocks).chain(0..file.nblocks) {
                        reader.read_block(id, &mut blk).unwrap();
                        grid.extract(&full, id as usize, &mut expected);
                        assert_eq!(blk, expected.data, "{name} block {id}");
                    }
                });
            }
        });
        assert!(ds.chunk_cache().hits() > 0, "second passes must hit the shared cache");
        // a second reader over the same quantity reuses the first's work
        let mut r = ds.block_reader("q0", &wav).unwrap();
        let bs = r.file.bs as usize;
        let mut blk = vec![0f32; bs * bs * bs];
        r.read_block(0, &mut blk).unwrap();
        assert!(
            r.cache_hits == 1 || r.cache_misses == 1,
            "block 0 either still cached or re-decoded after eviction"
        );
    }

    #[test]
    fn lazy_open_reads_only_touched_sections() {
        let engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let path = tmp("lazy.czs");
        let fields: Vec<(String, Field3)> =
            (0..3u64).map(|i| (format!("q{i}"), smooth_field(32, 700 + i))).collect();
        let mut w = Dataset::create(&path).unwrap();
        for (name, f) in &fields {
            w.write_quantity(&engine, f, name, &params).unwrap();
        }
        w.finish().unwrap();
        let archive_len = std::fs::metadata(&path).unwrap().len() as usize;

        let ds = Dataset::open(&path).unwrap();
        assert!(ds.is_file_backed());
        assert_eq!(ds.archive_bytes() as usize, archive_len);
        assert_eq!(ds.names(), vec!["q0", "q1", "q2"]);
        // opening touched nothing but the trailer
        assert_eq!(ds.resident_bytes(), 0);
        // header inspection reads a transient prefix, caches nothing
        let hdr = ds.quantity_header("q1").unwrap();
        assert_eq!(hdr.name, "q1");
        assert_eq!(ds.resident_bytes(), 0);
        // decoding one quantity loads exactly that section
        let (back, _) = ds.read_quantity("q1", &engine).unwrap();
        let q1_len = ds.entries()[1].len as usize;
        assert_eq!(ds.resident_bytes(), q1_len);
        assert!(ds.resident_bytes() < archive_len);
        // and matches the eager in-memory decode bit for bit
        let eager = Dataset::from_bytes(std::fs::read(&path).unwrap()).unwrap();
        let (expected, _) = eager.read_quantity("q1", &engine).unwrap();
        assert!(bits_equal(&back.data, &expected.data));
        // a second read re-uses the resident section (no growth)
        ds.read_quantity("q1", &engine).unwrap();
        assert_eq!(ds.resident_bytes(), q1_len);
    }

    #[test]
    fn lazy_decode_is_bit_identical_across_thread_counts() {
        let writer_engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let path = tmp("lazy_threads.czs");
        let fields: Vec<(String, Field3)> =
            (0..4u64).map(|i| (format!("q{i}"), smooth_field(64, 900 + i))).collect();
        let mut w = Dataset::create(&path).unwrap();
        for (name, f) in &fields {
            w.write_quantity(&writer_engine, f, name, &params).unwrap();
        }
        w.finish().unwrap();
        // eager per-quantity reference
        let eager = Dataset::from_bytes(std::fs::read(&path).unwrap()).unwrap();
        let reference: Vec<Vec<f32>> = fields
            .iter()
            .map(|(name, _)| {
                writer_engine.decompress_bytes(eager.section(name).unwrap()).unwrap().0.data
            })
            .collect();
        for threads in [1usize, 2, 3, 8] {
            let engine = Engine::builder().threads(threads).build();
            let ds = Dataset::open(&path).unwrap();
            let decoded = engine.decompress_dataset(&ds, None).unwrap();
            assert_eq!(decoded.len(), fields.len());
            for (i, (name, field, file)) in decoded.iter().enumerate() {
                assert_eq!(name, &fields[i].0);
                assert_eq!(&file.name, name);
                assert!(
                    bits_equal(&field.data, &reference[i]),
                    "{name} differs at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn concurrent_readers_over_a_file_backed_source() {
        let engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let path = tmp("concurrent.czs");
        let fields: Vec<(String, Field3)> =
            (0..4u64).map(|i| (format!("q{i}"), smooth_field(32, 1100 + i))).collect();
        let mut w = Dataset::create(&path).unwrap();
        for (name, f) in &fields {
            w.write_quantity(&engine, f, name, &params).unwrap();
        }
        w.finish().unwrap();
        let eager = Dataset::from_bytes(std::fs::read(&path).unwrap()).unwrap();
        let reference: Vec<Vec<f32>> = fields
            .iter()
            .map(|(name, _)| engine.decompress_bytes(eager.section(name).unwrap()).unwrap().0.data)
            .collect();
        let ds = Dataset::open(&path).unwrap();
        let wav = crate::pipeline::NativeEngine;
        // every thread lazily loads a different section concurrently;
        // two threads share q0 so one section also gets racing loads
        std::thread::scope(|s| {
            for (t, (name, f)) in fields.iter().enumerate().chain(std::iter::once((4, &fields[0])))
            {
                let ds = &ds;
                let wav = &wav;
                let expected = &reference[if t == 4 { 0 } else { t }];
                s.spawn(move || {
                    let mut reader = ds.block_reader(name, wav).unwrap();
                    let bs = reader.file.bs as usize;
                    let grid = crate::core::block::BlockGrid::new(f, bs);
                    let mut blk = vec![0f32; bs * bs * bs];
                    let mut exp = crate::core::block::Block::zeros(bs);
                    let full = Field3::from_vec(f.nx, f.ny, f.nz, expected.clone());
                    for id in 0..reader.file.nblocks {
                        reader.read_block(id, &mut blk).unwrap();
                        grid.extract(&full, id as usize, &mut exp);
                        assert_eq!(blk, exp.data, "{name} block {id}");
                    }
                });
            }
        });
        assert_eq!(ds.resident_bytes() as u64, ds.entries().iter().map(|e| e.len).sum::<u64>());
    }

    #[test]
    fn truncated_file_backed_sections_error_not_panic() {
        let engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let path = tmp("truncated.czs");
        let fields: Vec<(String, Field3)> =
            (0..2u64).map(|i| (format!("q{i}"), smooth_field(32, 1300 + i))).collect();
        let mut w = Dataset::create(&path).unwrap();
        for (name, f) in &fields {
            w.write_quantity(&engine, f, name, &params).unwrap();
        }
        w.finish().unwrap();
        // open first (index parses fine), then truncate into the last
        // section: its lazy load must surface an error, the section
        // before the cut must still decode
        let ds = Dataset::open(&path).unwrap();
        let cut = ds.entries()[1].offset + 4;
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(cut)
            .unwrap();
        let err = ds.read_quantity("q1", &engine).unwrap_err();
        assert!(err.contains("q1"), "{err}");
        assert!(ds.read_quantity("q0", &engine).is_ok());
        // the load error is cached, not retried into a panic
        assert!(ds.read_quantity("q1", &engine).is_err());
        // header-prefix reads past the cut error too
        assert!(ds.quantity_header("q1").is_err());
        // re-opening the truncated file fails at the trailer
        assert!(Dataset::open(&path).is_err());
    }

    #[test]
    fn quantity_header_fails_fast_on_corrupt_magic() {
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let path = tmp("corrupt_header.czs");
        let f = smooth_field(32, 37);
        let mut w = Dataset::create(&path).unwrap();
        w.write_quantity(&engine, &f, "p", &params).unwrap();
        w.finish().unwrap();
        let ds = Dataset::open(&path).unwrap();
        // smash the section's .czb magic on disk
        use std::io::{Seek, SeekFrom};
        let mut fh = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        fh.seek(SeekFrom::Start(ds.entries()[0].offset)).unwrap();
        fh.write_all(b"XXXX").unwrap();
        drop(fh);
        // corruption (not a short prefix) must fail fast, without
        // escalating to a whole-section read or caching anything
        let err = ds.quantity_header("p").unwrap_err();
        assert!(err.contains("magic"), "{err}");
        assert_eq!(ds.resident_bytes(), 0);
    }

    #[test]
    fn trailer_tail_short_reads_error() {
        // files shorter than header + trailer tail
        for len in [0usize, 4, 19] {
            let path = tmp(&format!("short_{len}.czs"));
            std::fs::write(&path, vec![0u8; len]).unwrap();
            assert!(Dataset::open(&path).is_err(), "len {len}");
        }
        // right length, garbage trailer magic
        let path = tmp("badmagic.czs");
        let mut bytes = DatasetWriter::new(Vec::new()).unwrap().finish().unwrap();
        let n = bytes.len();
        bytes[n - 1] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(Dataset::open(&path).is_err());
    }

    #[test]
    fn invalid_utf8_and_duplicate_names_are_rejected() {
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 17);
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        w.write_quantity(&engine, &f, "qa", &params).unwrap();
        w.write_quantity(&engine, &f, "qb", &params).unwrap();
        let bytes = w.finish().unwrap();
        // table layout: 2 entries x (1 + 2 + 16 + 4 + 9 + 1 + 32) = 130
        // bytes before the tail
        let table_start = bytes.len() - TRAILER_TAIL - 130;
        // corrupt the first name to invalid UTF-8
        let mut bad = bytes.clone();
        bad[table_start + 1] = 0xFF;
        bad[table_start + 2] = 0xFE;
        let err = Dataset::from_bytes(bad).unwrap_err();
        assert!(err.contains("UTF-8"), "{err}");
        // rename the second entry to alias the first
        let mut dup = bytes.clone();
        let second_name = table_start + 65 + 1;
        dup[second_name..second_name + 2].copy_from_slice(b"qa");
        let err = Dataset::from_bytes(dup).unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        // the pristine bytes still parse
        assert_eq!(Dataset::from_bytes(bytes).unwrap().names(), vec!["qa", "qb"]);
    }

    #[test]
    fn write_section_validates_czb_streams() {
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 6);
        let (czb, _) = engine.compress_vec(&f, "p", &params);
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        // garbage is rejected up front, naming the section
        let err = w.write_section("vel", b"not a czb stream").unwrap_err();
        assert!(err.to_string().contains("vel"), "{err}");
        assert!(w.entries().is_empty(), "rejected section must not be recorded");
        // a truncated-but-magic prefix is rejected too
        assert!(w.write_section("vel", &czb[..5]).is_err());
        // the real stream goes in, under its repackaged name
        w.write_section("vel", &czb).unwrap();
        let ds = Dataset::from_bytes(w.finish().unwrap()).unwrap();
        assert_eq!(ds.names(), vec!["vel"]);
        let (back, file) = ds.read_quantity("vel", &engine).unwrap();
        assert_eq!(file.name, "p"); // inner header keeps its original name
        let (expected, _) = engine.decompress_bytes(&czb).unwrap();
        assert!(bits_equal(&back.data, &expected.data));
    }

    #[test]
    fn cache_chunks_knob_sizes_the_shared_cache() {
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 21);
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        w.write_quantity(&engine, &f, "p", &params).unwrap();
        let bytes = w.finish().unwrap();
        let small = DatasetOptions::new().cache_chunks(1).from_bytes(bytes.clone()).unwrap();
        let big = DatasetOptions::new().cache_chunks(64).from_bytes(bytes).unwrap();
        assert!(small.chunk_cache().capacity() < big.chunk_cache().capacity());
        assert!(small.chunk_cache().capacity() >= 1);
        assert!(big.chunk_cache().capacity() >= 64);
    }

    #[test]
    fn writer_rejects_duplicate_and_bad_names() {
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 5);
        let mut w = DatasetWriter::new(Vec::<u8>::new()).unwrap();
        w.write_quantity(&engine, &f, "p", &params).unwrap();
        assert!(w.write_quantity(&engine, &f, "p", &params).is_err());
        assert!(w.write_section("", b"x").is_err());
    }

    #[test]
    fn unfinished_and_corrupt_archives_error() {
        assert!(Dataset::from_bytes(b"CZS1".to_vec()).is_err());
        assert!(Dataset::from_bytes(b"XXXX0123456789abcdef0123".to_vec()).is_err());
        // header-only archive (no trailer)
        let w = DatasetWriter::new(Vec::new()).unwrap();
        assert!(Dataset::from_bytes(w.sink).is_err());
        // empty but finished archive parses with zero quantities
        let bytes = DatasetWriter::new(Vec::new()).unwrap().finish().unwrap();
        let ds = Dataset::from_bytes(bytes).unwrap();
        assert!(ds.entries().is_empty());
        // a crafted trailer claiming u32::MAX entries must be rejected
        // up front, not allocated for
        let mut crafted = DatasetWriter::new(Vec::new()).unwrap().finish().unwrap();
        let tail = crafted.len() - 12;
        crafted[tail..tail + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = Dataset::from_bytes(crafted).unwrap_err();
        assert!(err.contains("entry count"), "{err}");
    }

    #[test]
    fn crafted_out_of_bounds_sections_are_rejected() {
        // a section claiming to extend past the entry table must be
        // rejected at parse time, for the in-memory and lazy path alike
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 31);
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        w.write_quantity(&engine, &f, "p", &params).unwrap();
        let bytes = w.finish().unwrap();
        // entry layout: u8 len | name | u64 offset | u64 len | u32 crc |
        // 9B bound | u8 presence | 32B quality
        let table_start = bytes.len() - TRAILER_TAIL - (1 + 1 + 16 + 4 + 9 + 1 + 32);
        let len_pos = table_start + 1 + 1 + 8;
        let mut bad = bytes.clone();
        bad[len_pos..len_pos + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = Dataset::from_bytes(bad.clone()).unwrap_err();
        assert!(err.contains("overflow") || err.contains("out of bounds"), "{err}");
        let path = tmp("oob.czs");
        std::fs::write(&path, &bad).unwrap();
        assert!(Dataset::open(&path).is_err());
    }

    #[test]
    fn v1_archives_still_parse_without_digests() {
        // hand-build the pre-digest layout: version byte 1 and 17-byte
        // minimum trailer entries with no CRC column — what every
        // archive written before czs v2 looks like on disk
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 41);
        let (czb, _) = engine.compress_vec(&f, "p", &params);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CZS_MAGIC);
        bytes.push(1);
        bytes.extend_from_slice(&[0u8; 3]);
        let offset = bytes.len() as u64;
        bytes.extend_from_slice(&czb);
        let mut table = Vec::new();
        table.push(1u8);
        table.extend_from_slice(b"p");
        table.extend_from_slice(&offset.to_le_bytes());
        table.extend_from_slice(&(czb.len() as u64).to_le_bytes());
        let table_len = table.len() as u32;
        bytes.extend_from_slice(&table);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&table_len.to_le_bytes());
        bytes.extend_from_slice(CZS_TRAILER_MAGIC);
        let ds = Dataset::from_bytes(bytes).unwrap();
        assert_eq!(ds.entries()[0].crc, None);
        let (back, _) = ds.read_quantity("p", &engine).unwrap();
        let (expected, _) = engine.decompress_bytes(&czb).unwrap();
        assert!(bits_equal(&back.data, &expected.data));
        // an unknown future version is refused up front
        let mut future = Vec::new();
        future.extend_from_slice(CZS_MAGIC);
        future.push(CZS_VERSION + 1);
        future.extend_from_slice(&vec![0u8; 32]);
        let err = Dataset::from_bytes(future).unwrap_err();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn v3_trailers_record_bound_and_achieved_quality() {
        use crate::codec::Codec;
        use crate::pipeline::format::Stage1;
        let engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let bounded = CompressParams::new(32, Stage1::Sz { eb_rel: 0.0 }, Codec::ZlibDef)
            .with_bound(Bound::Rel(1e-3));
        let unbounded = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 55);
        let mut w = DatasetWriter::new(Vec::new()).unwrap();
        let stats = w.write_quantity(&engine, &f, "p", &bounded).unwrap();
        w.write_quantity(&engine, &f, "rho", &unbounded).unwrap();
        let ds = Dataset::from_bytes(w.finish().unwrap()).unwrap();
        // the bounded quantity records its contract and achieved summary,
        // matching the section's own header bit for bit
        let e = &ds.entries()[0];
        assert_eq!(e.bound, Bound::Rel(1e-3));
        let q = e.quality.expect("v3 writer records achieved quality");
        assert_eq!(q, stats.quality);
        let (hdr, _) = CzbFile::parse_header(ds.section("p").unwrap()).unwrap();
        assert_eq!(q, hdr.achieved_quality().unwrap());
        e.bound.check(&q).expect("contract must hold");
        // the unbounded quantity still carries its measured quality,
        // under the default (vacuous) contract
        let e = &ds.entries()[1];
        assert_eq!(e.bound, Bound::None);
        assert!(e.quality.is_some());
        // a flipped presence byte cannot smuggle stale quality bytes:
        // zero the flag on the bounded entry and reparse
        let bytes = {
            let mut w = DatasetWriter::new(Vec::new()).unwrap();
            w.write_quantity(&engine, &f, "p", &bounded).unwrap();
            w.finish().unwrap()
        };
        let table_start = bytes.len() - TRAILER_TAIL - (1 + 1 + 16 + 4 + 9 + 1 + 32);
        let presence = table_start + 1 + 1 + 16 + 4 + 9;
        assert_eq!(bytes[presence], 1);
        let mut bad = bytes.clone();
        bad[presence] = 0;
        let err = Dataset::from_bytes(bad).unwrap_err();
        assert!(err.contains("marked absent"), "{err}");
        // and an out-of-range presence value is rejected outright
        let mut bad = bytes;
        bad[presence] = 7;
        let err = Dataset::from_bytes(bad).unwrap_err();
        assert!(err.contains("presence"), "{err}");
    }

    #[test]
    fn v2_archives_still_parse_without_quality() {
        // hand-build the czs v2 layout: 20-byte fixed entries ending at
        // the CRC column — what every archive written before v3 looks
        // like on disk. It must parse with no bound and no quality.
        let engine = Engine::builder().threads(1).build();
        let params = CompressParams::paper_default(1e-3);
        let f = smooth_field(32, 43);
        let (czb, _) = engine.compress_vec(&f, "p", &params);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(CZS_MAGIC);
        bytes.push(2);
        bytes.extend_from_slice(&[0u8; 3]);
        let offset = bytes.len() as u64;
        bytes.extend_from_slice(&czb);
        let mut table = Vec::new();
        table.push(1u8);
        table.extend_from_slice(b"p");
        table.extend_from_slice(&offset.to_le_bytes());
        table.extend_from_slice(&(czb.len() as u64).to_le_bytes());
        table.extend_from_slice(&crate::util::crc32c::crc32c(&czb).to_le_bytes());
        let table_len = table.len() as u32;
        bytes.extend_from_slice(&table);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&table_len.to_le_bytes());
        bytes.extend_from_slice(CZS_TRAILER_MAGIC);
        let ds = Dataset::from_bytes(bytes).unwrap();
        let e = &ds.entries()[0];
        assert!(e.crc.is_some());
        assert_eq!(e.bound, Bound::None);
        assert_eq!(e.quality, None);
        let (back, _) = ds.read_quantity("p", &engine).unwrap();
        let (expected, _) = engine.decompress_bytes(&czb).unwrap();
        assert!(bits_equal(&back.data, &expected.data));
    }

    #[test]
    fn section_digests_catch_flipped_bytes_on_first_touch() {
        let engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let path = tmp("digest.czs");
        let mut w = Dataset::create(&path).unwrap();
        for (i, name) in ["q0", "q1"].iter().enumerate() {
            w.write_quantity(&engine, &smooth_field(32, 1500 + i as u64), name, &params)
                .unwrap();
        }
        w.finish().unwrap();
        let clean = std::fs::read(&path).unwrap();
        // open first, then flip one payload byte deep inside q1 on disk:
        // the digest fires at q1's lazy load, q0 is untouched
        let ds = Dataset::open(&path).unwrap();
        assert!(ds.entries().iter().all(|e| e.crc.is_some()));
        let target = (ds.entries()[1].offset + ds.entries()[1].len / 2) as usize;
        let mut damaged = clean.clone();
        damaged[target] ^= 0x10;
        std::fs::write(&path, &damaged).unwrap();
        let err = ds.read_quantity("q1", &engine).unwrap_err();
        assert!(err.contains("digest mismatch"), "{err}");
        assert!(err.contains("q1"), "{err}");
        // the verdict is cached, and the sibling still decodes
        assert!(ds.read_quantity("q1", &engine).is_err());
        assert!(ds.read_quantity("q0", &engine).is_ok());
        // the in-memory path checks the same digest
        let ds2 = Dataset::from_bytes(damaged).unwrap();
        assert!(ds2.read_quantity("q1", &engine).unwrap_err().contains("digest mismatch"));
        assert!(ds2.read_quantity("q0", &engine).is_ok());
        // and the clean bytes still round-trip
        let ds3 = Dataset::from_bytes(clean).unwrap();
        assert!(ds3.read_quantity("q1", &engine).is_ok());
    }
}
