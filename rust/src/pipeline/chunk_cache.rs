//! Sharded concurrent chunk cache for random-access decompression.
//!
//! The paper keeps "recently decompressed chunks of blocks in a cache";
//! the original implementation was one LRU private to each
//! `BlockReader`, which serialized nothing (single reader) but also
//! shared nothing: a visualization front-end fanning out readers over
//! the quantities of a `.czs` archive paid one full cache per handle and
//! could never reuse a sibling's decode.
//!
//! [`ChunkCache`] replaces it with a fixed array of shards, each a small
//! mutex-guarded LRU map. Keys are `(stream, chunk index)` where a
//! *stream* ([`StreamId`]) identifies one compressed quantity — readers
//! over the same quantity share entries, readers over different
//! quantities coexist without key collisions. The shard is picked by a
//! Fibonacci hash of the key, so concurrent readers contend only when
//! they touch chunks that land on the same shard, not on one global
//! lock. Decoding happens *outside* any shard lock: a miss decodes into
//! reader-owned buffers first and only then inserts, so a slow inflate
//! never blocks other shards' hits (two racing readers may decode the
//! same chunk once each; both results are identical and the cache keeps
//! the last insert).
//!
//! Evicted chunks whose `Arc` has no other holders hand their buffers
//! back to the evicting reader for recycling, preserving the
//! allocation-free steady state of the warm random-access path.
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A stage-2-decoded chunk with per-block offsets into its raw stream.
pub(crate) struct DecodedChunk {
    pub(crate) raw: Vec<u8>,
    /// Byte offset and size of each block payload (without its u32 size
    /// prefix).
    pub(crate) block_offsets: Vec<(usize, usize)>,
    pub(crate) first_block: u32,
}

/// Identifies one compressed quantity (`.czb` stream) inside a shared
/// [`ChunkCache`]. Obtained from [`ChunkCache::register_stream`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StreamId(u64);

struct CacheEntry {
    chunk: Arc<DecodedChunk>,
    last_used: u64,
}

struct Shard {
    entries: HashMap<(u64, u32), CacheEntry>,
    /// Monotonic per-shard clock driving LRU eviction.
    tick: u64,
}

/// Sharded concurrent chunk cache shared across [`super::BlockReader`]
/// handles (and across the quantities of a `.czs`
/// [`super::dataset::Dataset`]).
pub struct ChunkCache {
    shards: Vec<Mutex<Shard>>,
    /// Maximum entries per shard before LRU eviction.
    per_shard: usize,
    next_stream: AtomicU64,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

const MAX_SHARDS: usize = 8;
/// Entries a shard keeps before LRU eviction, at minimum. Small caches
/// stay single-shard so they keep exact LRU behavior instead of
/// degrading to 1-entry direct-mapped slots that thrash on hot chunks.
const MIN_PER_SHARD: usize = 4;

impl ChunkCache {
    /// A cache holding about `capacity` decoded chunks in total, spread
    /// over up to 8 shards of at least [`MIN_PER_SHARD`] entries each
    /// (caches below `2 * MIN_PER_SHARD` are a single exact LRU).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let nshards = (capacity / MIN_PER_SHARD).clamp(1, MAX_SHARDS);
        let per_shard = capacity.div_ceil(nshards);
        Self {
            shards: (0..nshards)
                .map(|_| Mutex::new(Shard { entries: HashMap::new(), tick: 0 }))
                .collect(),
            per_shard,
            next_stream: AtomicU64::new(0),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Allocate a fresh stream identity; every distinct compressed
    /// quantity sharing this cache needs its own.
    pub fn register_stream(&self) -> StreamId {
        StreamId(self.next_stream.fetch_add(1, Ordering::Relaxed))
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Total decoded chunks the cache can hold (shards × per-shard
    /// budget; at least the `capacity` it was built with).
    pub fn capacity(&self) -> usize {
        self.per_shard * self.shards.len()
    }

    /// Total decoded chunks resident right now (sums shard sizes; racy
    /// by nature, intended for stats and tests).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache hits across all streams since creation.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses across all streams since creation.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    fn shard_of(&self, stream: u64, chunk: u32) -> usize {
        // Fibonacci hash over the combined key; high bits are the best
        // mixed, so index from them
        let key = stream ^ ((chunk as u64) << 32) ^ (chunk as u64);
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) as usize) % self.shards.len()
    }

    /// Look a decoded chunk up, refreshing its LRU position.
    pub(crate) fn get(&self, stream: StreamId, chunk: u32) -> Option<Arc<DecodedChunk>> {
        let mut shard = self.shards[self.shard_of(stream.0, chunk)].lock().unwrap();
        shard.tick += 1;
        let tick = shard.tick;
        match shard.entries.get_mut(&(stream.0, chunk)) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.chunk.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a freshly decoded chunk, evicting the shard's
    /// least-recently-used entry if the shard is full. When the evicted
    /// `Arc` has no other holders its buffers are returned for recycling.
    #[allow(clippy::type_complexity)]
    pub(crate) fn insert(
        &self,
        stream: StreamId,
        chunk: u32,
        decoded: Arc<DecodedChunk>,
    ) -> Option<(Vec<u8>, Vec<(usize, usize)>)> {
        let key = (stream.0, chunk);
        let mut shard = self.shards[self.shard_of(stream.0, chunk)].lock().unwrap();
        shard.tick += 1;
        let tick = shard.tick;
        shard.entries.insert(key, CacheEntry { chunk: decoded, last_used: tick });
        if shard.entries.len() <= self.per_shard {
            return None;
        }
        let victim = shard
            .entries
            .iter()
            .filter(|(k, _)| **k != key)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| *k)?;
        let evicted = shard.entries.remove(&victim)?;
        match Arc::try_unwrap(evicted.chunk) {
            Ok(c) => Some((c.raw, c.block_offsets)),
            Err(_) => None, // another reader still holds it; it frees later
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(first_block: u32, nbytes: usize) -> Arc<DecodedChunk> {
        Arc::new(DecodedChunk {
            raw: vec![first_block as u8; nbytes],
            block_offsets: vec![(0, nbytes)],
            first_block,
        })
    }

    #[test]
    fn capacity_covers_the_requested_budget() {
        for cap in [1usize, 3, 8, 32, 100] {
            let cache = ChunkCache::new(cap);
            assert!(cache.capacity() >= cap, "cap {cap} -> {}", cache.capacity());
            // the shard rounding never more than doubles the budget
            assert!(cache.capacity() <= cap.max(MIN_PER_SHARD) * 2, "cap {cap}");
        }
    }

    #[test]
    fn get_insert_roundtrip_and_stats() {
        let cache = ChunkCache::new(8);
        let s = cache.register_stream();
        assert!(cache.get(s, 0).is_none());
        assert_eq!(cache.misses(), 1);
        cache.insert(s, 0, chunk(0, 16));
        let got = cache.get(s, 0).expect("inserted chunk must hit");
        assert_eq!(got.first_block, 0);
        assert_eq!(got.raw.len(), 16);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn streams_do_not_collide() {
        let cache = ChunkCache::new(16);
        let a = cache.register_stream();
        let b = cache.register_stream();
        assert_ne!(a, b);
        cache.insert(a, 7, chunk(1, 8));
        cache.insert(b, 7, chunk(2, 8));
        assert_eq!(cache.get(a, 7).unwrap().first_block, 1);
        assert_eq!(cache.get(b, 7).unwrap().first_block, 2);
    }

    #[test]
    fn eviction_is_lru_and_recycles_sole_owner_buffers() {
        // capacity 1 -> single shard with one slot
        let cache = ChunkCache::new(1);
        assert_eq!(cache.shards(), 1);
        let s = cache.register_stream();
        assert!(cache.insert(s, 0, chunk(0, 32)).is_none());
        // inserting a second chunk evicts the first and recycles it
        let recycled = cache.insert(s, 1, chunk(1, 8)).expect("sole-owner eviction recycles");
        assert_eq!(recycled.0.len(), 32);
        assert!(cache.get(s, 0).is_none());
        assert!(cache.get(s, 1).is_some());
        // a chunk still held elsewhere is evicted but not recycled
        let held = chunk(2, 4);
        cache.insert(s, 2, held.clone());
        assert!(cache.insert(s, 3, chunk(3, 4)).is_none(), "held Arc must not recycle");
        assert_eq!(held.first_block, 2);
    }

    #[test]
    fn lru_evicts_the_stalest_entry_on_a_shard() {
        // capacity 3 -> a single shard holding 3 entries: the
        // least-recently-USED key goes, not the least-recently-inserted
        let cache = ChunkCache::new(3);
        assert_eq!(cache.shards(), 1, "small caches must stay exact-LRU single-shard");
        let s = cache.register_stream();
        cache.insert(s, 0, chunk(0, 4));
        cache.insert(s, 1, chunk(1, 4));
        cache.insert(s, 2, chunk(2, 4));
        assert!(cache.get(s, 0).is_some()); // refresh 0: now 1 is stalest
        cache.insert(s, 3, chunk(3, 4)); // evicts 1
        assert!(cache.get(s, 1).is_none(), "stalest entry must be the victim");
        assert!(cache.get(s, 0).is_some());
        assert!(cache.get(s, 2).is_some());
        assert!(cache.get(s, 3).is_some(), "the just-inserted key must never be the victim");
    }

    #[test]
    fn concurrent_readers_share_a_cache_without_corruption() {
        let cache = Arc::new(ChunkCache::new(16));
        let streams: Vec<StreamId> = (0..4).map(|_| cache.register_stream()).collect();
        std::thread::scope(|sc| {
            for (t, s) in streams.iter().enumerate() {
                let cache = cache.clone();
                let s = *s;
                sc.spawn(move || {
                    for round in 0..200u32 {
                        let c = round % 8;
                        match cache.get(s, c) {
                            Some(got) => {
                                // entries must always carry their own
                                // stream's payload
                                assert_eq!(got.first_block, c * 10 + t as u32);
                            }
                            None => {
                                cache.insert(s, c, chunk(c * 10 + t as u32, 4));
                            }
                        }
                    }
                });
            }
        });
        assert!(cache.hits() > 0);
        assert!(cache.len() <= 16 + cache.shards());
    }
}
