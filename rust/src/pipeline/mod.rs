//! The two-substage compression pipeline (paper Fig. 1), scheduled
//! dynamically over a shared atomic work queue.
//!
//! **Compression** ([`compressor`]): worker threads pull contiguous spans
//! of blocks (~`chunk_bytes` of raw data each) off a
//! [`crate::cluster::SpanQueue`]; each span becomes one chunk — per-block
//! lossy stage 1 into a worker-private buffer, lossless stage 2 (shuffle
//! + codec) over the filled buffer — and the chunks are concatenated in
//! block order into a single stream per quantity. Span boundaries are
//! fixed by block-id arithmetic, so the `.czb` output is byte-identical
//! for every thread count.
//!
//! **Decompression** ([`decompressor`]): whole-field decode pulls chunks
//! off the same queue type and scatters blocks into the shared output
//! field ([`decompress_field_mt`]); random access goes through the
//! chunk-cached [`BlockReader`].
//!
//! **Buffer lifecycle**: every worker owns its scratch — batch transform
//! buffer, block gather, [`compressor`]'s encode scratch, shuffle buffer,
//! the decompressor's inflate/offset buffers — allocated once per worker
//! and reused for every block/chunk; the wavelet transform keeps its line
//! buffers in a thread-local pool and the [`BlockReader`] LRU recycles
//! evicted chunk buffers. The steady-state per-block path allocates
//! nothing on either direction.
pub mod compressor;
pub mod decompressor;
pub mod format;

pub use compressor::{compress_field, CompressStats, NativeEngine, PipelineConfig, WaveletEngine};
pub use decompressor::{decompress_field, decompress_field_mt, BlockReader};
pub use format::{CoeffCodec, CzbFile, ShuffleMode, Stage1};
