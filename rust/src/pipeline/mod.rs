//! The two-substage compression pipeline (paper Fig. 1), scheduled
//! dynamically over a shared atomic work queue and driven either one-shot
//! or as a long-lived session.
//!
//! # Engine lifecycle
//!
//! The primary API is the session object [`Engine`]: build it once via
//! [`Engine::builder`] (`threads`, `chunk_bytes`, `batch`, wavelet
//! executor), then compress and decompress any number of quantities on
//! its persistent worker pool. `Engine::compress` streams a `.czb`
//! quantity to any `io::Write`; `Engine::decompress` reads one back from
//! any `io::Read`. Per-call, format-affecting options travel in
//! [`CompressParams`]; session-level scheduling knobs are fixed at build
//! time. Dropping the `Engine` joins the pool. The older free functions
//! ([`compress_field`], [`decompress_field_mt`]) remain as thin one-shot
//! wrappers over the same core using scoped threads — byte-for-byte
//! identical output, but they re-pay worker startup per call, which the
//! session exists to avoid (an in-situ code dumps ~7 quantities per
//! step).
//!
//! Whole simulation steps bundle into `.czs` archives ([`dataset`]):
//! [`Dataset::create`] + `DatasetWriter::write_quantity` append one
//! `.czb` section per quantity and a trailer index; [`Dataset::open`]
//! gives whole-quantity decode and chunk-cached random block access
//! without touching the other sections.
//!
//! # Stages
//!
//! **Compression** ([`compressor`]): worker threads pull contiguous spans
//! of blocks (~`chunk_bytes` of raw data each) off a
//! [`crate::cluster::SpanQueue`]; each span becomes one chunk — per-block
//! lossy stage 1 into a worker-private buffer, lossless stage 2 (shuffle
//! + codec) over the filled buffer — and the chunks are concatenated in
//! block order into a single stream per quantity. Span boundaries are
//! fixed by block-id arithmetic, so the `.czb` output is byte-identical
//! for every thread count and every executor (pool or scoped).
//!
//! Stage-1 schemes are trait objects ([`stage1::Stage1Codec`]): the
//! wavelet, zfp, sz, fpzip and copy paths all dispatch through one
//! registry, so a new scheme implements the trait and registers —
//! neither `compressor.rs` nor `decompressor.rs` changes.
//!
//! **Decompression** ([`decompressor`]): whole-field decode pulls chunks
//! off the same queue type and scatters blocks into the shared output
//! field, stopping early via a shared abort flag when any chunk fails;
//! random access goes through the chunk-cached [`BlockReader`].
//!
//! **Buffer lifecycle**: every worker owns its scratch — batch transform
//! buffer, block gather, the [`stage1::Stage1Scratch`] encode/decode
//! buffers, shuffle buffer, the decompressor's inflate/offset buffers —
//! allocated once per worker and reused for every block/chunk; the
//! wavelet transform keeps its line buffers in a thread-local pool, the
//! fpc decoders fill caller-owned `_into` buffers, and the
//! [`BlockReader`] LRU recycles evicted chunk buffers. The steady-state
//! per-block path allocates nothing in either direction.
pub mod compressor;
pub mod dataset;
pub mod decompressor;
pub mod engine;
pub mod format;
pub mod stage1;

pub use compressor::{compress_field, CompressStats, NativeEngine, PipelineConfig, WaveletEngine};
pub use dataset::{Dataset, DatasetWriter, QuantityEntry};
pub use decompressor::{decompress_field, decompress_field_mt, BlockReader};
pub use engine::{CompressParams, Engine, EngineBuilder};
pub use format::{CoeffCodec, CzbFile, ShuffleMode, Stage1};
pub use stage1::{Stage1Codec, Stage1Scratch};
