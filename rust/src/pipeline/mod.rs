//! The two-substage compression pipeline (paper Fig. 1), scheduled
//! dynamically over a shared atomic work queue and driven either one-shot
//! or as a long-lived session.
//!
//! # Engine lifecycle
//!
//! The primary API is the session object [`Engine`]: build it once via
//! [`Engine::builder`] (`threads`, `chunk_bytes`, `batch`, wavelet
//! executor), then compress and decompress any number of quantities on
//! its persistent worker pool. `Engine::compress` streams a `.czb`
//! quantity to any `io::Write`; `Engine::decompress` reads one back from
//! any `io::Read`. Per-call, format-affecting options travel in
//! [`CompressParams`]; session-level scheduling knobs are fixed at build
//! time. Dropping the `Engine` joins the pool. The older free functions
//! ([`compress_field`], [`decompress_field_mt`]) remain as thin one-shot
//! wrappers over the same core using scoped threads — byte-for-byte
//! identical output, but they re-pay worker startup per call, which the
//! session exists to avoid (an in-situ code dumps ~7 quantities per
//! step).
//!
//! # Concurrent submissions
//!
//! `Engine` is `Send + Sync` and every entry point takes `&self`: one
//! session serves any number of submitting threads with no external
//! locking. Each `compress`/`decompress`/`decompress_dataset` call is
//! one *submission* on the multi-generation
//! [`crate::cluster::WorkerPool`] — submissions register per-call work
//! queues in a shared injector, idle workers steal across the live
//! submissions oldest-first, and every submitting thread also drains its
//! own submission, so a small request completes while a large one
//! streams and a saturated pool degrades to caller-thread progress
//! instead of queueing. Determinism is per stream: whatever the
//! interleaving, each submission's bytes are identical to running it
//! alone (chunk, frame and span boundaries are fixed by arithmetic, and
//! all queue/abort/error state is call-local). A corrupt stream aborts
//! only its own submission's workers; the session stays healthy for its
//! other tenants. `coordinator::compress_files`/`decompress_files` (CLI:
//! `czb compress --dataset p,rho,E --jobs N`) batch many files over one
//! session this way.
//!
//! Whole simulation steps bundle into `.czs` archives ([`dataset`]):
//! [`Dataset::create`] + `DatasetWriter::write_quantity` append one
//! `.czb` section per quantity and a trailer index. [`Dataset::open`]
//! is *streaming*: it parses only the fixed-size trailer tail and loads
//! section bytes lazily on first touch (a [`dataset::SectionSource`]
//! abstracts file-backed vs in-memory archives), so reading one field
//! of a many-GB step never pulls the rest in. `Engine::decompress_dataset`
//! decodes all requested quantities concurrently on the session pool —
//! section I/O and stage-2 inflate of quantity *i+1* overlap quantity
//! *i*'s block decode — and both whole-quantity decode and random block
//! access route through the archive-wide sharded [`ChunkCache`].
//!
//! # Stages
//!
//! **Compression** ([`compressor`]): worker threads pull contiguous spans
//! of blocks (~`chunk_bytes` of raw data each) off a
//! [`crate::cluster::SpanQueue`]; each span becomes one chunk — per-block
//! lossy stage 1 into a worker-private buffer, lossless stage 2 (shuffle
//! + framed codec) over the filled buffer — and the chunks are
//! concatenated in block order into a single stream per quantity. Span
//! boundaries are fixed by block-id arithmetic, so the `.czb` output is
//! byte-identical for every thread count and every executor (pool or
//! scoped). When a field yields fewer spans than workers, the *wide
//! path* fans out inside each span instead — parallel stage-1 block
//! ranges, then parallel stage-2 sub-frames — with the same bytes.
//!
//! Both substages are trait objects behind registries: stage-1 schemes
//! implement [`stage1::Stage1Codec`] (wavelet, zfp, sz, fpzip, copy) and
//! stage-2 lossless back-ends implement
//! [`crate::codec::stage2::Stage2Codec`] (czlib, lz4lite, zstdlite,
//! lzmalite, copy). A new codec on either side implements its trait and
//! registers — neither `compressor.rs` nor `decompressor.rs` changes.
//!
//! **Decompression** ([`decompressor`]): whole-field decode pulls chunks
//! off the same queue type and scatters blocks into the shared output
//! field, stopping early via a shared abort flag when any chunk fails.
//! Archives with fewer chunks than workers decode through the wide path:
//! each chunk's stage-2 sub-frames (format v3) inflate concurrently into
//! disjoint slices and its blocks stage-1 decode concurrently — a
//! single-chunk archive scales with threads. Random access goes through
//! [`BlockReader`] over a sharded concurrent [`ChunkCache`]
//! ([`chunk_cache`]); `.czs` archives share one cache across every
//! reader they hand out.
//!
//! # Integrity and graceful degradation
//!
//! `.czb` v4 streams carry a CRC32C per compressed chunk plus a
//! whole-header digest, and `.czs` v2 trailers carry one CRC32C per
//! section ([`crate::util::crc32c`]); every decode path verifies what
//! it touches, so a flipped bit surfaces as a precise checksum error
//! instead of a downstream codec failure or silently wrong floats.
//! Older files (czb ≤ v3, czs v1) parse and decode bit-exactly with the
//! checks skipped. Three consumers sit on top:
//!
//! * [`verify_stream`] / `czb verify` — checksum-only walk (exit 0
//!   clean, 3 corrupt, 1 unreadable); `--deep` additionally decodes
//!   everything and reports per-quantity compression ratio and PSNR.
//! * [`decompress_field_salvage`] / `Engine::decompress_salvage` /
//!   `czb decompress --salvage` — decode every intact chunk, zero-fill
//!   and enumerate the corrupt ones in a [`DecodeReport`] instead of
//!   failing the stream; per-quantity isolation on `.czs` archives via
//!   `Engine::decompress_dataset_salvage`.
//! * [`crate::io::fault`] — a deterministic fault-injection harness
//!   (scripted short reads, transient errors, bit flips, truncation)
//!   armed on `.czs` positioned reads via
//!   [`DatasetOptions::open_with_faults`], proving end-to-end that
//!   every fault is retried, detected or salvaged — never a panic, a
//!   hang or a silent wrong answer (`rust/tests/fault_injection.rs`).
//!
//! **Buffer lifecycle**: every worker owns its scratch — batch transform
//! buffer, block gather, the [`stage1::Stage1Scratch`] encode/decode
//! buffers, shuffle buffer, the decompressor's inflate/offset buffers —
//! allocated once per worker and reused for every block/chunk; the
//! wavelet transform keeps its line buffers in a thread-local pool, the
//! fpc decoders fill caller-owned `_into` buffers, and the chunk cache
//! recycles evicted sole-owner buffers. The steady-state per-block path
//! allocates nothing in either direction.
pub mod chunk_cache;
pub mod compressor;
pub mod dataset;
pub mod decompressor;
pub mod engine;
pub mod format;
pub mod quality;
pub mod stage1;

pub use chunk_cache::{ChunkCache, StreamId};
pub use compressor::{
    compress_field, CompressStats, NativeEngine, PipelineConfig, WaveletEngine,
    DEFAULT_FRAME_BYTES,
};
pub use dataset::{
    Dataset, DatasetOptions, DatasetWriter, QuantityEntry, SectionSource,
    DEFAULT_DATASET_CACHE_CHUNKS,
};
pub use decompressor::{
    decompress_field, decompress_field_mt, decompress_field_salvage, verify_stream, BlockReader,
    DecodeReport,
};
pub use engine::{CompressParams, Engine, EngineBuilder};
pub use format::{CoeffCodec, CzbFile, ShuffleMode, Stage1, FORMAT_VERSION};
pub use quality::{
    AchievedQuality, Bound, BoundKind, ChunkQuality, ACHIEVED_WIRE_LEN, BOUND_WIRE_LEN,
};
pub use stage1::{Stage1Codec, Stage1Scratch};
