//! The two-substage compression pipeline (paper Fig. 1): per-block lossy
//! stage 1 into per-thread private buffers, lossless stage 2 over each
//! filled buffer ("chunk"), concatenation into a single stream per
//! quantity, and the chunk-cached block decompressor.
pub mod compressor;
pub mod decompressor;
pub mod format;

pub use compressor::{compress_field, CompressStats, NativeEngine, PipelineConfig, WaveletEngine};
pub use decompressor::{decompress_field, BlockReader};
pub use format::{CoeffCodec, CzbFile, ShuffleMode, Stage1};
