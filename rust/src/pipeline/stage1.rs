//! Trait-unified substage-1 (lossy) codecs.
//!
//! The pipeline used to hard-code one `match` per direction over the
//! [`Stage1`] scheme enum; every new compressor meant editing both
//! `compressor.rs` and `decompressor.rs`. This module turns each scheme
//! into a [`Stage1Codec`] implementation and gives the pipeline a single
//! dispatch point: [`codec_for`] (plus [`by_id`] / [`by_name`] lookups
//! for headers and CLIs). Registering a new scheme means adding a
//! [`Stage1`] variant for its header parameters, implementing the trait,
//! and appending it to [`REGISTRY`] — the compression and decompression
//! pipelines themselves stay untouched.
//!
//! Block payload bytes are identical to the pre-trait pipeline: this is
//! a dispatch refactor, not a format change.
use super::compressor::WaveletEngine;
use super::format::{CoeffCodec, Stage1};
use super::quality::{conservative_knob, Bound, BoundKind};
use crate::fpc::{self, Dims3};
use crate::wavelet::{self, WaveletKind};

/// Reusable per-worker scratch shared by every stage-1 codec, allocated
/// once per worker/reader so the per-block encode/decode paths allocate
/// nothing in the steady state.
#[derive(Default)]
pub struct Stage1Scratch {
    /// encode: plain wavelet encoding before coeff-codec recompression
    pub(crate) wav: Vec<u8>,
    /// encode: f32 view of the detail-coefficient payload
    pub(crate) coeffs: Vec<f32>,
    /// encode: coeff-codec compressed bytes
    pub(crate) cbuf: Vec<u8>,
    /// decode: reassembled plain wavelet encoding (coeff-codec path)
    pub(crate) plain: Vec<u8>,
    /// decode: float output of the fpc `_into` decompressors
    pub(crate) floats: Vec<f32>,
    /// decode: fpzip's mapped-integer plane
    pub(crate) ints: Vec<i64>,
    /// decode: spdp's raw byte stream
    pub(crate) bytes: Vec<u8>,
}

/// One substage-1 scheme behind a uniform interface. Implementations are
/// stateless: all per-file parameters travel in the [`Stage1`] value
/// (which is what the `.czb` header serializes), all per-worker state in
/// the caller-owned [`Stage1Scratch`].
pub trait Stage1Codec: Sync {
    /// Wire id, matching [`Stage1::id`] for the scheme's variants.
    fn id(&self) -> u8;
    /// Human name, matching [`Stage1::name`].
    fn name(&self) -> &'static str;

    /// Absolute error parameter derived from the scheme's relative one
    /// and the field range (0.0 for lossless/parameter-free schemes).
    fn eps_abs(&self, _params: &Stage1, _range: f32) -> f32 {
        0.0
    }

    /// Human name of the scheme's native quality knob (what
    /// `czb codecs` lists next to the honored bound kinds).
    fn knob(&self) -> &'static str;

    /// Whether this codec's encoder *guarantees* the given bound kind
    /// pointwise. Declaring a kind here is a strictness contract: the
    /// recorded achieved quality of any stream compressed under a
    /// honored bound must pass [`Bound::check`]. Every codec honors
    /// [`BoundKind::None`].
    fn honors(&self, kind: BoundKind) -> bool;

    /// Map a bound onto this codec's native knob, keeping the
    /// template's non-knob fields (e.g. the wavelet kind). `range` is
    /// the global field range the relative knobs are scaled by. Errors
    /// iff `!self.honors(bound.kind())` — callers validate the pairing
    /// up front and treat an error here as a bug.
    fn apply_bound(&self, template: &Stage1, bound: &Bound, range: f32) -> Result<Stage1, String> {
        if let Bound::None = bound {
            return Ok(*template);
        }
        Err(format!("stage-1 codec '{}' cannot honor a {} bound", self.name(), bound.kind().name()))
    }

    /// Wavelet kind to batch-transform blocks with *before*
    /// [`Stage1Codec::encode_block`] runs, if the scheme consumes
    /// transformed coefficients rather than raw samples.
    fn pre_transform(&self, _params: &Stage1) -> Option<WaveletKind> {
        None
    }

    /// Encode one bs³ block (already transformed when
    /// [`Stage1Codec::pre_transform`] returned a kind), appending the
    /// payload to `out` (no size prefix — the chunk layer owns that).
    fn encode_block(
        &self,
        params: &Stage1,
        block: &[f32],
        bs: usize,
        eps_abs: f32,
        out: &mut Vec<u8>,
        scratch: &mut Stage1Scratch,
    );

    /// Decode one block payload into `out` (bs³ floats), inverting the
    /// pre-transform if the scheme has one.
    fn decode_block(
        &self,
        params: &Stage1,
        payload: &[u8],
        bs: usize,
        engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String>;
}

/// The pointwise-relative knob a valued bound reduces to: `Abs` is
/// divided by the range, `Psnr` converts via `rmse <= max_abs_err`
/// (a pointwise bound of `range * 10^(-psnr/20)` guarantees the PSNR).
fn rel_knob_of(bound: &Bound, range: f32) -> Option<f64> {
    match *bound {
        Bound::Abs(a) => Some(a / range.max(f32::MIN_POSITIVE) as f64),
        Bound::Rel(r) => Some(r),
        Bound::Psnr(p) => Some(10f64.powf(-p / 20.0)),
        Bound::None | Bound::Lossless => None,
    }
}

/// Direct-copy scheme (no lossy stage).
pub struct CopyCodec;

impl Stage1Codec for CopyCodec {
    fn id(&self) -> u8 {
        0
    }
    fn name(&self) -> &'static str {
        "copy"
    }
    fn knob(&self) -> &'static str {
        "(none)"
    }
    fn honors(&self, _kind: BoundKind) -> bool {
        // bit-exact: every contract holds trivially
        true
    }
    fn apply_bound(&self, _template: &Stage1, _bound: &Bound, _range: f32) -> Result<Stage1, String> {
        Ok(Stage1::Copy)
    }

    fn encode_block(
        &self,
        _params: &Stage1,
        block: &[f32],
        _bs: usize,
        _eps_abs: f32,
        out: &mut Vec<u8>,
        _scratch: &mut Stage1Scratch,
    ) {
        for v in block {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode_block(
        &self,
        _params: &Stage1,
        payload: &[u8],
        bs: usize,
        _engine: &dyn WaveletEngine,
        _scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let vol = bs * bs * bs;
        if payload.len() != vol * 4 {
            return Err("copy block size mismatch".into());
        }
        for (i, c) in payload.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(())
    }
}

/// Wavelet transform + ε-threshold (+ optional coeff-codec recompression).
pub struct WaveletCodec;

impl Stage1Codec for WaveletCodec {
    fn id(&self) -> u8 {
        1
    }
    fn name(&self) -> &'static str {
        "wavelet"
    }
    fn knob(&self) -> &'static str {
        "eps-rel"
    }
    fn honors(&self, kind: BoundKind) -> bool {
        // the ε-threshold is applied per detail coefficient; inverse
        // levels superpose, so the pointwise error can exceed eps_abs by
        // well over an order of magnitude — no pointwise contract holds
        matches!(kind, BoundKind::None)
    }

    fn eps_abs(&self, params: &Stage1, range: f32) -> f32 {
        match *params {
            Stage1::Wavelet { eps_rel, .. } => eps_rel * range,
            _ => 0.0,
        }
    }

    fn pre_transform(&self, params: &Stage1) -> Option<WaveletKind> {
        match *params {
            Stage1::Wavelet { kind, .. } => Some(kind),
            _ => None,
        }
    }

    fn encode_block(
        &self,
        params: &Stage1,
        block: &[f32],
        bs: usize,
        eps_abs: f32,
        out: &mut Vec<u8>,
        scratch: &mut Stage1Scratch,
    ) {
        let (zbits, coeff) = match *params {
            Stage1::Wavelet { zbits, coeff, .. } => (zbits, coeff),
            _ => unreachable!("wavelet codec dispatched with non-wavelet params"),
        };
        let levels = wavelet::max_levels(bs);
        match coeff {
            CoeffCodec::None => {
                wavelet::encode_block(block, bs, levels, eps_abs, zbits as u32, out);
            }
            _ => {
                // encode to the reusable scratch, then recompress the
                // f32 coefficient payload with the chosen FP compressor
                scratch.wav.clear();
                wavelet::encode_block(block, bs, levels, eps_abs, zbits as u32, &mut scratch.wav);
                let vol = bs * bs * bs;
                let head = 4 + vol / 8; // nsig + mask
                scratch.coeffs.clear();
                scratch.coeffs.extend(
                    scratch.wav[head..]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
                );
                out.extend_from_slice(&scratch.wav[..head]);
                let coeffs = &scratch.coeffs;
                let cbuf = &mut scratch.cbuf;
                cbuf.clear();
                match coeff {
                    CoeffCodec::Fpzip => fpc::fpzip::compress(
                        coeffs,
                        Dims3 { nx: coeffs.len().max(1), ny: 1, nz: 1 },
                        32,
                        cbuf,
                    ),
                    CoeffCodec::Sz => {
                        // bound well below the threshold so stage-1 loss
                        // dominates (PSNR unaffected, as in the paper)
                        let eb = (eps_abs * 1e-3).max(f32::MIN_POSITIVE);
                        fpc::sz::compress(
                            coeffs,
                            Dims3 { nx: coeffs.len().max(1), ny: 1, nz: 1 },
                            eb,
                            cbuf,
                        )
                    }
                    CoeffCodec::Spdp => fpc::spdp::compress(coeffs, cbuf),
                    CoeffCodec::None => unreachable!(),
                }
                out.extend_from_slice(&(cbuf.len() as u32).to_le_bytes());
                out.extend_from_slice(cbuf);
            }
        }
    }

    fn decode_block(
        &self,
        params: &Stage1,
        payload: &[u8],
        bs: usize,
        engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let (kind, coeff) = match *params {
            Stage1::Wavelet { kind, coeff, .. } => (kind, coeff),
            _ => return Err("wavelet codec dispatched with non-wavelet params".into()),
        };
        let vol = bs * bs * bs;
        let levels = wavelet::max_levels(bs);
        match coeff {
            CoeffCodec::None => {
                wavelet::decode_block(payload, bs, out)?;
            }
            _ => {
                // [nsig][mask][u32 csize][compressed coeff payload]
                let head = 4 + vol / 8;
                if payload.len() < head + 4 {
                    return Err("wavelet+coeff block truncated".into());
                }
                let csize =
                    u32::from_le_bytes(payload[head..head + 4].try_into().unwrap()) as usize;
                let cbuf = &payload[head + 4..];
                if cbuf.len() < csize {
                    return Err("coeff payload truncated".into());
                }
                match coeff {
                    CoeffCodec::Fpzip => {
                        fpc::fpzip::decompress_into(
                            &cbuf[..csize],
                            &mut scratch.ints,
                            &mut scratch.floats,
                        )?;
                    }
                    CoeffCodec::Sz => {
                        fpc::sz::decompress_into(&cbuf[..csize], &mut scratch.floats)?;
                    }
                    CoeffCodec::Spdp => {
                        fpc::spdp::decompress_into(
                            &cbuf[..csize],
                            &mut scratch.bytes,
                            &mut scratch.floats,
                        )?;
                    }
                    CoeffCodec::None => unreachable!(),
                }
                // reassemble the plain encoding and decode it
                scratch.plain.clear();
                scratch.plain.extend_from_slice(&payload[..head]);
                for v in &scratch.floats {
                    scratch.plain.extend_from_slice(&v.to_le_bytes());
                }
                wavelet::decode_block(&scratch.plain, bs, out)?;
            }
        }
        engine.inverse_batch(kind, out, bs, levels);
        Ok(())
    }
}

/// ZFP-like fixed-accuracy scheme.
pub struct ZfpCodec;

impl Stage1Codec for ZfpCodec {
    fn id(&self) -> u8 {
        2
    }
    fn name(&self) -> &'static str {
        "zfp"
    }
    fn knob(&self) -> &'static str {
        "tol-rel"
    }
    fn honors(&self, kind: BoundKind) -> bool {
        // the plane cutoff guarantees maxerr <= tol pointwise; tol = 0
        // is only *near*-lossless, so Lossless is not honored
        matches!(kind, BoundKind::None | BoundKind::Abs | BoundKind::Rel | BoundKind::Psnr)
    }
    fn apply_bound(&self, template: &Stage1, bound: &Bound, range: f32) -> Result<Stage1, String> {
        match rel_knob_of(bound, range) {
            Some(rel) => Ok(Stage1::Zfp { tol_rel: conservative_knob(rel) }),
            None if *bound == Bound::None => Ok(*template),
            None => Err(format!("stage-1 codec 'zfp' cannot honor a {} bound", bound.kind().name())),
        }
    }

    fn eps_abs(&self, params: &Stage1, range: f32) -> f32 {
        match *params {
            Stage1::Zfp { tol_rel } => tol_rel * range,
            _ => 0.0,
        }
    }

    fn encode_block(
        &self,
        _params: &Stage1,
        block: &[f32],
        bs: usize,
        eps_abs: f32,
        out: &mut Vec<u8>,
        _scratch: &mut Stage1Scratch,
    ) {
        fpc::zfp::compress(block, Dims3::cube(bs), eps_abs, out);
    }

    fn decode_block(
        &self,
        _params: &Stage1,
        payload: &[u8],
        bs: usize,
        _engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let dims = fpc::zfp::decompress_into(payload, &mut scratch.floats)?;
        if dims.len() != bs * bs * bs {
            return Err("zfp dims mismatch".into());
        }
        out.copy_from_slice(&scratch.floats);
        Ok(())
    }
}

/// SZ-like error-bounded scheme.
pub struct SzCodec;

impl Stage1Codec for SzCodec {
    fn id(&self) -> u8 {
        3
    }
    fn name(&self) -> &'static str {
        "sz"
    }
    fn knob(&self) -> &'static str {
        "eb-rel"
    }
    fn honors(&self, kind: BoundKind) -> bool {
        // encode-time verification with an outlier escape keeps every
        // sample within abs_eb; the bound must stay > 0, so Lossless is
        // not honored
        matches!(kind, BoundKind::None | BoundKind::Abs | BoundKind::Rel | BoundKind::Psnr)
    }
    fn apply_bound(&self, template: &Stage1, bound: &Bound, range: f32) -> Result<Stage1, String> {
        match rel_knob_of(bound, range) {
            Some(rel) => Ok(Stage1::Sz { eb_rel: conservative_knob(rel) }),
            None if *bound == Bound::None => Ok(*template),
            None => Err(format!("stage-1 codec 'sz' cannot honor a {} bound", bound.kind().name())),
        }
    }

    fn eps_abs(&self, params: &Stage1, range: f32) -> f32 {
        match *params {
            Stage1::Sz { eb_rel } => eb_rel * range,
            _ => 0.0,
        }
    }

    fn encode_block(
        &self,
        _params: &Stage1,
        block: &[f32],
        bs: usize,
        eps_abs: f32,
        out: &mut Vec<u8>,
        _scratch: &mut Stage1Scratch,
    ) {
        fpc::sz::compress(block, Dims3::cube(bs), eps_abs.max(f32::MIN_POSITIVE), out);
    }

    fn decode_block(
        &self,
        _params: &Stage1,
        payload: &[u8],
        bs: usize,
        _engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let dims = fpc::sz::decompress_into(payload, &mut scratch.floats)?;
        if dims.len() != bs * bs * bs {
            return Err("sz dims mismatch".into());
        }
        out.copy_from_slice(&scratch.floats);
        Ok(())
    }
}

/// FPZIP-like precision-truncation scheme.
pub struct FpzipCodec;

impl Stage1Codec for FpzipCodec {
    fn id(&self) -> u8 {
        4
    }
    fn name(&self) -> &'static str {
        "fpzip"
    }
    fn knob(&self) -> &'static str {
        "prec"
    }
    fn honors(&self, kind: BoundKind) -> bool {
        // prec < 32 truncates mantissas with no pointwise guarantee;
        // prec = 32 is bit-exact — only the exact kinds are honorable
        matches!(kind, BoundKind::None | BoundKind::Lossless)
    }
    fn apply_bound(&self, template: &Stage1, bound: &Bound, _range: f32) -> Result<Stage1, String> {
        match bound {
            Bound::None => Ok(*template),
            Bound::Lossless => Ok(Stage1::Fpzip { prec: 32 }),
            _ => Err(format!(
                "stage-1 codec 'fpzip' cannot honor a {} bound",
                bound.kind().name()
            )),
        }
    }

    fn encode_block(
        &self,
        params: &Stage1,
        block: &[f32],
        bs: usize,
        _eps_abs: f32,
        out: &mut Vec<u8>,
        _scratch: &mut Stage1Scratch,
    ) {
        let prec = match *params {
            Stage1::Fpzip { prec } => prec,
            _ => 32,
        };
        fpc::fpzip::compress(block, Dims3::cube(bs), prec, out);
    }

    fn decode_block(
        &self,
        _params: &Stage1,
        payload: &[u8],
        bs: usize,
        _engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let dims =
            fpc::fpzip::decompress_into(payload, &mut scratch.ints, &mut scratch.floats)?;
        if dims.len() != bs * bs * bs {
            return Err("fpzip dims mismatch".into());
        }
        out.copy_from_slice(&scratch.floats);
        Ok(())
    }
}

/// All registered substage-1 codecs, indexable by [`Stage1Codec::id`].
/// New schemes append here (and add a [`Stage1`] parameter variant);
/// nothing in `compressor.rs`/`decompressor.rs` needs to change.
pub static REGISTRY: [&'static dyn Stage1Codec; 5] =
    [&CopyCodec, &WaveletCodec, &ZfpCodec, &SzCodec, &FpzipCodec];

/// Look a codec up by its wire id.
pub fn by_id(id: u8) -> Option<&'static dyn Stage1Codec> {
    REGISTRY.iter().copied().find(|c| c.id() == id)
}

/// Look a codec up by its scheme name.
pub fn by_name(name: &str) -> Option<&'static dyn Stage1Codec> {
    REGISTRY.iter().copied().find(|c| c.name() == name)
}

/// The codec serving a parsed [`Stage1`] parameter value.
pub fn codec_for(params: &Stage1) -> &'static dyn Stage1Codec {
    by_id(params.id()).expect("every Stage1 variant has a registered codec")
}

/// The scheme auto-selected when the user stated a contract but no
/// explicit `--scheme`: sz for the valued pointwise kinds (strict bound,
/// best default CR), fpzip at full precision for `Lossless`. `None`
/// means "keep the caller's default scheme" (no contract). The knob
/// value in the returned template is a placeholder —
/// [`Stage1Codec::apply_bound`] resolves it against the field range at
/// compression time.
pub fn default_scheme_for(bound: &Bound) -> Option<Stage1> {
    match bound.kind() {
        BoundKind::None => None,
        BoundKind::Lossless => Some(Stage1::Fpzip { prec: 32 }),
        BoundKind::Abs | BoundKind::Rel | BoundKind::Psnr => Some(Stage1::Sz { eb_rel: 0.0 }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::compressor::NativeEngine;

    #[test]
    fn registry_ids_and_names_match_stage1_variants() {
        let variants = [
            Stage1::Copy,
            Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 1e-3,
                zbits: 0,
                coeff: CoeffCodec::None,
            },
            Stage1::Zfp { tol_rel: 1e-3 },
            Stage1::Sz { eb_rel: 1e-3 },
            Stage1::Fpzip { prec: 24 },
        ];
        for v in variants {
            let c = codec_for(&v);
            assert_eq!(c.id(), v.id(), "{v:?}");
            assert_eq!(c.name(), v.name(), "{v:?}");
            assert_eq!(by_name(v.name()).unwrap().id(), v.id());
        }
        assert!(by_id(99).is_none());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn eps_abs_matches_enum_semantics() {
        let range = 10.0;
        let zfp = Stage1::Zfp { tol_rel: 1e-2 };
        assert!((codec_for(&zfp).eps_abs(&zfp, range) - 0.1).abs() < 1e-6);
        assert_eq!(codec_for(&Stage1::Copy).eps_abs(&Stage1::Copy, range), 0.0);
        let sz = Stage1::Sz { eb_rel: 2e-3 };
        assert!((codec_for(&sz).eps_abs(&sz, range) - 0.02).abs() < 1e-6);
        let w = Stage1::Wavelet {
            kind: WaveletKind::Avg3,
            eps_rel: 1e-3,
            zbits: 0,
            coeff: CoeffCodec::None,
        };
        assert!((codec_for(&w).eps_abs(&w, range) - 0.01).abs() < 1e-6);
    }

    #[test]
    fn only_wavelet_schemes_pre_transform() {
        let w = Stage1::Wavelet {
            kind: WaveletKind::Interp4,
            eps_rel: 1e-3,
            zbits: 0,
            coeff: CoeffCodec::None,
        };
        assert_eq!(codec_for(&w).pre_transform(&w), Some(WaveletKind::Interp4));
        for v in [Stage1::Copy, Stage1::Zfp { tol_rel: 0.1 }, Stage1::Sz { eb_rel: 0.1 }] {
            assert_eq!(codec_for(&v).pre_transform(&v), None, "{v:?}");
        }
    }

    #[test]
    fn honors_declarations_match_codec_strictness() {
        // copy is exact: everything holds
        for k in BoundKind::ALL {
            assert!(CopyCodec.honors(k), "{k:?}");
        }
        // wavelet thresholding is not a pointwise bound
        assert!(WaveletCodec.honors(BoundKind::None));
        for k in [BoundKind::Lossless, BoundKind::Abs, BoundKind::Rel, BoundKind::Psnr] {
            assert!(!WaveletCodec.honors(k), "{k:?}");
        }
        // zfp/sz: strict pointwise, never lossless
        for c in [&ZfpCodec as &dyn Stage1Codec, &SzCodec] {
            for k in [BoundKind::None, BoundKind::Abs, BoundKind::Rel, BoundKind::Psnr] {
                assert!(c.honors(k), "{} {k:?}", c.name());
            }
            assert!(!c.honors(BoundKind::Lossless), "{}", c.name());
        }
        // fpzip: exact kinds only
        assert!(FpzipCodec.honors(BoundKind::Lossless));
        assert!(FpzipCodec.honors(BoundKind::None));
        for k in [BoundKind::Abs, BoundKind::Rel, BoundKind::Psnr] {
            assert!(!FpzipCodec.honors(k), "{k:?}");
        }
    }

    #[test]
    fn apply_bound_maps_to_native_knobs() {
        let range = 10.0f32;
        let tpl = Stage1::Sz { eb_rel: 0.5 };
        // Rel maps (conservatively shrunk) onto the relative knob
        match SzCodec.apply_bound(&tpl, &Bound::Rel(1e-3), range).unwrap() {
            Stage1::Sz { eb_rel } => {
                assert!(eb_rel > 0.0 && (eb_rel as f64) <= 1e-3, "eb_rel {eb_rel}");
                assert!((eb_rel as f64) > 1e-3 * 0.999);
            }
            s => panic!("{s:?}"),
        }
        // Abs divides by the range
        match ZfpCodec.apply_bound(&Stage1::Zfp { tol_rel: 1.0 }, &Bound::Abs(0.05), range).unwrap()
        {
            Stage1::Zfp { tol_rel } => {
                assert!((tol_rel as f64) <= 0.005 && (tol_rel as f64) > 0.00499);
            }
            s => panic!("{s:?}"),
        }
        // Psnr reduces to 10^(-p/20)
        match SzCodec.apply_bound(&tpl, &Bound::Psnr(60.0), range).unwrap() {
            Stage1::Sz { eb_rel } => {
                assert!((eb_rel as f64) <= 1e-3 && (eb_rel as f64) > 0.999e-3);
            }
            s => panic!("{s:?}"),
        }
        // None keeps the template's knob
        assert_eq!(SzCodec.apply_bound(&tpl, &Bound::None, range).unwrap(), tpl);
        // Lossless resolves fpzip to full precision
        assert_eq!(
            FpzipCodec
                .apply_bound(&Stage1::Fpzip { prec: 16 }, &Bound::Lossless, range)
                .unwrap(),
            Stage1::Fpzip { prec: 32 }
        );
        // un-honored pairings error
        assert!(SzCodec.apply_bound(&tpl, &Bound::Lossless, range).is_err());
        assert!(FpzipCodec.apply_bound(&Stage1::Fpzip { prec: 16 }, &Bound::Rel(1e-3), range).is_err());
        let w = Stage1::Wavelet {
            kind: WaveletKind::Avg3,
            eps_rel: 1e-3,
            zbits: 0,
            coeff: CoeffCodec::None,
        };
        assert!(WaveletCodec.apply_bound(&w, &Bound::Rel(1e-3), range).is_err());
        assert_eq!(WaveletCodec.apply_bound(&w, &Bound::None, range).unwrap(), w);
        // copy honors everything at zero error
        assert_eq!(CopyCodec.apply_bound(&Stage1::Copy, &Bound::Abs(1e-9), range).unwrap(), Stage1::Copy);
    }

    #[test]
    fn default_scheme_for_bound_kinds() {
        assert_eq!(default_scheme_for(&Bound::None), None);
        assert_eq!(default_scheme_for(&Bound::Lossless), Some(Stage1::Fpzip { prec: 32 }));
        for b in [Bound::Abs(1e-3), Bound::Rel(1e-3), Bound::Psnr(60.0)] {
            let s = default_scheme_for(&b).unwrap();
            assert_eq!(s.name(), "sz");
            assert!(codec_for(&s).honors(b.kind()), "{b:?}");
        }
    }

    #[test]
    fn copy_codec_roundtrips_a_block() {
        let bs = 4;
        let block: Vec<f32> = (0..bs * bs * bs).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut scratch = Stage1Scratch::default();
        let mut payload = Vec::new();
        CopyCodec.encode_block(&Stage1::Copy, &block, bs, 0.0, &mut payload, &mut scratch);
        let mut back = vec![0f32; bs * bs * bs];
        CopyCodec
            .decode_block(&Stage1::Copy, &payload, bs, &NativeEngine, &mut scratch, &mut back)
            .unwrap();
        assert_eq!(back, block);
        assert!(CopyCodec
            .decode_block(&Stage1::Copy, &payload[..7], bs, &NativeEngine, &mut scratch, &mut back)
            .is_err());
    }
}
