//! Trait-unified substage-1 (lossy) codecs.
//!
//! The pipeline used to hard-code one `match` per direction over the
//! [`Stage1`] scheme enum; every new compressor meant editing both
//! `compressor.rs` and `decompressor.rs`. This module turns each scheme
//! into a [`Stage1Codec`] implementation and gives the pipeline a single
//! dispatch point: [`codec_for`] (plus [`by_id`] / [`by_name`] lookups
//! for headers and CLIs). Registering a new scheme means adding a
//! [`Stage1`] variant for its header parameters, implementing the trait,
//! and appending it to [`REGISTRY`] — the compression and decompression
//! pipelines themselves stay untouched.
//!
//! Block payload bytes are identical to the pre-trait pipeline: this is
//! a dispatch refactor, not a format change.
use super::compressor::WaveletEngine;
use super::format::{CoeffCodec, Stage1};
use crate::fpc::{self, Dims3};
use crate::wavelet::{self, WaveletKind};

/// Reusable per-worker scratch shared by every stage-1 codec, allocated
/// once per worker/reader so the per-block encode/decode paths allocate
/// nothing in the steady state.
#[derive(Default)]
pub struct Stage1Scratch {
    /// encode: plain wavelet encoding before coeff-codec recompression
    pub(crate) wav: Vec<u8>,
    /// encode: f32 view of the detail-coefficient payload
    pub(crate) coeffs: Vec<f32>,
    /// encode: coeff-codec compressed bytes
    pub(crate) cbuf: Vec<u8>,
    /// decode: reassembled plain wavelet encoding (coeff-codec path)
    pub(crate) plain: Vec<u8>,
    /// decode: float output of the fpc `_into` decompressors
    pub(crate) floats: Vec<f32>,
    /// decode: fpzip's mapped-integer plane
    pub(crate) ints: Vec<i64>,
    /// decode: spdp's raw byte stream
    pub(crate) bytes: Vec<u8>,
}

/// One substage-1 scheme behind a uniform interface. Implementations are
/// stateless: all per-file parameters travel in the [`Stage1`] value
/// (which is what the `.czb` header serializes), all per-worker state in
/// the caller-owned [`Stage1Scratch`].
pub trait Stage1Codec: Sync {
    /// Wire id, matching [`Stage1::id`] for the scheme's variants.
    fn id(&self) -> u8;
    /// Human name, matching [`Stage1::name`].
    fn name(&self) -> &'static str;

    /// Absolute error parameter derived from the scheme's relative one
    /// and the field range (0.0 for lossless/parameter-free schemes).
    fn eps_abs(&self, _params: &Stage1, _range: f32) -> f32 {
        0.0
    }

    /// Wavelet kind to batch-transform blocks with *before*
    /// [`Stage1Codec::encode_block`] runs, if the scheme consumes
    /// transformed coefficients rather than raw samples.
    fn pre_transform(&self, _params: &Stage1) -> Option<WaveletKind> {
        None
    }

    /// Encode one bs³ block (already transformed when
    /// [`Stage1Codec::pre_transform`] returned a kind), appending the
    /// payload to `out` (no size prefix — the chunk layer owns that).
    fn encode_block(
        &self,
        params: &Stage1,
        block: &[f32],
        bs: usize,
        eps_abs: f32,
        out: &mut Vec<u8>,
        scratch: &mut Stage1Scratch,
    );

    /// Decode one block payload into `out` (bs³ floats), inverting the
    /// pre-transform if the scheme has one.
    fn decode_block(
        &self,
        params: &Stage1,
        payload: &[u8],
        bs: usize,
        engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String>;
}

/// Direct-copy scheme (no lossy stage).
pub struct CopyCodec;

impl Stage1Codec for CopyCodec {
    fn id(&self) -> u8 {
        0
    }
    fn name(&self) -> &'static str {
        "copy"
    }

    fn encode_block(
        &self,
        _params: &Stage1,
        block: &[f32],
        _bs: usize,
        _eps_abs: f32,
        out: &mut Vec<u8>,
        _scratch: &mut Stage1Scratch,
    ) {
        for v in block {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode_block(
        &self,
        _params: &Stage1,
        payload: &[u8],
        bs: usize,
        _engine: &dyn WaveletEngine,
        _scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let vol = bs * bs * bs;
        if payload.len() != vol * 4 {
            return Err("copy block size mismatch".into());
        }
        for (i, c) in payload.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(())
    }
}

/// Wavelet transform + ε-threshold (+ optional coeff-codec recompression).
pub struct WaveletCodec;

impl Stage1Codec for WaveletCodec {
    fn id(&self) -> u8 {
        1
    }
    fn name(&self) -> &'static str {
        "wavelet"
    }

    fn eps_abs(&self, params: &Stage1, range: f32) -> f32 {
        match *params {
            Stage1::Wavelet { eps_rel, .. } => eps_rel * range,
            _ => 0.0,
        }
    }

    fn pre_transform(&self, params: &Stage1) -> Option<WaveletKind> {
        match *params {
            Stage1::Wavelet { kind, .. } => Some(kind),
            _ => None,
        }
    }

    fn encode_block(
        &self,
        params: &Stage1,
        block: &[f32],
        bs: usize,
        eps_abs: f32,
        out: &mut Vec<u8>,
        scratch: &mut Stage1Scratch,
    ) {
        let (zbits, coeff) = match *params {
            Stage1::Wavelet { zbits, coeff, .. } => (zbits, coeff),
            _ => unreachable!("wavelet codec dispatched with non-wavelet params"),
        };
        let levels = wavelet::max_levels(bs);
        match coeff {
            CoeffCodec::None => {
                wavelet::encode_block(block, bs, levels, eps_abs, zbits as u32, out);
            }
            _ => {
                // encode to the reusable scratch, then recompress the
                // f32 coefficient payload with the chosen FP compressor
                scratch.wav.clear();
                wavelet::encode_block(block, bs, levels, eps_abs, zbits as u32, &mut scratch.wav);
                let vol = bs * bs * bs;
                let head = 4 + vol / 8; // nsig + mask
                scratch.coeffs.clear();
                scratch.coeffs.extend(
                    scratch.wav[head..]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
                );
                out.extend_from_slice(&scratch.wav[..head]);
                let coeffs = &scratch.coeffs;
                let cbuf = &mut scratch.cbuf;
                cbuf.clear();
                match coeff {
                    CoeffCodec::Fpzip => fpc::fpzip::compress(
                        coeffs,
                        Dims3 { nx: coeffs.len().max(1), ny: 1, nz: 1 },
                        32,
                        cbuf,
                    ),
                    CoeffCodec::Sz => {
                        // bound well below the threshold so stage-1 loss
                        // dominates (PSNR unaffected, as in the paper)
                        let eb = (eps_abs * 1e-3).max(f32::MIN_POSITIVE);
                        fpc::sz::compress(
                            coeffs,
                            Dims3 { nx: coeffs.len().max(1), ny: 1, nz: 1 },
                            eb,
                            cbuf,
                        )
                    }
                    CoeffCodec::Spdp => fpc::spdp::compress(coeffs, cbuf),
                    CoeffCodec::None => unreachable!(),
                }
                out.extend_from_slice(&(cbuf.len() as u32).to_le_bytes());
                out.extend_from_slice(cbuf);
            }
        }
    }

    fn decode_block(
        &self,
        params: &Stage1,
        payload: &[u8],
        bs: usize,
        engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let (kind, coeff) = match *params {
            Stage1::Wavelet { kind, coeff, .. } => (kind, coeff),
            _ => return Err("wavelet codec dispatched with non-wavelet params".into()),
        };
        let vol = bs * bs * bs;
        let levels = wavelet::max_levels(bs);
        match coeff {
            CoeffCodec::None => {
                wavelet::decode_block(payload, bs, out)?;
            }
            _ => {
                // [nsig][mask][u32 csize][compressed coeff payload]
                let head = 4 + vol / 8;
                if payload.len() < head + 4 {
                    return Err("wavelet+coeff block truncated".into());
                }
                let csize =
                    u32::from_le_bytes(payload[head..head + 4].try_into().unwrap()) as usize;
                let cbuf = &payload[head + 4..];
                if cbuf.len() < csize {
                    return Err("coeff payload truncated".into());
                }
                match coeff {
                    CoeffCodec::Fpzip => {
                        fpc::fpzip::decompress_into(
                            &cbuf[..csize],
                            &mut scratch.ints,
                            &mut scratch.floats,
                        )?;
                    }
                    CoeffCodec::Sz => {
                        fpc::sz::decompress_into(&cbuf[..csize], &mut scratch.floats)?;
                    }
                    CoeffCodec::Spdp => {
                        fpc::spdp::decompress_into(
                            &cbuf[..csize],
                            &mut scratch.bytes,
                            &mut scratch.floats,
                        )?;
                    }
                    CoeffCodec::None => unreachable!(),
                }
                // reassemble the plain encoding and decode it
                scratch.plain.clear();
                scratch.plain.extend_from_slice(&payload[..head]);
                for v in &scratch.floats {
                    scratch.plain.extend_from_slice(&v.to_le_bytes());
                }
                wavelet::decode_block(&scratch.plain, bs, out)?;
            }
        }
        engine.inverse_batch(kind, out, bs, levels);
        Ok(())
    }
}

/// ZFP-like fixed-accuracy scheme.
pub struct ZfpCodec;

impl Stage1Codec for ZfpCodec {
    fn id(&self) -> u8 {
        2
    }
    fn name(&self) -> &'static str {
        "zfp"
    }

    fn eps_abs(&self, params: &Stage1, range: f32) -> f32 {
        match *params {
            Stage1::Zfp { tol_rel } => tol_rel * range,
            _ => 0.0,
        }
    }

    fn encode_block(
        &self,
        _params: &Stage1,
        block: &[f32],
        bs: usize,
        eps_abs: f32,
        out: &mut Vec<u8>,
        _scratch: &mut Stage1Scratch,
    ) {
        fpc::zfp::compress(block, Dims3::cube(bs), eps_abs, out);
    }

    fn decode_block(
        &self,
        _params: &Stage1,
        payload: &[u8],
        bs: usize,
        _engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let dims = fpc::zfp::decompress_into(payload, &mut scratch.floats)?;
        if dims.len() != bs * bs * bs {
            return Err("zfp dims mismatch".into());
        }
        out.copy_from_slice(&scratch.floats);
        Ok(())
    }
}

/// SZ-like error-bounded scheme.
pub struct SzCodec;

impl Stage1Codec for SzCodec {
    fn id(&self) -> u8 {
        3
    }
    fn name(&self) -> &'static str {
        "sz"
    }

    fn eps_abs(&self, params: &Stage1, range: f32) -> f32 {
        match *params {
            Stage1::Sz { eb_rel } => eb_rel * range,
            _ => 0.0,
        }
    }

    fn encode_block(
        &self,
        _params: &Stage1,
        block: &[f32],
        bs: usize,
        eps_abs: f32,
        out: &mut Vec<u8>,
        _scratch: &mut Stage1Scratch,
    ) {
        fpc::sz::compress(block, Dims3::cube(bs), eps_abs.max(f32::MIN_POSITIVE), out);
    }

    fn decode_block(
        &self,
        _params: &Stage1,
        payload: &[u8],
        bs: usize,
        _engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let dims = fpc::sz::decompress_into(payload, &mut scratch.floats)?;
        if dims.len() != bs * bs * bs {
            return Err("sz dims mismatch".into());
        }
        out.copy_from_slice(&scratch.floats);
        Ok(())
    }
}

/// FPZIP-like precision-truncation scheme.
pub struct FpzipCodec;

impl Stage1Codec for FpzipCodec {
    fn id(&self) -> u8 {
        4
    }
    fn name(&self) -> &'static str {
        "fpzip"
    }

    fn encode_block(
        &self,
        params: &Stage1,
        block: &[f32],
        bs: usize,
        _eps_abs: f32,
        out: &mut Vec<u8>,
        _scratch: &mut Stage1Scratch,
    ) {
        let prec = match *params {
            Stage1::Fpzip { prec } => prec,
            _ => 32,
        };
        fpc::fpzip::compress(block, Dims3::cube(bs), prec, out);
    }

    fn decode_block(
        &self,
        _params: &Stage1,
        payload: &[u8],
        bs: usize,
        _engine: &dyn WaveletEngine,
        scratch: &mut Stage1Scratch,
        out: &mut [f32],
    ) -> Result<(), String> {
        let dims =
            fpc::fpzip::decompress_into(payload, &mut scratch.ints, &mut scratch.floats)?;
        if dims.len() != bs * bs * bs {
            return Err("fpzip dims mismatch".into());
        }
        out.copy_from_slice(&scratch.floats);
        Ok(())
    }
}

/// All registered substage-1 codecs, indexable by [`Stage1Codec::id`].
/// New schemes append here (and add a [`Stage1`] parameter variant);
/// nothing in `compressor.rs`/`decompressor.rs` needs to change.
pub static REGISTRY: [&'static dyn Stage1Codec; 5] =
    [&CopyCodec, &WaveletCodec, &ZfpCodec, &SzCodec, &FpzipCodec];

/// Look a codec up by its wire id.
pub fn by_id(id: u8) -> Option<&'static dyn Stage1Codec> {
    REGISTRY.iter().copied().find(|c| c.id() == id)
}

/// Look a codec up by its scheme name.
pub fn by_name(name: &str) -> Option<&'static dyn Stage1Codec> {
    REGISTRY.iter().copied().find(|c| c.name() == name)
}

/// The codec serving a parsed [`Stage1`] parameter value.
pub fn codec_for(params: &Stage1) -> &'static dyn Stage1Codec {
    by_id(params.id()).expect("every Stage1 variant has a registered codec")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::compressor::NativeEngine;

    #[test]
    fn registry_ids_and_names_match_stage1_variants() {
        let variants = [
            Stage1::Copy,
            Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 1e-3,
                zbits: 0,
                coeff: CoeffCodec::None,
            },
            Stage1::Zfp { tol_rel: 1e-3 },
            Stage1::Sz { eb_rel: 1e-3 },
            Stage1::Fpzip { prec: 24 },
        ];
        for v in variants {
            let c = codec_for(&v);
            assert_eq!(c.id(), v.id(), "{v:?}");
            assert_eq!(c.name(), v.name(), "{v:?}");
            assert_eq!(by_name(v.name()).unwrap().id(), v.id());
        }
        assert!(by_id(99).is_none());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn eps_abs_matches_enum_semantics() {
        let range = 10.0;
        let zfp = Stage1::Zfp { tol_rel: 1e-2 };
        assert!((codec_for(&zfp).eps_abs(&zfp, range) - 0.1).abs() < 1e-6);
        assert_eq!(codec_for(&Stage1::Copy).eps_abs(&Stage1::Copy, range), 0.0);
        let sz = Stage1::Sz { eb_rel: 2e-3 };
        assert!((codec_for(&sz).eps_abs(&sz, range) - 0.02).abs() < 1e-6);
        let w = Stage1::Wavelet {
            kind: WaveletKind::Avg3,
            eps_rel: 1e-3,
            zbits: 0,
            coeff: CoeffCodec::None,
        };
        assert!((codec_for(&w).eps_abs(&w, range) - 0.01).abs() < 1e-6);
    }

    #[test]
    fn only_wavelet_schemes_pre_transform() {
        let w = Stage1::Wavelet {
            kind: WaveletKind::Interp4,
            eps_rel: 1e-3,
            zbits: 0,
            coeff: CoeffCodec::None,
        };
        assert_eq!(codec_for(&w).pre_transform(&w), Some(WaveletKind::Interp4));
        for v in [Stage1::Copy, Stage1::Zfp { tol_rel: 0.1 }, Stage1::Sz { eb_rel: 0.1 }] {
            assert_eq!(codec_for(&v).pre_transform(&v), None, "{v:?}");
        }
    }

    #[test]
    fn copy_codec_roundtrips_a_block() {
        let bs = 4;
        let block: Vec<f32> = (0..bs * bs * bs).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut scratch = Stage1Scratch::default();
        let mut payload = Vec::new();
        CopyCodec.encode_block(&Stage1::Copy, &block, bs, 0.0, &mut payload, &mut scratch);
        let mut back = vec![0f32; bs * bs * bs];
        CopyCodec
            .decode_block(&Stage1::Copy, &payload, bs, &NativeEngine, &mut scratch, &mut back)
            .unwrap();
        assert_eq!(back, block);
        assert!(CopyCodec
            .decode_block(&Stage1::Copy, &payload[..7], bs, &NativeEngine, &mut scratch, &mut back)
            .is_err());
    }
}
