//! `.czb` compressed-quantity format: the on-disk header and chunk
//! payloads for one compressed 3D field.
//!
//! The byte-level layout and full v1–v5 version history live in
//! `docs/FORMATS.md` (the single reference for every on-disk format);
//! this module is the reference implementation. Notes a reader of the
//! *code* needs:
//!
//! * **Version gates.** Readers accept v1..=v5; writers emit
//!   [`FORMAT_VERSION`]. Fields a version predates parse to their
//!   neutral value: `frame_raw == 0` means "unframed legacy payloads"
//!   (v≤2), [`CzbFile::chunk_crcs`] parses empty below v4 (every
//!   integrity check skipped), [`CzbFile::bound`] parses as
//!   [`Bound::None`] and [`CzbFile::chunk_quality`] empty below v5.
//! * **Integrity (v4+).** One CRC32C ([`crate::util::crc32c`]) per
//!   compressed chunk payload plus a whole-header CRC32C. The header
//!   digest is verified by [`CzbFile::parse_header`]; the per-chunk
//!   digests are verified by the decoder right before each payload is
//!   inflated (and by `czb verify` without decoding).
//! * **Framed payloads (v3+).** Each chunk's stage-2 payload is a
//!   framed container ([`crate::codec::stage2`]): sub-frames of
//!   `frame_raw` bytes, each an independent stage-2 stream. Frame
//!   boundaries are pure arithmetic on the stream length, so archives
//!   stay byte-identical across thread counts while one chunk's frames
//!   (de)compress concurrently.
//! * **Determinism.** CRC columns, the bound record and the v5
//!   [`ChunkQuality`] column are deterministic folds in block order —
//!   serialized bytes never depend on scheduling or SIMD level.
//! * **Block walk.** Within a chunk's *raw* stream every block is
//!   prefixed with its `u32` encoded size, so the decompressor can walk
//!   to any block after a single stage-2 inflate of the chunk.
use super::quality::{AchievedQuality, Bound, ChunkQuality, BOUND_WIRE_LEN, CHUNK_QUALITY_WIRE_LEN};
use crate::codec::Codec;
use crate::wavelet::WaveletKind;

/// Lossless post-processing applied to wavelet detail coefficients before
/// stage 2 (the paper's Table 2 study).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CoeffCodec {
    /// Plain f32 stream (default).
    None,
    /// fpzip-lossless the coefficient stream.
    Fpzip,
    /// sz the coefficient stream with a tiny bound (effectively lossless
    /// relative to the already-thresholded coefficients).
    Sz,
    /// spdp the coefficient stream.
    Spdp,
}

impl CoeffCodec {
    pub fn id(&self) -> u8 {
        match self {
            CoeffCodec::None => 0,
            CoeffCodec::Fpzip => 1,
            CoeffCodec::Sz => 2,
            CoeffCodec::Spdp => 3,
        }
    }
    pub fn from_id(v: u8) -> Option<Self> {
        [CoeffCodec::None, CoeffCodec::Fpzip, CoeffCodec::Sz, CoeffCodec::Spdp]
            .into_iter()
            .find(|c| c.id() == v)
    }
    pub fn name(&self) -> &'static str {
        match self {
            CoeffCodec::None => "none",
            CoeffCodec::Fpzip => "fpzip",
            CoeffCodec::Sz => "sz",
            CoeffCodec::Spdp => "spdp",
        }
    }
}

/// Substage-1 (lossy) scheme.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Stage1 {
    /// Direct copy (no lossy stage).
    Copy,
    /// Wavelet transform + ε-threshold. `eps_rel` is relative to the
    /// global field range; `zbits` zeroes detail-coefficient LSBs (Z4/Z8).
    Wavelet { kind: WaveletKind, eps_rel: f32, zbits: u8, coeff: CoeffCodec },
    /// ZFP-like fixed accuracy; tolerance relative to global range.
    Zfp { tol_rel: f32 },
    /// SZ-like error bound relative to global range.
    Sz { eb_rel: f32 },
    /// FPZIP-like with `prec` bits kept (32 = lossless).
    Fpzip { prec: u8 },
}

impl Stage1 {
    pub fn id(&self) -> u8 {
        match self {
            Stage1::Copy => 0,
            Stage1::Wavelet { .. } => 1,
            Stage1::Zfp { .. } => 2,
            Stage1::Sz { .. } => 3,
            Stage1::Fpzip { .. } => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Stage1::Copy => "copy",
            Stage1::Wavelet { .. } => "wavelet",
            Stage1::Zfp { .. } => "zfp",
            Stage1::Sz { .. } => "sz",
            Stage1::Fpzip { .. } => "fpzip",
        }
    }

    fn encode(&self) -> [u8; 12] {
        let mut out = [0u8; 12];
        out[0] = self.id();
        match *self {
            Stage1::Copy => {}
            Stage1::Wavelet { kind, eps_rel, zbits, coeff } => {
                out[1] = kind.id();
                out[2] = zbits;
                out[3] = coeff.id();
                out[4..8].copy_from_slice(&eps_rel.to_le_bytes());
            }
            Stage1::Zfp { tol_rel } => out[4..8].copy_from_slice(&tol_rel.to_le_bytes()),
            Stage1::Sz { eb_rel } => out[4..8].copy_from_slice(&eb_rel.to_le_bytes()),
            Stage1::Fpzip { prec } => out[1] = prec,
        }
        out
    }

    fn decode(b: &[u8; 12]) -> Result<Self, String> {
        // every byte a variant does not use must be zero (what every
        // writer emits), and tolerance parameters must be finite and
        // non-negative — a crafted header cannot smuggle NaN/negative
        // eps into the thresholding paths
        let zero = |range: std::ops::Range<usize>| -> Result<(), String> {
            if b[range.clone()].iter().any(|&v| v != 0) {
                Err(format!("stage1 blob has nonzero unused bytes in {range:?}"))
            } else {
                Ok(())
            }
        };
        let param = f32::from_le_bytes(b[4..8].try_into().unwrap());
        let tol = |name: &str| -> Result<f32, String> {
            if !param.is_finite() || param < 0.0 {
                Err(format!("stage1 {name} must be finite and >= 0, got {param}"))
            } else {
                Ok(param)
            }
        };
        Ok(match b[0] {
            0 => {
                zero(1..12)?;
                Stage1::Copy
            }
            1 => {
                zero(8..12)?;
                Stage1::Wavelet {
                    kind: WaveletKind::from_id(b[1]).ok_or("bad wavelet id")?,
                    eps_rel: tol("eps_rel")?,
                    zbits: b[2],
                    coeff: CoeffCodec::from_id(b[3]).ok_or("bad coeff codec id")?,
                }
            }
            2 => {
                zero(1..4)?;
                zero(8..12)?;
                Stage1::Zfp { tol_rel: tol("tol_rel")? }
            }
            3 => {
                zero(1..4)?;
                zero(8..12)?;
                Stage1::Sz { eb_rel: tol("eb_rel")? }
            }
            4 => {
                zero(2..12)?;
                if !(1..=32).contains(&b[1]) {
                    return Err(format!("fpzip prec must be 1..=32, got {}", b[1]));
                }
                Stage1::Fpzip { prec: b[1] }
            }
            v => return Err(format!("bad stage1 id {v}")),
        })
    }
}

/// Shuffle preconditioner applied to each chunk before stage 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShuffleMode {
    None,
    /// Byte shuffle with 4-byte elements (single-precision layout).
    Byte4,
    /// Bit shuffle with 4-byte elements (BLOSC2-style bit planes).
    Bit4,
}

impl ShuffleMode {
    pub const ALL: [ShuffleMode; 3] = [ShuffleMode::None, ShuffleMode::Byte4, ShuffleMode::Bit4];

    pub fn id(&self) -> u8 {
        match self {
            ShuffleMode::None => 0,
            ShuffleMode::Byte4 => 1,
            ShuffleMode::Bit4 => 2,
        }
    }
    pub fn from_id(v: u8) -> Option<Self> {
        Self::ALL.into_iter().find(|m| m.id() == v)
    }
    pub fn name(&self) -> &'static str {
        match self {
            ShuffleMode::None => "none",
            ShuffleMode::Byte4 => "byte4",
            ShuffleMode::Bit4 => "bit4",
        }
    }
    pub fn from_name(name: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|m| m.name() == name)
    }
}

/// One entry of the chunk index.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkEntry {
    pub offset: u64,
    pub csize: u32,
    pub rawsize: u32,
    pub first_block: u32,
    pub nblocks: u32,
}

/// Parsed `.czb` header + index (payload referenced externally).
#[derive(Clone, Debug)]
pub struct CzbFile {
    pub name: String,
    pub nx: u32,
    pub ny: u32,
    pub nz: u32,
    pub bs: u32,
    pub stage1: Stage1,
    pub stage2: Codec,
    pub shuffle: ShuffleMode,
    /// Header version this file was parsed from / will serialize as
    /// (1..=[`FORMAT_VERSION`]; history in `docs/FORMATS.md`).
    pub version: u8,
    /// Raw bytes per stage-2 sub-frame. `0` means unframed legacy chunk
    /// payloads (always the case for v≤2 files); `> 0` means every chunk
    /// payload carries a frame table.
    pub frame_raw: u32,
    pub global_min: f32,
    pub global_max: f32,
    pub nblocks: u32,
    pub chunks: Vec<ChunkEntry>,
    /// One CRC32C per chunk payload, parallel to `chunks`. Empty for
    /// v≤3 files (the layouts carry no checksums); serialized and
    /// required (`len == chunks.len()`) for v≥4.
    pub chunk_crcs: Vec<u32>,
    /// The error-bound contract the stream was compressed under.
    /// [`Bound::None`] for v≤4 files (the layouts carry no contract).
    pub bound: Bound,
    /// One measured [`ChunkQuality`] per chunk, parallel to `chunks`.
    /// Empty for v≤4 files; serialized and required
    /// (`len == chunks.len()`) for v≥5.
    pub chunk_quality: Vec<ChunkQuality>,
}

pub const MAGIC: &[u8; 4] = b"CZB1";

/// Current writer version (framed stage-2 chunk payloads + CRC32C
/// integrity checksums + error-bound contract with recorded per-chunk
/// achieved quality).
pub const FORMAT_VERSION: u8 = 5;

/// Exact error [`CzbFile::parse_header`] returns when the buffer is
/// merely too short. Callers feeding a growing header *prefix* (the
/// `.czs` lazy `quantity_header`) retry with more bytes on exactly this
/// error; any other parse error is genuine corruption and fails fast.
pub const ERR_TRUNCATED_HEADER: &str = "truncated czb header";

impl CzbFile {
    /// Serialized header size for `nchunks` entries at the current writer
    /// version ([`FORMAT_VERSION`]).
    pub fn header_size(name_len: usize, nchunks: usize) -> usize {
        Self::header_size_for(FORMAT_VERSION, name_len, nchunks)
    }

    /// Serialized header size for a specific format version.
    pub fn header_size_for(version: u8, name_len: usize, nchunks: usize) -> usize {
        let frame_field = if version >= 3 { 4 } else { 0 };
        // v4: one u32 CRC per chunk plus the whole-header digest
        let crc_fields = if version >= 4 { nchunks * 4 + 4 } else { 0 };
        // v5: the bound contract plus one quality record per chunk
        let quality_fields =
            if version >= 5 { BOUND_WIRE_LEN + nchunks * CHUNK_QUALITY_WIRE_LEN } else { 0 };
        4 + 1 + 1 + name_len + 16 + 12 + 2 + frame_field + 8 + 8 + nchunks * 24 + crc_fields
            + quality_fields
    }

    pub fn global_range(&self) -> f32 {
        (self.global_max - self.global_min).max(f32::MIN_POSITIVE)
    }

    /// Total serialized stream length implied by the index: header plus
    /// the chunk payloads laid back-to-back.
    pub fn total_bytes(&self) -> u64 {
        match self.chunks.last() {
            Some(c) => c.offset + c.csize as u64,
            None => Self::header_size_for(self.version, self.name.len(), 0) as u64,
        }
    }

    /// The achieved quality this header records, folded from the v5
    /// per-chunk column. `None` for v≤4 files — nothing was recorded.
    /// PSNR is computed over the samples the compressor measured
    /// (`nblocks * bs³`; edge blocks are padded and measured too), the
    /// ratio over the true field bytes (`nx*ny*nz*4`).
    pub fn achieved_quality(&self) -> Option<AchievedQuality> {
        if self.version < 5 {
            return None;
        }
        let bs = self.bs as u64;
        let nsamples = self.nblocks as u64 * bs * bs * bs;
        let raw = self.nx as u64 * self.ny as u64 * self.nz as u64 * 4;
        Some(AchievedQuality::fold(
            &self.chunk_quality,
            self.global_range() as f64,
            nsamples,
            raw,
            self.total_bytes(),
        ))
    }

    /// Length of a chunk's stage-2 *uncompressed* stream: the raw block
    /// stream after the shuffle preconditioner (bit shuffling pads the
    /// element count, so Bit4 streams are longer than `rawsize`). This is
    /// what frame spans slice and what decoders validate against.
    pub fn chunk_stage2_len(&self, entry: &ChunkEntry) -> usize {
        match self.shuffle {
            ShuffleMode::None | ShuffleMode::Byte4 => entry.rawsize as usize,
            ShuffleMode::Bit4 => crate::codec::shuffle::bit_shuffled_len(entry.rawsize as usize, 4),
        }
    }

    pub fn write_header(&self, out: &mut Vec<u8>) {
        assert!(
            (1..=FORMAT_VERSION).contains(&self.version),
            "unsupported writer version {}",
            self.version
        );
        // the header digest covers only this header's bytes, wherever
        // the caller's buffer already stood
        let start = out.len();
        out.extend_from_slice(MAGIC);
        out.push(self.version);
        let name = self.name.as_bytes();
        assert!(name.len() <= 255);
        out.push(name.len() as u8);
        out.extend_from_slice(name);
        for v in [self.nx, self.ny, self.nz, self.bs] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.stage1.encode());
        out.push(self.stage2.id());
        out.push(self.shuffle.id());
        if self.version >= 3 {
            assert!(self.frame_raw > 0, "v3 headers must carry a positive frame_raw");
            out.extend_from_slice(&self.frame_raw.to_le_bytes());
        }
        out.extend_from_slice(&self.global_min.to_le_bytes());
        out.extend_from_slice(&self.global_max.to_le_bytes());
        out.extend_from_slice(&self.nblocks.to_le_bytes());
        out.extend_from_slice(&(self.chunks.len() as u32).to_le_bytes());
        for c in &self.chunks {
            out.extend_from_slice(&c.offset.to_le_bytes());
            out.extend_from_slice(&c.csize.to_le_bytes());
            out.extend_from_slice(&c.rawsize.to_le_bytes());
            out.extend_from_slice(&c.first_block.to_le_bytes());
            out.extend_from_slice(&c.nblocks.to_le_bytes());
        }
        if self.version >= 4 {
            assert_eq!(
                self.chunk_crcs.len(),
                self.chunks.len(),
                "v4 headers need one chunk CRC per chunk entry"
            );
            for crc in &self.chunk_crcs {
                out.extend_from_slice(&crc.to_le_bytes());
            }
        }
        if self.version >= 5 {
            assert_eq!(
                self.chunk_quality.len(),
                self.chunks.len(),
                "v5 headers need one quality record per chunk entry"
            );
            out.extend_from_slice(&self.bound.encode());
            for q in &self.chunk_quality {
                out.extend_from_slice(&q.encode());
            }
        }
        if self.version >= 4 {
            // the digest comes last and covers everything before it,
            // including the v5 contract fields
            let digest = crate::util::crc32c::crc32c(&out[start..]);
            out.extend_from_slice(&digest.to_le_bytes());
        }
    }

    /// Parse a header from `buf`; returns (file, header bytes consumed).
    /// Accepts versions 1..=[`FORMAT_VERSION`]: v≤2 files parse with
    /// `frame_raw == 0` (unframed payloads) and decode bit-exactly.
    pub fn parse_header(buf: &[u8]) -> Result<(Self, usize), String> {
        let need = |n: usize, pos: usize| -> Result<(), String> {
            if buf.len() < pos + n {
                Err(ERR_TRUNCATED_HEADER.into())
            } else {
                Ok(())
            }
        };
        need(6, 0)?;
        if &buf[0..4] != MAGIC {
            return Err("bad magic".into());
        }
        let version = buf[4];
        if !(1..=FORMAT_VERSION).contains(&version) {
            return Err(format!("bad version {version} (supported 1..={FORMAT_VERSION})"));
        }
        let name_len = buf[5] as usize;
        let mut pos = 6;
        need(name_len, pos)?;
        let name = String::from_utf8_lossy(&buf[pos..pos + name_len]).into_owned();
        pos += name_len;
        let frame_field = if version >= 3 { 4 } else { 0 };
        need(16 + 12 + 2 + frame_field + 8 + 8, pos)?;
        let rd_u32 = |pos: usize| u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
        let (nx, ny, nz, bs) = (rd_u32(pos), rd_u32(pos + 4), rd_u32(pos + 8), rd_u32(pos + 12));
        pos += 16;
        let stage1 = Stage1::decode(buf[pos..pos + 12].try_into().unwrap())?;
        pos += 12;
        let stage2 = Codec::from_id(buf[pos]).ok_or("bad stage2 id")?;
        let shuffle = ShuffleMode::from_id(buf[pos + 1]).ok_or("bad shuffle id")?;
        pos += 2;
        let frame_raw = if version >= 3 {
            let v = rd_u32(pos);
            pos += 4;
            if v == 0 {
                return Err("v3 header with zero frame_raw".into());
            }
            v
        } else {
            0
        };
        let global_min = f32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
        let global_max = f32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        pos += 8;
        let nblocks = rd_u32(pos);
        let nchunks = rd_u32(pos + 4) as usize;
        pos += 8;
        need(nchunks * 24, pos)?;
        let mut chunks = Vec::with_capacity(nchunks);
        for _ in 0..nchunks {
            chunks.push(ChunkEntry {
                offset: u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap()),
                csize: rd_u32(pos + 8),
                rawsize: rd_u32(pos + 12),
                first_block: rd_u32(pos + 16),
                nblocks: rd_u32(pos + 20),
            });
            pos += 24;
        }
        let mut chunk_crcs = Vec::new();
        if version >= 4 {
            need(nchunks * 4, pos)?;
            chunk_crcs.reserve_exact(nchunks);
            for _ in 0..nchunks {
                chunk_crcs.push(rd_u32(pos));
                pos += 4;
            }
        }
        let mut bound = Bound::None;
        let mut chunk_quality = Vec::new();
        if version >= 5 {
            need(BOUND_WIRE_LEN + nchunks * CHUNK_QUALITY_WIRE_LEN, pos)?;
            bound = Bound::decode(buf[pos..pos + BOUND_WIRE_LEN].try_into().unwrap())?;
            pos += BOUND_WIRE_LEN;
            chunk_quality.reserve_exact(nchunks);
            for _ in 0..nchunks {
                chunk_quality.push(ChunkQuality::decode(
                    buf[pos..pos + CHUNK_QUALITY_WIRE_LEN].try_into().unwrap(),
                )?);
                pos += CHUNK_QUALITY_WIRE_LEN;
            }
        }
        if version >= 4 {
            // whole-header digest: every byte from the magic up to (not
            // including) the digest itself; every truncation check above
            // precedes this, so a growing prefix still reads as
            // ERR_TRUNCATED_HEADER rather than a digest mismatch
            need(4, pos)?;
            let stored = rd_u32(pos);
            let computed = crate::util::crc32c::crc32c(&buf[..pos]);
            if stored != computed {
                return Err(format!(
                    "czb header digest mismatch (stored {stored:#010x}, computed {computed:#010x})"
                ));
            }
            pos += 4;
        }
        Ok((
            Self {
                name,
                nx,
                ny,
                nz,
                bs,
                stage1,
                stage2,
                shuffle,
                version,
                frame_raw,
                global_min,
                global_max,
                nblocks,
                chunks,
                chunk_crcs,
                bound,
                chunk_quality,
            },
            pos,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CzbFile {
        CzbFile {
            name: "pressure".into(),
            nx: 256,
            ny: 256,
            nz: 256,
            bs: 32,
            stage1: Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 1e-3,
                zbits: 4,
                coeff: CoeffCodec::None,
            },
            stage2: Codec::ZlibDef,
            shuffle: ShuffleMode::Byte4,
            version: FORMAT_VERSION,
            frame_raw: 256 << 10,
            global_min: -1.5,
            global_max: 900.0,
            nblocks: 512,
            chunks: vec![
                ChunkEntry { offset: 0, csize: 100, rawsize: 400, first_block: 0, nblocks: 300 },
                ChunkEntry { offset: 100, csize: 50, rawsize: 200, first_block: 300, nblocks: 212 },
            ],
            chunk_crcs: vec![0xDEAD_BEEF, 0x0BAD_F00D],
            bound: Bound::Rel(1e-3),
            chunk_quality: vec![
                ChunkQuality { max_abs_err: 0.5, sum_sq_err: 12.25 },
                ChunkQuality { max_abs_err: 0.75, sum_sq_err: 8.5 },
            ],
        }
    }

    #[test]
    fn header_roundtrip() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_header(&mut buf);
        assert_eq!(buf.len(), CzbFile::header_size(f.name.len(), f.chunks.len()));
        let (g, consumed) = CzbFile::parse_header(&buf).unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(g.name, f.name);
        assert_eq!(g.stage1, f.stage1);
        assert_eq!(g.stage2, f.stage2);
        assert_eq!(g.shuffle, f.shuffle);
        assert_eq!(g.version, FORMAT_VERSION);
        assert_eq!(g.frame_raw, f.frame_raw);
        assert_eq!(g.chunks, f.chunks);
        assert_eq!(g.chunk_crcs, f.chunk_crcs);
        assert_eq!(g.bound, f.bound);
        assert_eq!(g.chunk_quality, f.chunk_quality);
        assert_eq!((g.nx, g.ny, g.nz, g.bs), (f.nx, f.ny, f.nz, f.bs));
    }

    #[test]
    fn legacy_v1_and_v2_headers_parse_unframed() {
        // v≤2 layouts have no frame_raw field; parsing must report
        // frame_raw == 0 so decoders take the unframed path
        for version in [1u8, 2] {
            let mut f = sample();
            f.version = version;
            f.frame_raw = 0;
            let mut buf = Vec::new();
            f.write_header(&mut buf);
            assert_eq!(
                buf.len(),
                CzbFile::header_size_for(version, f.name.len(), f.chunks.len())
            );
            // the legacy header lacks v3's frame_raw field, v4's CRC
            // fields (one per chunk + the header digest), and v5's
            // contract fields (bound + one quality record per chunk)
            assert_eq!(
                buf.len()
                    + 4
                    + f.chunks.len() * 4
                    + 4
                    + BOUND_WIRE_LEN
                    + f.chunks.len() * CHUNK_QUALITY_WIRE_LEN,
                CzbFile::header_size(f.name.len(), f.chunks.len())
            );
            let (g, consumed) = CzbFile::parse_header(&buf).unwrap();
            assert_eq!(consumed, buf.len());
            assert_eq!(g.version, version);
            assert_eq!(g.frame_raw, 0, "v{version} must parse as unframed");
            assert!(g.chunk_crcs.is_empty(), "v{version} carries no checksums");
            assert_eq!(g.bound, Bound::None, "v{version} carries no contract");
            assert!(g.chunk_quality.is_empty());
            assert_eq!(g.achieved_quality(), None);
            assert_eq!(g.chunks, f.chunks);
            assert_eq!(g.stage1, f.stage1);
        }
    }

    #[test]
    fn v4_headers_still_write_and_parse_without_quality() {
        let mut f = sample();
        f.version = 4;
        f.bound = Bound::None;
        f.chunk_quality.clear();
        let mut buf = Vec::new();
        f.write_header(&mut buf);
        assert_eq!(buf.len(), CzbFile::header_size_for(4, f.name.len(), f.chunks.len()));
        let (g, consumed) = CzbFile::parse_header(&buf).unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(g.version, 4);
        assert_eq!(g.chunk_crcs, f.chunk_crcs);
        assert_eq!(g.bound, Bound::None);
        assert!(g.chunk_quality.is_empty());
        assert_eq!(g.achieved_quality(), None);
    }

    #[test]
    fn unsupported_versions_and_zero_frame_raw_error() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_header(&mut buf);
        let mut future = buf.clone();
        future[4] = FORMAT_VERSION + 1;
        assert!(CzbFile::parse_header(&future).is_err());
        future[4] = 0;
        assert!(CzbFile::parse_header(&future).is_err());
        // zero out the frame_raw field of a v3 header
        let frame_pos = 6 + f.name.len() + 16 + 12 + 2;
        let mut bad = buf.clone();
        bad[frame_pos..frame_pos + 4].copy_from_slice(&0u32.to_le_bytes());
        assert!(CzbFile::parse_header(&bad).is_err());
    }

    #[test]
    fn chunk_stage2_len_accounts_for_bit_padding() {
        let mut f = sample();
        let entry = ChunkEntry { offset: 0, csize: 9, rawsize: 1001, first_block: 0, nblocks: 1 };
        f.shuffle = ShuffleMode::None;
        assert_eq!(f.chunk_stage2_len(&entry), 1001);
        f.shuffle = ShuffleMode::Byte4;
        assert_eq!(f.chunk_stage2_len(&entry), 1001);
        f.shuffle = ShuffleMode::Bit4;
        assert_eq!(
            f.chunk_stage2_len(&entry),
            crate::codec::shuffle::bit_shuffled_len(1001, 4)
        );
    }

    #[test]
    fn all_stage1_variants_roundtrip() {
        let variants = [
            Stage1::Copy,
            Stage1::Wavelet {
                kind: WaveletKind::Interp4,
                eps_rel: 1e-4,
                zbits: 8,
                coeff: CoeffCodec::Spdp,
            },
            Stage1::Zfp { tol_rel: 0.25 },
            Stage1::Sz { eb_rel: 1e-2 },
            Stage1::Fpzip { prec: 24 },
        ];
        for s in variants {
            let mut f = sample();
            f.stage1 = s;
            let mut buf = Vec::new();
            f.write_header(&mut buf);
            let (g, _) = CzbFile::parse_header(&buf).unwrap();
            assert_eq!(g.stage1, s);
        }
    }

    #[test]
    fn shuffle_mode_ids_and_names_roundtrip() {
        for m in ShuffleMode::ALL {
            assert_eq!(ShuffleMode::from_id(m.id()), Some(m));
            assert_eq!(ShuffleMode::from_name(m.name()), Some(m));
        }
        assert_eq!(ShuffleMode::from_id(9), None);
        assert_eq!(ShuffleMode::from_name("bitplane"), None);
        // Bit4 headers roundtrip
        let mut f = sample();
        f.shuffle = ShuffleMode::Bit4;
        let mut buf = Vec::new();
        f.write_header(&mut buf);
        let (g, _) = CzbFile::parse_header(&buf).unwrap();
        assert_eq!(g.shuffle, ShuffleMode::Bit4);
    }

    #[test]
    fn corrupt_headers_error() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_header(&mut buf);
        assert!(CzbFile::parse_header(&buf[..10]).is_err());
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(CzbFile::parse_header(&bad).is_err());
    }

    #[test]
    fn v3_headers_still_write_and_parse_without_checksums() {
        let mut f = sample();
        f.version = 3;
        f.chunk_crcs.clear();
        let mut buf = Vec::new();
        f.write_header(&mut buf);
        assert_eq!(buf.len(), CzbFile::header_size_for(3, f.name.len(), f.chunks.len()));
        let (g, consumed) = CzbFile::parse_header(&buf).unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(g.version, 3);
        assert_eq!(g.frame_raw, f.frame_raw);
        assert!(g.chunk_crcs.is_empty());
        assert_eq!(g.chunks, f.chunks);
    }

    #[test]
    fn header_digest_detects_any_flipped_byte() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_header(&mut buf);
        // a flip anywhere the digest covers — the name, a dimension, a
        // chunk-index field, a stored chunk CRC — must fail the parse
        // (some positions already fail a structural check; all must err)
        for pos in [7usize, 20, buf.len() - 30, buf.len() - 8] {
            let mut bad = buf.clone();
            bad[pos] ^= 0x10;
            assert!(CzbFile::parse_header(&bad).is_err(), "flip at {pos} undetected");
        }
        // flipping the stored digest itself is also a mismatch
        let mut bad = buf.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        let err = CzbFile::parse_header(&bad).unwrap_err();
        assert!(err.contains("digest mismatch"), "{err}");
        // writing appends to the caller's buffer: the digest must cover
        // only this header's bytes, independent of what precedes them
        let mut prefixed = vec![0xEEu8; 11];
        f.write_header(&mut prefixed);
        assert_eq!(&prefixed[11..], &buf[..]);
    }

    #[test]
    fn v5_headers_record_bound_and_achieved_quality() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_header(&mut buf);
        let (g, _) = CzbFile::parse_header(&buf).unwrap();
        let q = g.achieved_quality().expect("v5 records quality");
        assert_eq!(q.max_abs_err, 0.75);
        let range = g.global_range() as f64;
        assert!((q.max_rel_err - 0.75 / range).abs() < 1e-12);
        assert!(q.psnr_db.is_finite());
        assert!(q.ratio > 1.0);
        // the sample's recorded errors far exceed its Rel(1e-3) contract
        assert!(g.bound.check(&q).is_err());
    }

    #[test]
    fn stage1_decode_rejects_hostile_params() {
        // NaN / infinite / negative tolerances must not reach the
        // thresholding paths
        for id in [1u8, 2, 3] {
            for v in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -1e-3] {
                let mut b = [0u8; 12];
                b[0] = id;
                if id == 1 {
                    b[1] = WaveletKind::Avg3.id();
                }
                b[4..8].copy_from_slice(&v.to_le_bytes());
                assert!(Stage1::decode(&b).is_err(), "id {id} param {v} accepted");
            }
        }
        // fpzip prec outside 1..=32
        for prec in [0u8, 33, 255] {
            let mut b = [0u8; 12];
            b[0] = 4;
            b[1] = prec;
            assert!(Stage1::decode(&b).is_err(), "prec {prec} accepted");
        }
        // unused bytes must be zero (what every writer emits)
        let mut b = Stage1::Copy.encode();
        b[5] = 1;
        assert!(Stage1::decode(&b).is_err());
        let mut b = Stage1::Zfp { tol_rel: 1e-3 }.encode();
        b[9] = 1;
        assert!(Stage1::decode(&b).is_err());
    }

    #[test]
    fn stage1_fuzz_random_blobs_roundtrip_or_reject() {
        // random 12-byte blobs: decode never panics, anything it accepts
        // re-encodes to the identical blob (canonical wire form), and
        // every encoded valid value decodes back to itself
        let mut rng = crate::util::prng::Pcg32::new(0xF0221);
        let mut accepted = 0u32;
        for _ in 0..20_000 {
            let mut b = [0u8; 12];
            for byte in &mut b {
                *byte = (rng.next_u32() & 0xFF) as u8;
            }
            if let Ok(s) = Stage1::decode(&b) {
                accepted += 1;
                assert_eq!(s.encode(), b, "accepted blob must be canonical: {b:?} -> {s:?}");
            }
        }
        // the valid space is tiny relative to 2^96: random blobs should
        // almost never pass (nonzero padding bytes reject them)
        assert!(accepted < 100, "{accepted} random blobs accepted");
        // and genuine values roundtrip
        let valid = [
            Stage1::Copy,
            Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 0.0,
                zbits: 8,
                coeff: CoeffCodec::Sz,
            },
            Stage1::Zfp { tol_rel: 1e-6 },
            Stage1::Sz { eb_rel: 0.25 },
            Stage1::Fpzip { prec: 1 },
            Stage1::Fpzip { prec: 32 },
        ];
        for s in valid {
            assert_eq!(Stage1::decode(&s.encode()).unwrap(), s, "{s:?}");
        }
    }
}
