//! Parallel decompression (paper §2.3 "Data decompression"): fetch the
//! chunk containing the target block, stage-2 inflate it (LRU-cached),
//! then stage-1 decode the block.
//!
//! Two access paths:
//! * **Random access** via [`BlockReader::read_block`] — LRU chunk cache
//!   whose buffers are recycled on eviction, so a warm reader decodes
//!   chunks without reallocating.
//! * **Whole-field** via [`decompress_field_mt`] — chunks are pulled off
//!   the same shared atomic work queue the compressor uses
//!   ([`crate::cluster::SpanQueue`]); each worker inflates and decodes
//!   its chunks into worker-owned buffers and scatters the blocks into
//!   the output field (disjoint by construction, validated up front).
//!   The serial [`decompress_field`] remains bit-identical to it.
use super::compressor::{eps_abs_of, WaveletEngine};
use super::format::{CzbFile, ShuffleMode};
use super::stage1::{codec_for, Stage1Scratch};
use crate::cluster::{self, Execute, ScopedExec, SpanQueue};
use crate::codec::shuffle;
use crate::core::block::{Block, BlockGrid};
use crate::core::Field3;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A stage-2-decoded chunk with per-block offsets into the raw stream.
struct DecodedChunk {
    raw: Vec<u8>,
    /// Byte offset of each block payload (without its u32 size prefix).
    block_offsets: Vec<(usize, usize)>, // (offset, size)
    first_block: u32,
}

/// Stage-2 decode chunk `idx` into reusable buffers: `tmp` holds the
/// inflated stream when unshuffling is needed, `raw` ends up with the
/// (unshuffled) raw block stream and `offsets` with the per-block
/// (offset, size) pairs. Allocation-free once the buffers are warm.
fn decode_chunk_into(
    file: &CzbFile,
    payload: &[u8],
    idx: usize,
    tmp: &mut Vec<u8>,
    raw: &mut Vec<u8>,
    offsets: &mut Vec<(usize, usize)>,
) -> Result<(), String> {
    let entry = &file.chunks[idx];
    raw.clear();
    match file.shuffle {
        ShuffleMode::None => file.stage2.decompress(payload, raw)?,
        ShuffleMode::Byte4 => {
            tmp.clear();
            file.stage2.decompress(payload, tmp)?;
            shuffle::byte_unshuffle_into(tmp, 4, raw);
        }
        ShuffleMode::Bit4 => {
            tmp.clear();
            file.stage2.decompress(payload, tmp)?;
            // validate against the indexed raw size before unshuffling:
            // the plane layout depends on the element count
            let rawsize = entry.rawsize as usize;
            if tmp.len() != shuffle::bit_shuffled_len(rawsize, 4) {
                return Err(format!(
                    "chunk {idx}: bit-shuffled size {} inconsistent with raw size {rawsize}",
                    tmp.len()
                ));
            }
            shuffle::bit_unshuffle_into(tmp, 4, rawsize / 4, raw);
        }
    }
    if raw.len() != entry.rawsize as usize {
        return Err(format!(
            "chunk {idx}: raw size {} != index {}",
            raw.len(),
            entry.rawsize
        ));
    }
    // walk the u32 size prefixes
    offsets.clear();
    let mut pos = 0usize;
    for _ in 0..entry.nblocks {
        if raw.len() < pos + 4 {
            return Err("chunk truncated at block prefix".into());
        }
        let size = u32::from_le_bytes(raw[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        if raw.len() < pos + size {
            return Err("chunk truncated inside block".into());
        }
        offsets.push((pos, size));
        pos += size;
    }
    Ok(())
}

/// Decode one stage-1 block payload into bs³ floats via the registered
/// [`super::stage1::Stage1Codec`]; `scratch` is reused across blocks so
/// the steady state allocates nothing (the fpc schemes decode through
/// their `_into` variants into scratch buffers).
fn decode_block_payload(
    file: &CzbFile,
    payload: &[u8],
    engine: &dyn WaveletEngine,
    scratch: &mut Stage1Scratch,
    out: &mut [f32],
) -> Result<(), String> {
    let bs = file.bs as usize;
    debug_assert_eq!(out.len(), bs * bs * bs);
    codec_for(&file.stage1).decode_block(&file.stage1, payload, bs, engine, scratch, out)
}

/// Build the block grid for a parsed file, rejecting (rather than
/// panicking on) inconsistent headers.
fn grid_for(file: &CzbFile, field: &Field3) -> Result<BlockGrid, String> {
    let bs = file.bs as usize;
    if bs < 4 || !bs.is_power_of_two() {
        return Err(format!("bad block size {bs}"));
    }
    if field.nx % bs != 0 || field.ny % bs != 0 || field.nz % bs != 0 {
        return Err(format!(
            "dims {}x{}x{} not divisible by block size {bs}",
            field.nx, field.ny, field.nz
        ));
    }
    let grid = BlockGrid::new(field, bs);
    if grid.nblocks() != file.nblocks as usize {
        return Err(format!(
            "header nblocks {} != grid {}",
            file.nblocks,
            grid.nblocks()
        ));
    }
    Ok(grid)
}

/// Check that the chunk index tiles `0..nblocks` exactly — the invariant
/// the compressor guarantees and the parallel decoder's disjoint-write
/// safety relies on.
fn validate_chunk_index(file: &CzbFile) -> Result<(), String> {
    let mut next = 0u32;
    for (i, c) in file.chunks.iter().enumerate() {
        if c.first_block != next {
            return Err(format!(
                "chunk {i}: first_block {} != expected {next}",
                c.first_block
            ));
        }
        next = next
            .checked_add(c.nblocks)
            .ok_or_else(|| "chunk block count overflow".to_string())?;
    }
    if next != file.nblocks {
        return Err(format!("chunks cover {next} of {} blocks", file.nblocks));
    }
    Ok(())
}

/// Random-access block reader with an LRU chunk cache (paper: "we keep
/// recently decompressed chunks of blocks in a cache"). Buffers of
/// evicted chunks are recycled into the next decode, so a warm reader
/// allocates nothing per miss.
pub struct BlockReader<'a> {
    pub file: CzbFile,
    payload: &'a [u8],
    header_len: usize,
    engine: &'a dyn WaveletEngine,
    cache: HashMap<usize, Arc<DecodedChunk>>,
    lru: Vec<usize>,
    capacity: usize,
    /// stage-2 inflate scratch shared by all chunk decodes on this reader
    inflate_tmp: Vec<u8>,
    /// buffers reclaimed from the most recently evicted chunk
    spare: Option<(Vec<u8>, Vec<(usize, usize)>)>,
    /// stage-1 decode scratch shared by all block decodes on this reader
    scratch: Stage1Scratch,
    /// Cache statistics: (hits, misses).
    pub cache_hits: usize,
    pub cache_misses: usize,
}

impl<'a> BlockReader<'a> {
    pub fn new(bytes: &'a [u8], engine: &'a dyn WaveletEngine) -> Result<Self, String> {
        let (file, header_len) = CzbFile::parse_header(bytes)?;
        Ok(Self {
            file,
            payload: bytes,
            header_len,
            engine,
            cache: HashMap::new(),
            lru: Vec::new(),
            capacity: 8,
            inflate_tmp: Vec::new(),
            spare: None,
            scratch: Stage1Scratch::default(),
            cache_hits: 0,
            cache_misses: 0,
        })
    }

    pub fn with_cache_capacity(mut self, cap: usize) -> Self {
        self.capacity = cap.max(1);
        self
    }

    fn chunk_of_block(&self, block_id: u32) -> Result<usize, String> {
        // chunks are sorted by first_block
        let idx = self
            .file
            .chunks
            .partition_point(|c| c.first_block <= block_id)
            .checked_sub(1)
            .ok_or("block before first chunk")?;
        let c = &self.file.chunks[idx];
        if block_id < c.first_block + c.nblocks {
            Ok(idx)
        } else {
            Err(format!("block {block_id} not covered by any chunk"))
        }
    }

    fn get_chunk(&mut self, idx: usize) -> Result<Arc<DecodedChunk>, String> {
        if let Some(c) = self.cache.get(&idx) {
            self.cache_hits += 1;
            let c = c.clone();
            // refresh LRU position
            self.lru.retain(|&i| i != idx);
            self.lru.push(idx);
            return Ok(c);
        }
        self.cache_misses += 1;
        let entry = self.file.chunks[idx];
        let lo = entry.offset as usize;
        let hi = lo
            .checked_add(entry.csize as usize)
            .ok_or("chunk offset overflow")?;
        if self.payload.len() < hi {
            return Err("payload truncated".into());
        }
        let _ = self.header_len;
        // decode first (into buffers recycled from the previous eviction),
        // so a corrupt chunk never costs a healthy cached one
        let (mut raw, mut offsets) = self.spare.take().unwrap_or_default();
        if let Err(e) = decode_chunk_into(
            &self.file,
            &self.payload[lo..hi],
            idx,
            &mut self.inflate_tmp,
            &mut raw,
            &mut offsets,
        ) {
            self.spare = Some((raw, offsets));
            return Err(e);
        }
        if self.lru.len() >= self.capacity {
            let evict = self.lru.remove(0);
            if let Some(old) = self.cache.remove(&evict) {
                // sole owner -> recycle its buffers for the next miss
                if let Ok(old) = Arc::try_unwrap(old) {
                    self.spare = Some((old.raw, old.block_offsets));
                }
            }
        }
        let decoded =
            Arc::new(DecodedChunk { raw, block_offsets: offsets, first_block: entry.first_block });
        self.cache.insert(idx, decoded.clone());
        self.lru.push(idx);
        Ok(decoded)
    }

    /// Decode block `block_id` into `out` (bs³ floats).
    pub fn read_block(&mut self, block_id: u32, out: &mut [f32]) -> Result<(), String> {
        if block_id >= self.file.nblocks {
            return Err(format!("block {block_id} out of range {}", self.file.nblocks));
        }
        let cidx = self.chunk_of_block(block_id)?;
        let chunk = self.get_chunk(cidx)?;
        let local = (block_id - chunk.first_block) as usize;
        if local >= chunk.block_offsets.len() {
            return Err(format!("block {block_id} missing from its chunk"));
        }
        let (off, size) = chunk.block_offsets[local];
        let engine = self.engine;
        decode_block_payload(&self.file, &chunk.raw[off..off + size], engine, &mut self.scratch, out)
    }
}

/// Raw pointer to the output field for disjoint parallel block scatters.
/// SAFETY: senders must guarantee each block id is written by exactly one
/// worker ([`validate_chunk_index`] + the span queue's disjoint pulls).
struct FieldWriter {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for FieldWriter {}
unsafe impl Sync for FieldWriter {}

impl FieldWriter {
    /// # Safety
    /// `id` must be in range for `grid`, `grid` must describe the field
    /// behind `ptr`, `block` must hold bs³ values, and no other thread
    /// may write the same block concurrently.
    unsafe fn insert_block(&self, grid: &BlockGrid, id: usize, block: &[f32]) {
        let bs = grid.bs;
        debug_assert_eq!(block.len(), bs * bs * bs);
        // same addressing as the safe BlockGrid::insert — one source of
        // truth for the field layout
        let layout = grid.layout(id);
        for z in 0..bs {
            for y in 0..bs {
                let dst = layout.row_offset(z, y);
                debug_assert!(dst + bs <= self.len);
                std::ptr::copy_nonoverlapping(
                    block.as_ptr().add((z * bs + y) * bs),
                    self.ptr.add(dst),
                    bs,
                );
            }
        }
    }
}

/// Decompress the whole field from serialized `.czb` bytes (serial path;
/// bit-identical to [`decompress_field_mt`]).
pub fn decompress_field(
    bytes: &[u8],
    engine: &dyn WaveletEngine,
) -> Result<(Field3, CzbFile), String> {
    let mut reader = BlockReader::new(bytes, engine)?.with_cache_capacity(4);
    let file = reader.file.clone();
    let bs = file.bs as usize;
    let mut field = Field3::zeros(file.nx as usize, file.ny as usize, file.nz as usize);
    let grid = grid_for(&file, &field)?;
    let mut block = Block::zeros(bs);
    for id in 0..file.nblocks {
        reader.read_block(id, &mut block.data)?;
        grid.insert(&mut field, id as usize, &block);
    }
    Ok((field, file))
}

/// Whole-field decompression parallelized across chunks over `nthreads`
/// workers (paper §2.3 "parallel decompression").
///
/// Deprecated entry point: one-shot convenience that spawns scoped
/// workers per call; sessions should use `Engine::decompress`, which
/// drives the same core over a persistent pool.
pub fn decompress_field_mt(
    bytes: &[u8],
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> Result<(Field3, CzbFile), String> {
    decompress_field_core(&ScopedExec, bytes, engine, nthreads)
}

/// Whole-field parallel decompression on the given executor. Every
/// worker owns its inflate/decode buffers (allocation-free steady state)
/// and scatters finished blocks straight into the shared output field —
/// block writes are disjoint because the chunk index tiles the block
/// range (validated) and the queue hands each chunk to exactly one
/// worker. A shared abort flag stops the other workers from draining the
/// rest of the queue once any chunk fails to decode.
pub(crate) fn decompress_field_core(
    exec: &dyn Execute,
    bytes: &[u8],
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> Result<(Field3, CzbFile), String> {
    let (file, _header_len) = CzbFile::parse_header(bytes)?;
    let nchunks = file.chunks.len();
    let nthreads = nthreads.max(1).min(nchunks.max(1));
    if nthreads <= 1 {
        return decompress_field(bytes, engine);
    }
    validate_chunk_index(&file)?;
    let mut field = Field3::zeros(file.nx as usize, file.ny as usize, file.nz as usize);
    // grid_for validates bs before anything cubes it
    let grid = grid_for(&file, &field)?;
    let bs = file.bs as usize;
    let vol = bs * bs * bs;
    let writer = FieldWriter { ptr: field.data.as_mut_ptr(), len: field.data.len() };
    let queue = SpanQueue::new(nchunks, 1);
    let abort = AtomicBool::new(false);
    let results: Vec<Result<(), String>> = cluster::run_on(exec, nthreads, |_| {
        let r = (|| -> Result<(), String> {
            // worker-owned scratch: warm after the first chunk
            let mut tmp: Vec<u8> = Vec::new();
            let mut raw: Vec<u8> = Vec::new();
            let mut offsets: Vec<(usize, usize)> = Vec::new();
            let mut scratch = Stage1Scratch::default();
            let mut block = vec![0f32; vol];
            while let Some(span) = queue.next_span() {
                // a sibling hit a corrupt chunk: stop pulling work, its
                // error is what the caller will see
                if abort.load(Ordering::Relaxed) {
                    return Ok(());
                }
                for cidx in span {
                    let entry = file.chunks[cidx];
                    let lo = entry.offset as usize;
                    let hi = lo
                        .checked_add(entry.csize as usize)
                        .ok_or_else(|| "chunk offset overflow".to_string())?;
                    if bytes.len() < hi {
                        return Err("payload truncated".to_string());
                    }
                    decode_chunk_into(&file, &bytes[lo..hi], cidx, &mut tmp, &mut raw, &mut offsets)?;
                    for (j, &(off, size)) in offsets.iter().enumerate() {
                        decode_block_payload(
                            &file,
                            &raw[off..off + size],
                            engine,
                            &mut scratch,
                            &mut block,
                        )?;
                        // SAFETY: validate_chunk_index proved chunks tile
                        // 0..nblocks disjointly and each chunk is pulled by
                        // exactly one worker, so this block id is written
                        // exactly once and lies inside the field buffer.
                        unsafe {
                            writer.insert_block(&grid, entry.first_block as usize + j, &block)
                        };
                    }
                }
            }
            Ok(())
        })();
        if r.is_err() {
            abort.store(true, Ordering::Relaxed);
        }
        r
    });
    for r in results {
        r?;
    }
    Ok((field, file))
}

/// The absolute stage-1 parameter this file was encoded with.
pub fn file_eps_abs(file: &CzbFile) -> f32 {
    eps_abs_of(&file.stage1, file.global_range())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Codec;
    use crate::metrics::psnr;
    use crate::pipeline::compressor::{compress_field, NativeEngine, PipelineConfig};
    use crate::pipeline::format::{CoeffCodec, Stage1};
    use crate::util::prng::Pcg32;
    use crate::wavelet::WaveletKind;

    fn smooth_field(n: usize, seed: u64) -> Field3 {
        let mut rng = Pcg32::new(seed);
        Field3::from_vec(n, n, n, crate::util::prop::gen_smooth_field(&mut rng, n))
    }

    #[test]
    fn roundtrip_wavelet_psnr_scales_with_eps() {
        let f = smooth_field(64, 10);
        let mut prev_psnr = 0.0f64;
        for eps in [1e-2f32, 1e-3, 1e-4] {
            let cfg = PipelineConfig::paper_default(eps);
            let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
            let (back, _) = decompress_field(&bytes, &NativeEngine).unwrap();
            let p = psnr(&f.data, &back.data);
            // tighter epsilon -> higher PSNR
            assert!(p > prev_psnr - 1.0, "eps {eps}: psnr {p} prev {prev_psnr}");
            assert!(p > 40.0, "eps {eps}: psnr {p}");
            prev_psnr = p;
        }
    }

    #[test]
    fn roundtrip_copy_is_bit_exact() {
        let f = smooth_field(32, 11);
        let cfg = PipelineConfig::new(16, Stage1::Copy, Codec::ZlibDef);
        let (bytes, st) = compress_field(&f, "rho", &cfg, &NativeEngine);
        let (back, file) = decompress_field(&bytes, &NativeEngine).unwrap();
        assert_eq!(back.data, f.data);
        assert_eq!(file.name, "rho");
        assert!(st.ratio() > 0.5);
    }

    #[test]
    fn roundtrip_all_lossy_schemes_bounded_error() {
        let f = smooth_field(32, 12);
        let range = {
            let (lo, hi) = f.range();
            hi - lo
        };
        for (stage1, bound_factor) in [
            (Stage1::Zfp { tol_rel: 1e-3 }, 1.0),
            (Stage1::Sz { eb_rel: 1e-3 }, 1.0),
            (
                Stage1::Wavelet {
                    kind: WaveletKind::Avg3,
                    eps_rel: 1e-3,
                    zbits: 0,
                    coeff: CoeffCodec::None,
                },
                60.0,
            ),
        ] {
            let cfg = PipelineConfig::new(32, stage1, Codec::ZlibDef);
            let (bytes, _) = compress_field(&f, "e", &cfg, &NativeEngine);
            let (back, _) = decompress_field(&bytes, &NativeEngine).unwrap();
            let maxerr = f
                .data
                .iter()
                .zip(&back.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            let bound = 1e-3 * range * bound_factor;
            assert!(maxerr <= bound, "{stage1:?}: err {maxerr} bound {bound}");
        }
    }

    #[test]
    fn random_access_matches_full_decode() {
        let f = smooth_field(64, 13);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 8 << 10; // many chunks
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks >= 2);
        let (full, file) = decompress_field(&bytes, &NativeEngine).unwrap();
        let engine = NativeEngine;
        let mut reader = BlockReader::new(&bytes, &engine).unwrap().with_cache_capacity(2);
        let bs = file.bs as usize;
        let grid = crate::core::block::BlockGrid::new(&f, bs);
        let mut blk = vec![0f32; bs * bs * bs];
        let mut expected = crate::core::block::Block::zeros(bs);
        // access in a scattered order to exercise the cache (and its
        // buffer recycling on eviction)
        let order: Vec<u32> = (0..file.nblocks).rev().chain(0..file.nblocks).collect();
        for id in order {
            reader.read_block(id, &mut blk).unwrap();
            grid.extract(&full, id as usize, &mut expected);
            assert_eq!(blk, expected.data, "block {id}");
        }
        assert!(reader.cache_hits > 0);
        assert!(reader.cache_misses > 2, "eviction path must have run");
    }

    #[test]
    fn parallel_whole_field_decode_matches_serial() {
        let f = smooth_field(96, 31); // 27 blocks at bs=32
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 256 << 10; // 2-block spans -> 14 chunks
        cfg.nthreads = 4;
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks >= 4, "nchunks {}", st.nchunks);
        let (serial, _) = decompress_field(&bytes, &NativeEngine).unwrap();
        for nthreads in [2usize, 4, 8] {
            let (par, file) = decompress_field_mt(&bytes, &NativeEngine, nthreads).unwrap();
            assert_eq!(file.nblocks as usize, st.nblocks);
            let bitwise_equal = serial
                .data
                .iter()
                .zip(&par.data)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(bitwise_equal, "nthreads {nthreads}");
        }
    }

    #[test]
    fn coeff_codecs_do_not_change_psnr() {
        // paper Table 2: "The PSNR value is determined by the first
        // substage and is unaffected by the subsequent lossless techniques"
        let f = smooth_field(32, 14);
        let mut psnrs = Vec::new();
        for coeff in [CoeffCodec::None, CoeffCodec::Fpzip, CoeffCodec::Spdp] {
            let stage1 = Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 1e-3,
                zbits: 0,
                coeff,
            };
            let cfg = PipelineConfig::new(32, stage1, Codec::ZlibDef);
            let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
            let (back, _) = decompress_field(&bytes, &NativeEngine).unwrap();
            psnrs.push(psnr(&f.data, &back.data));
        }
        for w in psnrs.windows(2) {
            assert!((w[0] - w[1]).abs() < 0.6, "psnrs {psnrs:?}");
        }
    }

    #[test]
    fn bit4_shuffle_roundtrips_and_changes_the_stream() {
        // Bit4 is a lossless chunk preconditioner: the decompressed field
        // must be bit-identical to the Byte4 archive's, while the stage-2
        // input (and usually the stream size) differs
        let f = smooth_field(64, 77);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 64 << 10; // several chunks
        let (b_byte, _) = compress_field(&f, "p", &cfg.with_shuffle(ShuffleMode::Byte4), &NativeEngine);
        let (b_bit, st) = compress_field(&f, "p", &cfg.with_shuffle(ShuffleMode::Bit4), &NativeEngine);
        assert!(st.nchunks > 1);
        assert_ne!(b_byte, b_bit, "shuffle mode must reach the stream");
        let (file_bit, _) = CzbFile::parse_header(&b_bit).unwrap();
        assert_eq!(file_bit.shuffle, ShuffleMode::Bit4);
        let (d_byte, _) = decompress_field(&b_byte, &NativeEngine).unwrap();
        let (d_bit, _) = decompress_field(&b_bit, &NativeEngine).unwrap();
        assert!(d_byte
            .data
            .iter()
            .zip(&d_bit.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        // parallel decode handles Bit4 too
        let (d_mt, _) = decompress_field_mt(&b_bit, &NativeEngine, 4).unwrap();
        assert!(d_bit
            .data
            .iter()
            .zip(&d_mt.data)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn parallel_decode_aborts_on_corrupt_chunk() {
        let f = smooth_field(96, 41);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 128 << 10; // many chunks so the flag matters
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks >= 4);
        let (file, _) = CzbFile::parse_header(&bytes).unwrap();
        // truncate-corrupt the first chunk's payload so its stage-2
        // decode (or raw-size check) fails deterministically
        let mut bad = bytes.clone();
        let lo = file.chunks[0].offset as usize;
        let hi = lo + file.chunks[0].csize as usize;
        for b in &mut bad[lo..hi] {
            *b = 0xAB;
        }
        for nthreads in [2usize, 4, 8] {
            assert!(
                decompress_field_mt(&bad, &NativeEngine, nthreads).is_err(),
                "nthreads {nthreads}"
            );
        }
    }

    #[test]
    fn corrupted_payload_is_graceful() {
        let f = smooth_field(32, 15);
        let cfg = PipelineConfig::paper_default(1e-3);
        let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
        let (czb, hlen) = CzbFile::parse_header(&bytes).unwrap();
        let _ = czb;
        let mut bad = bytes.clone();
        for i in (hlen + 2..bad.len()).step_by(97) {
            bad[i] ^= 0xff;
        }
        // must not panic; error or wrong data both acceptable
        let _ = decompress_field(&bad, &NativeEngine);
        let _ = decompress_field_mt(&bad, &NativeEngine, 4);
        // truncated payload must error, in both paths
        assert!(decompress_field(&bytes[..bytes.len() - 10], &NativeEngine).is_err());
        assert!(decompress_field_mt(&bytes[..bytes.len() - 10], &NativeEngine, 4).is_err());
    }
}
